"""Kernel-vs-reference correctness: the build-time gate.

Every Pallas kernel must match its pure-jnp oracle (`kernels.ref`) to
float32 tolerance. Hypothesis sweeps values (shapes are fixed by the
AOT contract; the padded-batch semantics are swept too).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from compile.kernels import haversine, histogram, ref, transfer

# Deterministic, moderate example counts: this runs in `make test`.
SETTINGS = settings(
    max_examples=40,
    deadline=None,
    suppress_health_check=[HealthCheck.large_base_example, HealthCheck.too_slow],
)

f32 = np.float32

# --- haversine ---------------------------------------------------------------

coords = st.tuples(
    st.floats(-89.9, 89.9, allow_nan=False),
    st.floats(-180.0, 180.0, allow_nan=False),
)


@SETTINGS
@given(st.lists(coords, min_size=16, max_size=16), st.lists(coords, min_size=8, max_size=8))
def test_haversine_matches_ref(client_pts, cache_pts):
    clients = jnp.array(client_pts, dtype=f32)
    caches = jnp.array(cache_pts, dtype=f32)
    got = haversine.pairwise_haversine(clients, caches)
    want = ref.pairwise_haversine(clients, caches)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)


def test_haversine_multi_block():
    # 64 clients = 4 grid steps; block boundaries must be seamless.
    rng = np.random.default_rng(7)
    clients = jnp.array(
        np.stack([rng.uniform(-89, 89, 64), rng.uniform(-180, 180, 64)], axis=1),
        dtype=f32,
    )
    caches = jnp.array(
        np.stack([rng.uniform(-89, 89, 16), rng.uniform(-180, 180, 16)], axis=1),
        dtype=f32,
    )
    got = haversine.pairwise_haversine(clients, caches)
    want = ref.pairwise_haversine(clients, caches)
    assert got.shape == (64, 16)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)


def test_haversine_known_distance():
    # Chicago → Lincoln NE ≈ 750 km (same fixture as the rust tests).
    clients = jnp.array([[41.7886, -87.5987]] * 16, dtype=f32)
    caches = jnp.array([[40.8202, -96.7005]] * 8, dtype=f32)
    got = haversine.pairwise_haversine(clients, caches)
    assert 700.0 < float(got[0, 0]) < 820.0


def test_haversine_zero_distance():
    pt = jnp.array([[12.34, 56.78]] * 16, dtype=f32)
    got = haversine.pairwise_haversine(pt, pt[:8])
    np.testing.assert_allclose(got, np.zeros((16, 8)), atol=1e-3)


# --- histogram ---------------------------------------------------------------


@SETTINGS
@given(
    st.lists(
        st.floats(1.0, 1e13, allow_nan=False),
        min_size=histogram.BLOCK_N,
        max_size=histogram.BLOCK_N,
    )
)
def test_histogram_matches_ref(sizes):
    x = jnp.array(sizes, dtype=f32)
    got = histogram.usage_hist(x)
    want = ref.usage_hist(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_histogram_multi_block_accumulates():
    rng = np.random.default_rng(3)
    x = jnp.array(10.0 ** rng.uniform(0, 13, 4 * histogram.BLOCK_N), dtype=f32)
    got = histogram.usage_hist(x)
    want = ref.usage_hist(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert float(got.sum()) == 4 * histogram.BLOCK_N


def test_histogram_padding_ignored():
    x = np.zeros(histogram.BLOCK_N, dtype=f32)
    x[:10] = 1e6
    got = histogram.usage_hist(jnp.array(x))
    assert float(got.sum()) == 10.0, "zero padding must land in no bin"


def test_histogram_bin_edges_match_rust():
    # size_to_bin in rust: bin(1) == 0, bin(10TB) == 63.
    x = np.zeros(histogram.BLOCK_N, dtype=f32)
    x[0] = 1.0
    x[1] = 9.99e12
    got = np.asarray(histogram.usage_hist(jnp.array(x)))
    assert got[0] == 1.0
    assert got[histogram.BINS - 1] == 1.0


# --- transfer ----------------------------------------------------------------


@SETTINGS
@given(
    st.lists(
        st.tuples(
            st.floats(1.0, 1e10),        # bytes
            st.floats(0.1, 300.0),       # rtt ms
            st.floats(1e5, 1.25e10),     # bottleneck B/s
            st.floats(1.0, 64.0),        # streams
        ),
        min_size=transfer.BLOCK_N,
        max_size=transfer.BLOCK_N,
    )
)
def test_transfer_matches_ref(rows):
    batch = jnp.array(rows, dtype=f32)
    got = transfer.transfer_est(batch)
    want = ref.transfer_est(batch)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_transfer_monotone_in_bytes():
    base = np.tile(np.array([1e6, 20.0, 1e8, 4.0], dtype=f32), (transfer.BLOCK_N, 1))
    bigger = base.copy()
    bigger[:, 0] *= 10
    t1 = transfer.transfer_est(jnp.array(base))
    t2 = transfer.transfer_est(jnp.array(bigger))
    assert np.all(np.asarray(t2) > np.asarray(t1))


def test_transfer_multistream_faster():
    one = np.tile(np.array([1e9, 20.0, 1e8, 1.0], dtype=f32), (transfer.BLOCK_N, 1))
    many = one.copy()
    many[:, 3] = 16.0
    t1 = transfer.transfer_est(jnp.array(one))
    t16 = transfer.transfer_est(jnp.array(many))
    assert np.all(np.asarray(t16) < np.asarray(t1)), "multi-stream must win (paper §3.1)"


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
