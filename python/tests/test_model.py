"""L2 model shape/semantics tests + AOT lowering smoke tests."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

f32 = np.float32


def test_geo_score_shapes_and_semantics():
    rng = np.random.default_rng(1)
    clients = jnp.array(
        np.stack(
            [rng.uniform(-89, 89, model.GEO_CLIENTS), rng.uniform(-180, 180, model.GEO_CLIENTS)],
            axis=1,
        ),
        dtype=f32,
    )
    caches = jnp.array(
        np.stack(
            [rng.uniform(-89, 89, model.GEO_CACHES), rng.uniform(-180, 180, model.GEO_CACHES)],
            axis=1,
        ),
        dtype=f32,
    )
    loads = jnp.array(rng.uniform(0, 1, model.GEO_CACHES), dtype=f32)
    scores = model.geo_score(clients, caches, loads)
    assert scores.shape == (model.GEO_CLIENTS, model.GEO_CACHES)
    want = ref.geo_score(clients, caches, loads)
    np.testing.assert_allclose(scores, want, rtol=1e-5, atol=1e-2)


def test_geo_score_padding_convention():
    # Padded cache slots at (0,0) with load 1e6 must never win.
    clients = jnp.zeros((model.GEO_CLIENTS, 2), dtype=f32).at[:, 0].set(40.0)
    caches = jnp.zeros((model.GEO_CACHES, 2), dtype=f32)
    caches = caches.at[0].set(jnp.array([40.0, 0.0]))  # one real cache at the client
    loads = jnp.full((model.GEO_CACHES,), 1e6, dtype=f32).at[0].set(0.0)
    scores = np.asarray(model.geo_score(clients, caches, loads))
    assert (scores.argmin(axis=1) == 0).all()


def test_usage_hist_full_batch():
    rng = np.random.default_rng(2)
    sizes = np.zeros(model.HIST_N, dtype=f32)
    sizes[:100] = 10.0 ** rng.uniform(3, 10, 100)
    got = np.asarray(model.usage_hist(jnp.array(sizes)))
    assert got.shape == (model.HIST_BINS,)
    assert got.sum() == 100.0


def test_transfer_est_full_batch():
    batch = np.zeros((model.TRANSFER_N, 4), dtype=f32)
    batch[:, 0] = 1e6
    batch[:, 1] = 10.0
    batch[:, 2] = 1e8
    batch[:, 3] = 4.0
    got = np.asarray(model.transfer_est(jnp.array(batch)))
    assert got.shape == (model.TRANSFER_N,)
    want = np.asarray(ref.transfer_est(jnp.array(batch)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_aot_lowering_produces_hlo_text():
    artifacts = list(aot.lower_all())
    names = [n for n, _, _ in artifacts]
    assert names == ["geo_score", "usage_hist", "transfer_est"]
    for name, text, shapes in artifacts:
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "ROOT" in text
        assert all(isinstance(s, list) for s in shapes)


def test_lowered_numerics_match_eager():
    # Execute the lowered computation via jax and compare to eager —
    # the same HLO the rust runtime loads.
    name, fn, args = model.jitted_with_shapes()[0]
    rng = np.random.default_rng(3)
    concrete = (
        jnp.array(rng.uniform(-80, 80, (model.GEO_CLIENTS, 2)), dtype=f32),
        jnp.array(rng.uniform(-80, 80, (model.GEO_CACHES, 2)), dtype=f32),
        jnp.array(rng.uniform(0, 1, model.GEO_CACHES), dtype=f32),
    )
    eager = model.geo_score(*concrete)
    jitted = fn(*concrete)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager), rtol=1e-5, atol=1e-2)
    del name, args
