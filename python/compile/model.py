"""L2 JAX model: the federation's AOT-compiled compute graphs.

Three jitted functions, each calling its L1 Pallas kernel, lowered
once at build time (``aot.py``) and executed from the rust coordinator
through PJRT (``rust/src/runtime``). Shapes are fixed — HLO is
shape-monomorphic — and the rust side pads batches to them:

* ``geo_score``:    (64,2) clients × (16,2) caches × (16,) loads → (64,16)
* ``usage_hist``:   (4096,) sizes → (64,) bin counts
* ``transfer_est``: (256,4) transfer params → (256,) seconds

Padding conventions (mirrored in ``runtime``):
* geo_score — pad clients with any coords (rows ignored by caller);
  pad caches at (0,0) with load 1e6 so they never win a ranking.
* usage_hist — pad sizes with 0 (explicitly invalid, lands in no bin).
* transfer_est — pad rows with zeros; outputs ignored by caller.
"""

import jax
import jax.numpy as jnp

from .kernels import haversine, histogram, ref, transfer

# Fixed AOT shapes.
GEO_CLIENTS = 64
GEO_CACHES = 16
HIST_N = 4096
HIST_BINS = ref.HIST_BINS
TRANSFER_N = 256


def geo_score(clients, caches, loads):
    """Nearest-cache ranking scores (lower = better).

    distance_km + load × LOAD_PENALTY_KM, exactly
    ``geoip::RustGeoBackend`` on the rust side.
    """
    dist = haversine.pairwise_haversine(clients, caches)
    return dist + loads[None, :] * jnp.float32(ref.LOAD_PENALTY_KM)


def usage_hist(sizes):
    """File-size histogram (Table 2's binning)."""
    return histogram.usage_hist(sizes)


def transfer_est(batch):
    """Batched WAN transfer-time estimates."""
    return transfer.transfer_est(batch)


def jitted_with_shapes():
    """(name, jitted_fn, example_args) for every AOT artifact."""
    f32 = jnp.float32
    return [
        (
            "geo_score",
            jax.jit(geo_score),
            (
                jax.ShapeDtypeStruct((GEO_CLIENTS, 2), f32),
                jax.ShapeDtypeStruct((GEO_CACHES, 2), f32),
                jax.ShapeDtypeStruct((GEO_CACHES,), f32),
            ),
        ),
        (
            "usage_hist",
            jax.jit(usage_hist),
            (jax.ShapeDtypeStruct((HIST_N,), f32),),
        ),
        (
            "transfer_est",
            jax.jit(transfer_est),
            (jax.ShapeDtypeStruct((TRANSFER_N, 4), f32),),
        ),
    ]
