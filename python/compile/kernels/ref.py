"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal of the build-time layer: every
kernel in this package must match its reference to float32 tolerance
(pytest + hypothesis sweep them in ``python/tests``), and the rust side
re-implements the same formulas (``rust/src/geoip``, ``monitoring``)
so the three layers agree on the numbers.

Constants here must stay in lock-step with the rust twins:

* ``EARTH_RADIUS_KM``  ↔ ``geoip::EARTH_RADIUS_KM``
* ``LOAD_PENALTY_KM``  ↔ ``geoip::LOAD_PENALTY_KM``
* ``HIST_*``           ↔ ``monitoring::aggregator::{HIST_BINS, ...}``
* transfer model       ↔ ``sim::estimate`` (rust)
"""

import jax.numpy as jnp

# --- geo scoring -----------------------------------------------------------

EARTH_RADIUS_KM = 6371.0088  # IUGG mean Earth radius
LOAD_PENALTY_KM = 1500.0     # km of distance one unit of cache load costs

def haversine_km(lat1, lon1, lat2, lon2):
    """Great-circle distance (km) between degree coordinates."""
    phi1, phi2 = jnp.radians(lat1), jnp.radians(lat2)
    dphi = jnp.radians(lat2 - lat1)
    dlam = jnp.radians(lon2 - lon1)
    a = jnp.sin(dphi / 2.0) ** 2 + jnp.cos(phi1) * jnp.cos(phi2) * jnp.sin(dlam / 2.0) ** 2
    return 2.0 * EARTH_RADIUS_KM * jnp.arcsin(jnp.minimum(jnp.sqrt(a), 1.0))

def pairwise_haversine(clients, caches):
    """(C,2) × (K,2) degree coords → (C,K) distances in km."""
    lat1 = clients[:, 0:1]  # (C,1)
    lon1 = clients[:, 1:2]
    lat2 = caches[None, :, 0]  # (1,K)
    lon2 = caches[None, :, 1]
    return haversine_km(lat1, lon1, lat2, lon2)

def geo_score(clients, caches, loads):
    """Nearest-cache ranking scores: distance + load penalty.

    Must match ``geoip::RustGeoBackend::score``.
    """
    return pairwise_haversine(clients, caches) + loads[None, :] * LOAD_PENALTY_KM

# --- usage histogram ---------------------------------------------------------

HIST_BINS = 64
HIST_LOG_MIN = 0.0   # log10(1 B)
HIST_LOG_MAX = 13.0  # log10(10 TB)

def usage_hist(sizes):
    """(N,) file sizes in bytes → (HIST_BINS,) float32 counts.

    Log10-spaced bins over [1 B, 10 TB]; non-positive sizes are padding
    and fall in no bin. Must match
    ``monitoring::aggregator::size_to_bin``.
    """
    lg = jnp.log10(jnp.maximum(sizes, 1.0))
    frac = (lg - HIST_LOG_MIN) / (HIST_LOG_MAX - HIST_LOG_MIN)
    idx = jnp.clip(jnp.floor(frac * HIST_BINS), 0, HIST_BINS - 1).astype(jnp.int32)
    valid = sizes > 0.0
    one_hot = (idx[:, None] == jnp.arange(HIST_BINS)[None, :]) & valid[:, None]
    return one_hot.astype(jnp.float32).sum(axis=0)

# --- transfer-time estimate --------------------------------------------------

HANDSHAKE_ROUNDS = 3.0       # TCP + application handshakes before data
STREAM_HALF_SAT = 2.0        # streams at which multi-stream reaches 2/3 bw

def transfer_est(batch):
    """(N,4) [bytes, rtt_ms, bottleneck_bps, streams] → (N,) seconds.

    A simple analytic WAN model used by the simulator's fast-path
    estimator: handshake rounds at the RTT, then bulk bytes at the
    bottleneck scaled by multi-stream efficiency
    ``streams / (streams + STREAM_HALF_SAT)`` (XRootD's multi-stream
    advantage over single-stream HTTP, paper §3.1). Must match
    ``sim::estimate::transfer_secs``.
    """
    bytes_, rtt_ms, bw, streams = (batch[:, 0], batch[:, 1], batch[:, 2], batch[:, 3])
    startup = HANDSHAKE_ROUNDS * rtt_ms / 1e3
    eff = streams / (streams + STREAM_HALF_SAT)
    bulk = bytes_ / jnp.maximum(bw * eff, 1.0)
    return startup + bulk
