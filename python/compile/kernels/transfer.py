"""L1 Pallas kernel: batched WAN transfer-time estimator.

The simulator's fast-path estimator prices many candidate transfers at
once (which cache to fetch from, proxy vs cache paths) without running
the flow-level allocator. Pure element-wise VPU work over a (BLOCK_N,
4) tile — the simplest of the three kernels, included because it sits
on the L3 scheduler's decision path.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_N = 128


def _transfer_kernel(batch_ref, out_ref):
    b = batch_ref[...]  # (BLOCK_N, 4)
    bytes_ = b[:, 0]
    rtt_ms = b[:, 1]
    bw = b[:, 2]
    streams = b[:, 3]
    startup = jnp.float32(ref.HANDSHAKE_ROUNDS) * rtt_ms / 1e3
    eff = streams / (streams + jnp.float32(ref.STREAM_HALF_SAT))
    bulk = bytes_ / jnp.maximum(bw * eff, 1.0)
    out_ref[...] = startup + bulk


def transfer_est(batch):
    """(N,4) [bytes, rtt_ms, bottleneck_bps, streams] → (N,) seconds."""
    n, four = batch.shape
    assert four == 4 and n % BLOCK_N == 0, batch.shape
    grid = (n // BLOCK_N,)
    return pl.pallas_call(
        _transfer_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_N, 4), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(batch.astype(jnp.float32))
