"""L1 Pallas kernel: log-spaced file-size histogram.

The monitoring aggregator bins every transferred file's size into 64
log10-spaced buckets (Table 2's percentile machinery, paper §3.2/§4).
Binning a batch is a scatter — data-dependent addressing that maps
poorly to systolic hardware — so the kernel uses the TPU idiom: turn
the scatter into a dense **one-hot mask reduction**. Each grid step
builds a (BLOCK_N, BINS) comparison mask and column-sums it; on real
TPU the same mask matmul'd against identity runs on the MXU in
bfloat16 (DESIGN.md §Hardware-Adaptation).

The output block is shared by every grid step (index map is constant),
giving the standard Pallas accumulator pattern: step 0 zeroes, every
step adds its partial counts.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BINS = ref.HIST_BINS
BLOCK_N = 512


def _hist_kernel(sizes_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    sizes = sizes_ref[...]  # (BLOCK_N,)
    lg = jnp.log10(jnp.maximum(sizes, 1.0))
    frac = (lg - jnp.float32(ref.HIST_LOG_MIN)) / jnp.float32(
        ref.HIST_LOG_MAX - ref.HIST_LOG_MIN
    )
    idx = jnp.clip(jnp.floor(frac * BINS), 0, BINS - 1).astype(jnp.int32)
    valid = sizes > 0.0
    # Dense one-hot: (BLOCK_N, BINS) — MXU-friendly on real hardware.
    one_hot = (idx[:, None] == jax.lax.iota(jnp.int32, BINS)[None, :]) & valid[:, None]
    out_ref[...] += one_hot.astype(jnp.float32).sum(axis=0)


def usage_hist(sizes):
    """(N,) float32 byte sizes → (BINS,) float32 counts.

    N must be a multiple of BLOCK_N (the AOT wrapper pads with zeros,
    which are ignored as invalid).
    """
    (n,) = sizes.shape
    assert n % BLOCK_N == 0, sizes.shape
    grid = (n // BLOCK_N,)
    return pl.pallas_call(
        _hist_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_N,), lambda i: (i,))],
        # Every step accumulates into the same (BINS,) block.
        out_specs=pl.BlockSpec((BINS,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((BINS,), jnp.float32),
        interpret=True,
    )(sizes.astype(jnp.float32))
