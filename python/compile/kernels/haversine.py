"""L1 Pallas kernel: tiled pairwise haversine distance.

The GeoIP nearest-cache decision (paper §3: "clients are responsible
for finding the nearest cache using GeoIP") reduces to a pairwise
great-circle distance matrix between a batch of clients and the cache
table. This kernel computes it tile-by-tile.

TPU shaping (DESIGN.md §Hardware-Adaptation): the grid walks blocks of
``BLOCK_C`` clients; each step holds a (BLOCK_C, 2) client tile, the
full (K, 2) cache table and the (BLOCK_C, K) output tile in VMEM —
a few KB per step, far under the ~16 MB VMEM budget, leaving room to
scale BLOCK_C into the thousands on real hardware. All math is
element-wise VPU work over a broadcasted (BLOCK_C, K) tile.

Lowered with ``interpret=True``: the CPU PJRT runtime cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md), so the kernel is
compiled to plain HLO ops; the *structure* (BlockSpec schedule) is
what carries to real TPU builds.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Client rows per grid step. 16 clients × 16 caches tiles are small on
# CPU-interpret; on TPU this would grow to fill VMEM.
BLOCK_C = 16


def _haversine_kernel(clients_ref, caches_ref, out_ref):
    """One grid step: distances from a client tile to every cache."""
    lat1 = clients_ref[:, 0:1]           # (BLOCK_C, 1) degrees
    lon1 = clients_ref[:, 1:2]
    lat2 = caches_ref[:, 0][None, :]     # (1, K)
    lon2 = caches_ref[:, 1][None, :]

    deg = jnp.float32(jnp.pi / 180.0)
    phi1 = lat1 * deg
    phi2 = lat2 * deg
    dphi = (lat2 - lat1) * deg
    dlam = (lon2 - lon1) * deg

    a = (
        jnp.sin(dphi * 0.5) ** 2
        + jnp.cos(phi1) * jnp.cos(phi2) * jnp.sin(dlam * 0.5) ** 2
    )
    dist = 2.0 * jnp.float32(ref.EARTH_RADIUS_KM) * jnp.arcsin(
        jnp.minimum(jnp.sqrt(a), 1.0)
    )
    out_ref[...] = dist


def pairwise_haversine(clients, caches):
    """(C,2) × (K,2) → (C,K) great-circle distances in km.

    C must be a multiple of BLOCK_C (the AOT wrapper pads).
    """
    c, two = clients.shape
    k, _ = caches.shape
    assert two == 2 and c % BLOCK_C == 0, (clients.shape, caches.shape)
    grid = (c // BLOCK_C,)
    return pl.pallas_call(
        _haversine_kernel,
        grid=grid,
        in_specs=[
            # i-th block of clients...
            pl.BlockSpec((BLOCK_C, 2), lambda i: (i, 0)),
            # ...against the whole cache table every step.
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_C, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, k), jnp.float32),
        interpret=True,
    )(clients.astype(jnp.float32), caches.astype(jnp.float32))
