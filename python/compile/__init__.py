"""Build-time compile package: L2 jax model + L1 pallas kernels + AOT lowering."""
