"""AOT lowering: JAX (L2+L1) → HLO text artifacts for the rust runtime.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange is **HLO text**, not a serialized ``HloModuleProto``: jax
>= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. The
computation is built with ``return_tuple=True`` so the rust side
always unwraps a 1-tuple (see /opt/xla-example/README.md).

Python never runs on the request path: after this script writes
``artifacts/*.hlo.txt`` the rust binary is self-contained.
"""

import argparse
import json
import pathlib

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """Yield (name, hlo_text, arg_shapes) for every artifact."""
    for name, fn, example_args in model.jitted_with_shapes():
        lowered = fn.lower(*example_args)
        text = to_hlo_text(lowered)
        shapes = [list(a.shape) for a in example_args]
        yield name, text, shapes


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {}
    for name, text, shapes in lower_all():
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest[name] = {"file": path.name, "arg_shapes": shapes}
        print(f"wrote {path} ({len(text)} chars)")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
