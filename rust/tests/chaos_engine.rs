//! Integration: the fault-timeline chaos engine.
//!
//! The contracts under test:
//!
//! 1. **Zero failed downloads** — a single-cache outage at peak load
//!    (the acceptance scenario) completes every job: sessions fail
//!    over to other caches or fall back to the origin.
//! 2. **Bit-reproducibility** — the same seed gives identical
//!    `TransferRecord`s, fault log, and failover counters across runs.
//! 3. **JoinWait safety** — sessions parked on a fetch that is aborted
//!    by a mid-transfer cache death are woken and re-plan (never hang).
//! 4. **Batch-vs-sequential equivalence** — a fault between two
//!    non-overlapping sessions produces the same records whether the
//!    sessions run in one engine or as sequential `download` calls.
//! 5. **Link cuts and brownouts** — severed links kill and re-route
//!    in-flight flows; degraded origins slow transfers; total
//!    redirector outages are ridden out by retries.
//! 6. **Waiter-list hygiene** — every JoinWait exit path (wake, abort,
//!    failover, finish) removes the session from the waiter map.
//! 7. **Ledger consistency** — an outage left open by an earlier run
//!    on a reused federation is charged consistently (outages and
//!    downtime agree) in the next run's availability report.
//! 8. **Bounded direct-origin retries** — the last-resort origin
//!    stream polls a severed route on a fixed backoff and completes
//!    promptly after the heal, without unbounded spinning.

use stashcache::config::defaults::paper_federation;
use stashcache::fault::{FaultKind, FaultTimeline};
use stashcache::federation::driver::SessionEngine;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::sim::campaign::{self, CampaignConfig};
use stashcache::sim::workload::FileRef;
use stashcache::util::{ByteSize, Duration, SimTime};

fn file(path: &str, bytes: u64) -> FileRef {
    FileRef {
        path: path.into(),
        size: ByteSize(bytes),
        version: 1,
    }
}

fn t(s: f64) -> SimTime {
    SimTime::from_secs_f64(s)
}

fn chaos_campaign() -> CampaignConfig {
    CampaignConfig {
        sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
        jobs: 96,
        arrival_window_secs: 4.0,
        catalog_files: 32,
        zipf_s: 1.1,
        background_flows: 1,
        ..CampaignConfig::default()
    }
}

/// The acceptance scenario: syracuse's cache dies mid-window (peak
/// load) and never recovers. Every download still completes, and the
/// whole run — records, fault log, counters, downtime — is
/// bit-identical under the same seed.
#[test]
fn single_cache_outage_at_peak_load_completes_and_reproduces() {
    let ccfg = chaos_campaign();
    let victim_name = "syracuse";
    let run = || {
        let mut fed = FedSim::build(paper_federation());
        let victim = fed.topo.site_index(victim_name).unwrap();
        let mut faults = FaultTimeline::new();
        faults.push(t(2.0), FaultKind::CacheDown { site: victim });
        campaign::run_on_with_faults(&mut fed, &ccfg, &faults)
    };
    let r1 = run();

    // Zero failed downloads: every job completed with its full payload.
    assert_eq!(r1.campaign.records.len(), 96, "every job completes");
    assert!(r1.campaign.records.iter().all(|r| r.record.bytes > 0));
    assert_eq!(r1.availability.downloads_completed, 96);

    // The outage actually bit: transfers were aborted mid-flight and
    // failed over.
    assert_eq!(r1.availability.faults_applied, 1);
    assert!(
        r1.availability.failovers > 0,
        "peak-load outage must abort in-flight transfers"
    );
    assert!(r1.availability.retries >= r1.availability.failovers);
    assert!(r1.availability.aborted_bytes > 0);
    let syr = r1
        .availability
        .caches
        .iter()
        .find(|c| c.site == victim_name)
        .unwrap();
    assert_eq!(syr.outages, 1);
    assert!(
        syr.downtime.as_secs_f64() > 0.0,
        "open outage counts to the end of the run"
    );
    assert!(syr.availability(r1.availability.window) < 1.0);
    assert!(r1.availability.mean_availability() < 1.0);

    // Bit-reproducibility of the whole chaos run.
    let r2 = run();
    assert_eq!(r1.campaign.records, r2.campaign.records);
    assert_eq!(r1.fault_log, r2.fault_log);
    assert_eq!(r1.campaign.engine, r2.campaign.engine);
    assert_eq!(r1.availability, r2.availability);
}

/// JoinWait sessions are woken and re-plan when the fetch they joined
/// is aborted by a mid-transfer cache death — they never leak or hang.
#[test]
fn joinwait_woken_and_replans_on_cache_death() {
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("syracuse").unwrap();
    let f = file("/ospool/des/data/join-abort.dat", 10_000_000_000);

    // A starts the cold fetch at t0 (a 10 GB stream lasts well past
    // 5 s); B lands at t0+2 s and joins A's in-flight fetch; the cache
    // dies at 5 s with A mid-transfer and B parked.
    let mut faults = FaultTimeline::new();
    faults.push(t(5.0), FaultKind::CacheDown { site });
    fed.inject_faults(&faults).expect("valid fault timeline");

    let mut engine = SessionEngine::new(fed.now);
    let t0 = fed.now;
    let a = engine.spawn_at(&mut fed, t0, site, f.clone(), DownloadMethod::Stash);
    let b = engine.spawn_at(
        &mut fed,
        t0 + Duration::from_secs(2),
        site,
        f,
        DownloadMethod::Stash,
    );
    engine.run(&mut fed);

    assert_eq!(engine.completed().len(), 2, "no session leaks or hangs");
    assert!(engine.session(b).joins >= 1, "B joined A's fetch");
    assert!(
        engine.session(a).failovers >= 1,
        "A's transfer was aborted mid-flight"
    );
    assert!(engine.session(b).retries >= 1, "B re-planned after the abort");
    assert_eq!(engine.record(a).bytes, 10_000_000_000);
    assert_eq!(engine.record(b).bytes, 10_000_000_000);
    // Neither was ultimately served by the dead cache.
    assert_ne!(engine.session(a).cache_site, Some(site));
    assert_ne!(engine.session(b).cache_site, Some(site));
    assert!(engine.stats.aborted_bytes > 0, "A's partial stream was wasted");
    assert!(fed.faults.is_cache_down(site));
}

/// A fault between two non-overlapping sessions: one batch engine and
/// two sequential `download` calls walk the same records.
#[test]
fn chaos_batch_vs_sequential_equivalence() {
    let fa = file("/ospool/nova/data/chaos-serial-a.dat", 200_000_000);
    let fb = file("/ospool/nova/data/chaos-serial-b.dat", 350_000_000);
    let gap = t(3_600.0);
    // Nebraska's cache dies at t=300 s — after the first download
    // finishes, long before the second arrives.
    let outage_site = "nebraska";
    let timeline = |fed: &FedSim| {
        let mut tl = FaultTimeline::new();
        tl.push(
            t(300.0),
            FaultKind::CacheDown {
                site: fed.topo.site_index(outage_site).unwrap(),
            },
        );
        tl
    };

    // Leg 1: sequential convenience API.
    let mut fed1 = FedSim::build(paper_federation());
    fed1.start_background_load(2);
    fed1.inject_faults(&timeline(&fed1)).expect("valid fault timeline");
    let site = fed1.topo.site_index(outage_site).unwrap();
    let r1a = fed1.download(site, &fa, DownloadMethod::Stash);
    fed1.advance_to(gap);
    let r1b = fed1.download(site, &fb, DownloadMethod::Stash);

    // Leg 2: one engine, both sessions spawned up front.
    let mut fed2 = FedSim::build(paper_federation());
    fed2.start_background_load(2);
    fed2.inject_faults(&timeline(&fed2)).expect("valid fault timeline");
    let mut engine = SessionEngine::new(fed2.now);
    let a = engine.spawn_at(&mut fed2, fed2.now, site, fa, DownloadMethod::Stash);
    let b = engine.spawn_at(&mut fed2, gap, site, fb, DownloadMethod::Stash);
    engine.run(&mut fed2);

    assert_eq!(r1a, engine.record(a), "pre-outage download identical");
    assert_eq!(r1b, engine.record(b), "post-outage download identical");
    // Both legs applied the fault, and the post-outage download went
    // to a remote cache (nebraska's own cache is dark).
    assert_eq!(fed1.fault_log.len(), 1);
    assert_eq!(fed2.fault_log.len(), 1);
    assert_ne!(engine.session(b).cache_site, Some(site));
    assert!(!r1b.cache_hit, "failover cache starts cold");
}

/// A cut WAN link kills the in-flight fetch; the session retries, and
/// completes once the link heals (via whatever path then works).
#[test]
fn wan_cut_mid_fetch_recovers_after_heal() {
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("syracuse").unwrap();
    let wan = fed.topo.wan_link(site);
    // Cut syracuse's border link at 2 s (mid cold fetch of a 10 GB
    // file), heal at 30 s. Until then nothing reaches syracuse at all.
    let mut faults = FaultTimeline::new();
    faults.link_outage(wan, t(2.0), t(30.0));
    fed.inject_faults(&faults).expect("valid fault timeline");

    let rec = fed.download(
        site,
        &file("/ospool/ligo/data/cut.dat", 10_000_000_000),
        DownloadMethod::Stash,
    );
    assert_eq!(rec.bytes, 10_000_000_000);
    assert!(
        rec.duration.as_secs_f64() > 28.0,
        "transfer had to outlast the outage, took {}",
        rec.duration
    );
    assert_eq!(fed.fault_log.len(), 2, "cut and heal both applied");
    assert!(fed.net.link_is_up(wan));
}

/// An origin brownout (DTN at 5% capacity) visibly slows a cold fetch
/// relative to the un-degraded run.
#[test]
fn origin_brownout_slows_cold_fetches() {
    let f = file("/ospool/des/data/brownout.dat", 2_335_000_000);
    let run = |factor: Option<f64>| {
        let mut fed = FedSim::build(paper_federation());
        if let Some(factor) = factor {
            let origin = fed.namespace.resolve(&f.path).unwrap();
            let mut faults = FaultTimeline::new();
            faults.push(
                SimTime::ZERO,
                FaultKind::OriginDegraded {
                    origin: origin.0,
                    factor,
                },
            );
            fed.inject_faults(&faults).expect("valid fault timeline");
        }
        let site = fed.topo.site_index("bellarmine").unwrap();
        fed.download(site, &f, DownloadMethod::Stash).duration
    };
    let healthy = run(None);
    let browned = run(Some(0.05));
    assert!(
        browned.as_secs_f64() > healthy.as_secs_f64() * 2.0,
        "brownout must bite: healthy {healthy} vs browned {browned}"
    );
}

/// Both redirector instances down when a cold miss needs discovery:
/// bounded retries, then the direct-to-origin fallback completes the
/// download without discovery at all. Once an instance recovers, the
/// next download goes through a cache again.
#[test]
fn total_redirector_outage_falls_back_then_recovers() {
    use stashcache::client::Method;
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("chicago").unwrap();
    let mut faults = FaultTimeline::new();
    // Down before the download starts; instance 0 returns at 8 s —
    // after the first download's bounded retries give up, before the
    // second download's retries do.
    faults.push(SimTime::ZERO, FaultKind::RedirectorDown { instance: 0 });
    faults.push(SimTime::ZERO, FaultKind::RedirectorDown { instance: 1 });
    faults.push(t(8.0), FaultKind::RedirectorUp { instance: 0 });
    fed.inject_faults(&faults).expect("valid fault timeline");

    let r1 = fed.download(
        site,
        &file("/ospool/ligo/data/redir-a.dat", 50_000_000),
        DownloadMethod::Stash,
    );
    assert_eq!(r1.bytes, 50_000_000, "outage must not fail the workflow");
    assert_eq!(
        r1.method,
        Method::HttpOrigin,
        "with discovery dark, the session streams from the origin"
    );
    assert!(!r1.cache_hit);

    // The next download retries discovery until instance 0 is back,
    // then fetches through a cache as usual.
    let r2 = fed.download(
        site,
        &file("/ospool/ligo/data/redir-b.dat", 50_000_000),
        DownloadMethod::Stash,
    );
    assert_eq!(r2.bytes, 50_000_000);
    assert_eq!(r2.method, Method::Xrootd, "pool recovered; discovery works");
    assert_eq!(fed.redirectors.healthy_count(), 1);
}

/// Campaign determinism survives a *restored* outage too (down + up
/// inside the window): two runs agree event-for-event.
#[test]
fn restored_outage_campaign_bit_identical() {
    let ccfg = CampaignConfig {
        jobs: 48,
        arrival_window_secs: 6.0,
        ..chaos_campaign()
    };
    let run = || {
        let mut fed = FedSim::build(paper_federation());
        let victim = fed.topo.site_index("chicago").unwrap();
        let mut faults = FaultTimeline::new();
        faults.cache_outage(victim, t(2.0), t(4.0));
        campaign::run_on_with_faults(&mut fed, &ccfg, &faults)
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.campaign.records, r2.campaign.records);
    assert_eq!(r1.fault_log, r2.fault_log);
    assert_eq!(r1.availability, r2.availability);
    assert_eq!(r1.campaign.records.len(), 48);
    // The chicago cache's ledger shows the closed two-second outage.
    let chi = r1
        .availability
        .caches
        .iter()
        .find(|c| c.site == "chicago")
        .unwrap();
    assert_eq!(chi.outages, 1);
    assert_eq!(chi.downtime, Duration::from_secs(2));
}

/// Thread-count determinism for chaos runs: once the restored outage
/// heals and the fault timeline drains, the campaign tail is eligible
/// to shard — and the whole run (records, engine counters, fault log,
/// availability ledger) must stay bit-identical to serial.
#[test]
fn chaos_bit_identical_across_thread_counts() {
    let ccfg = CampaignConfig {
        jobs: 48,
        arrival_window_secs: 6.0,
        ..chaos_campaign()
    };
    let leg = |threads: usize| {
        let mut fed = FedSim::build(paper_federation());
        let victim = fed.topo.site_index("chicago").unwrap();
        let mut faults = FaultTimeline::new();
        faults.cache_outage(victim, t(2.0), t(4.0));
        campaign::run_on_with_faults_threads(&mut fed, &ccfg, &faults, threads)
    };
    let serial = leg(1);
    assert_eq!(serial.campaign.records.len(), 48);
    for threads in [2usize, 8] {
        let r = leg(threads);
        assert_eq!(
            r.campaign.records, serial.campaign.records,
            "{threads}-thread records diverged from serial"
        );
        assert_eq!(
            r.campaign.engine, serial.campaign.engine,
            "{threads}-thread EngineStats"
        );
        assert_eq!(r.fault_log, serial.fault_log, "{threads}-thread fault log");
        assert_eq!(
            r.availability, serial.availability,
            "{threads}-thread availability report"
        );
        assert_eq!(r.campaign.peak_concurrent, serial.campaign.peak_concurrent);
        assert_eq!(r.campaign.events_processed, serial.campaign.events_processed);
    }
}

/// Every session exit path releases its cache slot: after a run with
/// mid-transfer failovers and JoinWait re-plans, the per-cache
/// in-flight counts are all back to zero — a leak here would feed
/// phantom load to the `least-loaded` policy forever after.
#[test]
fn cache_slots_drain_on_failover_exit_paths() {
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("syracuse").unwrap();
    let f = file("/ospool/des/data/slot-drain.dat", 10_000_000_000);
    let mut faults = FaultTimeline::new();
    faults.push(t(5.0), FaultKind::CacheDown { site });
    fed.inject_faults(&faults).expect("valid fault timeline");

    let mut engine = SessionEngine::new(fed.now);
    let t0 = fed.now;
    engine.spawn_at(&mut fed, t0, site, f.clone(), DownloadMethod::Stash);
    engine.spawn_at(
        &mut fed,
        t0 + Duration::from_secs(2),
        site,
        f,
        DownloadMethod::Stash,
    );
    engine.run(&mut fed);
    assert_eq!(engine.completed().len(), 2);
    assert!(engine.stats.failovers >= 1, "the outage must bite");
    assert!(
        engine.cache_in_flight().values().all(|&n| n == 0),
        "cache slots leaked after failover: {:?}",
        engine.cache_in_flight()
    );
}

/// Waiter-list hygiene across the full kill-then-recommit cycle: B
/// parks on A's fetch, the cache dies (waking B), and the re-fetch at
/// the failover cache commits with a third late joiner in play. No
/// stale entry may survive in the waiter map — a leaked id there would
/// later be "woken" in a non-JoinWait phase and corrupt its protocol
/// state.
#[test]
fn waiter_lists_scrubbed_when_cache_dies_then_refetch_commits() {
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("syracuse").unwrap();
    let f = file("/ospool/des/data/stale-waiter.dat", 10_000_000_000);
    let mut faults = FaultTimeline::new();
    faults.push(t(5.0), FaultKind::CacheDown { site });
    fed.inject_faults(&faults).expect("valid fault timeline");

    let mut engine = SessionEngine::new(fed.now);
    let t0 = fed.now;
    let a = engine.spawn_at(&mut fed, t0, site, f.clone(), DownloadMethod::Stash);
    let b = engine.spawn_at(
        &mut fed,
        t0 + Duration::from_secs(2),
        site,
        f.clone(),
        DownloadMethod::Stash,
    );
    let c = engine.spawn_at(
        &mut fed,
        t0 + Duration::from_secs(8),
        site,
        f,
        DownloadMethod::Stash,
    );
    engine.run(&mut fed);

    assert_eq!(engine.completed().len(), 3, "no session leaks or hangs");
    assert!(engine.session(b).joins >= 1, "B parked on A's fetch");
    assert!(
        engine.waiters().is_empty(),
        "stale waiter-list entries survived the run: {:?}",
        engine.waiters()
    );
    for id in [a, b, c] {
        assert_eq!(engine.record(id).bytes, 10_000_000_000);
        assert_ne!(engine.session(id).cache_site, Some(site));
    }
    assert!(
        engine.cache_in_flight().values().all(|&n| n == 0),
        "cache slots leaked: {:?}",
        engine.cache_in_flight()
    );
}

/// An outage left *open* by an earlier run on a reused federation must
/// be charged consistently in the next run's ledger: the cache is down
/// for that entire window, so the report must say one outage with
/// downtime equal to the window — not "0 outages" with downtime > 0
/// (the `outages_of` increment happened in the previous run, before
/// the baseline snapshot).
#[test]
fn open_outage_charged_consistently_across_runs() {
    let ccfg = CampaignConfig {
        sites: vec!["syracuse".into()],
        jobs: 24,
        arrival_window_secs: 4.0,
        catalog_files: 16,
        background_flows: 0,
        ..CampaignConfig::default()
    };
    let mut fed = FedSim::build(paper_federation());

    // Run 1: syracuse's cache dies at 2 s and never recovers.
    let victim = fed.topo.site_index("syracuse").unwrap();
    let mut faults = FaultTimeline::new();
    faults.push(t(2.0), FaultKind::CacheDown { site: victim });
    let r1 = campaign::run_on_with_faults(&mut fed, &ccfg, &faults);
    assert_eq!(r1.campaign.records.len(), 24);
    let syr1 = r1
        .availability
        .caches
        .iter()
        .find(|c| c.site == "syracuse")
        .unwrap();
    assert_eq!(syr1.outages, 1);
    // Down from the fault's effective instant to the end of the run.
    assert_eq!(
        syr1.downtime.0,
        r1.availability.window.0 - r1.fault_log[0].at.0
    );

    // Run 2 on the same federation, no new faults: the cache is still
    // dark the whole window. Downtime accrues for the full window, so
    // the outage must be counted too — before the open-outage baseline
    // fix this reported 0 outages with downtime > 0.
    assert!(fed.faults.is_cache_down(victim));
    let r2 = campaign::run_on_with_faults(&mut fed, &ccfg, &FaultTimeline::new());
    assert_eq!(r2.campaign.records.len(), 24, "jobs fail over and complete");
    let syr2 = r2
        .availability
        .caches
        .iter()
        .find(|c| c.site == "syracuse")
        .unwrap();
    assert_eq!(
        syr2.downtime, r2.availability.window,
        "down for the whole window"
    );
    assert_eq!(
        syr2.outages, 1,
        "the open outage must be charged to the window it darkens"
    );
    assert!(syr2.availability(r2.availability.window) <= 0.0);
}

/// The last-resort direct-origin path polls a severed route on the
/// fixed retry backoff: with discovery dark *and* the worker's WAN cut
/// for 30 s, the session keeps polling (each poll advances virtual
/// time — no spinning), completes promptly once the link heals, and
/// the retry count stays bounded by outage / backoff, not by luck.
#[test]
fn direct_origin_retry_loop_bounded_and_heals() {
    use stashcache::client::Method;
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("syracuse").unwrap();
    let wan = fed.topo.wan_link(site);
    let mut faults = FaultTimeline::new();
    // Discovery dark for the whole run → the session must go direct;
    // the WAN cut then severs the origin route under the direct path.
    faults.push(SimTime::ZERO, FaultKind::RedirectorDown { instance: 0 });
    faults.push(SimTime::ZERO, FaultKind::RedirectorDown { instance: 1 });
    faults.link_outage(wan, t(0.5), t(30.0));
    fed.inject_faults(&faults).expect("valid fault timeline");

    let mut engine = SessionEngine::new(fed.now);
    let id = engine.spawn_at(
        &mut fed,
        fed.now,
        site,
        file("/ospool/ligo/data/direct-retry.dat", 50_000_000),
        DownloadMethod::Stash,
    );
    engine.run(&mut fed);

    assert_eq!(engine.completed().len(), 1, "the retry loop terminates");
    let rec = engine.record(id);
    assert_eq!(rec.method, Method::HttpOrigin);
    assert_eq!(rec.bytes, 50_000_000);
    let secs = rec.duration.as_secs_f64();
    assert!(
        secs > 29.0,
        "the transfer must outlast the 30 s outage, took {secs:.2}s"
    );
    assert!(
        secs < 40.0,
        "after the heal, one backoff + the stream suffices, took {secs:.2}s"
    );
    let retries = engine.session(id).retries;
    assert!(
        retries >= 5,
        "a 30 s outage over a 2 s backoff means many polls, saw {retries}"
    );
    assert!(
        retries <= 40,
        "retries must be bounded by outage / backoff, saw {retries}"
    );
    assert!(engine.cache_in_flight().values().all(|&n| n == 0));
}

/// The direct-to-origin fallback (discovery fully dark) also releases
/// its slot on every bounded retry before giving up on caches.
#[test]
fn cache_slots_drain_through_direct_fallback() {
    use stashcache::client::Method;
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("chicago").unwrap();
    let mut faults = FaultTimeline::new();
    faults.push(SimTime::ZERO, FaultKind::RedirectorDown { instance: 0 });
    faults.push(SimTime::ZERO, FaultKind::RedirectorDown { instance: 1 });
    fed.inject_faults(&faults).expect("valid fault timeline");

    let mut engine = SessionEngine::new(fed.now);
    let id = engine.spawn_at(
        &mut fed,
        fed.now,
        site,
        file("/ospool/ligo/data/slot-direct.dat", 50_000_000),
        DownloadMethod::Stash,
    );
    engine.run(&mut fed);
    assert_eq!(
        engine.record(id).method,
        Method::HttpOrigin,
        "with discovery dark, the session streams from the origin"
    );
    assert!(engine.stats.direct_fallbacks >= 1);
    assert!(
        engine.cache_in_flight().values().all(|&n| n == 0),
        "cache slots leaked on the direct path: {:?}",
        engine.cache_in_flight()
    );
}

/// Gray-failure acceptance (ISSUE 9): one cache degraded 20× — no
/// death event, the cache keeps answering, just 20× slower — with
/// transfer deadlines and the breaker armed. Every session completes,
/// deadlines actually fire (the slow cache blows its budget), p99
/// stays bounded relative to the undefended run, the breaker makes
/// goodput strictly better than deadlines alone, and the whole run is
/// bit-identical across reruns and thread counts.
#[test]
fn degraded_cache_with_deadlines_completes_bounded_and_reproduces() {
    let ccfg = chaos_campaign();
    let leg = |deadline_factor: f64, breaker: bool, threads: usize| {
        let mut cfg = paper_federation();
        cfg.resilience.deadline_factor = deadline_factor;
        cfg.resilience.breaker = breaker;
        let mut fed = FedSim::build(cfg);
        let victim = fed.topo.site_index("syracuse").unwrap();
        let mut faults = FaultTimeline::new();
        faults.push(
            t(0.4),
            FaultKind::CacheSlow {
                site: victim,
                factor: 0.05,
            },
        );
        campaign::run_on_with_faults_threads(&mut fed, &ccfg, &faults, threads)
    };

    let defended = leg(3.0, true, 1);
    assert_eq!(defended.campaign.records.len(), 96, "every session completes");
    assert!(defended.campaign.records.iter().all(|r| r.record.bytes > 0));
    assert!(
        defended.campaign.engine.deadline_expiries > 0,
        "the 20x-slow cache must blow transfer deadlines"
    );

    // Bounded p99: without any defence a 20x-degraded cache stalls its
    // sessions for ~20x the healthy duration; deadline failover caps
    // the damage at the deadline plus a healthy retry.
    let undefended = leg(0.0, false, 1);
    assert_eq!(undefended.campaign.records.len(), 96);
    let p99 = |r: &campaign::CampaignResults| r.duration_percentiles(&[99.0])[0];
    assert!(
        p99(&defended.campaign) < p99(&undefended.campaign),
        "deadline failover must beat the unbounded stall: {:.2}s vs {:.2}s",
        p99(&defended.campaign),
        p99(&undefended.campaign),
    );

    // The breaker on top of deadlines is strictly better: ejecting the
    // degraded cache spares later sessions the blown deadline that
    // deadline-only runs pay before failing over.
    let deadline_only = leg(3.0, false, 1);
    assert_eq!(deadline_only.campaign.records.len(), 96);
    assert!(
        defended.campaign.aggregate_mbps() > deadline_only.campaign.aggregate_mbps(),
        "breaker-on goodput must beat breaker-off: {:.0} vs {:.0} Mbps",
        defended.campaign.aggregate_mbps(),
        deadline_only.campaign.aggregate_mbps(),
    );

    // Digest determinism: reruns and thread counts agree exactly.
    let rerun = leg(3.0, true, 1);
    assert_eq!(defended.campaign.records, rerun.campaign.records);
    assert_eq!(defended.campaign.engine, rerun.campaign.engine);
    assert_eq!(defended.fault_log, rerun.fault_log);
    for threads in [2usize, 8] {
        let r = leg(3.0, true, threads);
        assert_eq!(
            r.campaign.records, defended.campaign.records,
            "{threads}-thread gray-failure records diverged from serial"
        );
        assert_eq!(r.campaign.engine, defended.campaign.engine);
        assert_eq!(r.campaign.events_processed, defended.campaign.events_processed);
    }
}

/// Breaker transitions never strand a session mid-phase: a staggered
/// stream of sessions at a degraded site drives the breaker through
/// closed → open → half-open → closed (the cache is restored before
/// the tail arrives), and every session still completes with clean
/// waiter lists and drained cache slots.
#[test]
fn breaker_transitions_never_strand_sessions() {
    let mut cfg = paper_federation();
    cfg.resilience.deadline_factor = 2.0;
    cfg.resilience.breaker = true;
    cfg.resilience.breaker_alpha = 0.5;
    cfg.resilience.breaker_threshold = 0.6;
    cfg.resilience.breaker_cooldown_secs = 4.0;
    let mut fed = FedSim::build(cfg);
    let site = fed.topo.site_index("syracuse").unwrap();
    let mut faults = FaultTimeline::new();
    faults.push(
        t(1.0),
        FaultKind::CacheSlow {
            site,
            factor: 0.05,
        },
    );
    faults.push(t(40.0), FaultKind::CacheRestored { site });
    fed.inject_faults(&faults).expect("valid fault timeline");

    let mut engine = SessionEngine::new(fed.now);
    let t0 = fed.now;
    let mut ids = Vec::new();
    for i in 0..12u64 {
        ids.push(engine.spawn_at(
            &mut fed,
            t0 + Duration::from_secs(4 * i),
            site,
            file(&format!("/ospool/des/data/strand-{i}.dat"), 400_000_000),
            DownloadMethod::Stash,
        ));
    }
    engine.run(&mut fed);

    assert_eq!(engine.completed().len(), 12, "no session stranded by a breaker transition");
    for id in ids {
        assert_eq!(engine.record(id).bytes, 400_000_000);
    }
    assert!(
        engine.waiters().is_empty(),
        "stale waiter-list entries: {:?}",
        engine.waiters()
    );
    assert!(
        engine.cache_in_flight().values().all(|&n| n == 0),
        "cache slots leaked: {:?}",
        engine.cache_in_flight()
    );
    let b = fed.breaker.as_ref().expect("breaker armed");
    assert!(b.trips >= 1, "the degraded cache must trip the breaker");
    assert!(
        engine.stats.deadline_expiries >= 1,
        "deadline expiries drive the breaker's failure outcomes"
    );
}

/// [`paper_federation`] with `origin-des` relocated to syracuse and
/// `origin-ligo` to nebraska (the same multi-origin shape as the
/// session_engine cold twin), plus the size mixture clamped to small
/// files so transfers are short relative to arrival spacing. That is
/// the window shape the bounded epoch planner needs: many sessions
/// finish comfortably before the next fault instant, in three disjoint
/// origin components (syracuse, nebraska, chicago).
fn multi_origin_small_files_federation() -> stashcache::config::FederationConfig {
    let mut cfg = paper_federation();
    for o in &mut cfg.origins {
        if o.name == "origin-des" {
            o.site = "syracuse".into();
        } else if o.name == "origin-ligo" {
            o.site = "nebraska".into();
        }
    }
    cfg.workload.size_dist.min = ByteSize(64 * 1024);
    cfg.workload.size_dist.max = ByteSize(4 * 1024 * 1024);
    cfg
}

/// A cache dies mid-campaign and heals eight seconds later. The epoch
/// planner must keep sharding *around* the fault — bounded epochs
/// before the outage, more between outage and heal, and the full tail
/// after — while every thread count reproduces the serial records,
/// fault log, and availability report byte-for-byte. Arrivals are
/// spaced wider than the ~1 s session lifetime so in-flight work
/// drains between jobs, giving the epoch loop its re-plan points.
#[test]
fn chaos_mid_run_epochs_engage_and_stay_bit_identical() {
    let ccfg = CampaignConfig {
        sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
        site_experiments: vec!["des".into(), "ligo".into(), "gwosc".into()],
        jobs: 24,
        arrival_window_secs: 60.0,
        catalog_files: 16,
        zipf_s: 1.1,
        background_flows: 0,
        ..CampaignConfig::default()
    };
    let leg = |threads: usize| {
        let mut fed = FedSim::build(multi_origin_small_files_federation());
        let victim = fed.topo.site_index("chicago").unwrap();
        let mut faults = FaultTimeline::new();
        faults.cache_outage(victim, t(12.0), t(20.0));
        campaign::run_on_with_faults_threads(&mut fed, &ccfg, &faults, threads)
    };
    let serial = leg(1);
    assert_eq!(serial.campaign.records.len(), 24, "every job completes");
    assert!(serial.campaign.records.iter().all(|r| r.record.bytes > 0));
    assert_eq!(serial.availability.faults_applied, 2, "down + heal");
    assert_eq!(
        serial.campaign.epochs.epochs_engaged, 0,
        "serial never shards"
    );
    for threads in [2usize, 8] {
        let r = leg(threads);
        assert_eq!(
            r.campaign.records, serial.campaign.records,
            "{threads}-thread chaos records diverged from serial"
        );
        assert_eq!(r.campaign.engine, serial.campaign.engine, "{threads}-thread EngineStats");
        assert_eq!(
            r.campaign.telemetry, serial.campaign.telemetry,
            "{threads}-thread telemetry snapshot"
        );
        assert_eq!(r.fault_log, serial.fault_log, "{threads}-thread fault log");
        assert_eq!(r.availability, serial.availability, "{threads}-thread availability");
        assert_eq!(r.campaign.peak_concurrent, serial.campaign.peak_concurrent);
        assert_eq!(r.campaign.events_processed, serial.campaign.events_processed);
        assert_eq!(r.campaign.makespan, serial.campaign.makespan);
        assert!(
            r.campaign.epochs.epochs_engaged >= 2,
            "{threads} threads: mid-run epochs must engage around the fault, got {:?}",
            r.campaign.epochs
        );
        assert!(
            r.campaign.epochs.sessions_sharded > 0,
            "{threads} threads: chaos sessions must run on shard workers"
        );
    }
}
