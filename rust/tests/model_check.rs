//! Integration: the small-scope model checker (`stashcache check`).
//!
//! The contracts under test:
//!
//! 1. **All built-in scenarios pass** — every explored interleaving of
//!    the hit/miss/join × cache-death × link-cut family satisfies the
//!    five global invariants (no violation, no deadlock, and — when
//!    the state space is fully explored — every state reaches a
//!    terminal state).
//! 2. **The search is genuinely combinatorial** — thousands of
//!    distinct transitions, not a handful of linear replays.
//! 3. **Determinism** — two explorations of the same scenario with the
//!    same budget report identical counts (the search is stateless
//!    rebuild-and-replay, so any divergence means a non-deterministic
//!    scenario builder).
//! 4. **Replay** — a choice-index prefix re-runs step by step with a
//!    described trace, the mechanism counterexamples are printed with.
//!
//! Budgets here are sized for debug-mode CI; `stashcache check` (and
//! the CI `check` job) runs the same scenarios in release with a much
//! larger budget.

use stashcache::mc::{builtin_scenarios, check_scenario, replay_trace};

#[test]
fn builtin_scenarios_hold_all_invariants() {
    let scenarios = builtin_scenarios();
    assert!(scenarios.len() >= 3, "the built-in family has 3+ scenarios");
    for sc in scenarios {
        let r = check_scenario(sc, 4_000);
        assert!(
            r.violation.is_none(),
            "{}: {:?}",
            sc.name,
            r.violation.as_ref().map(|v| (&v.invariant, &v.choices))
        );
        assert!(r.states >= 25, "{}: only {} states", sc.name, r.states);
        assert!(
            r.transitions >= 100,
            "{}: only {} transitions",
            sc.name,
            r.transitions
        );
        if !r.truncated {
            assert!(
                r.terminals >= 1,
                "{}: fully explored but no terminal state",
                sc.name
            );
        }
    }
}

#[test]
fn join_cache_death_explores_thousands_of_interleavings() {
    let sc = builtin_scenarios()
        .iter()
        .find(|s| s.name == "join-cache-death")
        .unwrap();
    let r = check_scenario(sc, 6_000);
    assert!(r.violation.is_none(), "{:?}", r.violation);
    // 3 racing sessions × a cache-death/recovery pair is a real state
    // space: either the budget was hit (≥ thousands of transitions) or
    // the full graph was closed and is itself that large.
    assert!(
        r.transitions >= 1_000,
        "expected thousands of interleavings, got {} transitions / {} states",
        r.transitions,
        r.states
    );
    assert!(r.states >= 100, "state dedup collapsed too far: {}", r.states);
}

#[test]
fn exploration_is_deterministic() {
    let sc = &builtin_scenarios()[1]; // miss-failover: the cheapest builder
    let a = check_scenario(sc, 2_000);
    let b = check_scenario(sc, 2_000);
    assert!(a.violation.is_none(), "{:?}", a.violation);
    assert_eq!(a.states, b.states);
    assert_eq!(a.transitions, b.transitions);
    assert_eq!(a.terminals, b.terminals);
    assert_eq!(a.max_depth, b.max_depth);
    assert_eq!(a.truncated, b.truncated);
}

#[test]
fn replay_of_a_prefix_describes_each_step() {
    let sc = &builtin_scenarios()[1];
    // Index 0 is always enabled until the run drains; three steps stay
    // well short of that.
    let (trace, error) = replay_trace(sc, &[0, 0, 0]);
    assert_eq!(error, None);
    assert_eq!(trace.len(), 3);
    assert!(trace[0].contains("session"), "step text: {:?}", trace[0]);

    // An out-of-range index is reported, not panicked on.
    let (_, error) = replay_trace(sc, &[99]);
    assert!(error.unwrap().contains("out of range"));
}
