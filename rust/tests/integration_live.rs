//! Integration: the live TCP/UDP federation on loopback.
//!
//! Real sockets, real bytes, real monitoring datagrams — asserts the
//! protocol stack works outside the simulator (DESIGN.md: live mode).

use stashcache::config::CacheConfig;
use stashcache::live::client::LiveCacheEndpoint;
use stashcache::live::{stashcp_live, CollectorDaemon, LiveCache, LiveOrigin, LiveRedirector};
use stashcache::util::ByteSize;

struct Fixture {
    origin: LiveOrigin,
    _redirector: LiveRedirector,
    monitor: CollectorDaemon,
    caches: Vec<LiveCache>,
    endpoints: Vec<LiveCacheEndpoint>,
}

fn federation(files: &[(&str, u64, u64)]) -> Fixture {
    let origin = LiveOrigin::start("o", "/ospool/test", files).unwrap();
    let redirector =
        LiveRedirector::start(vec![("/ospool/test".into(), origin.addr.clone())]).unwrap();
    let monitor =
        CollectorDaemon::start(vec![(0, "cache-a".into()), (1, "cache-b".into())]).unwrap();
    let cfg = CacheConfig {
        capacity: ByteSize::mb(600),
        chunk_size: ByteSize::mb(2),
        ..Default::default()
    };
    let a = LiveCache::start("cache-a", 0, cfg, redirector.addr.clone(), monitor.addr.clone())
        .unwrap();
    let b = LiveCache::start("cache-b", 1, cfg, redirector.addr.clone(), monitor.addr.clone())
        .unwrap();
    let endpoints = vec![
        LiveCacheEndpoint {
            site: stashcache::geoip::CacheSite {
                name: "cache-a".into(),
                lat: 40.8,
                lon: -96.7,
            },
            addr: a.addr.clone(),
        },
        LiveCacheEndpoint {
            site: stashcache::geoip::CacheSite {
                name: "cache-b".into(),
                lat: 52.4,
                lon: 4.9,
            },
            addr: b.addr.clone(),
        },
    ];
    Fixture {
        origin,
        _redirector: redirector,
        monitor,
        caches: vec![a, b],
        endpoints,
    }
}

#[test]
fn live_roundtrip_with_verification() {
    let fx = federation(&[("/ospool/test/a.dat", 5_000_000, 3)]);
    // US client → cache-a (nearest).
    let t = stashcp_live("/ospool/test/a.dat", 41.0, -100.0, &fx.endpoints).unwrap();
    assert_eq!(t.bytes.len(), 5_000_000);
    assert!(t.verified, "content must verify against the keystream");
    assert_eq!(t.cache_used, "cache-a");
    // EU client → cache-b.
    let t2 = stashcp_live("/ospool/test/a.dat", 50.0, 5.0, &fx.endpoints).unwrap();
    assert_eq!(t2.cache_used, "cache-b");
    // Each cache fetched once from the origin.
    assert_eq!(fx.origin.bytes_served(), 2 * 5_000_000 + 0);
}

#[test]
fn live_cache_hit_skips_origin() {
    let fx = federation(&[("/ospool/test/b.dat", 3_000_000, 1)]);
    let _ = stashcp_live("/ospool/test/b.dat", 41.0, -100.0, &fx.endpoints).unwrap();
    let origin_after_first = fx.origin.bytes_served();
    let t = stashcp_live("/ospool/test/b.dat", 41.0, -100.0, &fx.endpoints).unwrap();
    assert!(t.verified);
    assert_eq!(
        fx.origin.bytes_served(),
        origin_after_first,
        "second read is a cache hit"
    );
    let stats = fx.caches[0].stats();
    assert!(stats.bytes_served_hit >= 3_000_000);
}

#[test]
fn live_monitoring_joins_udp_packets() {
    let fx = federation(&[("/ospool/test/c.dat", 1_000_000, 1)]);
    for _ in 0..3 {
        stashcp_live("/ospool/test/c.dat", 41.0, -100.0, &fx.endpoints).unwrap();
    }
    // UDP is async: wait for the reports to land.
    for _ in 0..50 {
        if fx.monitor.reports() >= 3 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    assert_eq!(fx.monitor.reports(), 3, "collector joins every transfer");
    assert_eq!(fx.monitor.experiment_bytes("test"), Some(3_000_000));
    let stats = fx.monitor.collector_stats();
    assert_eq!(stats.decode_errors, 0);
    assert_eq!(stats.orphan_closes, 0);
}

#[test]
fn live_missing_file_fails_cleanly() {
    let fx = federation(&[("/ospool/test/d.dat", 1_000, 1)]);
    let err = stashcp_live("/ospool/test/nope.dat", 41.0, -100.0, &fx.endpoints);
    assert!(err.is_err(), "missing file must error, not hang");
}

#[test]
fn live_fallback_to_second_cache() {
    let fx = federation(&[("/ospool/test/e.dat", 100_000, 1)]);
    // Point the nearest endpoint at a dead address: stashcp must fall
    // back to the other cache (the §3.1 fallback behaviour).
    let mut endpoints = fx.endpoints.clone();
    endpoints[0].addr = "127.0.0.1:1".into(); // connection refused
    let t = stashcp_live("/ospool/test/e.dat", 41.0, -100.0, &endpoints).unwrap();
    assert_eq!(t.cache_used, "cache-b", "fallback cache served");
    assert!(t.verified);
}
