//! Integration: the PJRT-compiled artifacts driving the real services,
//! checked against the pure-rust reference implementations.
//!
//! This is the three-layer contract: L1 Pallas kernels (validated vs
//! ref.py by pytest) → L2 jax model → HLO text → PJRT executors →
//! L3 services. Here we assert the rust ends agree bit-for-bit (hist)
//! or to float tolerance (geo), so simulations are backend-invariant.

use stashcache::config::defaults::paper_federation;
use stashcache::federation::backend::GeoBackend;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::monitoring::aggregator::{Aggregator, HistBackend, RustHistBackend, HIST_BINS};
use stashcache::monitoring::TransferReport;
use stashcache::runtime::{HistAgg, Runtime, TransferEst, TransferParams};
use stashcache::sim::estimate;
use stashcache::sim::workload::FileRef;
use stashcache::util::{ByteSize, Pcg64, SimTime};

/// `None` on offline/stub builds — each test skips with a stderr note.
fn runtime() -> Option<Runtime> {
    Runtime::try_available()
}

#[test]
fn federation_runs_identically_on_both_geo_backends() {
    let pjrt = match GeoBackend::pjrt() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping federation_runs_identically_on_both_geo_backends: {e:#}");
            return;
        }
    };
    let cfg = paper_federation();
    let mut rust_fed = FedSim::build(cfg.clone());
    let mut pjrt_fed = FedSim::build_with_backend(cfg, pjrt);
    for i in 0..8 {
        let f = FileRef {
            path: format!("/ospool/gwosc/data/b{i:03}.dat"),
            size: ByteSize::mb(64 + i * 16),
            version: 1,
        };
        for site in ["syracuse", "colorado", "bellarmine"] {
            let s1 = rust_fed.topo.site_index(site).unwrap();
            let r1 = rust_fed.download(s1, &f, DownloadMethod::Stash);
            let r2 = pjrt_fed.download(s1, &f, DownloadMethod::Stash);
            assert_eq!(
                r1.duration, r2.duration,
                "{site}/{i}: geo backend must not change outcomes"
            );
        }
    }
}

#[test]
fn pjrt_histogram_backend_in_aggregator() {
    let Some(rt) = runtime() else {
        return;
    };
    let pjrt = HistAgg::load(&rt).expect("usage_hist artifact");
    let mut agg_pjrt = Aggregator::new(pjrt);
    let mut agg_rust = Aggregator::default();
    let mut rng = Pcg64::new(42, 1);
    for i in 0..5_000 {
        let size = 10f64.powf(rng.gen_f64(2.0, 10.5)) as u64;
        let r = TransferReport {
            server: "s".into(),
            client_host: "h".into(),
            protocol: "xrootd".into(),
            ipv6: false,
            path: "/ospool/des/f".into(),
            file_size: size,
            bytes_read: size,
            bytes_written: 0,
            read_ops: 1,
            write_ops: 0,
            opened_at: SimTime(i),
            closed_at: SimTime(i + 1),
        };
        agg_pjrt.ingest(&r);
        agg_rust.ingest(&r);
    }
    let h1 = agg_pjrt.histogram_snapshot();
    let h2 = agg_rust.histogram_snapshot();
    assert_eq!(h1.len(), HIST_BINS);
    assert_eq!(h1, h2, "PJRT and rust histogram backends must agree exactly");
    // And the Table 2 readout follows.
    let p1 = agg_pjrt.table2(&[25.0, 50.0, 75.0, 95.0]);
    let p2 = agg_rust.table2(&[25.0, 50.0, 75.0, 95.0]);
    assert_eq!(p1, p2);
}

#[test]
fn transfer_estimator_matches_rust_mirror() {
    let Some(rt) = runtime() else {
        return;
    };
    let mut est = TransferEst::load(&rt).expect("transfer_est artifact");
    let mut rng = Pcg64::new(7, 7);
    let batch: Vec<TransferParams> = (0..600)
        .map(|_| TransferParams {
            bytes: rng.gen_f64(1e3, 1e10),
            rtt_ms: rng.gen_f64(0.2, 200.0),
            bottleneck_bps: rng.gen_f64(1e6, 1.25e10),
            streams: rng.gen_f64(1.0, 32.0),
        })
        .collect();
    let got = est.estimate(&batch).expect("batched estimate");
    assert_eq!(got.len(), 600);
    assert_eq!(est.invocations, 3, "600 rows = 3 × 256-row artifact calls");
    for (g, p) in got.iter().zip(&batch) {
        let want = estimate::transfer_secs(p.bytes, p.rtt_ms, p.bottleneck_bps, p.streams);
        let rel = (g - want).abs() / want.max(1e-9);
        // f32 kernel vs f64 mirror.
        assert!(rel < 1e-3, "got {g}, want {want} for {p:?}");
    }
}

#[test]
fn rust_hist_matches_pjrt_on_adversarial_bin_edges() {
    // Values sitting exactly on bin edges are where f32-vs-f64
    // disagreements would hide.
    let Some(rt) = runtime() else {
        return;
    };
    let mut pjrt = HistAgg::load(&rt).expect("artifact");
    // Near-edge values (±1e-4 relative — well-resolved in f32) must
    // bin identically; *exact* edges can differ by one ulp of log10
    // between libm implementations, so only conservation is asserted
    // for those.
    let mut near = Vec::new();
    let mut exact = Vec::new();
    for bin in 0..HIST_BINS {
        let edge = 10f64.powf(13.0 * bin as f64 / HIST_BINS as f64);
        near.push(edge * (1.0 + 1e-4));
        near.push(edge * (1.0 - 1e-4));
        exact.push(edge);
    }
    let h_pjrt = HistAgg::histogram(&mut pjrt, &near).unwrap();
    let h_rust = RustHistBackend.histogram(&near);
    assert_eq!(h_pjrt, h_rust, "near-edge values must bin identically");
    let e_pjrt = HistAgg::histogram(&mut pjrt, &exact).unwrap();
    let e_rust = RustHistBackend.histogram(&exact);
    assert_eq!(
        e_pjrt.iter().sum::<f32>(),
        e_rust.iter().sum::<f32>(),
        "exact-edge values conserve counts"
    );
    let moved: f32 = e_pjrt
        .iter()
        .zip(&e_rust)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(moved <= 4.0, "at most a couple of ulp boundary moves: {moved}");
}
