//! CLI robustness: bad invocations must exit non-zero with the usage
//! text on stderr (scripts and CI depend on both).

use std::process::Command;

fn run(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_stashcache"))
        .args(args)
        .output()
        .expect("spawn stashcache binary")
}

#[test]
fn unknown_subcommand_fails_with_usage_on_stderr() {
    let out = run(&["frobnicate"]);
    assert!(!out.status.success(), "unknown subcommand must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("unknown command"),
        "stderr names the problem: {stderr}"
    );
    assert!(
        stderr.contains("commands:") && stderr.contains("sweep"),
        "stderr carries the usage text: {stderr}"
    );
}

#[test]
fn malformed_flag_fails_with_usage_on_stderr() {
    let out = run(&["campaign", "--jobs", "notanumber"]);
    assert!(!out.status.success(), "malformed flag must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("--jobs") && stderr.contains("notanumber"),
        "stderr names the bad flag: {stderr}"
    );
    assert!(stderr.contains("commands:"), "stderr carries usage: {stderr}");
}

#[test]
fn bad_flag_values_fail_cleanly() {
    for args in [
        &["campaign", "--jobs", "0"][..],
        &["campaign", "--method", "carrier-pigeon"][..],
        &["campaign", "--sites", "atlantis"][..],
        &["sweep", "--preset", "nope"][..],
        &["scenario", "--runtime", "abacus"][..],
    ] {
        let out = run(args);
        assert!(
            !out.status.success(),
            "{args:?} must exit non-zero"
        );
        assert!(
            String::from_utf8_lossy(&out.stderr).contains("error:"),
            "{args:?} reports an error on stderr"
        );
    }
}

#[test]
fn help_succeeds_on_stdout() {
    let out = run(&["help"]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("commands:") && stdout.contains("sweep"));
    assert!(out.stderr.is_empty(), "help is not an error");
}
