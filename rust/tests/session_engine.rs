//! Integration: the event-driven session engine.
//!
//! The three contracts the refactor must honour:
//!
//! 1. **Cross-client coalescing** — two concurrent sessions missing
//!    the same file trigger exactly one origin fetch; both are served.
//! 2. **Determinism** — a campaign with the same `Pcg64` seed yields a
//!    bit-identical `TransferRecord` stream; the serial §4.1 scenario
//!    is reproducible run-to-run through the engine.
//! 3. **Serial equivalence** — a batch engine whose sessions do not
//!    overlap produces exactly what sequential `FedSim::download`
//!    calls produce.

use stashcache::config::defaults::paper_federation;
use stashcache::federation::driver::SessionEngine;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::sim::campaign::{self, CampaignConfig, CampaignRecord};
use stashcache::sim::scenario::{self, ScenarioConfig};
use stashcache::sim::workload::FileRef;
use stashcache::util::{fnv1a, ByteSize, Duration, SimTime};

fn file(path: &str, bytes: u64) -> FileRef {
    FileRef {
        path: path.into(),
        size: ByteSize(bytes),
        version: 1,
    }
}

#[test]
fn cross_client_coalescing_single_origin_fetch() {
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("syracuse").unwrap();
    let f = file("/ospool/des/data/coalesce.dat", 500_000_000);

    let mut engine = SessionEngine::new(fed.now);
    let t0 = fed.now;
    let a = engine.spawn_at(&mut fed, t0, site, f.clone(), DownloadMethod::Stash);
    // Second client lands mid-fetch: ~2 s into a ~4 s origin stream.
    let b = engine.spawn_at(
        &mut fed,
        t0 + Duration::from_secs(2),
        site,
        f.clone(),
        DownloadMethod::Stash,
    );
    engine.run(&mut fed);

    let ra = engine.record(a);
    let rb = engine.record(b);
    assert_eq!(ra.bytes, 500_000_000);
    assert_eq!(rb.bytes, 500_000_000);
    assert!(!ra.cache_hit, "first session is the cold fetch");
    assert!(!rb.cache_hit, "joiner missed at request time");
    assert!(
        engine.session(b).joins >= 1,
        "second session must coalesce onto the first fetch"
    );

    // Both sessions used the same (local) cache, and the file's bytes
    // were fetched from the origin exactly once.
    let cache_site = engine.session(a).cache_site.unwrap();
    assert_eq!(engine.session(b).cache_site, Some(cache_site));
    let cache = &fed.caches[&cache_site];
    assert_eq!(
        cache.stats.bytes_fetched_origin, 500_000_000,
        "coalescing must not duplicate origin traffic"
    );
    let origin_served: u64 = fed.origins.iter().map(|o| o.bytes_served).sum();
    assert_eq!(origin_served, 500_000_000, "joiner never touched the origin");
    // Both clients were fully served.
    assert_eq!(
        cache.stats.bytes_served_hit + cache.stats.bytes_served_miss,
        1_000_000_000
    );
    // The joiner waited for the fetcher's commit, so it finishes after
    // the fetcher despite requesting the same bytes.
    assert_eq!(engine.completed(), &[a, b], "fetcher finishes first");
}

#[test]
fn campaign_256_concurrent_clients_deterministic() {
    // The acceptance campaign: ≥256 concurrent clients across ≥3
    // sites, to completion, twice, bit-identically.
    let ccfg = CampaignConfig {
        jobs: 320,
        arrival_window_secs: 2.0,
        catalog_files: 64,
        zipf_s: 1.0,
        background_flows: 2,
        ..CampaignConfig::default()
    };
    assert!(ccfg.sites.len() >= 3);
    let r1 = campaign::run(paper_federation(), &ccfg);
    assert_eq!(r1.records.len(), 320, "every job completes");
    assert!(
        r1.peak_concurrent >= 256,
        "campaign must overlap ≥256 sessions, peak {}",
        r1.peak_concurrent
    );
    assert!(
        r1.coalesced_joins > 0,
        "a hot catalog under this much concurrency must coalesce"
    );
    // Sessions ran at 3+ distinct sites.
    let mut sites: Vec<&str> = r1.records.iter().map(|r| r.site.as_str()).collect();
    sites.sort_unstable();
    sites.dedup();
    assert!(sites.len() >= 3, "sites covered: {sites:?}");

    let r2 = campaign::run(paper_federation(), &ccfg);
    assert_eq!(r1.records, r2.records, "same seed ⇒ identical record stream");
    assert_eq!(r1.peak_concurrent, r2.peak_concurrent);
    assert_eq!(r1.events_processed, r2.events_processed);
}

#[test]
fn non_overlapping_batch_equals_sequential_downloads() {
    // A batch engine whose second session arrives long after the first
    // finishes must reproduce the serial blocking API exactly —
    // including background-flow respawns in the idle gap.
    let fa = file("/ospool/nova/data/serial-a.dat", 200_000_000);
    let fb = file("/ospool/nova/data/serial-b.dat", 350_000_000);
    let gap = SimTime::from_secs_f64(3_600.0);

    // Leg 1: sequential convenience API.
    let mut fed1 = FedSim::build(paper_federation());
    fed1.start_background_load(2);
    let site = fed1.topo.site_index("nebraska").unwrap();
    let r1a = fed1.download(site, &fa, DownloadMethod::Stash);
    fed1.advance_to(gap);
    let r1b = fed1.download(site, &fb, DownloadMethod::Stash);

    // Leg 2: one engine, both sessions spawned up front.
    let mut fed2 = FedSim::build(paper_federation());
    fed2.start_background_load(2);
    let mut engine = SessionEngine::new(fed2.now);
    let a = engine.spawn_at(&mut fed2, fed2.now, site, fa, DownloadMethod::Stash);
    let b = engine.spawn_at(&mut fed2, gap, site, fb, DownloadMethod::Stash);
    engine.run(&mut fed2);

    assert_eq!(r1a, engine.record(a), "first download identical");
    assert_eq!(r1b, engine.record(b), "second download identical");
    // Monitoring saw the same two transfers in both legs.
    assert_eq!(fed1.aggregator.reports, 2);
    assert_eq!(fed2.aggregator.reports, 2);
    assert_eq!(
        fed1.aggregator.total_bytes().as_u64(),
        fed2.aggregator.total_bytes().as_u64()
    );
}

#[test]
fn serial_scenario_reproducible_through_engine() {
    // The §4.1 scenario (serial by construction) through the session
    // engine: run-to-run bit reproducibility of every measurement.
    let scenario_cfg = ScenarioConfig {
        sites: vec!["syracuse".into(), "colorado".into()],
        files: vec![
            ("p01".into(), ByteSize(5_797)),
            ("p95".into(), ByteSize(2_335_000_000)),
        ],
        ..ScenarioConfig::default()
    };
    let r1 = scenario::run(paper_federation(), &scenario_cfg);
    let r2 = scenario::run(paper_federation(), &scenario_cfg);
    let recs1: Vec<_> = r1.measurements.iter().map(|m| &m.record).collect();
    let recs2: Vec<_> = r2.measurements.iter().map(|m| &m.record).collect();
    assert_eq!(recs1, recs2, "serial scenario must be bit-reproducible");
    // And the paper's headline shape survives the engine swap.
    assert!(r1.pct_difference("colorado", "p95").unwrap() > 50.0);
    assert!(r1.pct_difference("syracuse", "p95").unwrap().abs() < 25.0);
}

#[test]
fn concurrent_proxy_sessions_share_the_proxy() {
    // The engine handles concurrent HTTP-proxy sessions too: same
    // object requested twice concurrently relays twice (squid caches
    // only on commit), but a later session hits.
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("nebraska").unwrap();
    let f = file("/ospool/nova/data/proxy-conc.dat", 100_000_000);

    let mut engine = SessionEngine::new(fed.now);
    let t0 = fed.now;
    let a = engine.spawn_at(&mut fed, t0, site, f.clone(), DownloadMethod::HttpProxy);
    let b = engine.spawn_at(
        &mut fed,
        t0 + Duration::from_millis(100),
        site,
        f.clone(),
        DownloadMethod::HttpProxy,
    );
    engine.run(&mut fed);
    assert!(!engine.record(a).cache_hit);
    assert!(
        !engine.record(b).cache_hit,
        "second request arrived before the first committed"
    );

    // A third, later session hits the now-cached object.
    let mut engine2 = SessionEngine::new(fed.now);
    let c = engine2.spawn_at(&mut fed, fed.now, site, f, DownloadMethod::HttpProxy);
    engine2.run(&mut fed);
    assert!(engine2.record(c).cache_hit, "object cached after commit");
}

/// FNV-1a digest of a campaign's full record stream — the compact
/// bit-identity witness the threaded determinism gate asserts on.
fn record_digest(records: &[CampaignRecord]) -> u64 {
    use std::fmt::Write;
    let mut buf = String::new();
    for r in records {
        let _ = write!(
            buf,
            "{}|{}|{}|{}|{}|{:?}|{}|{};",
            r.session,
            r.site,
            r.arrival.0,
            r.record.path,
            r.record.bytes,
            r.record.method,
            r.record.cache_hit,
            r.record.duration.0,
        );
    }
    fnv1a(buf.as_bytes())
}

#[test]
fn campaign_bit_identical_across_thread_counts() {
    // A hot, small catalog: the head of the run fills the caches, so
    // the tail is whole hits — the shape the terminal epoch shards.
    // Thread count must not change a single byte of the results.
    let ccfg = CampaignConfig {
        jobs: 96,
        arrival_window_secs: 30.0,
        catalog_files: 8,
        zipf_s: 1.4,
        background_flows: 1,
        ..CampaignConfig::default()
    };
    let serial = campaign::run_threads(paper_federation(), &ccfg, 1);
    assert_eq!(serial.records.len(), 96, "every job completes");
    let digest = record_digest(&serial.records);
    for threads in [2usize, 8] {
        let r = campaign::run_threads(paper_federation(), &ccfg, threads);
        assert_eq!(
            record_digest(&r.records),
            digest,
            "{threads}-thread record digest diverged from serial"
        );
        assert_eq!(r.records, serial.records, "{threads}-thread records");
        assert_eq!(r.engine, serial.engine, "{threads}-thread EngineStats");
        assert_eq!(r.peak_concurrent, serial.peak_concurrent);
        assert_eq!(r.events_processed, serial.events_processed);
        assert_eq!(r.makespan, serial.makespan);
    }
}

#[test]
fn warmed_tail_shards_and_matches_serial_exactly() {
    // Whole-hit sessions at two cache-owning sites: the terminal epoch
    // must actually engage (two shards), and the merged results must
    // be byte-for-byte what the serial loop produces — records, stats,
    // the federation clock, and the cache-slot ledger.
    let fa = file("/ospool/des/data/shard-a.dat", 50_000_000);
    let fb = file("/ospool/nova/data/shard-b.dat", 80_000_000);
    let leg = |threads: usize| {
        let mut fed = FedSim::build(paper_federation());
        let syr = fed.topo.site_index("syracuse").unwrap();
        let neb = fed.topo.site_index("nebraska").unwrap();
        // Warm both caches so every engine session is a whole hit.
        fed.download(syr, &fa, DownloadMethod::Stash);
        fed.download(neb, &fb, DownloadMethod::Stash);
        let mut engine = SessionEngine::new(fed.now);
        let t0 = fed.now;
        for k in 0..4u64 {
            let (site, f) = if k % 2 == 0 { (syr, &fa) } else { (neb, &fb) };
            engine.spawn_at(
                &mut fed,
                t0 + Duration::from_millis(10 * k),
                site,
                f.clone(),
                DownloadMethod::Stash,
            );
        }
        engine.run_threaded(&mut fed, threads);
        assert_eq!(engine.completed().len(), 4);
        assert!(
            engine.cache_in_flight().values().all(|&n| n == 0),
            "cache slots leaked: {:?}",
            engine.cache_in_flight()
        );
        let records: Vec<_> = engine
            .completed()
            .iter()
            .map(|&id| engine.record(id))
            .collect();
        (records, engine.stats, engine.epoch_durations.count(), fed.now)
    };
    let (serial_recs, serial_stats, serial_epoch, serial_now) = leg(1);
    assert_eq!(serial_epoch, 0, "1 thread is the serial path byte-for-byte");
    assert!(serial_recs.iter().all(|r| r.cache_hit), "warmed ⇒ all hits");
    for threads in [2usize, 8] {
        let (recs, stats, epoch_count, now) = leg(threads);
        assert_eq!(
            epoch_count, 4,
            "{threads} threads: the warmed whole-hit tail must shard"
        );
        assert_eq!(recs, serial_recs, "{threads}-thread records");
        assert_eq!(stats, serial_stats, "{threads}-thread EngineStats");
        assert_eq!(now, serial_now, "{threads}-thread federation clock");
    }
}

/// [`paper_federation`] with two experiment origins relocated to
/// cache-owning compute sites: `origin-des` moves to syracuse and
/// `origin-ligo` to nebraska. Each of those sites then pulls its
/// experiment's cold misses from a same-site origin DTN — the fetch
/// route never crosses the WAN — so the epoch planner sees three
/// disjoint origin components (syracuse, nebraska, chicago) instead of
/// one blob coupled through Chicago's border.
fn multi_origin_federation() -> stashcache::config::FederationConfig {
    let mut cfg = paper_federation();
    for o in &mut cfg.origins {
        if o.name == "origin-des" {
            o.site = "syracuse".into();
        } else if o.name == "origin-ligo" {
            o.site = "nebraska".into();
        }
    }
    cfg
}

#[test]
fn cold_start_campaign_shards_and_matches_serial_exactly() {
    // All-miss start against three self-contained sites, each reading
    // an experiment whose origin sits behind its own border: the epoch
    // planner must shard the cold fetches by origin component, and the
    // merged results must be byte-for-byte what the serial loop
    // produces. This is the cold twin of
    // `campaign_bit_identical_across_thread_counts`.
    let ccfg = CampaignConfig {
        sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
        site_experiments: vec!["des".into(), "ligo".into(), "gwosc".into()],
        jobs: 48,
        arrival_window_secs: 20.0,
        catalog_files: 12,
        zipf_s: 1.1,
        background_flows: 0,
        ..CampaignConfig::default()
    };
    let serial = campaign::run_threads(multi_origin_federation(), &ccfg, 1);
    assert_eq!(serial.records.len(), 48, "every job completes");
    assert!(
        serial.records.iter().any(|r| !r.record.cache_hit),
        "a cold start must produce misses"
    );
    assert!(
        serial.records.iter().any(|r| r.record.cache_hit),
        "repeat reads within the window should hit the warming cache"
    );
    assert_eq!(serial.epochs.epochs_engaged, 0, "serial never shards");
    let digest = record_digest(&serial.records);
    for threads in [2usize, 8] {
        let r = campaign::run_threads(multi_origin_federation(), &ccfg, threads);
        assert_eq!(
            record_digest(&r.records),
            digest,
            "{threads}-thread cold record digest diverged from serial"
        );
        assert_eq!(r.records, serial.records, "{threads}-thread records");
        assert_eq!(r.engine, serial.engine, "{threads}-thread EngineStats");
        assert_eq!(r.telemetry, serial.telemetry, "{threads}-thread telemetry");
        assert_eq!(r.events_processed, serial.events_processed);
        assert_eq!(r.makespan, serial.makespan);
        assert!(
            r.epochs.epochs_engaged >= 1,
            "{threads} threads: a cold epoch must engage, got {:?}",
            r.epochs
        );
        assert!(
            r.epochs.sessions_sharded > 0,
            "{threads} threads: cold sessions must run on shard workers"
        );
    }
}

#[test]
fn telemetry_identical_across_thread_counts() {
    // The telemetry export is built from thread-invariant state
    // (EngineStats, cache/collector/bus counters, phase sketches folded
    // in completion order), so the whole snapshot — JSON, exposition,
    // and trace ring — must be byte-identical at 1/2/8 threads.
    let ccfg = CampaignConfig {
        jobs: 96,
        arrival_window_secs: 30.0,
        catalog_files: 8,
        zipf_s: 1.4,
        background_flows: 1,
        trace: 64,
        ..CampaignConfig::default()
    };
    let serial = campaign::run_threads(paper_federation(), &ccfg, 1);
    let snap = &serial.telemetry;
    // Sanity: the instrumentation actually fired on this run.
    assert_eq!(
        snap.registry
            .counter_value("stashcache_engine_sessions_completed_total"),
        96
    );
    for phase in ["geo_resolve", "cache_check", "transfer"] {
        let sk = snap
            .phase_sketch(phase)
            .unwrap_or_else(|| panic!("missing phase sketch {phase}"));
        assert!(sk.count() > 0, "phase {phase} recorded no spans");
    }
    assert_eq!(snap.traces.len(), 64, "trace ring kept the last 64");
    assert!(snap.exposition().contains("stashcache_phase_seconds"));
    for threads in [2usize, 8] {
        let r = campaign::run_threads(paper_federation(), &ccfg, threads);
        assert_eq!(
            r.telemetry, serial.telemetry,
            "{threads}-thread telemetry snapshot diverged from serial"
        );
        assert_eq!(
            r.telemetry.to_json_string(),
            snap.to_json_string(),
            "{threads}-thread metrics JSON"
        );
        assert_eq!(
            r.telemetry.exposition(),
            snap.exposition(),
            "{threads}-thread exposition"
        );
    }
}

#[test]
fn telemetry_off_leaves_results_bit_identical() {
    // Telemetry must live entirely off the bit-identity surface:
    // disabling it (or enabling tracing) cannot perturb a single
    // record, stat, or digest.
    let on = CampaignConfig {
        jobs: 96,
        arrival_window_secs: 30.0,
        catalog_files: 8,
        zipf_s: 1.4,
        background_flows: 1,
        trace: 32,
        telemetry: true,
        ..CampaignConfig::default()
    };
    let off = CampaignConfig {
        trace: 0,
        telemetry: false,
        ..on.clone()
    };
    let r_on = campaign::run(paper_federation(), &on);
    let r_off = campaign::run(paper_federation(), &off);
    assert_eq!(
        record_digest(&r_on.records),
        record_digest(&r_off.records),
        "telemetry on/off changed the record digest"
    );
    assert_eq!(r_on.records, r_off.records);
    assert_eq!(r_on.engine, r_off.engine);
    assert_eq!(r_on.makespan, r_off.makespan);
    assert_eq!(r_on.events_processed, r_off.events_processed);
    // Disabled ⇒ an empty default snapshot, nothing collected.
    assert!(r_off.telemetry.phases.is_empty());
    assert!(r_off.telemetry.traces.is_empty());
    assert!(!r_on.telemetry.traces.is_empty());
}
