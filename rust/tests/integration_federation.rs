//! Integration: the assembled federation end-to-end in simulation.
//!
//! These tests cross module boundaries: clients → geoip → cache →
//! redirector → origin → netsim → monitoring → aggregator, asserting
//! conservation laws the paper's architecture implies.

use stashcache::config::defaults::{paper_federation, test_file_sizes, COMPUTE_SITES};
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::sim::scenario::{self, ScenarioConfig};
use stashcache::sim::usage::{self, UsageConfig};
use stashcache::sim::workload::FileRef;
use stashcache::util::ByteSize;

#[test]
fn bytes_conservation_across_layers() {
    // Bytes served by caches == bytes clients read; bytes fetched from
    // origins == bytes origins served to caches (stash path).
    let mut fed = FedSim::build(paper_federation());
    let mut client_bytes = 0u64;
    for (i, site) in COMPUTE_SITES.iter().enumerate() {
        let idx = fed.topo.site_index(site).unwrap();
        for j in 0..4 {
            let f = FileRef {
                path: format!("/ospool/des/data/int{i}-{j}.dat"),
                size: ByteSize::mb(50 + 10 * j),
                version: 1,
            };
            let rec = fed.download(idx, &f, DownloadMethod::Stash);
            client_bytes += rec.bytes;
        }
    }
    let served: u64 = fed
        .caches
        .values()
        .map(|c| c.stats.bytes_served_hit + c.stats.bytes_served_miss)
        .sum();
    let fetched: u64 = fed.caches.values().map(|c| c.stats.bytes_fetched_origin).sum();
    let origin_served: u64 = fed.origins.iter().map(|o| o.bytes_served).sum();
    assert_eq!(served, client_bytes, "cache-served == client-read");
    assert_eq!(fetched, origin_served, "cache-fetched == origin-served");
    assert!(fetched <= client_bytes, "no over-fetch on whole-file reads");
    // Monitoring accounted every stash transfer.
    assert_eq!(fed.aggregator.reports, 20);
    assert_eq!(fed.aggregator.total_bytes().as_u64(), client_bytes);
}

#[test]
fn scenario_full_run_shape() {
    // The complete §4.1 scenario at full size: 5 sites × 7 files × 4
    // downloads = 140 measurements.
    let results = scenario::run(paper_federation(), &ScenarioConfig::default());
    assert_eq!(results.measurements.len(), 5 * 7 * 4);
    // Every (site, file, tool, pass) cell exists.
    for site in COMPUTE_SITES {
        for (label, _) in test_file_sizes() {
            for tool in ["http", "stash"] {
                for pass in ["cold", "hot"] {
                    assert!(
                        results.rate(site, &label, tool, pass).is_some(),
                        "missing cell {site}/{label}/{tool}/{pass}"
                    );
                }
            }
        }
    }
    // Paper Table 3 signs.
    assert!(results.pct_difference("colorado", "f10g").unwrap() > 0.0);
    assert!(results.pct_difference("bellarmine", "p95").unwrap() < 0.0);
}

#[test]
fn usage_sim_monitoring_equals_ground_truth() {
    let ucfg = UsageConfig {
        days: 0.25,
        jobs_per_hour: Some(60.0),
        background_flows: 1,
        weekly_intensity: Vec::new(),
        wan_bucket_secs: 1_800.0,
    };
    let out = usage::run(paper_federation(), &ucfg);
    // Every download produced exactly one monitoring report.
    assert_eq!(out.fed.aggregator.reports, out.downloads);
    assert_eq!(out.fed.collector.stats.orphan_closes, 0);
    assert_eq!(out.fed.collector.stats.decode_errors, 0);
    // Aggregated bytes equal the caches' served bytes.
    let served: u64 = out
        .fed
        .caches
        .values()
        .map(|c| c.stats.bytes_served_hit + c.stats.bytes_served_miss)
        .sum();
    assert_eq!(out.fed.aggregator.total_bytes().as_u64(), served);
}

#[test]
fn proxy_and_stash_paths_are_independent() {
    // Downloading via the proxy must not warm the stash cache, and
    // vice versa (they are distinct systems in the paper).
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("nebraska").unwrap();
    let f = FileRef {
        path: "/ospool/nova/data/indep.dat".into(),
        size: ByteSize::mb(100),
        version: 1,
    };
    let _http = fed.download(site, &f, DownloadMethod::HttpProxy);
    let stash_first = fed.download(site, &f, DownloadMethod::Stash);
    assert!(
        !stash_first.cache_hit,
        "proxy download must not pre-warm the stash cache"
    );
    let f2 = FileRef {
        path: "/ospool/nova/data/indep2.dat".into(),
        size: ByteSize::mb(100),
        version: 1,
    };
    let _stash = fed.download(site, &f2, DownloadMethod::Stash);
    let http_second = fed.download(site, &f2, DownloadMethod::HttpProxy);
    assert!(
        !http_second.cache_hit,
        "stash download must not pre-warm the proxy"
    );
}

#[test]
fn dataset_update_invalidates_cached_copy() {
    // The owner rewrites a file at the origin (new mtime); the cache
    // must serve the new version, not the stale chunks.
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("syracuse").unwrap();
    let v1 = FileRef {
        path: "/ospool/lsst/data/cat.fits".into(),
        size: ByteSize::mb(200),
        version: 1,
    };
    fed.download(site, &v1, DownloadMethod::Stash);
    let hot = fed.download(site, &v1, DownloadMethod::Stash);
    assert!(hot.cache_hit);
    let v2 = FileRef { version: 2, ..v1.clone() };
    let after_update = fed.download(site, &v2, DownloadMethod::Stash);
    assert!(
        !after_update.cache_hit,
        "version bump must invalidate cached chunks"
    );
    let cache_site = fed.nearest_cache_site(site);
    assert_eq!(fed.caches[&cache_site].stats.invalidations, 1);
}

#[test]
fn wan_accounting_matches_link_counters() {
    // Fig 5's counter: a cold remote fetch at a cache-less site moves
    // ~file-size bytes across that site's WAN link.
    let mut fed = FedSim::build(paper_federation());
    let col = fed.topo.site_index("colorado").unwrap();
    let before = fed.wan_bytes(col);
    let f = FileRef {
        path: "/ospool/dune/data/wan.dat".into(),
        size: ByteSize::mb(300),
        version: 1,
    };
    fed.download(col, &f, DownloadMethod::Stash);
    let delta = fed.wan_bytes(col) - before;
    let expected = 300_000_000.0;
    assert!(
        (delta - expected).abs() < expected * 0.01,
        "WAN delta {delta} vs expected {expected}"
    );
}
