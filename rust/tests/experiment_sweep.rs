//! Acceptance tests for the experiment lab (ISSUE 3 tentpole).
//!
//! The determinism contract: a grid run on N OS threads is
//! **bit-identical** to the same grid run single-threaded — per-trial
//! records (via the FNV record digest), per-cell summaries, and the
//! JSON artifact bytes. And the `proxy-vs-stash` preset reproduces the
//! §4.1 Table 3 scenario as one cell of the grid, matching a direct
//! `sim::scenario` run exactly.

use stashcache::config::defaults::{paper_federation, COMPUTE_SITES};
use stashcache::experiment::{artifact, grid::FaultProfile, grid::SizeProfile, run_grid, GridSpec};
use stashcache::federation::DownloadMethod;
use stashcache::report::paper;
use stashcache::sim::scenario::{self, ScenarioConfig};

/// 2 methods × 2 capacities × 2 fault profiles × 3 reps = 24 trials.
fn acceptance_grid() -> GridSpec {
    GridSpec {
        name: "acceptance".into(),
        root_seed: 7,
        reps: 3,
        methods: vec![DownloadMethod::Stash, DownloadMethod::HttpProxy],
        capacity_scales: vec![0.5, 1.0],
        jobs: vec![8],
        arrival_windows: vec![15.0],
        zipf_s: vec![1.3],
        size_profiles: vec![SizeProfile::Paper],
        fault_profiles: vec![FaultProfile::None, FaultProfile::CacheOutage],
        policies: vec![stashcache::redirector::PolicyKind::Nearest],
        deadline_factors: vec![0.0],
        breakers: vec![false],
        sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
        experiment: "gwosc".into(),
        catalog_files: 32,
        files_per_job: (1, 1),
        background_flows: 1,
        table3_cell: false,
    }
}

#[test]
fn parallel_run_is_bit_identical_to_serial() {
    let grid = acceptance_grid();
    assert!(grid.trial_count() >= 24, "grid too small for the gate");

    let serial = run_grid(&paper_federation(), &grid, 1);
    let parallel = run_grid(&paper_federation(), &grid, 4);

    assert_eq!(serial.trials.len(), grid.trial_count());
    // Per-trial records: the digest covers every TransferRecord field
    // in completion order, so equality here is record-level equality.
    for (a, b) in serial.trials.iter().zip(&parallel.trials) {
        assert_eq!(a.spec, b.spec);
        assert_eq!(
            a.records_digest, b.records_digest,
            "trial {} ({}) diverged across thread counts",
            a.spec.index,
            a.spec.cell.label()
        );
    }
    assert_eq!(serial.trials, parallel.trials, "full metric vectors");
    assert_eq!(serial.cells, parallel.cells, "per-cell summaries");
    assert_eq!(serial, parallel, "whole SweepResults");
    assert_eq!(
        artifact::sweep_json(&serial),
        artifact::sweep_json(&parallel),
        "JSON artifact bytes"
    );
}

#[test]
fn every_trial_completes_and_faulted_cells_differ() {
    let grid = acceptance_grid();
    let r = run_grid(&paper_federation(), &grid, 4);
    // Every job of every trial completed, faults or not.
    for t in &r.trials {
        assert_eq!(t.downloads, 8, "trial {} lost jobs", t.spec.cell.label());
    }
    // The fault axis is live: cache-outage cells actually applied
    // their CacheDown events mid-run.
    let outage_faults: u64 = r
        .trials
        .iter()
        .filter(|t| t.spec.cell.fault_profile == FaultProfile::CacheOutage)
        .map(|t| t.faults_applied)
        .sum();
    assert!(
        outage_faults > 0,
        "cache-outage cells never applied their fault"
    );
    let none_faults: u64 = r
        .trials
        .iter()
        .filter(|t| t.spec.cell.fault_profile == FaultProfile::None)
        .map(|t| t.faults_applied)
        .sum();
    assert_eq!(none_faults, 0, "fault-free cells must stay fault-free");
    // The frontier pairs every stash cell with its http twin.
    let frontier = paper::frontier_table(&r);
    assert_eq!(frontier.rows.len(), r.cells.len() / 2);
}

#[test]
fn proxy_vs_stash_preset_reproduces_table3() {
    let preset = GridSpec::proxy_vs_stash();
    assert!(preset.table3_cell, "preset must carry the Table 3 cell");
    let sweep = run_grid(&paper_federation(), &preset, 4);
    let cell = sweep.table3.as_ref().expect("preset ran the Table 3 cell");

    // The cell must match a direct §4.1 scenario run *exactly* — the
    // sweep runs the same deterministic scenario on a fresh paper
    // federation, so every percent-difference agrees to the bit.
    let direct = scenario::run(paper_federation(), &ScenarioConfig::default());
    assert_eq!(cell.rows.len(), COMPUTE_SITES.len());
    for (row, site) in cell.rows.iter().zip(COMPUTE_SITES.iter()) {
        assert_eq!(&row.site, site);
        assert_eq!(
            row.pct_2_3gb,
            direct.pct_difference(site, "p95"),
            "{site} 2.3GB cell"
        );
        assert_eq!(
            row.pct_10gb,
            direct.pct_difference(site, "f10g"),
            "{site} 10GB cell"
        );
    }
    // And the headline signs survive inside the sweep: Colorado's
    // proxy wins big, Syracuse's local cache wins at 10 GB (Table 3).
    let get = |site: &str| cell.rows.iter().find(|r| r.site == site).unwrap();
    assert!(get("colorado").pct_2_3gb.unwrap() > 50.0);
    assert!(get("syracuse").pct_10gb.unwrap() < 0.0);

    // The campaign half of the preset produced the frontier around it.
    assert_eq!(sweep.trials.len(), preset.trial_count());
    assert!(!paper::frontier_table(&sweep).rows.is_empty());
}
