//! Integration: the policy-driven redirection layer (ISSUE 5).
//!
//! The contracts:
//!
//! 1. **Nearest is the legacy behavior, bit-for-bit** — the policy
//!    machinery under `policy = "nearest"` returns exactly what the
//!    hardcoded `nearest_cache_site_filtered` ladder returns, call for
//!    call, and a campaign run under the explicit policy digests equal
//!    to one built through the legacy default path.
//! 2. **Consistent hashing converges federation-wide** — one path maps
//!    to one cache no matter which site asks, excluded caches are ring
//!    holes (the walk continues), and on a Zipf-skewed shared
//!    namespace it fetches strictly fewer origin bytes than `nearest`.
//! 3. **Least-loaded spreads a burst** that `nearest` serialises onto
//!    one cache.
//! 4. **Tiered stops at the regional ring** — a site with no cache
//!    within `regional_km` streams from the origin instead of a WAN
//!    cache.
//! 5. The `policy` sweep axis runs every variant on the identical
//!    workload draw and surfaces the comparison in the frontier and
//!    policy tables.

use std::collections::HashMap;

use stashcache::client::Method;
use stashcache::config::defaults::{paper_federation, paper_workload, COMPUTE_SITES};
use stashcache::config::{
    FederationConfig, LinkProfile, OriginConfig, RedirectionConfig, ResilienceConfig, SiteConfig,
};
use stashcache::experiment::summary::digest_records;
use stashcache::experiment::{grid::FaultProfile, grid::SizeProfile, run_grid, GridSpec};
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::redirector::{PolicyKind, ALL_POLICIES};
use stashcache::report::paper;
use stashcache::sim::campaign::{self, CampaignConfig};
use stashcache::sim::workload::FileRef;
use stashcache::util::ByteSize;

fn file(path: &str, bytes: u64) -> FileRef {
    FileRef {
        path: path.into(),
        size: ByteSize(bytes),
        version: 1,
    }
}

fn small_campaign() -> CampaignConfig {
    CampaignConfig {
        sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
        jobs: 24,
        arrival_window_secs: 10.0,
        catalog_files: 32,
        zipf_s: 1.1,
        background_flows: 1,
        ..CampaignConfig::default()
    }
}

fn fed_with_policy(policy: PolicyKind) -> FedSim {
    let mut cfg = paper_federation();
    cfg.redirection.policy = policy;
    FedSim::build(cfg)
}

// --- contract 1: Nearest ≡ legacy ----------------------------------------

#[test]
fn nearest_policy_matches_legacy_ladder_call_for_call() {
    let mut fed = fed_with_policy(PolicyKind::Nearest);
    let none = HashMap::new();
    let sites: Vec<usize> = COMPUTE_SITES
        .iter()
        .map(|s| fed.topo.site_index(s).unwrap())
        .collect();
    let mut cache_sites: Vec<usize> = fed.caches.keys().copied().collect();
    cache_sites.sort_unstable();
    for &site in &sites {
        // No exclusions, then every ladder depth: knocking out the
        // current best repeatedly must walk both APIs identically.
        let mut excluded: Vec<usize> = Vec::new();
        loop {
            let legacy = fed.nearest_cache_site_filtered(site, &excluded);
            let policy = fed.select_cache(site, "/ospool/gwosc/data/f000000.dat", &excluded, &none);
            assert_eq!(
                legacy, policy,
                "site {site} excluded {excluded:?}: legacy {legacy:?} vs policy {policy:?}"
            );
            match legacy {
                Some(best) => excluded.push(best),
                None => break,
            }
        }
        assert_eq!(excluded.len(), cache_sites.len(), "walked the whole ladder");
    }
}

#[test]
fn explicit_nearest_campaign_is_bit_identical_to_default_path() {
    // Legacy default path: no [redirection] table at all.
    let default_cfg = paper_federation();
    assert_eq!(default_cfg.redirection, RedirectionConfig::default());
    let a = campaign::run(default_cfg, &small_campaign());

    // Explicit `policy = "nearest"` through the config surface.
    let mut explicit_cfg = paper_federation();
    explicit_cfg.redirection.policy = PolicyKind::Nearest;
    let b = campaign::run(explicit_cfg, &small_campaign());

    assert_eq!(a.records, b.records, "record streams must be identical");
    assert_eq!(digest_records(&a.records), digest_records(&b.records));
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.peak_concurrent, b.peak_concurrent);
}

// --- satellite: tie-breaking is pinned ------------------------------------

/// Two caches at *identical* coordinates plus one compute site. The
/// geo scores tie exactly (same haversine, both unloaded), so the
/// pinned order must win: (score, geo index), where the geo index is
/// the config's site order.
fn twin_cache_config(first: &str, second: &str) -> FederationConfig {
    let cache_site = |name: &str| SiteConfig {
        name: name.into(),
        lat: 40.0,
        lon: -100.0,
        worker_slots: 0,
        links: LinkProfile::default(),
        proxy: None,
        cache: Some(Default::default()),
    };
    let client = SiteConfig {
        name: "client".into(),
        lat: 30.0,
        lon: -90.0,
        worker_slots: 4,
        links: LinkProfile::default(),
        proxy: Some(Default::default()),
        cache: None,
    };
    FederationConfig {
        name: "twins".into(),
        seed: 1,
        redirector_instances: 2,
        redirection: RedirectionConfig::default(),
        resilience: ResilienceConfig::default(),
        sites: vec![cache_site(first), cache_site(second), client],
        origins: vec![OriginConfig {
            name: "origin".into(),
            site: "client".into(),
            prefix: "/ospool/gwosc".into(),
        }],
        workload: paper_workload(),
    }
}

#[test]
fn equal_distance_caches_tie_break_on_config_order() {
    for (first, second) in [("twin-a", "twin-b"), ("twin-b", "twin-a")] {
        let mut fed = FedSim::build(twin_cache_config(first, second));
        let client = fed.topo.site_index("client").unwrap();
        let expect = fed.topo.site_index(first).unwrap();
        let pick = fed.nearest_cache_site(client);
        assert_eq!(
            pick, expect,
            "first-configured cache must win the tie ({first} before {second})"
        );
        // Deterministic across repeated calls, and identical through
        // the policy layer.
        assert_eq!(fed.nearest_cache_site(client), pick);
        assert_eq!(
            fed.select_cache(client, "/ospool/gwosc/f", &[], &HashMap::new()),
            Some(pick)
        );
        // Excluding the winner falls to its twin.
        assert_eq!(
            fed.nearest_cache_site_filtered(client, &[pick]),
            Some(fed.topo.site_index(second).unwrap())
        );
    }
}

// --- contract 2: consistent hashing ---------------------------------------

#[test]
fn consistent_hash_converges_federation_wide() {
    let mut fed = fed_with_policy(PolicyKind::ConsistentHash);
    let none = HashMap::new();
    let sites: Vec<usize> = COMPUTE_SITES
        .iter()
        .map(|s| fed.topo.site_index(s).unwrap())
        .collect();
    let mut owners = std::collections::HashSet::new();
    for i in 0..16 {
        let path = format!("/ospool/gwosc/data/f{i:06}.dat");
        let owner = fed.select_cache(sites[0], &path, &[], &none);
        assert!(owner.is_some(), "ring covers every path");
        for &site in &sites[1..] {
            assert_eq!(
                fed.select_cache(site, &path, &[], &none),
                owner,
                "{path} must map to one cache from every site"
            );
        }
        owners.insert(owner.unwrap());
    }
    assert!(
        owners.len() > 1,
        "16 paths must shard over more than one cache, got {owners:?}"
    );
}

#[test]
fn consistent_hash_excluded_cache_is_a_ring_hole() {
    let mut fed = fed_with_policy(PolicyKind::ConsistentHash);
    let none = HashMap::new();
    let site = fed.topo.site_index("syracuse").unwrap();
    let path = "/ospool/gwosc/data/f000001.dat";
    let owner = fed.select_cache(site, path, &[], &none).unwrap();
    let successor = fed.select_cache(site, path, &[owner], &none).unwrap();
    assert_ne!(owner, successor, "hole walks to the next ring owner");
    // The walk is stable: excluding unrelated caches does not move the
    // owner.
    let unrelated: Vec<usize> = fed
        .caches
        .keys()
        .copied()
        .filter(|&s| s != owner && s != successor)
        .take(2)
        .collect();
    assert_eq!(fed.select_cache(site, path, &unrelated, &none), Some(owner));
    // Every cache excluded ⇒ origin fallback.
    let all: Vec<usize> = fed.caches.keys().copied().collect();
    assert_eq!(fed.select_cache(site, path, &all, &none), None);
}

#[test]
fn consistent_hash_campaign_is_deterministic() {
    let run = || {
        let mut fed = fed_with_policy(PolicyKind::ConsistentHash);
        digest_records(&campaign::run_on(&mut fed, &small_campaign()).records)
    };
    assert_eq!(run(), run(), "same seed ⇒ identical records under CH");
}

// --- contract 3: least-loaded ---------------------------------------------

/// How many caches saw any request during a run.
fn caches_used(fed: &FedSim) -> usize {
    fed.caches.values().filter(|c| c.stats.requests > 0).count()
}

#[test]
fn least_loaded_prefers_idle_neighbours() {
    // Deterministic view-level check: with the local cache busy, the
    // policy must pick an idle cache from the nearest-k pool, and
    // release of the load restores the local choice.
    let mut fed = fed_with_policy(PolicyKind::LeastLoaded);
    let syr = fed.topo.site_index("syracuse").unwrap();
    let mut in_flight: HashMap<usize, u64> = HashMap::new();
    let first = fed.select_cache(syr, "/p", &[], &in_flight).unwrap();
    assert_eq!(
        first,
        fed.nearest_cache_site(syr),
        "an idle federation degenerates to nearest"
    );
    in_flight.insert(first, 1);
    let second = fed.select_cache(syr, "/p", &[], &in_flight).unwrap();
    assert_ne!(second, first, "busy local cache loses to an idle neighbour");
    in_flight.insert(second, 1);
    let third = fed.select_cache(syr, "/p", &[], &in_flight).unwrap();
    assert!(third != first && third != second, "k=3 pool spreads three ways");
    in_flight.clear();
    assert_eq!(fed.select_cache(syr, "/p", &[], &in_flight), Some(first));
}

#[test]
fn least_loaded_spreads_a_burst_nearest_serialises() {
    // One site, 32 jobs inside 50 ms — arrival gaps are far below any
    // transfer time, so sessions overlap massively. Under `nearest`
    // every session piles onto the local cache (storage load is
    // negligible, so the GeoIP penalty never moves); under
    // `least-loaded` the in-flight counts push the burst across the
    // k nearest caches.
    let burst = CampaignConfig {
        sites: vec!["syracuse".into()],
        jobs: 32,
        arrival_window_secs: 0.05,
        catalog_files: 64,
        zipf_s: 0.0, // near-uniform file draws: mostly cold misses
        background_flows: 0,
        ..CampaignConfig::default()
    };

    let mut nearest_fed = fed_with_policy(PolicyKind::Nearest);
    let r = campaign::run_on(&mut nearest_fed, &burst);
    assert_eq!(r.records.len(), 32);
    assert_eq!(
        caches_used(&nearest_fed),
        1,
        "nearest must serialise the burst onto the local cache"
    );

    let mut ll_fed = fed_with_policy(PolicyKind::LeastLoaded);
    let r = campaign::run_on(&mut ll_fed, &burst);
    assert_eq!(r.records.len(), 32);
    assert!(
        caches_used(&ll_fed) >= 2,
        "least-loaded must spread the burst, used {}",
        caches_used(&ll_fed)
    );
}

// --- contract 4: tiered ---------------------------------------------------

#[test]
fn tiered_falls_to_origin_outside_the_regional_ring() {
    // A 1 km ring: only a site-local cache qualifies. Colorado has no
    // local cache, so its downloads must stream from the origin.
    let mut cfg = paper_federation();
    cfg.redirection.policy = PolicyKind::Tiered;
    cfg.redirection.regional_km = 1.0;
    let mut fed = FedSim::build(cfg);
    let colorado = fed.topo.site_index("colorado").unwrap();
    let fr = file("/ospool/gwosc/data/t0.dat", 50_000_000);
    let rec = fed.download(colorado, &fr, DownloadMethod::Stash);
    assert_eq!(rec.method, Method::HttpOrigin, "no regional cache ⇒ origin");
    assert!(!rec.cache_hit);

    // Syracuse hosts a cache: tier 1 serves it, and the second pull
    // is a local hit.
    let syr = fed.topo.site_index("syracuse").unwrap();
    let fr = file("/ospool/gwosc/data/t1.dat", 50_000_000);
    let cold = fed.download(syr, &fr, DownloadMethod::Stash);
    assert_eq!(cold.method, Method::Xrootd);
    let hot = fed.download(syr, &fr, DownloadMethod::Stash);
    assert!(hot.cache_hit, "tier-1 local cache must be warm");
}

#[test]
fn tiered_default_ring_reaches_a_regional_cache() {
    // With the default 2000 km ring Colorado reaches the midwest
    // caches and never pays the origin path.
    let mut fed = fed_with_policy(PolicyKind::Tiered);
    let colorado = fed.topo.site_index("colorado").unwrap();
    let fr = file("/ospool/gwosc/data/t2.dat", 50_000_000);
    let rec = fed.download(colorado, &fr, DownloadMethod::Stash);
    assert_eq!(rec.method, Method::Xrootd, "regional cache serves colorado");
}

// --- contract 5: the policy sweep axis ------------------------------------

fn policy_axis_grid() -> GridSpec {
    GridSpec {
        name: "policy-acceptance".into(),
        root_seed: 20190728,
        reps: 1,
        methods: vec![DownloadMethod::Stash, DownloadMethod::HttpProxy],
        capacity_scales: vec![1.0],
        jobs: vec![30],
        arrival_windows: vec![10.0],
        zipf_s: vec![1.5],
        size_profiles: vec![SizeProfile::Paper],
        fault_profiles: vec![FaultProfile::None],
        policies: ALL_POLICIES.to_vec(),
        deadline_factors: vec![0.0],
        breakers: vec![false],
        sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
        experiment: "gwosc".into(),
        catalog_files: 8,
        files_per_job: (1, 1),
        background_flows: 1,
        table3_cell: false,
    }
}

#[test]
fn policy_sweep_consistent_hash_fetches_fewer_origin_bytes_than_nearest() {
    let grid = policy_axis_grid();
    let results = run_grid(&paper_federation(), &grid, 2);
    assert_eq!(results.trials.len(), 2 * 4, "4 policies × stash/http");
    for t in &results.trials {
        assert_eq!(t.downloads, 30, "{} lost jobs", t.spec.cell.label());
    }

    let stash = |policy: PolicyKind| {
        results
            .trials
            .iter()
            .find(|t| {
                t.spec.cell.method == DownloadMethod::Stash && t.spec.cell.policy == policy
            })
            .expect("stash trial for policy")
    };
    let nearest = stash(PolicyKind::Nearest);
    let ch = stash(PolicyKind::ConsistentHash);
    // The headline: a Zipf-hot shared namespace across three sites,
    // each with a local cache. `nearest` fetches a hot file from the
    // origin once per site; sharding converges the federation on one
    // cache per file.
    assert!(
        ch.origin_bytes < nearest.origin_bytes,
        "consistent-hash must fetch strictly fewer origin bytes: {} vs {}",
        ch.origin_bytes,
        nearest.origin_bytes,
    );

    // The proxy path never consults the redirection layer: its four
    // policy variants (identical workload seeds) are bit-identical.
    let http_digests: Vec<u64> = results
        .trials
        .iter()
        .filter(|t| t.spec.cell.method == DownloadMethod::HttpProxy)
        .map(|t| t.records_digest)
        .collect();
    assert_eq!(http_digests.len(), 4);
    assert!(
        http_digests.iter().all(|&d| d == http_digests[0]),
        "http twins must not vary across policies"
    );

    // The comparison is surfaced: frontier rows carry the policy in
    // their cell label, and the policy table ranks every variant.
    let frontier_md = paper::frontier_table(&results).to_markdown();
    assert!(
        frontier_md.contains("policy=consistent-hash"),
        "frontier markdown must surface the policy axis:\n{frontier_md}"
    );
    assert!(frontier_md.contains("policy=nearest"));
    let policy_md = paper::policy_table(&results).to_markdown();
    assert_eq!(
        policy_md.matches("consistent-hash").count(),
        2,
        "policy table lists the stash and http consistent-hash cells:\n{policy_md}"
    );
}

#[test]
fn parallel_policy_sweep_is_bit_identical_to_serial() {
    let grid = policy_axis_grid();
    let serial = run_grid(&paper_federation(), &grid, 1);
    let parallel = run_grid(&paper_federation(), &grid, 4);
    assert_eq!(serial, parallel, "policy axis preserves sweep determinism");
}
