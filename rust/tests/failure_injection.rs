//! Failure injection: the paper's operational claims under faults.
//!
//! §1: "The resource owner may want to reclaim space from the
//! opportunistic user ... the resource provider can reclaim space in
//! the cache without worry of causing workflow failures" — eviction
//! and data-removal must degrade to origin fetches, never to errors.
//! §3: two redirectors run "in a round robin, high availability
//! configuration" — one instance down must be invisible to clients.

use stashcache::config::defaults::paper_federation;
use stashcache::config::CacheConfig;
use stashcache::federation::{DownloadMethod, FedSim};
use stashcache::sim::workload::FileRef;
use stashcache::util::ByteSize;

fn file(n: u64, mb: u64) -> FileRef {
    FileRef {
        path: format!("/ospool/minerva/data/fi{n:04}.dat"),
        size: ByteSize::mb(mb),
        version: 1,
    }
}

#[test]
fn redirector_instance_failure_is_transparent() {
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("nebraska").unwrap();
    // Kill instance 0 (of 2).
    fed.redirectors.set_healthy(0, false);
    for i in 0..6 {
        let rec = fed.download(site, &file(i, 50), DownloadMethod::Stash);
        assert!(rec.bytes > 0, "download {i} must succeed on the HA pair");
    }
    // All discovery went through instance 1.
    assert_eq!(fed.redirectors.instances[0].broadcasts, 0);
    assert!(fed.redirectors.instances[1].broadcasts > 0);
    // Recovery: bring 0 back, kill 1.
    fed.redirectors.set_healthy(0, true);
    fed.redirectors.set_healthy(1, false);
    let rec = fed.download(site, &file(99, 50), DownloadMethod::Stash);
    assert!(rec.bytes > 0, "failover back to instance 0");
}

#[test]
fn cache_eviction_under_pressure_never_fails_workflows() {
    // Tiny caches: every download evicts something; workflows still
    // complete (the §1 claim).
    let mut cfg = paper_federation();
    for s in &mut cfg.sites {
        if let Some(c) = &mut s.cache {
            *c = CacheConfig {
                capacity: ByteSize::mb(600),
                ..*c
            };
        }
    }
    let mut fed = FedSim::build(cfg);
    let site = fed.topo.site_index("syracuse").unwrap();
    for round in 0..3 {
        for i in 0..5 {
            let rec = fed.download(site, &file(i, 200), DownloadMethod::Stash);
            assert!(rec.bytes > 0, "round {round} file {i}");
        }
    }
    let cache_site = fed.nearest_cache_site(site);
    let c = &fed.caches[&cache_site];
    assert!(c.stats.evictions > 0, "pressure must evict");
    assert!(
        c.usage().as_u64() <= 600_000_000,
        "capacity respected: {}",
        c.usage()
    );
    // Everything was still delivered and monitored.
    assert_eq!(fed.aggregator.reports, 15); // 3 rounds × 5 files
}

#[test]
fn owner_reclaims_data_at_origin() {
    // The data owner deletes a file; cached copies still serve reads
    // (transient cache semantics), but a *new* file at the same path
    // with a new version fetches fresh content.
    let mut fed = FedSim::build(paper_federation());
    let site = fed.topo.site_index("chicago").unwrap();
    let f = file(1, 100);
    fed.download(site, &f, DownloadMethod::Stash);
    // Owner removes it from the origin.
    let oid = fed.namespace.resolve(&f.path).unwrap();
    fed.origins[oid.0].remove_file(&f.path);
    // Cached copy still serves (the cache is authoritative for its
    // transient copy — no workflow failure).
    let hot = fed.download(site, &f, DownloadMethod::Stash);
    assert!(hot.cache_hit, "cached copy survives origin removal");
}

#[test]
fn all_redirectors_down_is_detected() {
    let mut fed = FedSim::build(paper_federation());
    fed.redirectors.set_healthy(0, false);
    fed.redirectors.set_healthy(1, false);
    let err = fed.redirectors.locate(
        "/ospool/ligo/data/x.dat",
        &mut fed.origins,
        stashcache::util::SimTime::ZERO,
    );
    assert!(err.is_err(), "total redirector outage must surface");
}

#[test]
fn cache_abort_on_failed_fetch_releases_state() {
    // Direct state-machine check: a failed origin fetch must leave the
    // cache able to retry (no stuck in-flight chunks, no pins).
    use stashcache::cache::CacheServer;
    use stashcache::util::SimTime;
    let mut c = CacheServer::new(
        "t",
        CacheConfig {
            capacity: ByteSize::gb(1),
            ..CacheConfig::default()
        },
    );
    let plan = c.plan_read("/f", 0, 1_000_000, 1_000_000, 1, SimTime::ZERO);
    c.begin_fetch("/f", 1, &plan.fetch);
    c.abort_fetch("/f", 1, &plan.fetch); // origin died
    let retry = c.plan_read("/f", 0, 1_000_000, 1_000_000, 1, SimTime(1));
    assert_eq!(retry.fetch, plan.fetch, "retry can re-fetch everything");
    assert!(retry.join.is_empty(), "no phantom in-flight chunks");
}
