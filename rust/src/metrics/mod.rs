//! Lightweight metrics: counters and time series used by the drivers
//! and the report generators (e.g. the Fig 5 WAN bandwidth trace).

use crate::util::{ByteSize, SimTime};

/// Hard cap on retained buckets. A write past the cap first doubles
/// the bucket width (pair-merging counts, conserving every byte)
/// until the instant fits, so a year-long campaign holds at most this
/// many buckets instead of growing without bound.
pub const MAX_BUCKETS: usize = 4096;

/// A time-bucketed series of byte counts (bandwidth traces, weekly
/// usage). Bucket width is set at construction and doubles whenever
/// the series would exceed [`MAX_BUCKETS`].
#[derive(Debug, Clone)]
pub struct ByteSeries {
    bucket_secs: f64,
    buckets: Vec<u64>,
}

impl ByteSeries {
    pub fn new(bucket_secs: f64) -> Self {
        assert!(bucket_secs > 0.0);
        ByteSeries {
            bucket_secs,
            buckets: Vec::new(),
        }
    }

    /// Current bucket width (grows past [`MAX_BUCKETS`] coarsenings).
    pub fn bucket_secs(&self) -> f64 {
        self.bucket_secs
    }

    fn index(&self, at: SimTime) -> usize {
        (at.as_secs_f64() / self.bucket_secs) as usize
    }

    /// Index of `at`, coarsening the series until it fits the cap.
    fn slot(&mut self, at: SimTime) -> usize {
        let mut i = self.index(at);
        while i >= MAX_BUCKETS {
            self.coarsen();
            i = self.index(at);
        }
        if i >= self.buckets.len() {
            self.buckets.resize(i + 1, 0);
        }
        i
    }

    /// Double the bucket width, summing adjacent pairs — exact on the
    /// u64 counts, so `total()` is invariant across coarsening.
    fn coarsen(&mut self) {
        self.bucket_secs *= 2.0;
        let mut merged = Vec::with_capacity(self.buckets.len().div_ceil(2));
        for pair in self.buckets.chunks(2) {
            merged.push(pair.iter().sum());
        }
        self.buckets = merged;
    }

    /// Add bytes at an instant.
    pub fn add(&mut self, at: SimTime, bytes: u64) {
        let i = self.slot(at);
        self.buckets[i] += bytes;
    }

    /// Spread bytes uniformly across `[start, end)` (a flow's lifetime).
    pub fn add_spread(&mut self, start: SimTime, end: SimTime, bytes: u64) {
        if end <= start || bytes == 0 {
            return self.add(start, bytes);
        }
        // Fit the far edge first: any coarsening this triggers also
        // rescales where `start` lands, so compute `i0` afterwards.
        let i1 = self.slot(end);
        let i0 = self.index(start);
        if i0 == i1 {
            self.buckets[i0] += bytes;
            return;
        }
        let total_secs = (end - start).as_secs_f64();
        let mut assigned = 0u64;
        for i in i0..=i1 {
            let b_start = i as f64 * self.bucket_secs;
            let b_end = b_start + self.bucket_secs;
            let lo = b_start.max(start.as_secs_f64());
            let hi = b_end.min(end.as_secs_f64());
            let share = ((hi - lo) / total_secs * bytes as f64) as u64;
            self.buckets[i] += share;
            assigned += share;
        }
        // Rounding remainder lands in the final bucket.
        self.buckets[i1] += bytes - assigned;
    }

    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// (bucket start seconds, bytes) pairs.
    pub fn points(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, &b)| (i as f64 * self.bucket_secs, b))
    }

    /// Average rate in a bucket, bytes/sec.
    pub fn rate_at(&self, bucket: usize) -> f64 {
        self.buckets.get(bucket).copied().unwrap_or(0) as f64 / self.bucket_secs
    }

    pub fn total(&self) -> ByteSize {
        ByteSize(self.buckets.iter().sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_index() {
        let mut s = ByteSeries::new(10.0);
        s.add(SimTime::from_secs_f64(5.0), 100);
        s.add(SimTime::from_secs_f64(15.0), 200);
        s.add(SimTime::from_secs_f64(15.5), 50);
        assert_eq!(s.len(), 2);
        let pts: Vec<(f64, u64)> = s.points().collect();
        assert_eq!(pts, vec![(0.0, 100), (10.0, 250)]);
        assert_eq!(s.total(), ByteSize(350));
        assert_eq!(s.rate_at(1), 25.0);
    }

    #[test]
    fn spread_conserves_bytes() {
        let mut s = ByteSeries::new(1.0);
        s.add_spread(
            SimTime::from_secs_f64(0.5),
            SimTime::from_secs_f64(3.5),
            3_000,
        );
        assert_eq!(s.total(), ByteSize(3_000));
        assert_eq!(s.len(), 4);
        // Middle buckets get a full second's share each (1000).
        let pts: Vec<(f64, u64)> = s.points().collect();
        assert_eq!(pts[1].1, 1_000);
        assert_eq!(pts[2].1, 1_000);
    }

    #[test]
    fn spread_degenerate_interval() {
        let mut s = ByteSeries::new(1.0);
        s.add_spread(SimTime::from_secs_f64(2.0), SimTime::from_secs_f64(2.0), 77);
        assert_eq!(s.total(), ByteSize(77));
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn property_spread_conserves() {
        use crate::util::prop::check;
        check("byteseries conservation", 60, |g| {
            let mut s = ByteSeries::new(g.f64(0.5, 30.0));
            let mut expected = 0u64;
            for _ in 0..g.usize(1, 20) {
                let a = g.f64(0.0, 1_000.0);
                let b = a + g.f64(0.0, 500.0);
                let bytes = g.u64(0, 1_000_000);
                s.add_spread(SimTime::from_secs_f64(a), SimTime::from_secs_f64(b), bytes);
                expected += bytes;
            }
            (
                s.total().as_u64() == expected,
                format!("total {} expected {expected}", s.total()),
            )
        });
    }

    #[test]
    fn growth_is_bounded_by_coarsening() {
        // A year of half-second buckets would be ~63M entries; the cap
        // forces the width up until the series fits.
        let mut s = ByteSeries::new(0.5);
        let year = 365.0 * 86_400.0;
        s.add(SimTime::from_secs_f64(1.0), 100);
        s.add(SimTime::from_secs_f64(year), 200);
        assert!(s.len() <= MAX_BUCKETS, "len {} over cap", s.len());
        assert!(s.bucket_secs() > 0.5, "width must have doubled");
        assert_eq!(s.total(), ByteSize(300), "coarsening loses no bytes");
    }

    #[test]
    fn property_conservation_across_coarsening() {
        use crate::util::prop::check;
        // Same conservation law, but with instants scattered far
        // enough apart that every case crosses the coarsening path
        // (cap × initial width is ~2048 s here; spans reach ~2M s).
        check("byteseries conservation under coarsening", 60, |g| {
            let mut s = ByteSeries::new(g.f64(0.5, 2.0));
            let mut expected = 0u64;
            for _ in 0..g.usize(2, 24) {
                let a = g.f64(0.0, 2.0e6);
                let b = a + g.f64(0.0, 5_000.0);
                let bytes = g.u64(0, 1_000_000);
                s.add_spread(SimTime::from_secs_f64(a), SimTime::from_secs_f64(b), bytes);
                expected += bytes;
            }
            let ok = s.total().as_u64() == expected && s.len() <= MAX_BUCKETS;
            (
                ok,
                format!(
                    "total {} expected {expected}, len {} (cap {MAX_BUCKETS}), width {}s",
                    s.total(),
                    s.len(),
                    s.bucket_secs()
                ),
            )
        });
    }
}
