//! Lightweight metrics: counters and time series used by the drivers
//! and the report generators (e.g. the Fig 5 WAN bandwidth trace).

use crate::util::{ByteSize, SimTime};

/// A time-bucketed series of byte counts (bandwidth traces, weekly
/// usage). Bucket width is fixed at construction.
#[derive(Debug, Clone)]
pub struct ByteSeries {
    bucket_secs: f64,
    buckets: Vec<u64>,
}

impl ByteSeries {
    pub fn new(bucket_secs: f64) -> Self {
        assert!(bucket_secs > 0.0);
        ByteSeries {
            bucket_secs,
            buckets: Vec::new(),
        }
    }

    fn index(&self, at: SimTime) -> usize {
        (at.as_secs_f64() / self.bucket_secs) as usize
    }

    /// Add bytes at an instant.
    pub fn add(&mut self, at: SimTime, bytes: u64) {
        let i = self.index(at);
        if i >= self.buckets.len() {
            self.buckets.resize(i + 1, 0);
        }
        self.buckets[i] += bytes;
    }

    /// Spread bytes uniformly across `[start, end)` (a flow's lifetime).
    pub fn add_spread(&mut self, start: SimTime, end: SimTime, bytes: u64) {
        if end <= start || bytes == 0 {
            return self.add(start, bytes);
        }
        let (i0, i1) = (self.index(start), self.index(end));
        if i1 >= self.buckets.len() {
            self.buckets.resize(i1 + 1, 0);
        }
        if i0 == i1 {
            self.buckets[i0] += bytes;
            return;
        }
        let total_secs = (end - start).as_secs_f64();
        let mut assigned = 0u64;
        for i in i0..=i1 {
            let b_start = i as f64 * self.bucket_secs;
            let b_end = b_start + self.bucket_secs;
            let lo = b_start.max(start.as_secs_f64());
            let hi = b_end.min(end.as_secs_f64());
            let share = ((hi - lo) / total_secs * bytes as f64) as u64;
            self.buckets[i] += share;
            assigned += share;
        }
        // Rounding remainder lands in the final bucket.
        self.buckets[i1] += bytes - assigned;
    }

    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// (bucket start seconds, bytes) pairs.
    pub fn points(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, &b)| (i as f64 * self.bucket_secs, b))
    }

    /// Average rate in a bucket, bytes/sec.
    pub fn rate_at(&self, bucket: usize) -> f64 {
        self.buckets.get(bucket).copied().unwrap_or(0) as f64 / self.bucket_secs
    }

    pub fn total(&self) -> ByteSize {
        ByteSize(self.buckets.iter().sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_index() {
        let mut s = ByteSeries::new(10.0);
        s.add(SimTime::from_secs_f64(5.0), 100);
        s.add(SimTime::from_secs_f64(15.0), 200);
        s.add(SimTime::from_secs_f64(15.5), 50);
        assert_eq!(s.len(), 2);
        let pts: Vec<(f64, u64)> = s.points().collect();
        assert_eq!(pts, vec![(0.0, 100), (10.0, 250)]);
        assert_eq!(s.total(), ByteSize(350));
        assert_eq!(s.rate_at(1), 25.0);
    }

    #[test]
    fn spread_conserves_bytes() {
        let mut s = ByteSeries::new(1.0);
        s.add_spread(
            SimTime::from_secs_f64(0.5),
            SimTime::from_secs_f64(3.5),
            3_000,
        );
        assert_eq!(s.total(), ByteSize(3_000));
        assert_eq!(s.len(), 4);
        // Middle buckets get a full second's share each (1000).
        let pts: Vec<(f64, u64)> = s.points().collect();
        assert_eq!(pts[1].1, 1_000);
        assert_eq!(pts[2].1, 1_000);
    }

    #[test]
    fn spread_degenerate_interval() {
        let mut s = ByteSeries::new(1.0);
        s.add_spread(SimTime::from_secs_f64(2.0), SimTime::from_secs_f64(2.0), 77);
        assert_eq!(s.total(), ByteSize(77));
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn property_spread_conserves() {
        use crate::util::prop::check;
        check("byteseries conservation", 60, |g| {
            let mut s = ByteSeries::new(g.f64(0.5, 30.0));
            let mut expected = 0u64;
            for _ in 0..g.usize(1, 20) {
                let a = g.f64(0.0, 1_000.0);
                let b = a + g.f64(0.0, 500.0);
                let bytes = g.u64(0, 1_000_000);
                s.add_spread(SimTime::from_secs_f64(a), SimTime::from_secs_f64(b), bytes);
                expected += bytes;
            }
            (
                s.total().as_u64() == expected,
                format!("total {} expected {expected}", s.total()),
            )
        });
    }
}
