//! The paper's §4.1 benchmark scenario (Figures 6-8, Table 3).
//!
//! "We created an HTCondor DAGMan workflow to submit the jobs to each
//! site, without two sites running at the same time. ... Each job
//! downloads all files four times. The first time it uses curl to
//! download through the HTTP cache [cold]. It then downloads the file
//! again through the HTTP proxy which will be a cache hit. The third
//! download is through stashcp and the StashCache federation [cold].
//! The fourth download is again using stashcp, but it should be
//! cached."
//!
//! The test dataset is the Table 2 percentile files plus a 10 GB file,
//! hosted on the Stash origin at Chicago. Sites run serially (no
//! competition at the origin between sites), but the origin's DTN link
//! carries background load throughout (§4.1's "realistic
//! infrastructure conditions").

use crate::client::TransferRecord;
use crate::config::defaults::{self, COMPUTE_SITES};
use crate::config::FederationConfig;
use crate::federation::{DownloadMethod, FedSim, DEFAULT_BACKGROUND_FLOWS};
use crate::sim::workload::FileRef;
use crate::util::ByteSize;

/// One measured download.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub site: String,
    pub file_label: String,
    pub size: ByteSize,
    /// "http" (curl via proxy) or "stash" (stashcp via cache).
    pub tool: &'static str,
    /// First (cold) or second (hot) download with that tool.
    pub pass: &'static str,
    pub record: TransferRecord,
}

impl Measurement {
    pub fn rate_mbps(&self) -> f64 {
        self.record.rate_mbps()
    }
    pub fn secs(&self) -> f64 {
        self.record.duration.as_secs_f64()
    }
}

/// Scenario knobs.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Sites to test, in Table 3 order.
    pub sites: Vec<String>,
    /// (label, size) of each test file (§4.1's percentile set).
    pub files: Vec<(String, ByteSize)>,
    /// Background flows per origin DTN link.
    pub background_flows: usize,
    /// Repeats of the whole 4-download cycle per (site, file).
    pub repeats: usize,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            sites: COMPUTE_SITES.iter().map(|s| s.to_string()).collect(),
            files: defaults::test_file_sizes(),
            background_flows: DEFAULT_BACKGROUND_FLOWS,
            repeats: 1,
        }
    }
}

/// Scenario results: every measurement, queryable per figure/table.
#[derive(Debug, Default)]
pub struct ScenarioResults {
    pub measurements: Vec<Measurement>,
}

impl ScenarioResults {
    /// Mean download rate in Mbit/s for a (site, file, tool, pass).
    pub fn rate(&self, site: &str, file_label: &str, tool: &str, pass: &str) -> Option<f64> {
        let rates: Vec<f64> = self
            .measurements
            .iter()
            .filter(|m| {
                m.site == site && m.file_label == file_label && m.tool == tool && m.pass == pass
            })
            .map(Measurement::rate_mbps)
            .collect();
        (!rates.is_empty()).then(|| rates.iter().sum::<f64>() / rates.len() as f64)
    }

    /// Mean duration (s) over both passes of a tool — the quantity
    /// Table 3 compares.
    pub fn mean_secs(&self, site: &str, file_label: &str, tool: &str) -> Option<f64> {
        let secs: Vec<f64> = self
            .measurements
            .iter()
            .filter(|m| m.site == site && m.file_label == file_label && m.tool == tool)
            .map(Measurement::secs)
            .collect();
        (!secs.is_empty()).then(|| secs.iter().sum::<f64>() / secs.len() as f64)
    }

    /// Table 3's cell: percent difference in download time,
    /// StashCache vs HTTP proxy. Negative ⇒ StashCache is faster.
    pub fn pct_difference(&self, site: &str, file_label: &str) -> Option<f64> {
        let http = self.mean_secs(site, file_label, "http")?;
        let stash = self.mean_secs(site, file_label, "stash")?;
        Some((stash - http) / http * 100.0)
    }
}

/// Run the full §4.1 scenario on a fresh federation.
pub fn run(cfg: FederationConfig, scenario: &ScenarioConfig) -> ScenarioResults {
    let mut fed = FedSim::build(cfg);
    run_on(&mut fed, scenario)
}

/// Run the scenario on an existing federation (callers can inject
/// failures — [`FedSim::inject_faults`] — or swap backends first; the
/// serial downloads apply scheduled faults as they come due, so the
/// §4.1 cycle keeps completing through cache outages).
pub fn run_on(fed: &mut FedSim, scenario: &ScenarioConfig) -> ScenarioResults {
    fed.start_background_load(scenario.background_flows);
    let mut results = ScenarioResults::default();

    for site_name in &scenario.sites {
        let site = fed
            .topo
            .site_index(site_name)
            .unwrap_or_else(|| panic!("unknown site {site_name}"));
        for rep in 0..scenario.repeats {
            for (label, size) in &scenario.files {
                // A unique path per (site, repeat, file): each cycle's
                // first download must be a genuine cold miss ("it is
                // assumed and verified that the first time is a cache
                // miss", §4.1).
                let file = FileRef {
                    path: format!(
                        "/osgconnect/public/dweitzel/pearc19/{site_name}/r{rep}/{label}.dat"
                    ),
                    size: *size,
                    version: 1,
                };
                let passes: [(&str, DownloadMethod, &str); 4] = [
                    ("http", DownloadMethod::HttpProxy, "cold"),
                    ("http", DownloadMethod::HttpProxy, "hot"),
                    ("stash", DownloadMethod::Stash, "cold"),
                    ("stash", DownloadMethod::Stash, "hot"),
                ];
                for (tool, method, pass) in passes {
                    let record = fed.download(site, &file, method);
                    results.measurements.push(Measurement {
                        site: site_name.clone(),
                        file_label: label.clone(),
                        size: *size,
                        tool,
                        pass,
                        record,
                    });
                }
            }
        }
    }
    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;

    fn quick_results() -> ScenarioResults {
        // Two sites, three sizes — fast but covers the shape.
        let scenario = ScenarioConfig {
            sites: vec!["syracuse".into(), "colorado".into()],
            files: vec![
                ("p01".into(), ByteSize(5_797)),
                ("p95".into(), ByteSize(2_335_000_000)),
                ("f10g".into(), ByteSize::gb(10)),
            ],
            ..ScenarioConfig::default()
        };
        run(paper_federation(), &scenario)
    }

    #[test]
    fn four_downloads_per_site_file() {
        let r = quick_results();
        assert_eq!(r.measurements.len(), 2 * 3 * 4);
    }

    #[test]
    fn http_hot_faster_than_cold_for_cacheable() {
        let r = quick_results();
        // 5.797 KB is cacheable by the proxy.
        let cold = r.rate("syracuse", "p01", "http", "cold").unwrap();
        let hot = r.rate("syracuse", "p01", "http", "hot").unwrap();
        assert!(hot >= cold, "hot {hot} >= cold {cold}");
    }

    #[test]
    fn stash_hot_always_at_least_cold() {
        // §5: "the cached StashCache is always better than the
        // non-cached".
        let r = quick_results();
        for site in ["syracuse", "colorado"] {
            for f in ["p01", "p95", "f10g"] {
                let cold = r.rate(site, f, "stash", "cold").unwrap();
                let hot = r.rate(site, f, "stash", "hot").unwrap();
                assert!(
                    hot >= cold * 0.999,
                    "{site}/{f}: hot {hot} < cold {cold}"
                );
            }
        }
    }

    #[test]
    fn small_files_favor_http_everywhere() {
        // Fig 8's universal result.
        let r = quick_results();
        for site in ["syracuse", "colorado"] {
            let d = r.pct_difference(site, "p01").unwrap();
            assert!(d > 50.0, "{site}: small file pct diff {d} should be ≫ 0");
        }
    }

    #[test]
    fn scenario_survives_cache_outage() {
        use crate::fault::{FaultKind, FaultTimeline};
        use crate::util::SimTime;
        let mut fed = FedSim::build(paper_federation());
        let syr = fed.topo.site_index("syracuse").unwrap();
        // Syracuse's cache dies almost immediately and never recovers:
        // the stash passes must fail over to a remote cache, not error.
        let mut faults = FaultTimeline::new();
        faults.push(
            SimTime::from_secs_f64(1.0),
            FaultKind::CacheDown { site: syr },
        );
        fed.inject_faults(&faults).expect("valid fault timeline");
        let scenario = ScenarioConfig {
            sites: vec!["syracuse".into()],
            files: vec![("p50".into(), ByteSize(467_852_000))],
            repeats: 1,
            ..ScenarioConfig::default()
        };
        let r = run_on(&mut fed, &scenario);
        assert_eq!(r.measurements.len(), 4);
        assert!(r.measurements.iter().all(|m| m.record.bytes > 0));
        // The hot stash pass still hits — the *remote* cache kept it.
        let hot = r
            .measurements
            .iter()
            .find(|m| m.tool == "stash" && m.pass == "hot")
            .unwrap();
        assert!(hot.record.cache_hit, "failover cache serves the hot pass");
        assert!(fed.faults.is_cache_down(syr));
    }

    #[test]
    fn colorado_positive_syracuse_negative_at_10g() {
        // Table 3's key shape.
        let r = quick_results();
        let colorado = r.pct_difference("colorado", "f10g").unwrap();
        let syracuse = r.pct_difference("syracuse", "f10g").unwrap();
        assert!(colorado > 50.0, "colorado 10G: {colorado}");
        assert!(syracuse < 0.0, "syracuse 10G: {syracuse}");
    }
}
