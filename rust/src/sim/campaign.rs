//! Concurrent campaign scenario: Poisson job arrivals at many sites,
//! hundreds of overlapping downloads through one
//! [`SessionEngine`](crate::federation::driver::SessionEngine).
//!
//! The §4.1 scenario is deliberately serial ("without two sites
//! running at the same time"); production StashCache is the opposite —
//! whole analysis campaigns hammer the federation at once (the CDN
//! follow-on work, arXiv:2007.01408, scales exactly this). A campaign
//! models that: each site receives a Poisson stream of jobs, each job
//! downloads Zipf-popular files from an experiment's catalog, and all
//! sessions advance concurrently on the shared flow-level network, so
//! cache coalescing, link contention, and origin DTN saturation all
//! interact the way the event-driven engine allows and the old
//! blocking downloader never could.
//!
//! Everything derives from `Pcg64` streams seeded by
//! `(federation seed) ^ (campaign seed)`, so identical configs give
//! bit-identical [`TransferRecord`] streams.
//!
//! [`run_with_faults`] is the campaign-with-faults mode: a
//! [`FaultTimeline`] of cache/link/origin/redirector outages applies
//! mid-run, sessions fail over, and the results carry the availability
//! ledger (per-cache downtime, failovers, retries, aborted bytes) next
//! to the usual records. Fault application is deterministic, so chaos
//! runs are bit-reproducible too.

use crate::client::TransferRecord;
use crate::config::defaults::COMPUTE_SITES;
use crate::config::FederationConfig;
use crate::fault::{FaultEvent, FaultTimeline};
use crate::federation::driver::{EngineStats, EpochStats, SessionEngine};
use crate::federation::{DownloadMethod, FedSim};
use crate::monitoring::availability::{AvailabilityReport, CacheAvailability};
use crate::sim::workload::Catalog;
use crate::telemetry::{MetricsRegistry, PhaseLabel, TelemetrySnapshot, TraceRow};
use crate::util::{Duration, Pcg64, SimTime, Zipf};

/// Campaign knobs.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Compute sites receiving job streams.
    pub sites: Vec<String>,
    /// Total jobs, distributed round-robin across `sites`.
    pub jobs: usize,
    /// Per-site Poisson arrival window: a site with `k` jobs draws
    /// exponential gaps at rate `k / window` (≈ all arrivals inside
    /// the window, so jobs overlap heavily when transfers are slower
    /// than the window).
    pub arrival_window_secs: f64,
    /// Files each job downloads (inclusive range, Zipf-popular).
    pub files_per_job: (u64, u64),
    /// Zipf catalog support (truncated to the workload catalog size).
    pub catalog_files: u64,
    /// Zipf skew (≥ 0; higher ⇒ hotter head, more coalescing).
    pub zipf_s: f64,
    /// Experiment whose catalog (and origin) the campaign reads.
    pub experiment: String,
    /// Per-site experiment override: when non-empty, the site at
    /// position `i` in `sites` reads `site_experiments[i % len]`'s
    /// catalog instead of `experiment`. Cold multi-origin campaigns
    /// use this so each site's misses pull from its own origin DTN —
    /// with origins placed at distinct sites the cold traffic forms
    /// disjoint origin components the epoch planner can shard.
    pub site_experiments: Vec<String>,
    /// Background flows per origin DTN link.
    pub background_flows: usize,
    pub method: DownloadMethod,
    /// Extra seed XORed with the federation seed.
    pub seed: u64,
    /// Keep the last N completed sessions' full span traces
    /// (`--trace N`; 0 = off).
    pub trace: usize,
    /// Master switch for the telemetry layer. Off skips every span
    /// fold and rollup tick; records are bit-identical either way.
    pub telemetry: bool,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            sites: COMPUTE_SITES.iter().map(|s| s.to_string()).collect(),
            jobs: 64,
            arrival_window_secs: 60.0,
            files_per_job: (1, 1),
            catalog_files: 256,
            zipf_s: 1.1,
            experiment: "gwosc".into(),
            site_experiments: Vec::new(),
            background_flows: 2,
            method: DownloadMethod::Stash,
            seed: 0,
            trace: 0,
            telemetry: true,
        }
    }
}

/// One finished campaign download.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CampaignRecord {
    /// Engine session id (spawn order).
    pub session: u64,
    pub site: String,
    /// Job arrival instant.
    pub arrival: SimTime,
    pub record: TransferRecord,
}

/// Campaign outputs, in completion order.
#[derive(Debug)]
pub struct CampaignResults {
    pub records: Vec<CampaignRecord>,
    /// Maximum simultaneously active sessions.
    pub peak_concurrent: usize,
    /// Sessions that coalesced onto another session's origin fetch.
    pub coalesced_joins: u64,
    /// Engine events processed (timers + completions + faults).
    pub events_processed: u64,
    /// First job arrival to last completion.
    pub makespan: Duration,
    /// Full engine counters (failovers, retries, aborted bytes, …).
    pub engine: EngineStats,
    /// Epoch-loop counters (epochs planned/engaged, shard vs serial
    /// session counts, per-reason plan bails). Thread-count dependent
    /// by design — execution-strategy observability, never part of
    /// the cross-thread bit-identity surface.
    pub epochs: EpochStats,
    /// End-of-run telemetry export bundle (empty when
    /// [`CampaignConfig::telemetry`] is off).
    pub telemetry: TelemetrySnapshot,
}

impl CampaignResults {
    pub fn total_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.record.bytes).sum()
    }

    /// Aggregate delivered throughput in Mbit/s over the makespan.
    pub fn aggregate_mbps(&self) -> f64 {
        let secs = self.makespan.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.total_bytes() as f64 * 8.0 / 1e6 / secs
    }

    /// Percentiles of per-download duration, in seconds.
    pub fn duration_percentiles(&self, ps: &[f64]) -> Vec<f64> {
        let mut secs: Vec<f64> = self
            .records
            .iter()
            .map(|r| r.record.duration.as_secs_f64())
            .collect();
        crate::util::stats::percentiles(&mut secs, ps)
    }
}

/// FNV-1a hash of a site name, used as that site's `Pcg64` stream id
/// (odd so distinct names give distinct streams).
fn site_stream(name: &str) -> u64 {
    crate::util::fnv1a(name.as_bytes()) | 1
}

/// Run a campaign on a fresh federation.
pub fn run(cfg: FederationConfig, ccfg: &CampaignConfig) -> CampaignResults {
    run_threads(cfg, ccfg, 1)
}

/// [`run`] with a worker-thread budget for the sharded session engine.
/// `threads = 1` is the serial path byte-for-byte; any `threads` value
/// yields bit-identical results (see
/// [`SessionEngine::run_threaded`](crate::federation::driver::SessionEngine::run_threaded)).
pub fn run_threads(cfg: FederationConfig, ccfg: &CampaignConfig, threads: usize) -> CampaignResults {
    let mut fed = FedSim::build(cfg);
    run_on_threads(&mut fed, ccfg, threads)
}

/// Run a campaign on an existing federation (drivers can pre-warm
/// caches or inject failures first).
pub fn run_on(fed: &mut FedSim, ccfg: &CampaignConfig) -> CampaignResults {
    run_on_threads(fed, ccfg, 1)
}

/// [`run_on`] with a worker-thread budget for the sharded engine.
pub fn run_on_threads(fed: &mut FedSim, ccfg: &CampaignConfig, threads: usize) -> CampaignResults {
    assert!(!ccfg.sites.is_empty(), "campaign without sites");
    assert!(ccfg.files_per_job.0 <= ccfg.files_per_job.1);
    {
        // Duplicate sites would replay identical per-site RNG streams
        // (perfectly correlated duplicate jobs) — reject loudly.
        let mut names: Vec<&String> = ccfg.sites.iter().collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(
            names.len(),
            ccfg.sites.len(),
            "duplicate sites in campaign config"
        );
    }
    // Top-up rather than add: back-to-back campaigns on one federation
    // must not stack permanent background flows.
    fed.ensure_background_load(ccfg.background_flows);

    let base = fed.now;
    let catalog = Catalog::new(fed.cfg.seed, &fed.cfg.workload);
    let support = ccfg
        .catalog_files
        .min(catalog.files_per_experiment())
        .max(1);
    let zipf = Zipf::new(support, ccfg.zipf_s);

    let mut engine = SessionEngine::new(base);
    engine.tele.set_enabled(ccfg.telemetry);
    engine.tele.set_trace_cap(ccfg.trace);
    let mut first_arrival: Option<SimTime> = None;
    let n_sites = ccfg.sites.len();
    for (i, site_name) in ccfg.sites.iter().enumerate() {
        let site_idx = fed
            .topo
            .site_index(site_name)
            .unwrap_or_else(|| panic!("unknown campaign site {site_name}"));
        let site_jobs = ccfg.jobs / n_sites + usize::from(i < ccfg.jobs % n_sites);
        if site_jobs == 0 {
            continue;
        }
        // Stateless per-site RNG stream (seed ⊕ name hash): adding,
        // dropping, or reordering a site never perturbs the arrivals
        // at the others.
        let mut site_rng = Pcg64::new(fed.cfg.seed ^ ccfg.seed, site_stream(site_name));
        let experiment = if ccfg.site_experiments.is_empty() {
            &ccfg.experiment
        } else {
            &ccfg.site_experiments[i % ccfg.site_experiments.len()]
        };
        let rate = site_jobs as f64 / ccfg.arrival_window_secs.max(1e-9);
        let mut t = base;
        for _ in 0..site_jobs {
            t += Duration::from_secs_f64(site_rng.gen_exp(rate));
            first_arrival = Some(first_arrival.map_or(t, |f| f.min(t)));
            let (lo, hi) = ccfg.files_per_job;
            let n_files = site_rng.gen_range(lo, hi + 1).max(1);
            for _ in 0..n_files {
                let idx = zipf.sample(&mut site_rng);
                let file = catalog.file(experiment, idx);
                engine.spawn_at(fed, t, site_idx, file, ccfg.method);
            }
        }
    }

    engine.run_threaded(fed, threads);

    let records = engine
        .completed()
        .iter()
        .map(|&id| {
            let s = engine.session(id);
            CampaignRecord {
                session: id.0,
                site: fed.topo.site_name(s.site_idx).to_string(),
                arrival: s.arrival,
                record: s.record.clone().expect("session completed"),
            }
        })
        .collect();

    CampaignResults {
        records,
        peak_concurrent: engine.stats.peak_concurrent,
        coalesced_joins: engine.stats.coalesced_joins,
        events_processed: engine.stats.events_processed,
        // First arrival → last completion (the idle lead-in before the
        // first Poisson arrival is not campaign time).
        makespan: fed.now - first_arrival.unwrap_or(base),
        telemetry: snapshot_telemetry(fed, &engine),
        engine: engine.stats,
        epochs: engine.epochs,
    }
}

/// Fold the run's telemetry into its export bundle: the engine's
/// thread-invariant counters, per-cache and per-link end-of-run
/// gauges, the monitoring pipeline's counters, phase histograms,
/// rollup series, and resolved span traces.
///
/// Everything registered here must be bit-identical across thread
/// counts — `EngineStats` equality at 1/2/8 threads is asserted by
/// `tests/session_engine.rs`, cache/collector/bus state is replayed
/// serially at the epoch barrier, and the one f64 integrated in
/// event order (per-link WAN bytes) is rounded to whole bytes so
/// split-point ulps cannot leak into the export.
pub fn snapshot_telemetry(fed: &FedSim, engine: &SessionEngine) -> TelemetrySnapshot {
    if !engine.tele.enabled() {
        return TelemetrySnapshot::default();
    }
    let mut reg = MetricsRegistry::new();
    let e = &engine.stats;
    reg.counter("stashcache_engine_events_total", e.events_processed);
    reg.counter("stashcache_engine_sessions_completed_total", e.sessions_completed);
    reg.counter("stashcache_engine_coalesced_joins_total", e.coalesced_joins);
    reg.counter("stashcache_engine_faults_applied_total", e.faults_applied);
    reg.counter("stashcache_engine_failovers_total", e.failovers);
    reg.counter("stashcache_engine_retries_total", e.retries);
    reg.counter("stashcache_engine_aborted_bytes_total", e.aborted_bytes);
    reg.counter("stashcache_engine_direct_fallbacks_total", e.direct_fallbacks);
    reg.counter("stashcache_engine_deadline_expiries_total", e.deadline_expiries);
    reg.counter(
        "stashcache_engine_corruptions_detected_total",
        e.corruptions_detected,
    );
    reg.counter("stashcache_engine_background_respawns_total", e.background_respawns);
    reg.counter("stashcache_netsim_allocator_passes_total", e.allocator_passes);
    reg.counter("stashcache_netsim_components_touched_total", e.components_touched);
    reg.counter("stashcache_netsim_flows_refixed_total", e.flows_refixed);
    reg.gauge("stashcache_engine_peak_concurrent", e.peak_concurrent as f64);
    reg.gauge("stashcache_netsim_peak_component", e.peak_component as f64);
    if let Some(b) = &fed.breaker {
        reg.counter("stashcache_breaker_trips_total", b.trips);
        reg.counter("stashcache_breaker_reopens_total", b.reopens);
        reg.counter("stashcache_breaker_recoveries_total", b.recoveries);
        reg.gauge("stashcache_breaker_open_caches", b.open_count(fed.now) as f64);
    }
    reg.gauge(
        &format!(
            "stashcache_policy_info{{policy=\"{}\"}}",
            fed.policy.kind().name()
        ),
        1.0,
    );

    let mut cache_sites: Vec<usize> = fed.caches.keys().copied().collect();
    cache_sites.sort_unstable();
    for &site in &cache_sites {
        let c = &fed.caches[&site];
        let l = format!("{{cache=\"{}\"}}", fed.topo.site_name(site));
        let s = c.stats;
        reg.counter(&format!("stashcache_cache_requests_total{l}"), s.requests);
        reg.counter(
            &format!("stashcache_cache_whole_file_hits_total{l}"),
            s.whole_file_hits,
        );
        reg.counter(
            &format!("stashcache_cache_bytes_served_hit_total{l}"),
            s.bytes_served_hit,
        );
        reg.counter(
            &format!("stashcache_cache_bytes_served_miss_total{l}"),
            s.bytes_served_miss,
        );
        reg.counter(
            &format!("stashcache_cache_bytes_fetched_origin_total{l}"),
            s.bytes_fetched_origin,
        );
        reg.counter(&format!("stashcache_cache_evictions_total{l}"), s.evictions);
        reg.counter(
            &format!("stashcache_cache_bytes_evicted_total{l}"),
            s.bytes_evicted,
        );
        let hit_ratio = if s.requests > 0 {
            s.whole_file_hits as f64 / s.requests as f64
        } else {
            0.0
        };
        reg.gauge(&format!("stashcache_cache_hit_ratio{l}"), hit_ratio);
        reg.gauge(
            &format!("stashcache_cache_usage_bytes{l}"),
            c.usage().as_u64() as f64,
        );
        reg.gauge(&format!("stashcache_cache_load_factor{l}"), c.load_factor());
        reg.gauge(
            &format!("stashcache_cache_resident_files{l}"),
            c.resident_files() as f64,
        );
        reg.gauge(
            &format!("stashcache_cache_in_flight{l}"),
            engine.cache_in_flight().get(&site).copied().unwrap_or(0) as f64,
        );
        reg.gauge(
            &format!("stashcache_cache_down{l}"),
            f64::from(u8::from(fed.faults.is_cache_down(site))),
        );
        reg.counter(
            &format!("stashcache_cache_outages_total{l}"),
            u64::from(fed.faults.outages_of(site)),
        );
        reg.gauge(
            &format!("stashcache_cache_downtime_seconds{l}"),
            fed.faults.downtime_of(site, fed.now).as_secs_f64(),
        );
    }

    for site in 0..fed.topo.site_count() {
        let l = format!("{{site=\"{}\"}}", fed.topo.site_name(site));
        // Per-link carried bytes are the one f64 the network
        // integrates in event order; serial and sharded runs split
        // the integration at different instants, so round to whole
        // bytes before export (ulp-level noise, never whole bytes).
        reg.counter(
            &format!("stashcache_wan_bytes_total{l}"),
            fed.wan_bytes(site).round() as u64,
        );
        reg.gauge(
            &format!("stashcache_wan_link_up{l}"),
            f64::from(u8::from(fed.net.link_is_up(fed.topo.wan_link(site)))),
        );
    }

    let cs = fed.collector.stats;
    reg.counter("stashcache_collector_packets_total", cs.packets);
    reg.counter("stashcache_collector_reports_published_total", cs.reports_published);
    reg.counter("stashcache_collector_orphan_closes_total", cs.orphan_closes);
    reg.counter("stashcache_collector_unknown_users_total", cs.unknown_users);
    reg.counter("stashcache_collector_expired_entries_total", cs.expired_entries);
    reg.counter("stashcache_collector_decode_errors_total", cs.decode_errors);
    reg.counter("stashcache_bus_published_total", fed.bus.published);
    reg.counter("stashcache_bus_compacted_total", fed.bus.compacted);
    reg.gauge("stashcache_bus_queue_depth", fed.bus.total_depth() as f64);

    let mut phases = Vec::with_capacity(PhaseLabel::ALL.len());
    for label in PhaseLabel::ALL {
        let sk = engine.tele.phase_sketch(label);
        if !sk.is_empty() {
            reg.histogram(
                &format!("stashcache_phase_seconds{{phase=\"{}\"}}", label.name()),
                sk,
            );
        }
        phases.push((label.name(), sk.clone()));
    }

    let rollup = engine.tele.rollup();
    let rollups = rollup
        .iter()
        .map(|(key, windows)| {
            let label = if key < 0 {
                "(none)".to_string()
            } else {
                fed.topo.site_name(key as usize).to_string()
            };
            (label, windows.to_vec())
        })
        .collect();

    let traces = engine
        .tele
        .traces()
        .map(|t| TraceRow {
            session: t.session,
            site: fed.topo.site_name(t.site).to_string(),
            path: t.path.clone(),
            arrival: t.arrival,
            completed: t.completed,
            bytes: t.bytes,
            cache: t.cache_site.map(|s| fed.topo.site_name(s).to_string()),
            hit: t.hit,
            spans: t.spans.clone(),
        })
        .collect();

    TelemetrySnapshot {
        registry: reg,
        phases,
        rollup_window_secs: rollup.window_secs(),
        rollups,
        traces,
    }
}

/// A campaign run under fault injection, plus the availability ledger.
#[derive(Debug)]
pub struct ChaosResults {
    pub campaign: CampaignResults,
    /// Faults applied during the run, at their effective instants.
    pub fault_log: Vec<FaultEvent>,
    /// Per-cache downtime and the fault-layer counters.
    pub availability: AvailabilityReport,
}

/// Run a campaign with a fault timeline on a fresh federation. Every
/// job still completes — sessions whose cache, link, or redirector
/// dies mid-transfer fail over to another cache or fall back to the
/// origin — and identical configs give bit-identical records, fault
/// logs, and counters.
pub fn run_with_faults(
    cfg: FederationConfig,
    ccfg: &CampaignConfig,
    faults: &FaultTimeline,
) -> ChaosResults {
    run_with_faults_threads(cfg, ccfg, faults, 1)
}

/// [`run_with_faults`] with a worker-thread budget for the sharded
/// engine. While faults are pending the engine stays serial; once the
/// timeline drains, the remaining sessions may shard across threads.
pub fn run_with_faults_threads(
    cfg: FederationConfig,
    ccfg: &CampaignConfig,
    faults: &FaultTimeline,
    threads: usize,
) -> ChaosResults {
    let mut fed = FedSim::build(cfg);
    run_on_with_faults_threads(&mut fed, ccfg, faults, threads)
}

/// Run a campaign with a fault timeline on an existing federation.
pub fn run_on_with_faults(
    fed: &mut FedSim,
    ccfg: &CampaignConfig,
    faults: &FaultTimeline,
) -> ChaosResults {
    run_on_with_faults_threads(fed, ccfg, faults, 1)
}

/// [`run_on_with_faults`] with a worker-thread budget.
pub fn run_on_with_faults_threads(
    fed: &mut FedSim,
    ccfg: &CampaignConfig,
    faults: &FaultTimeline,
    threads: usize,
) -> ChaosResults {
    fed.inject_faults(faults)
        .expect("fault timeline rejected by federation");
    // One time base for the whole availability report: the run span
    // [start, end]. Faults apply at clamped instants ≥ start, so
    // downtime deltas can never exceed the window; snapshotting the
    // ledger means a reused federation reports only *this* run.
    let start = fed.now;
    let log_start = fed.fault_log.len();
    let mut cache_sites: Vec<usize> = fed.caches.keys().copied().collect();
    cache_sites.sort_unstable();
    let before: Vec<(u32, Duration, bool)> = cache_sites
        .iter()
        .map(|&site| {
            (
                fed.faults.outages_of(site),
                fed.faults.downtime_of(site, start),
                // An outage still open at `start` — a kill with no
                // recovery event in an earlier run on this federation —
                // keeps accruing downtime into this window, but its
                // `outages_of` increment happened back when the cache
                // went down. Without counting it here, a reused
                // federation reports downtime > 0 with "0 outages".
                fed.faults.is_cache_down(site),
            )
        })
        .collect();
    let campaign = run_on_threads(fed, ccfg, threads);
    let window = fed.now - start;
    let caches = cache_sites
        .iter()
        .zip(&before)
        .map(|(&site, &(outages0, downtime0, open_at_start))| CacheAvailability {
            site: fed.topo.site_name(site).to_string(),
            outages: fed.faults.outages_of(site) - outages0 + u32::from(open_at_start),
            downtime: Duration(
                fed.faults
                    .downtime_of(site, fed.now)
                    .0
                    .saturating_sub(downtime0.0),
            ),
        })
        .collect();
    let e = campaign.engine;
    ChaosResults {
        // Only this run's events — a reused federation keeps its full
        // history in `fed.fault_log`.
        fault_log: fed.fault_log[log_start..].to_vec(),
        availability: AvailabilityReport {
            window,
            caches,
            faults_applied: e.faults_applied,
            failovers: e.failovers,
            retries: e.retries,
            aborted_bytes: e.aborted_bytes,
            direct_fallbacks: e.direct_fallbacks,
            downloads_completed: e.sessions_completed,
        },
        campaign,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;

    fn small() -> CampaignConfig {
        CampaignConfig {
            sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
            jobs: 24,
            arrival_window_secs: 30.0,
            catalog_files: 64,
            background_flows: 1,
            ..CampaignConfig::default()
        }
    }

    #[test]
    fn campaign_completes_every_job() {
        let r = run(paper_federation(), &small());
        assert_eq!(r.records.len(), 24);
        assert!(r.records.iter().all(|c| c.record.bytes > 0));
        assert!(r.makespan.as_secs_f64() > 0.0);
        assert!(r.aggregate_mbps() > 0.0);
        // Jobs were spread over all three sites.
        for site in ["syracuse", "nebraska", "chicago"] {
            assert!(
                r.records.iter().any(|c| c.site == site),
                "no records at {site}"
            );
        }
    }

    #[test]
    fn campaign_overlaps_sessions() {
        // 24 jobs arriving inside ~1 s of multi-second transfers must
        // overlap heavily.
        let ccfg = CampaignConfig {
            arrival_window_secs: 1.0,
            ..small()
        };
        let r = run(paper_federation(), &ccfg);
        assert!(
            r.peak_concurrent >= 12,
            "expected heavy overlap, peak {}",
            r.peak_concurrent
        );
    }

    #[test]
    fn hot_catalog_coalesces_across_clients() {
        // A nearly-degenerate catalog: everyone wants the same couple
        // of files, and arrivals are much denser than one cold fetch,
        // so concurrent misses must join a single origin fetch.
        let ccfg = CampaignConfig {
            arrival_window_secs: 10.0,
            catalog_files: 2,
            zipf_s: 2.0,
            ..small()
        };
        let r = run(paper_federation(), &ccfg);
        assert_eq!(r.records.len(), 24);
        assert!(
            r.coalesced_joins > 0,
            "hot files under concurrency must coalesce"
        );
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let a = run(paper_federation(), &small());
        let b = run(paper_federation(), &small());
        assert_eq!(a.records, b.records);
        assert_eq!(a.peak_concurrent, b.peak_concurrent);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn empty_fault_timeline_is_identical_to_plain_run() {
        let plain = run(paper_federation(), &small());
        let chaos = run_with_faults(paper_federation(), &small(), &FaultTimeline::new());
        assert_eq!(plain.records, chaos.campaign.records);
        assert_eq!(plain.events_processed, chaos.campaign.events_processed);
        assert_eq!(chaos.availability.failovers, 0);
        assert_eq!(chaos.availability.faults_applied, 0);
        assert!(chaos.fault_log.is_empty());
        assert!((chaos.availability.mean_availability() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn different_seed_differs() {
        let a = run(paper_federation(), &small());
        let b = run(
            paper_federation(),
            &CampaignConfig {
                seed: 99,
                ..small()
            },
        );
        assert_ne!(a.records, b.records, "seed must matter");
    }
}
