//! Analytic transfer-time model — rust mirror of the `transfer_est`
//! Pallas kernel (`python/compile/kernels/transfer.py`). The two
//! implementations must agree (asserted by `runtime` integration
//! tests); the PJRT artifact serves batched scheduler queries, this
//! mirror serves one-off estimates and tests.

/// TCP + application handshake rounds before data flows.
pub const HANDSHAKE_ROUNDS: f64 = 3.0;
/// Streams at which multi-stream transfers reach 2/3 of the bottleneck.
pub const STREAM_HALF_SAT: f64 = 2.0;

/// Estimated seconds to move `bytes` over a path with `rtt_ms` and a
/// `bottleneck_bps` bottleneck using `streams` parallel streams.
pub fn transfer_secs(bytes: f64, rtt_ms: f64, bottleneck_bps: f64, streams: f64) -> f64 {
    let startup = HANDSHAKE_ROUNDS * rtt_ms / 1e3;
    let eff = streams / (streams + STREAM_HALF_SAT);
    startup + bytes / (bottleneck_bps * eff).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_stream_penalised() {
        let one = transfer_secs(1e9, 20.0, 1.25e8, 1.0);
        let many = transfer_secs(1e9, 20.0, 1.25e8, 16.0);
        assert!(one > many, "multi-stream must be faster (paper §3.1)");
        // 16 streams ≈ 8/9 efficiency → ~9 s bulk.
        assert!((many - (0.06 + 1e9 / (1.25e8 * 16.0 / 18.0))).abs() < 1e-6);
    }

    #[test]
    fn rtt_dominates_small_files() {
        // 5.797 KB over a fast path: startup is everything.
        let t = transfer_secs(5_797.0, 40.0, 1.25e8, 1.0);
        assert!(t < 0.2 && t > 0.12, "t={t}");
    }

    #[test]
    fn degenerate_bandwidth_clamped() {
        let t = transfer_secs(100.0, 1.0, 0.0, 1.0);
        assert!(t.is_finite());
    }
}
