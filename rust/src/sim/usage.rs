//! Long-running usage simulations (Table 1, Table 2, Figure 4,
//! Figure 5).
//!
//! Poisson job arrivals at the compute sites read Zipf-popular files
//! from the Table 1 experiment mix through the StashCache path; every
//! transfer flows through the monitoring pipeline, and the paper's
//! usage artifacts are read back from the [`Aggregator`] — produced by
//! the *monitoring system*, not computed on the side.

use crate::config::FederationConfig;
use crate::federation::{DownloadMethod, FedSim};
use crate::metrics::ByteSeries;
use crate::monitoring::aggregator::Aggregator;
use crate::sim::workload::WorkloadGen;
use crate::util::{Duration, SimTime};

/// Usage-simulation knobs.
#[derive(Debug, Clone)]
pub struct UsageConfig {
    /// Simulated duration in days.
    pub days: f64,
    /// Override the workload's jobs/hour (scale runs down for CI).
    pub jobs_per_hour: Option<f64>,
    /// Background flows per origin.
    pub background_flows: usize,
    /// Weekly intensity profile: multiplies the arrival rate per week
    /// (Fig 4's ramp-up and bursts). Empty ⇒ constant 1.0.
    pub weekly_intensity: Vec<f64>,
    /// WAN-trace bucket width in seconds (Fig 5 uses 30-minute
    /// averages).
    pub wan_bucket_secs: f64,
}

impl Default for UsageConfig {
    fn default() -> Self {
        UsageConfig {
            days: 7.0,
            jobs_per_hour: Some(40.0),
            background_flows: 2,
            weekly_intensity: Vec::new(),
            wan_bucket_secs: 1_800.0,
        }
    }
}

/// Figure 4's observed production profile, eyeballed from the paper:
/// usage grows through the year with heavy bursts in the final
/// quarter (LIGO/GWOSC reprocessing campaigns).
pub fn fig4_weekly_intensity() -> Vec<f64> {
    (0..52)
        .map(|w| {
            let ramp = 0.3 + 1.4 * (w as f64 / 51.0);
            let burst = match w {
                18..=20 => 1.8, // spring reprocessing
                34..=36 => 2.2, // late-summer campaign
                44..=48 => 2.6, // year-end surge
                _ => 1.0,
            };
            ramp * burst
        })
        .collect()
}

/// Outputs of a usage run.
pub struct UsageOutputs {
    pub fed: FedSim,
    /// Per-site WAN byte trace (bucketed).
    pub wan_traces: Vec<(String, ByteSeries)>,
    pub jobs_run: u64,
    pub downloads: u64,
}

impl UsageOutputs {
    pub fn aggregator(&mut self) -> &mut Aggregator {
        &mut self.fed.aggregator
    }
}

/// Run a usage simulation on a fresh federation.
pub fn run(cfg: FederationConfig, ucfg: &UsageConfig) -> UsageOutputs {
    let mut workload = cfg.workload.clone();
    if let Some(jph) = ucfg.jobs_per_hour {
        workload.jobs_per_hour = jph;
    }
    let compute_sites: Vec<String> = cfg.compute_sites().map(|s| s.name.clone()).collect();
    let mut gen = WorkloadGen::new(cfg.seed, workload, compute_sites);

    let mut fed = FedSim::build(cfg);
    fed.start_background_load(ucfg.background_flows);

    let end = SimTime::from_secs_f64(ucfg.days * 86_400.0);
    let week = 7.0 * 86_400.0;
    let mut wan_counters: Vec<(usize, f64)> = (0..fed.topo.site_count())
        .map(|i| (i, 0.0))
        .collect();
    let mut traces: Vec<ByteSeries> = (0..fed.topo.site_count())
        .map(|_| ByteSeries::new(ucfg.wan_bucket_secs))
        .collect();
    let mut next_sample = SimTime::ZERO;
    let sample_every = Duration::from_secs_f64(ucfg.wan_bucket_secs);

    let mut arrival = SimTime::ZERO;
    let mut jobs = 0u64;
    let mut downloads = 0u64;

    loop {
        // Next arrival, thinned by the weekly intensity profile.
        let gap = gen.next_arrival_gap();
        let intensity = if ucfg.weekly_intensity.is_empty() {
            1.0
        } else {
            let w = (arrival.as_secs_f64() / week) as usize;
            ucfg.weekly_intensity[w.min(ucfg.weekly_intensity.len() - 1)].max(1e-3)
        };
        arrival += Duration::from_secs_f64(gap.as_secs_f64() / intensity);
        if arrival >= end {
            break;
        }

        // Sample WAN counters on schedule as time passes.
        while next_sample <= arrival {
            fed.advance_to(next_sample);
            for (i, last) in wan_counters.iter_mut() {
                let now_bytes = fed.wan_bytes(*i);
                traces[*i].add(next_sample, (now_bytes - *last) as u64);
                *last = now_bytes;
            }
            next_sample += sample_every;
        }

        fed.advance_to(arrival);
        let job = gen.next_job();
        let site = fed
            .topo
            .site_index(&job.site)
            .expect("workload site exists");
        for file in &job.files {
            fed.download(site, file, DownloadMethod::Stash);
            downloads += 1;
        }
        jobs += 1;
    }

    let site_names: Vec<String> = (0..fed.topo.site_count())
        .map(|i| fed.topo.site_name(i).to_string())
        .collect();
    UsageOutputs {
        fed,
        wan_traces: site_names.into_iter().zip(traces).collect(),
        jobs_run: jobs,
        downloads,
    }
}

/// Figure 5's experiment: the same workload with and without a local
/// cache at `site`, split at the midpoint ("the bold red line shows
/// when the StashCache server was installed"). Returns the
/// concatenated WAN trace and the install bucket index.
pub fn fig5_before_after(
    mut cfg: FederationConfig,
    site: &str,
    ucfg: &UsageConfig,
) -> (ByteSeries, usize) {
    let half = UsageConfig {
        days: ucfg.days / 2.0,
        ..ucfg.clone()
    };

    // Phase 1: no cache at the site.
    let mut cfg_before = cfg.clone();
    cfg_before
        .sites
        .iter_mut()
        .find(|s| s.name == site)
        .unwrap_or_else(|| panic!("unknown site {site}"))
        .cache = None;
    let before = run(cfg_before, &half);

    // Phase 2: cache installed (default parameters).
    cfg.sites
        .iter_mut()
        .find(|s| s.name == site)
        .unwrap()
        .cache
        .get_or_insert_with(Default::default);
    let after = run(cfg, &half);

    let trace_of = |o: &UsageOutputs| {
        o.wan_traces
            .iter()
            .find(|(n, _)| n == site)
            .map(|(_, t)| t.clone())
            .expect("site trace")
    };
    let t_before = trace_of(&before);
    let t_after = trace_of(&after);

    let mut merged = ByteSeries::new(ucfg.wan_bucket_secs);
    let mut install_bucket = 0;
    for (secs, bytes) in t_before.points() {
        merged.add(SimTime::from_secs_f64(secs), bytes);
        install_bucket += 1;
    }
    let offset = install_bucket as f64 * ucfg.wan_bucket_secs;
    for (secs, bytes) in t_after.points() {
        merged.add(SimTime::from_secs_f64(secs + offset), bytes);
    }
    (merged, install_bucket)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;

    fn tiny() -> UsageConfig {
        UsageConfig {
            days: 0.5,
            jobs_per_hour: Some(30.0),
            background_flows: 1,
            weekly_intensity: Vec::new(),
            wan_bucket_secs: 1_800.0,
        }
    }

    #[test]
    fn usage_flows_through_monitoring() {
        let mut out = run(paper_federation(), &tiny());
        assert!(out.jobs_run > 100, "jobs {}", out.jobs_run);
        assert!(out.downloads >= out.jobs_run);
        let downloads = out.downloads;
        let agg = out.aggregator();
        assert_eq!(agg.reports, downloads);
        // The heaviest experiments dominate Table 1. At this tiny scale
        // byte totals are noisy (a handful of hot files dominate), so
        // assert the head is one of the top-two-share experiments.
        let t1 = agg.table1();
        assert!(
            t1[0].0 == "gwosc" || t1[0].0 == "des",
            "t1 head: {t1:?}"
        );
        let gwosc_rank = t1.iter().position(|(n, _)| n == "gwosc").unwrap();
        assert!(gwosc_rank < 3, "gwosc must rank top-3: {t1:?}");
    }

    #[test]
    fn zipf_reuse_gives_cache_hits() {
        let out = run(paper_federation(), &tiny());
        let served_hit: u64 = out
            .fed
            .caches
            .values()
            .map(|c| c.stats.bytes_served_hit)
            .sum();
        let served_total: u64 = out
            .fed
            .caches
            .values()
            .map(|c| c.stats.bytes_served_hit + c.stats.bytes_served_miss)
            .sum();
        let hit_rate = served_hit as f64 / served_total as f64;
        assert!(
            hit_rate > 0.2,
            "popular files must hit the cache: {hit_rate}"
        );
    }

    #[test]
    fn wan_traces_cover_duration() {
        let out = run(paper_federation(), &tiny());
        let (name, syr) = out
            .wan_traces
            .iter()
            .find(|(n, _)| n == "syracuse")
            .unwrap();
        assert_eq!(name, "syracuse");
        // 0.5 days at 1800 s buckets = 24 buckets, minus in-flight tail.
        assert!(syr.len() >= 20, "trace has {} buckets", syr.len());
    }

    #[test]
    fn fig5_wan_drops_after_install() {
        let ucfg = UsageConfig {
            days: 1.0,
            jobs_per_hour: Some(60.0),
            ..tiny()
        };
        let (trace, install) = fig5_before_after(paper_federation(), "syracuse", &ucfg);
        let pts: Vec<(f64, u64)> = trace.points().collect();
        assert!(install > 0 && install < pts.len());
        let before: u64 = pts[..install].iter().map(|p| p.1).sum();
        let after: u64 = pts[install..].iter().map(|p| p.1).sum();
        assert!(
            (before as f64) > (after as f64) * 2.0,
            "WAN must drop ≥2× after install: before {before} after {after}"
        );
    }

    #[test]
    fn weekly_intensity_shapes_fig4() {
        let profile = fig4_weekly_intensity();
        assert_eq!(profile.len(), 52);
        assert!(profile[47] > profile[0] * 5.0, "year-end surge");
    }
}
