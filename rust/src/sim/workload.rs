//! Synthetic OSG workload generation.
//!
//! The paper's production numbers (Table 1 usage mix, Table 2 size
//! percentiles) parameterise a generative model: each experiment owns
//! a catalog of files whose sizes come from the calibrated log-normal
//! mixture; jobs arrive Poisson at compute sites and read a few
//! Zipf-popular files from one experiment. Everything is derived
//! deterministically from the run seed.

use crate::config::schema::{SizeDistribution, WorkloadConfig};
use crate::util::{ByteSize, Duration, Pcg64, Zipf};

/// A file reference a job wants to read.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileRef {
    pub path: String,
    pub size: ByteSize,
    /// Content version (mtime) — bumped by dataset updates.
    pub version: u64,
}

/// One job: runs at a site, reads files from one experiment.
#[derive(Debug, Clone)]
pub struct Job {
    pub experiment: String,
    pub site: String,
    pub files: Vec<FileRef>,
}

/// Deterministic per-experiment file catalog. File `i`'s size is a
/// pure function of (seed, experiment, i), so catalogs are never
/// materialised — 9 experiments × 20k files cost nothing until used.
#[derive(Debug)]
pub struct Catalog {
    seed: u64,
    dist: SizeDistribution,
    files_per_experiment: u64,
}

impl Catalog {
    pub fn new(seed: u64, cfg: &WorkloadConfig) -> Self {
        Catalog {
            seed,
            dist: cfg.size_dist.clone(),
            files_per_experiment: cfg.files_per_experiment,
        }
    }

    pub fn files_per_experiment(&self) -> u64 {
        self.files_per_experiment
    }

    /// The file at index `i` of an experiment's catalog.
    ///
    /// Size depends on `i` only (same ladder for every experiment) and
    /// is a **stratified quantile** of the mixture: index `i` maps to
    /// low-discrepancy points `(u_i, v_i)` that pick the component and
    /// the within-component quantile. Consequences:
    /// * byte usage per experiment ∝ its job share (Table 1 ordering
    ///   is not decided by which experiment's hot files drew large
    ///   sizes), and
    /// * the Zipf-hot prefix of the catalog spans the whole size
    ///   distribution, so the popularity-weighted sizes the monitoring
    ///   sees still match Table 2.
    pub fn file(&self, experiment: &str, i: u64) -> FileRef {
        assert!(i < self.files_per_experiment);
        let size = quantile_size(&self.dist, i);
        FileRef {
            path: format!("/ospool/{experiment}/data/f{i:06}.dat"),
            size,
            version: 1,
        }
    }

    /// Total catalog bytes of an experiment (exact, by enumeration).
    pub fn experiment_bytes(&self, experiment: &str) -> ByteSize {
        (0..self.files_per_experiment)
            .map(|i| self.file(experiment, i).size)
            .sum()
    }
}

/// Golden-ratio and plastic-number fractions for the low-discrepancy
/// index mapping.
const PHI_FRAC: f64 = 0.618_033_988_749_894_9;
const PLASTIC_FRAC: f64 = 0.754_877_666_246_692_8;

/// Deterministic stratified size for catalog index `i`: inverse-CDF of
/// the mixture at low-discrepancy points.
pub fn quantile_size(dist: &SizeDistribution, i: u64) -> ByteSize {
    let u = ((i as f64 + 0.5) * PHI_FRAC).fract();
    let v = ((i as f64 + 0.5) * PLASTIC_FRAC).fract().clamp(1e-9, 1.0 - 1e-9);
    // Component by cumulative weight.
    let mut acc = 0.0;
    let mut chosen = dist.components.len() - 1;
    for (k, &(w, _, _)) in dist.components.iter().enumerate() {
        acc += w;
        if u < acc {
            chosen = k;
            break;
        }
    }
    let (_, mu, sigma) = dist.components[chosen];
    let bytes = (mu + sigma * crate::util::stats::probit(v)).exp();
    ByteSize((bytes.round() as u64).clamp(dist.min.as_u64(), dist.max.as_u64()))
}

/// Draw a file size from the calibrated mixture.
pub fn sample_size(dist: &SizeDistribution, rng: &mut Pcg64) -> ByteSize {
    let weights: Vec<f64> = dist.components.iter().map(|c| c.0).collect();
    let k = rng.weighted_index(&weights);
    let (_, mu, sigma) = dist.components[k];
    let bytes = rng.gen_lognormal(mu, sigma);
    ByteSize(
        (bytes.round() as u64).clamp(dist.min.as_u64(), dist.max.as_u64()),
    )
}

/// The job generator.
pub struct WorkloadGen {
    cfg: WorkloadConfig,
    pub catalog: Catalog,
    zipf: Zipf,
    rng: Pcg64,
    exp_weights: Vec<f64>,
    compute_sites: Vec<String>,
    jobs_emitted: u64,
}

impl WorkloadGen {
    pub fn new(seed: u64, cfg: WorkloadConfig, compute_sites: Vec<String>) -> Self {
        assert!(!compute_sites.is_empty());
        let catalog = Catalog::new(seed, &cfg);
        let zipf = Zipf::new(cfg.files_per_experiment, cfg.zipf_s);
        let exp_weights = cfg.experiments.iter().map(|e| e.share).collect();
        WorkloadGen {
            zipf,
            catalog,
            rng: Pcg64::new(seed, 0x0b5),
            exp_weights,
            compute_sites,
            jobs_emitted: 0,
            cfg,
        }
    }

    /// Exponential inter-arrival gap to the next job.
    pub fn next_arrival_gap(&mut self) -> Duration {
        let rate_per_sec = self.cfg.jobs_per_hour / 3_600.0;
        Duration::from_secs_f64(self.rng.gen_exp(rate_per_sec))
    }

    /// Generate the next job.
    pub fn next_job(&mut self) -> Job {
        self.jobs_emitted += 1;
        let e = self.rng.weighted_index(&self.exp_weights);
        let experiment = self.cfg.experiments[e].name.clone();
        let site = self
            .compute_sites[self.rng.gen_range(0, self.compute_sites.len() as u64) as usize]
            .clone();
        let (lo, hi) = self.cfg.files_per_job;
        let n = self.rng.gen_range(lo, hi + 1);
        let files = (0..n)
            .map(|_| {
                let idx = self.zipf.sample(&mut self.rng);
                self.catalog.file(&experiment, idx)
            })
            .collect();
        Job {
            experiment,
            site,
            files,
        }
    }

    pub fn jobs_emitted(&self) -> u64 {
        self.jobs_emitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::{paper_workload, COMPUTE_SITES};
    use crate::util::bytes::{GB, KB, MB};
    use crate::util::stats;

    fn gen() -> WorkloadGen {
        WorkloadGen::new(
            42,
            paper_workload(),
            COMPUTE_SITES.iter().map(|s| s.to_string()).collect(),
        )
    }

    #[test]
    fn catalog_is_deterministic() {
        let w1 = gen();
        let w2 = gen();
        for i in [0u64, 7, 4_999] {
            assert_eq!(w1.catalog.file("ligo", i), w2.catalog.file("ligo", i));
        }
        // Same size ladder across experiments (Table 1 ordering
        // stability); distinct namespaces.
        assert_eq!(
            w1.catalog.file("ligo", 3).size,
            w1.catalog.file("des", 3).size,
        );
        assert_ne!(
            w1.catalog.file("ligo", 3).path,
            w1.catalog.file("des", 3).path,
        );
    }

    #[test]
    fn size_distribution_matches_table2() {
        // Sample the mixture and check the paper's percentiles within
        // a tolerance band (the mixture was calibrated offline).
        let cfg = paper_workload();
        let mut rng = Pcg64::new(123, 9);
        let mut sizes: Vec<f64> = (0..40_000)
            .map(|_| sample_size(&cfg.size_dist, &mut rng).as_f64())
            .collect();
        let ps = stats::percentiles(&mut sizes, &[5.0, 25.0, 50.0, 75.0, 95.0]);
        let paper = [
            22.801 * MB as f64,
            170.131 * MB as f64,
            467.852 * MB as f64,
            493.337 * MB as f64,
            2.335 * GB as f64,
        ];
        for ((p, got), want) in [5.0, 25.0, 50.0, 75.0, 95.0].iter().zip(&ps).zip(&paper) {
            let ratio = got / want;
            assert!(
                (0.5..2.0).contains(&ratio),
                "p{p}: got {got:.3e} want {want:.3e} (ratio {ratio:.2})"
            );
        }
        // 1st percentile is tiny (5.797 KB in the paper).
        let p1 = stats::percentiles(&mut sizes, &[1.0])[0];
        assert!(p1 < 500.0 * KB as f64, "p1 {p1}");
    }

    #[test]
    fn quantile_catalog_matches_distribution() {
        // The stratified catalog's percentiles must match the mixture.
        let cfg = paper_workload();
        let mut sizes: Vec<f64> = (0..5_000)
            .map(|i| quantile_size(&cfg.size_dist, i).as_f64())
            .collect();
        let ps = stats::percentiles(&mut sizes, &[50.0, 75.0, 95.0]);
        assert!((0.6..1.6).contains(&(ps[0] / (467.852 * MB as f64))), "p50 {}", ps[0]);
        assert!((0.6..1.6).contains(&(ps[1] / (493.337 * MB as f64))), "p75 {}", ps[1]);
        assert!((0.7..1.4).contains(&(ps[2] / (2.335 * GB as f64))), "p95 {}", ps[2]);
        // The hot prefix (first 16 indices) also spans the modes.
        let hot: Vec<f64> = (0..16)
            .map(|i| quantile_size(&cfg.size_dist, i).as_f64())
            .collect();
        let dominant = hot
            .iter()
            .filter(|&&s| (3e8..7e8).contains(&s))
            .count();
        assert!(dominant >= 6, "hot prefix carries the ~480MB mode: {hot:?}");
        assert!(hot.iter().any(|&s| s > 1.5e9), "hot prefix has a large file");
        assert!(hot.iter().any(|&s| s < 3e8), "hot prefix has smaller files");
    }

    #[test]
    fn jobs_have_valid_shape() {
        let mut w = gen();
        for _ in 0..100 {
            let j = w.next_job();
            assert!(!j.files.is_empty() && j.files.len() <= 6);
            assert!(COMPUTE_SITES.contains(&j.site.as_str()));
            for f in &j.files {
                assert!(f.path.starts_with(&format!("/ospool/{}/", j.experiment)));
                assert!(f.size.as_u64() >= 512);
            }
        }
        assert_eq!(w.jobs_emitted(), 100);
    }

    #[test]
    fn experiment_mix_respects_shares() {
        let mut w = gen();
        let mut gwosc = 0;
        let mut dune = 0;
        for _ in 0..5_000 {
            let j = w.next_job();
            match j.experiment.as_str() {
                "gwosc" => gwosc += 1,
                "dune" => dune += 1,
                _ => {}
            }
        }
        // gwosc share is ~92× dune's.
        assert!(gwosc > 20 * dune.max(1), "gwosc {gwosc} dune {dune}");
    }

    #[test]
    fn popularity_is_zipf_skewed() {
        let mut w = gen();
        let mut f0 = 0u64;
        let mut rest = 0u64;
        for _ in 0..3_000 {
            let j = w.next_job();
            for f in &j.files {
                if f.path.contains("f000000") {
                    f0 += 1;
                } else {
                    rest += 1;
                }
            }
        }
        // Rank-0 file of each experiment is dramatically over-selected
        // vs the uniform expectation of total/20000.
        let uniform_expect = (f0 + rest) / 20_000;
        assert!(f0 > uniform_expect * 20, "f0 {f0}, uniform {uniform_expect}");
    }

    #[test]
    fn arrival_gaps_mean_matches_rate() {
        let mut w = gen();
        let n = 20_000;
        let total: f64 = (0..n).map(|_| w.next_arrival_gap().as_secs_f64()).sum();
        let mean = total / n as f64;
        let expected = 3_600.0 / paper_workload().jobs_per_hour;
        assert!(
            (mean - expected).abs() < expected * 0.05,
            "mean {mean} expected {expected}"
        );
    }
}
