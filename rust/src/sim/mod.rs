//! Simulation drivers: workload generation, the §4.1 benchmark
//! scenario, and the long-running usage simulations.
//!
//! * [`workload`] — synthetic OSG workload: Table 1's experiment mix,
//!   Table 2's file-size distribution, Zipf popularity, Poisson job
//!   arrivals.
//! * [`estimate`] — the analytic transfer-time model (rust mirror of
//!   the `transfer_est` kernel; used by schedulers and sanity checks).
//! * [`scenario`] — the paper's HTCondor-DAGMan test (Figs 6-8,
//!   Table 3): per site, per file size, four downloads (HTTP proxy
//!   cold/hot, stashcp cold/hot).
//! * [`campaign`] — the concurrent counterpart: Poisson job arrivals
//!   at many sites at once, hundreds of overlapping sessions through
//!   the event-driven engine (cross-client coalescing, contention).
//! * [`usage`] — months of federation traffic through the monitoring
//!   pipeline (Table 1, Table 2, Fig 4, Fig 5).

pub mod campaign;
pub mod estimate;
pub mod scenario;
pub mod usage;
pub mod workload;
