//! Site HTTP forward proxy — the paper's baseline (squid-like).
//!
//! Paper §1: "the HTTP proxies have well known limitations ... the
//! proxies are optimized for small files such as software and
//! experiment conditions rather than the multi-gigabyte files that
//! some users require." §5 observed two concrete behaviours this
//! module reproduces:
//!
//! * **Max object size** — "The HTTP proxies at sites are configured to
//!   not cache large files. In all of our tests, the 95th percentile
//!   file and the 10GB file were never cached": objects larger than
//!   [`crate::config::ProxyConfig::max_object`] pass through uncached.
//! * **Rapid expiry** — "we experienced expiration of files within the
//!   HTTP proxies ... the first files were already expired within the
//!   cache and deleted": objects expire after `ttl_secs` and LRU
//!   eviction reclaims space under capacity pressure.

use crate::config::ProxyConfig;
use crate::util::{ByteSize, Duration, SimTime};
use std::collections::HashMap;

#[derive(Debug)]
struct CachedObject {
    size: u64,
    stored_at: SimTime,
    last_access: SimTime,
    access_seq: u64,
}

/// Result of a proxy lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProxyLookup {
    /// Object is cached and fresh: served from the proxy.
    Hit,
    /// Object must be fetched from upstream; `cacheable` says whether
    /// the proxy will store it on the way through.
    Miss { cacheable: bool, reason: MissReason },
}

/// Why a lookup missed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MissReason {
    /// Never seen (or previously evicted).
    Cold,
    /// Cached copy was past its TTL ("expiration of files within the
    /// HTTP proxies", §5).
    Expired,
    /// Larger than `max_object`: pass-through, never cached.
    TooLarge,
}

/// Proxy counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ProxyStats {
    pub requests: u64,
    pub hits: u64,
    pub misses_cold: u64,
    pub misses_expired: u64,
    pub passthrough_too_large: u64,
    pub evictions: u64,
    pub bytes_served_hit: u64,
    pub bytes_fetched_upstream: u64,
}

/// The squid-like forward proxy state machine.
#[derive(Debug)]
pub struct ProxyServer {
    pub name: String,
    pub cfg: ProxyConfig,
    objects: HashMap<String, CachedObject>,
    usage: u64,
    seq: u64,
    pub stats: ProxyStats,
}

impl ProxyServer {
    pub fn new(name: impl Into<String>, cfg: ProxyConfig) -> Self {
        ProxyServer {
            name: name.into(),
            cfg,
            objects: HashMap::new(),
            usage: 0,
            seq: 0,
            stats: ProxyStats::default(),
        }
    }

    pub fn usage(&self) -> ByteSize {
        ByteSize(self.usage)
    }

    pub fn object_count(&self) -> usize {
        self.objects.len()
    }

    fn ttl(&self) -> Duration {
        Duration::from_secs_f64(self.cfg.ttl_secs)
    }

    /// Look up `url` (an object of `size` bytes) at time `now`.
    pub fn lookup(&mut self, url: &str, size: u64, now: SimTime) -> ProxyLookup {
        self.stats.requests += 1;
        if size > self.cfg.max_object.as_u64() {
            self.stats.passthrough_too_large += 1;
            return ProxyLookup::Miss {
                cacheable: false,
                reason: MissReason::TooLarge,
            };
        }
        let ttl = self.ttl();
        match self.objects.get_mut(url) {
            Some(obj) if now - obj.stored_at <= ttl => {
                self.seq += 1;
                obj.last_access = now;
                obj.access_seq = self.seq;
                self.stats.hits += 1;
                self.stats.bytes_served_hit += obj.size;
                ProxyLookup::Hit
            }
            Some(_) => {
                // Expired: squid deletes on validation failure.
                let obj = self.objects.remove(url).expect("checked above");
                self.usage -= obj.size;
                self.stats.misses_expired += 1;
                ProxyLookup::Miss {
                    cacheable: true,
                    reason: MissReason::Expired,
                }
            }
            None => {
                self.stats.misses_cold += 1;
                ProxyLookup::Miss {
                    cacheable: true,
                    reason: MissReason::Cold,
                }
            }
        }
    }

    /// Store an object after fetching it upstream (only called when the
    /// preceding lookup said `cacheable`). Runs LRU eviction to fit.
    pub fn commit(&mut self, url: &str, size: u64, now: SimTime) {
        assert!(
            size <= self.cfg.max_object.as_u64(),
            "committing an uncacheable object"
        );
        self.stats.bytes_fetched_upstream += size;
        // Evict LRU objects until the new one fits.
        while self.usage + size > self.cfg.capacity.as_u64() && !self.objects.is_empty() {
            let victim = self
                .objects
                .iter()
                .min_by_key(|(_, o)| (o.last_access, o.access_seq))
                .map(|(k, _)| k.clone())
                .expect("non-empty");
            let obj = self.objects.remove(&victim).expect("victim exists");
            self.usage -= obj.size;
            self.stats.evictions += 1;
        }
        self.seq += 1;
        if let Some(prev) = self.objects.insert(
            url.to_string(),
            CachedObject {
                size,
                stored_at: now,
                last_access: now,
                access_seq: self.seq,
            },
        ) {
            self.usage -= prev.size;
        }
        self.usage += size;
    }

    /// Hit ratio so far (requests > 0).
    pub fn hit_ratio(&self) -> f64 {
        if self.stats.requests == 0 {
            0.0
        } else {
            self.stats.hits as f64 / self.stats.requests as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(capacity: u64, max_object: u64, ttl: f64) -> ProxyConfig {
        ProxyConfig {
            capacity: ByteSize(capacity),
            max_object: ByteSize(max_object),
            ttl_secs: ttl,
            per_conn_gbps: 1.0,
        }
    }

    fn t(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn cold_then_hit() {
        let mut p = ProxyServer::new("sq", cfg(10_000, 5_000, 3600.0));
        assert_eq!(
            p.lookup("/u", 100, t(0.0)),
            ProxyLookup::Miss { cacheable: true, reason: MissReason::Cold }
        );
        p.commit("/u", 100, t(0.0));
        assert_eq!(p.lookup("/u", 100, t(1.0)), ProxyLookup::Hit);
        assert_eq!(p.stats.hits, 1);
        assert_eq!(p.usage().as_u64(), 100);
    }

    #[test]
    fn large_files_never_cached() {
        // "the 95th percentile file and the 10GB file were never cached"
        let mut p = ProxyServer::new("sq", cfg(100_000, 1_000, 3600.0));
        for _ in 0..3 {
            let r = p.lookup("/big", 2_335, t(0.0));
            assert_eq!(
                r,
                ProxyLookup::Miss { cacheable: false, reason: MissReason::TooLarge }
            );
        }
        assert_eq!(p.object_count(), 0);
        assert_eq!(p.stats.passthrough_too_large, 3);
    }

    #[test]
    fn ttl_expiry_forces_refetch() {
        let mut p = ProxyServer::new("sq", cfg(10_000, 5_000, 60.0));
        p.lookup("/u", 100, t(0.0));
        p.commit("/u", 100, t(0.0));
        assert_eq!(p.lookup("/u", 100, t(59.0)), ProxyLookup::Hit);
        assert_eq!(
            p.lookup("/u", 100, t(61.0)),
            ProxyLookup::Miss { cacheable: true, reason: MissReason::Expired }
        );
        assert_eq!(p.usage().as_u64(), 0, "expired object deleted");
    }

    #[test]
    fn expiry_exactly_at_ttl_boundary_still_serves() {
        // Freshness is `now - stored_at <= ttl`: an object is valid
        // *through* the TTL instant and expired one microsecond after
        // (squid's max-age semantics are inclusive).
        let mut p = ProxyServer::new("sq", cfg(10_000, 5_000, 60.0));
        p.lookup("/u", 100, t(0.0));
        p.commit("/u", 100, t(0.0));
        assert_eq!(p.lookup("/u", 100, t(60.0)), ProxyLookup::Hit, "age == ttl");
        assert_eq!(
            p.lookup("/u", 100, t(60.000001)),
            ProxyLookup::Miss { cacheable: true, reason: MissReason::Expired },
            "one microsecond past the ttl"
        );
        assert_eq!(p.stats.hits, 1);
        assert_eq!(p.stats.misses_expired, 1);
    }

    #[test]
    fn refetch_after_expiry_resets_stored_at_and_lru_position() {
        // ttl 200 s, capacity for two 100-byte objects.
        let mut p = ProxyServer::new("sq", cfg(250, 200, 200.0));
        p.lookup("/a", 100, t(0.0));
        p.commit("/a", 100, t(0.0));
        p.lookup("/b", 100, t(10.0));
        p.commit("/b", 100, t(10.0));
        // /a expires (age 250 > 200) and is re-fetched at t=250. Its
        // freshness clock must restart from the new commit...
        assert_eq!(
            p.lookup("/a", 100, t(250.0)),
            ProxyLookup::Miss { cacheable: true, reason: MissReason::Expired }
        );
        p.commit("/a", 100, t(250.0));
        assert_eq!(
            p.lookup("/a", 100, t(420.0)),
            ProxyLookup::Hit,
            "age counts from the re-commit (170 < 200), not the original store"
        );
        // ...and its LRU position must be the re-commit, so the stale
        // /b (last touched t=10) is the eviction victim, not /a.
        p.lookup("/c", 100, t(430.0));
        p.commit("/c", 100, t(430.0));
        assert_eq!(p.lookup("/a", 100, t(431.0)), ProxyLookup::Hit, "/a survived");
        assert_eq!(
            p.lookup("/b", 100, t(431.0)),
            ProxyLookup::Miss { cacheable: true, reason: MissReason::Cold },
            "/b was evicted (LRU) — and as a *cold* miss, not expired: eviction deleted it"
        );
        assert_eq!(p.stats.evictions, 1);
        assert!(p.usage().as_u64() <= 250);
    }

    #[test]
    fn lru_eviction_under_pressure() {
        let mut p = ProxyServer::new("sq", cfg(250, 200, 3600.0));
        p.lookup("/a", 100, t(0.0));
        p.commit("/a", 100, t(0.0));
        p.lookup("/b", 100, t(1.0));
        p.commit("/b", 100, t(1.0));
        // Touch /a so /b is LRU.
        assert_eq!(p.lookup("/a", 100, t(2.0)), ProxyLookup::Hit);
        // /c (100) forces eviction of /b.
        p.lookup("/c", 100, t(3.0));
        p.commit("/c", 100, t(3.0));
        assert_eq!(p.lookup("/a", 100, t(4.0)), ProxyLookup::Hit);
        assert!(matches!(p.lookup("/b", 100, t(4.0)), ProxyLookup::Miss { .. }));
        assert_eq!(p.stats.evictions, 1);
        assert!(p.usage().as_u64() <= 250);
    }

    #[test]
    fn paper_loop_expiry_scenario() {
        // §5: "Our initial design ... would loop through the list of
        // download files, then loop again ... After downloading the
        // last large file, the first files were already expired."
        let mut p = ProxyServer::new("sq", cfg(1 << 30, 1 << 20, 100.0));
        let files: Vec<String> = (0..5).map(|i| format!("/f{i}")).collect();
        // First pass: each download takes 30 "seconds".
        for (i, f) in files.iter().enumerate() {
            let now = t(30.0 * i as f64);
            assert!(matches!(p.lookup(f, 1_000, now), ProxyLookup::Miss { .. }));
            p.commit(f, 1_000, now);
        }
        // Second pass starting at t=150: /f0 (stored t=0) and /f1
        // (t=30) are past the 100 s TTL.
        let mut expired = 0;
        for (i, f) in files.iter().enumerate() {
            let now = t(150.0 + 5.0 * i as f64);
            if matches!(
                p.lookup(f, 1_000, now),
                ProxyLookup::Miss { reason: MissReason::Expired, .. }
            ) {
                expired += 1;
                p.commit(f, 1_000, now);
            }
        }
        assert!(expired >= 2, "early files expired during the loop: {expired}");
    }

    #[test]
    fn recommit_replaces_object() {
        let mut p = ProxyServer::new("sq", cfg(10_000, 5_000, 3600.0));
        p.commit("/u", 100, t(0.0));
        p.commit("/u", 200, t(1.0));
        assert_eq!(p.usage().as_u64(), 200);
        assert_eq!(p.object_count(), 1);
    }

    #[test]
    fn property_usage_never_exceeds_capacity() {
        use crate::util::prop::check;
        check("proxy capacity invariant", 60, |g| {
            let cap = g.u64(500, 5_000);
            let mut p = ProxyServer::new("p", cfg(cap, cap, 1e9));
            for i in 0..g.usize(1, 50) {
                let url = format!("/o{}", g.u64(0, 20));
                let size = g.u64(1, cap);
                let now = t(i as f64);
                if matches!(p.lookup(&url, size, now), ProxyLookup::Miss { cacheable: true, .. }) {
                    p.commit(&url, size, now);
                }
                if p.usage().as_u64() > cap {
                    return (false, format!("usage {} > cap {cap}", p.usage()));
                }
            }
            (true, String::new())
        });
    }
}
