//! Deterministic synthetic file content.
//!
//! The paper's evaluation moves real experiment files we do not have;
//! the substitution (DESIGN.md §2) is a keyed keystream: byte `i` of a
//! file is a pure function of `(path, mtime, i)`. Live-mode transfers
//! therefore carry *real bytes* that any party can independently
//! regenerate and verify — which is exactly the consistency guarantee
//! CVMFS's chunk checksums provide in production (§6: "CVMFS
//! calculates checksums of the data, which guarantees consistency").
//!
//! The stream is SHA-256 in counter mode: block `b` of a file is
//! `sha256(path \0 mtime \0 b)`. Changing `mtime` (a rewrite of the
//! file) changes every byte, so stale-cache detection is testable.

use sha2::{Digest, Sha256};

/// Bytes per keystream block (SHA-256 output size).
pub const BLOCK: u64 = 32;

fn block_digest(path: &str, mtime: u64, block_idx: u64) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(path.as_bytes());
    h.update([0u8]);
    h.update(mtime.to_le_bytes());
    h.update([0u8]);
    h.update(block_idx.to_le_bytes());
    h.finalize().into()
}

/// Fill `buf` with the content of `path` (version `mtime`) starting at
/// byte `offset`.
pub fn fill(path: &str, mtime: u64, offset: u64, buf: &mut [u8]) {
    let mut pos = 0usize;
    let mut abs = offset;
    while pos < buf.len() {
        let block_idx = abs / BLOCK;
        let within = (abs % BLOCK) as usize;
        let digest = block_digest(path, mtime, block_idx);
        let take = ((BLOCK as usize) - within).min(buf.len() - pos);
        buf[pos..pos + take].copy_from_slice(&digest[within..within + take]);
        pos += take;
        abs += take as u64;
    }
}

/// SHA-256 of a content extent — the indexer's chunk-boundary checksum
/// (§3.1: "Checksum of files along the chunk boundaries").
pub fn extent_checksum(path: &str, mtime: u64, offset: u64, len: u64) -> [u8; 32] {
    let mut h = Sha256::new();
    let mut remaining = len;
    let mut abs = offset;
    let mut buf = [0u8; 8192];
    while remaining > 0 {
        let take = remaining.min(buf.len() as u64) as usize;
        fill(path, mtime, abs, &mut buf[..take]);
        h.update(&buf[..take]);
        abs += take as u64;
        remaining -= take as u64;
    }
    h.finalize().into()
}

/// Verify a received buffer against the expected content.
pub fn verify(path: &str, mtime: u64, offset: u64, got: &[u8]) -> bool {
    let mut expected = vec![0u8; got.len()];
    fill(path, mtime, offset, &mut expected);
    expected == got
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = [0u8; 100];
        let mut b = [0u8; 100];
        fill("/data/f1", 7, 0, &mut a);
        fill("/data/f1", 7, 0, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn offset_consistency() {
        // Reading [100, 200) directly equals bytes 100..200 of [0, 300).
        let mut whole = vec![0u8; 300];
        fill("/data/f2", 1, 0, &mut whole);
        let mut part = vec![0u8; 100];
        fill("/data/f2", 1, 100, &mut part);
        assert_eq!(&whole[100..200], &part[..]);
    }

    #[test]
    fn unaligned_offsets() {
        let mut whole = vec![0u8; 200];
        fill("/f", 0, 0, &mut whole);
        for &(off, len) in &[(1u64, 31usize), (31, 33), (33, 1), (63, 65)] {
            let mut part = vec![0u8; len];
            fill("/f", 0, off, &mut part);
            assert_eq!(&whole[off as usize..off as usize + len], &part[..], "off={off}");
        }
    }

    #[test]
    fn distinct_paths_and_versions_differ() {
        let mut a = [0u8; 64];
        let mut b = [0u8; 64];
        fill("/p1", 0, 0, &mut a);
        fill("/p2", 0, 0, &mut b);
        assert_ne!(a, b);
        fill("/p1", 1, 0, &mut b); // same path, new mtime
        assert_ne!(a, b);
    }

    #[test]
    fn checksum_matches_manual_hash() {
        use sha2::{Digest, Sha256};
        let mut buf = vec![0u8; 10_000];
        fill("/cks", 3, 500, &mut buf);
        let manual: [u8; 32] = Sha256::digest(&buf).into();
        assert_eq!(extent_checksum("/cks", 3, 500, 10_000), manual);
    }

    #[test]
    fn verify_accepts_and_rejects() {
        let mut buf = vec![0u8; 256];
        fill("/v", 9, 64, &mut buf);
        assert!(verify("/v", 9, 64, &buf));
        buf[10] ^= 0xff;
        assert!(!verify("/v", 9, 64, &buf));
        // Wrong version (stale cache) detected.
        let mut stale = vec![0u8; 256];
        fill("/v", 8, 64, &mut stale);
        assert!(!verify("/v", 9, 64, &stale));
    }

    #[test]
    fn property_fill_is_extent_consistent() {
        use crate::util::prop::check;
        check("content extent consistency", 50, |g| {
            let off = g.u64(0, 1_000);
            let len = g.usize(1, 512);
            let split = g.usize(0, len);
            let mut whole = vec![0u8; len];
            fill("/prop", 5, off, &mut whole);
            let mut left = vec![0u8; split];
            let mut right = vec![0u8; len - split];
            fill("/prop", 5, off, &mut left);
            fill("/prop", 5, off + split as u64, &mut right);
            let ok = whole[..split] == left[..] && whole[split..] == right[..];
            (ok, format!("off={off} len={len} split={split}"))
        });
    }
}
