//! Data origin: the authoritative source of data in the federation.
//!
//! Paper §3: "Data origins are installed on the researcher's storage.
//! The origin is the authoritative source of data within the
//! federation. Each Origin is registered to serve a subset of the
//! global namespace." Built on XRootD in production; here a from-
//! scratch service (DESIGN.md §2 row 2) with:
//!
//! * a [`Dataset`] of exported files (the "researcher's storage"),
//! * deterministic synthetic [`content`] so live transfers carry real,
//!   verifiable bytes without shipping real experiment data,
//! * the CVMFS [`indexer`] that scans the origin and computes
//!   chunk-boundary checksums (§3.1).

pub mod content;
pub mod indexer;

use crate::namespace::OriginId;
use std::collections::BTreeMap;

/// Metadata of one exported file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FileMeta {
    pub size: u64,
    /// Modification time (seconds since epoch) — drives re-indexing.
    pub mtime: u64,
    /// POSIX permission bits (the indexer records them).
    pub perm: u16,
}

/// An origin server exporting one namespace prefix.
#[derive(Debug)]
pub struct Origin {
    pub id: OriginId,
    pub name: String,
    /// Namespace prefix this origin is authoritative for.
    pub prefix: String,
    files: BTreeMap<String, FileMeta>,
    /// Served-bytes counter (monitoring).
    pub bytes_served: u64,
    /// Location queries answered (redirector traffic).
    pub locate_queries: u64,
}

/// Errors from origin operations.
#[derive(Debug, PartialEq)]
pub enum OriginError {
    OutsidePrefix(String),
    NotFound(String),
    BadRange {
        path: String,
        offset: u64,
        len: u64,
        size: u64,
    },
}

impl std::fmt::Display for OriginError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OriginError::OutsidePrefix(p) => write!(f, "path {p:?} is outside origin prefix"),
            OriginError::NotFound(p) => write!(f, "no such file: {p:?}"),
            OriginError::BadRange {
                path,
                offset,
                len,
                size,
            } => write!(
                f,
                "read past EOF: {path:?} offset {offset} len {len} size {size}"
            ),
        }
    }
}

impl std::error::Error for OriginError {}

impl Origin {
    pub fn new(id: OriginId, name: impl Into<String>, prefix: impl Into<String>) -> Self {
        let prefix = prefix.into();
        assert!(prefix.starts_with('/'), "origin prefix must be absolute");
        Origin {
            id,
            name: name.into(),
            prefix,
            files: BTreeMap::new(),
            bytes_served: 0,
            locate_queries: 0,
        }
    }

    fn check_prefix(&self, path: &str) -> Result<(), OriginError> {
        if path.starts_with(&self.prefix) {
            Ok(())
        } else {
            Err(OriginError::OutsidePrefix(path.to_string()))
        }
    }

    /// Export (or overwrite) a file.
    pub fn put_file(&mut self, path: &str, meta: FileMeta) -> Result<(), OriginError> {
        self.check_prefix(path)?;
        self.files.insert(path.to_string(), meta);
        Ok(())
    }

    /// Remove a file (owner reclaiming space).
    pub fn remove_file(&mut self, path: &str) -> Option<FileMeta> {
        self.files.remove(path)
    }

    /// Update mtime/size in place (researcher rewrote the file) — the
    /// indexer must notice this (§3.1).
    pub fn modify_file(&mut self, path: &str, size: u64, mtime: u64) -> Result<(), OriginError> {
        let meta = self
            .files
            .get_mut(path)
            .ok_or_else(|| OriginError::NotFound(path.to_string()))?;
        meta.size = size;
        meta.mtime = mtime;
        Ok(())
    }

    /// Does this origin hold `path`? (The redirector's question.)
    pub fn locate(&mut self, path: &str) -> bool {
        self.locate_queries += 1;
        self.files.contains_key(path)
    }

    pub fn stat(&self, path: &str) -> Result<FileMeta, OriginError> {
        self.files
            .get(path)
            .copied()
            .ok_or_else(|| OriginError::NotFound(path.to_string()))
    }

    /// Validate a logical read and account the served bytes. Flow-level
    /// simulation transfers no payload; live mode pairs this with
    /// [`content::fill`] for the actual bytes.
    pub fn read(&mut self, path: &str, offset: u64, len: u64) -> Result<FileMeta, OriginError> {
        let meta = self.stat(path)?;
        if offset.checked_add(len).is_none_or(|end| end > meta.size) {
            return Err(OriginError::BadRange {
                path: path.to_string(),
                offset,
                len,
                size: meta.size,
            });
        }
        self.bytes_served += len;
        Ok(meta)
    }

    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.files.values().map(|m| m.size).sum()
    }

    /// Iterate over all exported files (the indexer's scan).
    pub fn iter(&self) -> impl Iterator<Item = (&String, &FileMeta)> {
        self.files.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn origin() -> Origin {
        let mut o = Origin::new(OriginId(0), "stash-chicago", "/osgconnect/public");
        o.put_file(
            "/osgconnect/public/user1/data.tar",
            FileMeta {
                size: 1_000_000,
                mtime: 100,
                perm: 0o644,
            },
        )
        .unwrap();
        o
    }

    #[test]
    fn put_and_stat() {
        let o = origin();
        let m = o.stat("/osgconnect/public/user1/data.tar").unwrap();
        assert_eq!(m.size, 1_000_000);
        assert_eq!(o.file_count(), 1);
        assert_eq!(o.total_bytes(), 1_000_000);
    }

    #[test]
    fn rejects_out_of_prefix() {
        let mut o = origin();
        let e = o
            .put_file(
                "/ospool/ligo/f.gwf",
                FileMeta { size: 1, mtime: 0, perm: 0o644 },
            )
            .unwrap_err();
        assert!(matches!(e, OriginError::OutsidePrefix(_)));
    }

    #[test]
    fn read_accounting_and_ranges() {
        let mut o = origin();
        o.read("/osgconnect/public/user1/data.tar", 0, 500_000).unwrap();
        o.read("/osgconnect/public/user1/data.tar", 500_000, 500_000)
            .unwrap();
        assert_eq!(o.bytes_served, 1_000_000);
        let e = o
            .read("/osgconnect/public/user1/data.tar", 900_000, 200_000)
            .unwrap_err();
        assert!(matches!(e, OriginError::BadRange { .. }));
        // Overflowing range must not panic.
        let e = o
            .read("/osgconnect/public/user1/data.tar", u64::MAX, 2)
            .unwrap_err();
        assert!(matches!(e, OriginError::BadRange { .. }));
    }

    #[test]
    fn locate_counts_queries() {
        let mut o = origin();
        assert!(o.locate("/osgconnect/public/user1/data.tar"));
        assert!(!o.locate("/osgconnect/public/nope"));
        assert_eq!(o.locate_queries, 2);
    }

    #[test]
    fn modify_updates_meta() {
        let mut o = origin();
        o.modify_file("/osgconnect/public/user1/data.tar", 42, 200)
            .unwrap();
        let m = o.stat("/osgconnect/public/user1/data.tar").unwrap();
        assert_eq!((m.size, m.mtime), (42, 200));
        assert_eq!(
            o.modify_file("/osgconnect/public/zzz", 1, 1),
            Err(OriginError::NotFound("/osgconnect/public/zzz".into()))
        );
    }

    #[test]
    fn remove_file() {
        let mut o = origin();
        assert!(o.remove_file("/osgconnect/public/user1/data.tar").is_some());
        assert!(o.remove_file("/osgconnect/public/user1/data.tar").is_none());
        assert_eq!(o.file_count(), 0);
    }
}
