//! CVMFS origin indexer.
//!
//! Paper §3.1: "we wrote an indexer which will scan a remote data
//! origin and gather metadata about the files": name and directory
//! structure, size and permissions, and checksums along chunk
//! boundaries. "The indexer will detect changes to files by checking
//! the file modification time and file size. ... The indexer must scan
//! the entire filesystem each iteration, causing a delay proportional
//! to the number of files."
//!
//! This module reproduces that component: [`Indexer::scan`] walks an
//! [`Origin`] and incrementally maintains an [`Index`]; the returned
//! [`ScanDelta`] reports what changed, and [`Indexer::scan_duration`]
//! models the per-file latency so simulations can account for the
//! publication delay CVMFS clients experience.

use super::content;
use super::Origin;
use crate::util::{ByteSize, Duration};
use std::collections::BTreeMap;

/// Indexed metadata of one file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexEntry {
    pub size: u64,
    pub mtime: u64,
    pub perm: u16,
    /// Chunk size used for the checksum boundaries.
    pub chunk_size: u64,
    /// SHA-256 per chunk (last chunk may be short). Present only when
    /// the scan ran with checksums enabled.
    pub checksums: Option<Vec<[u8; 32]>>,
}

/// Result of one scan iteration.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ScanDelta {
    pub added: usize,
    pub reindexed: usize,
    pub removed: usize,
    pub unchanged: usize,
}

/// The published catalog the CVMFS client mounts.
#[derive(Debug, Default)]
pub struct Index {
    entries: BTreeMap<String, IndexEntry>,
    /// Scan iterations performed.
    pub scans: u64,
}

impl Index {
    pub fn get(&self, path: &str) -> Option<&IndexEntry> {
        self.entries.get(path)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Directory listing: immediate children of `dir` (the POSIX
    /// interface CVMFS exposes, §3.1).
    pub fn list(&self, dir: &str) -> Vec<String> {
        let prefix = if dir.ends_with('/') {
            dir.to_string()
        } else {
            format!("{dir}/")
        };
        let mut children: Vec<String> = Vec::new();
        for path in self.entries.keys() {
            if let Some(rest) = path.strip_prefix(&prefix) {
                let child = match rest.find('/') {
                    Some(i) => format!("{}{}/", prefix, &rest[..i]),
                    None => path.clone(),
                };
                if children.last() != Some(&child) {
                    children.push(child);
                }
            }
        }
        children.dedup();
        children
    }
}

/// Indexer configuration + state.
#[derive(Debug)]
pub struct Indexer {
    /// Chunk size for checksum boundaries (CVMFS: 24 MB, §3.1).
    pub chunk_size: ByteSize,
    /// Compute chunk checksums during scans. Disabled for simulation
    /// scans over multi-TB synthetic catalogs; enabled in live mode
    /// and tests, where transfers verify against these.
    pub compute_checksums: bool,
    /// Modelled metadata stat cost per file per iteration.
    pub per_file_cost: Duration,
    /// Modelled checksum throughput (bytes/sec) for changed files.
    pub hash_bytes_per_sec: f64,
}

impl Default for Indexer {
    fn default() -> Self {
        Indexer {
            chunk_size: ByteSize::mb(24),
            compute_checksums: true,
            per_file_cost: Duration::from_micros(200),
            hash_bytes_per_sec: 400e6,
        }
    }
}

impl Indexer {
    /// One scan iteration over the origin, updating `index` in place.
    pub fn scan(&self, origin: &Origin, index: &mut Index) -> ScanDelta {
        index.scans += 1;
        let mut delta = ScanDelta::default();
        let chunk = self.chunk_size.as_u64().max(1);

        // Removal pass: entries whose file vanished from the origin.
        let removed: Vec<String> = index
            .entries
            .keys()
            .filter(|p| origin.stat(p).is_err())
            .cloned()
            .collect();
        delta.removed = removed.len();
        for p in removed {
            index.entries.remove(&p);
        }

        // Add/update pass: "checking the file modification time and
        // file size" (§3.1).
        for (path, meta) in origin.iter() {
            match index.entries.get(path) {
                Some(e) if e.mtime == meta.mtime && e.size == meta.size => {
                    delta.unchanged += 1;
                    continue;
                }
                Some(_) => delta.reindexed += 1,
                None => delta.added += 1,
            }
            let checksums = self.compute_checksums.then(|| {
                let mut sums = Vec::new();
                let mut off = 0;
                while off < meta.size {
                    let len = chunk.min(meta.size - off);
                    sums.push(content::extent_checksum(path, meta.mtime, off, len));
                    off += len;
                }
                sums
            });
            index.entries.insert(
                path.clone(),
                IndexEntry {
                    size: meta.size,
                    mtime: meta.mtime,
                    perm: meta.perm,
                    chunk_size: chunk,
                    checksums,
                },
            );
        }
        delta
    }

    /// Modelled wall-clock duration of a scan: a stat per file plus
    /// hashing for changed bytes — "a delay proportional to the number
    /// of files in the filesystem" (§3.1).
    pub fn scan_duration(&self, file_count: usize, changed_bytes: u64) -> Duration {
        let stat = self.per_file_cost * file_count as u64;
        let hash = if self.compute_checksums {
            Duration::from_secs_f64(changed_bytes as f64 / self.hash_bytes_per_sec)
        } else {
            Duration::ZERO
        };
        stat + hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::namespace::OriginId;
    use crate::origin::FileMeta;

    fn origin_with(files: &[(&str, u64, u64)]) -> Origin {
        let mut o = Origin::new(OriginId(0), "test", "/data");
        for &(p, size, mtime) in files {
            o.put_file(p, FileMeta { size, mtime, perm: 0o644 }).unwrap();
        }
        o
    }

    fn small_indexer() -> Indexer {
        Indexer {
            chunk_size: ByteSize::bytes(1000),
            ..Indexer::default()
        }
    }

    #[test]
    fn first_scan_adds_everything() {
        let o = origin_with(&[("/data/a", 2_500, 1), ("/data/b", 10, 1)]);
        let idx = small_indexer();
        let mut index = Index::default();
        let d = idx.scan(&o, &mut index);
        assert_eq!(d, ScanDelta { added: 2, reindexed: 0, removed: 0, unchanged: 0 });
        assert_eq!(index.len(), 2);
        // /data/a spans 3 chunks of 1000.
        let e = index.get("/data/a").unwrap();
        assert_eq!(e.checksums.as_ref().unwrap().len(), 3);
        assert_eq!(e.chunk_size, 1000);
    }

    #[test]
    fn unchanged_files_skip_reindex() {
        let o = origin_with(&[("/data/a", 100, 1)]);
        let idx = small_indexer();
        let mut index = Index::default();
        idx.scan(&o, &mut index);
        let d = idx.scan(&o, &mut index);
        assert_eq!(d, ScanDelta { added: 0, reindexed: 0, removed: 0, unchanged: 1 });
        assert_eq!(index.scans, 2);
    }

    #[test]
    fn mtime_change_triggers_reindex() {
        let mut o = origin_with(&[("/data/a", 100, 1)]);
        let idx = small_indexer();
        let mut index = Index::default();
        idx.scan(&o, &mut index);
        let before = index.get("/data/a").unwrap().checksums.clone().unwrap();
        o.modify_file("/data/a", 100, 2).unwrap();
        let d = idx.scan(&o, &mut index);
        assert_eq!(d.reindexed, 1);
        let after = index.get("/data/a").unwrap().checksums.clone().unwrap();
        assert_ne!(before, after, "new content version must re-checksum");
    }

    #[test]
    fn size_change_triggers_reindex() {
        let mut o = origin_with(&[("/data/a", 100, 1)]);
        let idx = small_indexer();
        let mut index = Index::default();
        idx.scan(&o, &mut index);
        o.modify_file("/data/a", 2_100, 1).unwrap();
        let d = idx.scan(&o, &mut index);
        assert_eq!(d.reindexed, 1);
        assert_eq!(index.get("/data/a").unwrap().checksums.as_ref().unwrap().len(), 3);
    }

    #[test]
    fn removed_files_dropped() {
        let mut o = origin_with(&[("/data/a", 10, 1), ("/data/b", 10, 1)]);
        let idx = small_indexer();
        let mut index = Index::default();
        idx.scan(&o, &mut index);
        o.remove_file("/data/a");
        let d = idx.scan(&o, &mut index);
        assert_eq!(d.removed, 1);
        assert!(index.get("/data/a").is_none());
        assert!(index.get("/data/b").is_some());
    }

    #[test]
    fn checksums_match_content_module() {
        let o = origin_with(&[("/data/a", 2_500, 7)]);
        let idx = small_indexer();
        let mut index = Index::default();
        idx.scan(&o, &mut index);
        let e = index.get("/data/a").unwrap();
        let sums = e.checksums.as_ref().unwrap();
        assert_eq!(sums[0], content::extent_checksum("/data/a", 7, 0, 1000));
        assert_eq!(sums[2], content::extent_checksum("/data/a", 7, 2000, 500));
    }

    #[test]
    fn listing_directories() {
        let o = origin_with(&[
            ("/data/u1/a", 1, 1),
            ("/data/u1/sub/b", 1, 1),
            ("/data/u2/c", 1, 1),
        ]);
        let idx = small_indexer();
        let mut index = Index::default();
        idx.scan(&o, &mut index);
        assert_eq!(index.list("/data"), vec!["/data/u1/", "/data/u2/"]);
        assert_eq!(index.list("/data/u1"), vec!["/data/u1/a", "/data/u1/sub/"]);
    }

    #[test]
    fn scan_duration_proportional_to_files() {
        let idx = Indexer::default();
        let d1 = idx.scan_duration(1_000, 0);
        let d2 = idx.scan_duration(2_000, 0);
        assert_eq!(d2.as_micros(), 2 * d1.as_micros());
        // Hashing cost adds on top.
        let d3 = idx.scan_duration(1_000, 400_000_000);
        assert!((d3.as_secs_f64() - (d1.as_secs_f64() + 1.0)).abs() < 1e-6);
    }

    #[test]
    fn no_checksum_mode_skips_hashing() {
        let o = origin_with(&[("/data/a", 1_000_000, 1)]);
        let idx = Indexer {
            compute_checksums: false,
            ..small_indexer()
        };
        let mut index = Index::default();
        idx.scan(&o, &mut index);
        assert!(index.get("/data/a").unwrap().checksums.is_none());
        assert_eq!(idx.scan_duration(10, 1 << 30), idx.scan_duration(10, 0));
    }
}
