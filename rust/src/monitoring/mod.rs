//! XRootD-style detailed monitoring (paper §3.2, Figure 3).
//!
//! "Each StashCache cache sends a UDP packet for each file open, user
//! login, and file close. The collector of this information is complex
//! since each packet contains different information. ... On each file
//! close packet, the collector combines the data from the file open
//! and user login packets and sends a JSON message to the OSG message
//! bus. The OSG message bus distributes the file monitoring to
//! databases in the OSG and the WLCG."
//!
//! Pipeline, exactly as Figure 3:
//!
//! ```text
//! caches --binary UDP--> [packets] --> [collector] --JSON--> [bus] --> [aggregator]
//! ```
//!
//! * [`packets`] — the three binary packet formats and their codecs.
//! * [`collector`] — joins login/open/close streams per server into
//!   complete [`TransferReport`]s.
//! * [`json`] — minimal JSON writer/parser (no serde offline).
//! * [`bus`] — the message bus between collector and consumers.
//! * [`aggregator`] — the "database": per-experiment usage (Table 1),
//!   file-size percentiles (Table 2), weekly usage series (Figure 4).
//! * [`availability`] — fault-layer counters (per-cache downtime,
//!   failovers, retries, aborted bytes) for the chaos reports.

pub mod aggregator;
pub mod availability;
pub mod bus;
pub mod collector;
pub mod json;
pub mod packets;

use crate::util::SimTime;

/// Fully-joined record of one file transfer — the JSON message the
/// collector publishes on every file close.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferReport {
    /// Cache server that served the transfer.
    pub server: String,
    /// Client host (from the user login packet).
    pub client_host: String,
    /// Login protocol: "xrootd" or "http".
    pub protocol: String,
    pub ipv6: bool,
    pub path: String,
    pub file_size: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub read_ops: u32,
    pub write_ops: u32,
    pub opened_at: SimTime,
    pub closed_at: SimTime,
}

impl TransferReport {
    /// Experiment owning the path, by namespace convention
    /// (`/ospool/<experiment>/...`; anything else is "other").
    pub fn experiment(&self) -> &str {
        let mut parts = self.path.split('/').filter(|s| !s.is_empty());
        match (parts.next(), parts.next()) {
            (Some("ospool"), Some(exp)) => exp,
            (Some("osgconnect"), Some(_)) => "osg-connect",
            _ => "other",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(path: &str) -> TransferReport {
        TransferReport {
            server: "syracuse".into(),
            client_host: "worker01.syr.edu".into(),
            protocol: "xrootd".into(),
            ipv6: false,
            path: path.into(),
            file_size: 100,
            bytes_read: 100,
            bytes_written: 0,
            read_ops: 4,
            write_ops: 0,
            opened_at: SimTime::ZERO,
            closed_at: SimTime::from_secs_f64(2.0),
        }
    }

    #[test]
    fn experiment_extraction() {
        assert_eq!(report("/ospool/ligo/frames/a.gwf").experiment(), "ligo");
        assert_eq!(report("/ospool/des/y3/cat.fits").experiment(), "des");
        assert_eq!(report("/osgconnect/public/u/f").experiment(), "osg-connect");
        assert_eq!(report("/weird/path").experiment(), "other");
        assert_eq!(report("/ospool").experiment(), "other");
    }
}
