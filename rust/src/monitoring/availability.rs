//! Availability accounting: the fault layer's counters, shaped for the
//! paper report's availability section.
//!
//! Production StashCache operations (and the OSDF follow-up monitoring
//! work) track exactly these quantities: how long each cache was dark,
//! how many transfers had to fail over, and how much transferred work
//! was thrown away. A chaos campaign
//! ([`crate::sim::campaign::run_with_faults`]) assembles one
//! [`AvailabilityReport`] from the engine's counters and the
//! federation's [`crate::fault::FaultState`] downtime ledger;
//! [`crate::report::paper::availability_table`] renders it.

use crate::util::Duration;

/// Availability of one cache over an observation window.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheAvailability {
    pub site: String,
    /// Outages that started during the window.
    pub outages: u32,
    /// Accumulated downtime (open outages counted to the window end).
    pub downtime: Duration,
}

impl CacheAvailability {
    /// Fraction of `window` the cache was serving, in [0, 1].
    pub fn availability(&self, window: Duration) -> f64 {
        if window.as_micros() == 0 {
            return 1.0;
        }
        1.0 - (self.downtime.as_secs_f64() / window.as_secs_f64()).min(1.0)
    }
}

/// Fault-layer counters over one run: the availability section of the
/// report.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AvailabilityReport {
    /// Observation window: the run span from fault injection to the
    /// last completion. Downtime is measured on the same clock, so
    /// `downtime <= window` always holds.
    pub window: Duration,
    /// Per-cache downtime, in site order.
    pub caches: Vec<CacheAvailability>,
    /// Fault events applied during the run.
    pub faults_applied: u64,
    /// Mid-transfer aborts survived (flow cancelled, session re-planned).
    pub failovers: u64,
    /// Session re-resolution attempts after any failure.
    pub retries: u64,
    /// Bytes already transferred by flows that were then aborted.
    pub aborted_bytes: u64,
    /// Sessions that gave up on caches and streamed from the origin.
    pub direct_fallbacks: u64,
    /// Downloads that completed (a chaos run completes all of them or
    /// panics — this equals the job count, never less).
    pub downloads_completed: u64,
}

impl AvailabilityReport {
    /// Mean cache availability over the window (1.0 when no cache has
    /// downtime — or when there are no caches at all).
    pub fn mean_availability(&self) -> f64 {
        if self.caches.is_empty() {
            return 1.0;
        }
        self.caches
            .iter()
            .map(|c| c.availability(self.window))
            .sum::<f64>()
            / self.caches.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn availability_math() {
        let c = CacheAvailability {
            site: "syracuse".into(),
            outages: 1,
            downtime: Duration::from_secs(25),
        };
        assert!((c.availability(Duration::from_secs(100)) - 0.75).abs() < 1e-12);
        // Downtime longer than the window clamps to zero.
        assert_eq!(c.availability(Duration::from_secs(10)), 0.0);
        // Degenerate window: vacuously available.
        assert_eq!(c.availability(Duration::ZERO), 1.0);
    }

    #[test]
    fn mean_availability_averages_caches() {
        let report = AvailabilityReport {
            window: Duration::from_secs(100),
            caches: vec![
                CacheAvailability {
                    site: "a".into(),
                    outages: 1,
                    downtime: Duration::from_secs(50),
                },
                CacheAvailability {
                    site: "b".into(),
                    outages: 0,
                    downtime: Duration::ZERO,
                },
            ],
            ..AvailabilityReport::default()
        };
        assert!((report.mean_availability() - 0.75).abs() < 1e-12);
        assert_eq!(AvailabilityReport::default().mean_availability(), 1.0);
    }
}
