//! The monitoring collector (paper §3.2, Figure 3).
//!
//! "The collector combines the different UDP packets to fill in full
//! information for each file transfer. On each file close packet, the
//! collector combines the data from the file open and user login
//! packets and sends a JSON message to the OSG message bus."
//!
//! State is kept **per server** (user and file IDs are only unique
//! within one cache's stream). Orphan closes (open packet lost — UDP
//! is lossy) and logins/opens that never close are counted and
//! expired, since a production collector must bound its memory.

use super::bus::Bus;
use super::json::{self, ObjBuilder};
use super::packets::{Envelope, Packet};
use super::TransferReport;
use crate::util::{Duration, SimTime};
use std::collections::HashMap;

#[derive(Debug, Clone)]
struct LoginState {
    client_host: String,
    protocol: &'static str,
    ipv6: bool,
    seen_at: SimTime,
}

#[derive(Debug, Clone)]
struct OpenState {
    user_id: u32,
    path: String,
    file_size: u64,
    opened_at: SimTime,
}

/// Collector statistics (lossy-stream accounting).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CollectorStats {
    pub packets: u64,
    pub reports_published: u64,
    /// Close without a matching open (lost open packet).
    pub orphan_closes: u64,
    /// Open referencing an unknown user (lost login packet).
    pub unknown_users: u64,
    /// Entries dropped by state expiry.
    pub expired_entries: u64,
    pub decode_errors: u64,
}

/// The collector: joins packet streams into [`TransferReport`]s and
/// publishes them as JSON on the [`Bus`] topic `"transfers"`.
#[derive(Debug)]
pub struct Collector {
    /// server_id → (user_id → login).
    logins: HashMap<u32, HashMap<u32, LoginState>>,
    /// server_id → (file_id → open).
    opens: HashMap<u32, HashMap<u32, OpenState>>,
    /// server_id → display name (registered by the federation).
    server_names: HashMap<u32, String>,
    /// Drop login/open state older than this (bounded memory).
    pub state_ttl: Duration,
    pub stats: CollectorStats,
}

/// Topic the collector publishes on.
pub const TRANSFER_TOPIC: &str = "transfers";

impl Default for Collector {
    fn default() -> Self {
        Self::new()
    }
}

impl Collector {
    pub fn new() -> Self {
        Collector {
            logins: HashMap::new(),
            opens: HashMap::new(),
            server_names: HashMap::new(),
            state_ttl: Duration::from_hours(24),
            stats: CollectorStats::default(),
        }
    }

    /// Register a cache server's display name.
    pub fn register_server(&mut self, server_id: u32, name: impl Into<String>) {
        self.server_names.insert(server_id, name.into());
    }

    fn server_name(&self, id: u32) -> String {
        self.server_names
            .get(&id)
            .cloned()
            .unwrap_or_else(|| format!("server-{id}"))
    }

    /// Ingest a raw datagram (live mode). Malformed data is counted,
    /// never fatal.
    pub fn ingest_datagram(&mut self, datagram: &[u8], bus: &mut Bus) {
        match super::packets::decode(datagram) {
            Ok(env) => self.ingest(env, bus),
            Err(_) => self.stats.decode_errors += 1,
        }
    }

    /// Ingest a decoded packet (sim mode feeds these directly).
    pub fn ingest(&mut self, env: Envelope, bus: &mut Bus) {
        self.stats.packets += 1;
        let server = env.server_id;
        match env.packet {
            Packet::UserLogin { user_id, protocol, ipv6, client_host } => {
                self.logins.entry(server).or_default().insert(
                    user_id,
                    LoginState {
                        client_host,
                        protocol: protocol.as_str(),
                        ipv6,
                        seen_at: env.timestamp,
                    },
                );
            }
            Packet::FileOpen { file_id, user_id, file_size, path } => {
                if !self
                    .logins
                    .get(&server)
                    .is_some_and(|m| m.contains_key(&user_id))
                {
                    self.stats.unknown_users += 1;
                }
                self.opens.entry(server).or_default().insert(
                    file_id,
                    OpenState { user_id, path, file_size, opened_at: env.timestamp },
                );
            }
            Packet::FileClose { file_id, bytes_read, bytes_written, read_ops, write_ops } => {
                let Some(open) = self
                    .opens
                    .get_mut(&server)
                    .and_then(|m| m.remove(&file_id))
                else {
                    self.stats.orphan_closes += 1;
                    return;
                };
                let login = self
                    .logins
                    .get(&server)
                    .and_then(|m| m.get(&open.user_id));
                let report = TransferReport {
                    server: self.server_name(server),
                    client_host: login
                        .map(|l| l.client_host.clone())
                        .unwrap_or_else(|| "unknown".into()),
                    protocol: login
                        .map(|l| l.protocol.to_string())
                        .unwrap_or_else(|| "unknown".into()),
                    ipv6: login.is_some_and(|l| l.ipv6),
                    path: open.path,
                    file_size: open.file_size,
                    bytes_read,
                    bytes_written,
                    read_ops,
                    write_ops,
                    opened_at: open.opened_at,
                    closed_at: env.timestamp,
                };
                self.publish(&report, bus);
            }
        }
    }

    fn publish(&mut self, r: &TransferReport, bus: &mut Bus) {
        let msg = ObjBuilder::new()
            .str("server", &r.server)
            .str("client_host", &r.client_host)
            .str("protocol", &r.protocol)
            .bool("ipv6", r.ipv6)
            .str("path", &r.path)
            .int("file_size", r.file_size)
            .int("bytes_read", r.bytes_read)
            .int("bytes_written", r.bytes_written)
            .int("read_ops", r.read_ops as u64)
            .int("write_ops", r.write_ops as u64)
            .int("opened_us", r.opened_at.as_micros())
            .int("closed_us", r.closed_at.as_micros())
            .build();
        bus.publish(TRANSFER_TOPIC, json::to_string(&msg));
        self.stats.reports_published += 1;
    }

    /// Expire login/open state older than `state_ttl` (run periodically).
    pub fn expire(&mut self, now: SimTime) {
        let ttl = self.state_ttl;
        let mut dropped = 0usize;
        for m in self.logins.values_mut() {
            let before = m.len();
            m.retain(|_, l| now.saturating_sub(l.seen_at) <= ttl);
            dropped += before - m.len();
        }
        for m in self.opens.values_mut() {
            let before = m.len();
            m.retain(|_, o| now.saturating_sub(o.opened_at) <= ttl);
            dropped += before - m.len();
        }
        self.stats.expired_entries += dropped as u64;
    }

    /// Parse a bus message back into a [`TransferReport`] (consumer
    /// side — used by the aggregator and tests).
    pub fn parse_report(text: &str) -> Option<TransferReport> {
        let v = json::parse(text).ok()?;
        Some(TransferReport {
            server: v.get("server")?.as_str()?.to_string(),
            client_host: v.get("client_host")?.as_str()?.to_string(),
            protocol: v.get("protocol")?.as_str()?.to_string(),
            ipv6: v.get("ipv6")?.as_bool()?,
            path: v.get("path")?.as_str()?.to_string(),
            file_size: v.get("file_size")?.as_u64()?,
            bytes_read: v.get("bytes_read")?.as_u64()?,
            bytes_written: v.get("bytes_written")?.as_u64()?,
            read_ops: v.get("read_ops")?.as_u64()? as u32,
            write_ops: v.get("write_ops")?.as_u64()? as u32,
            opened_at: SimTime(v.get("opened_us")?.as_u64()?),
            closed_at: SimTime(v.get("closed_us")?.as_u64()?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitoring::packets::Protocol;

    fn env(server_id: u32, t: f64, packet: Packet) -> Envelope {
        Envelope {
            server_id,
            timestamp: SimTime::from_secs_f64(t),
            packet,
        }
    }

    fn login(user: u32) -> Packet {
        Packet::UserLogin {
            user_id: user,
            protocol: Protocol::Xrootd,
            ipv6: false,
            client_host: format!("host-{user}"),
        }
    }

    fn open(file: u32, user: u32, path: &str, size: u64) -> Packet {
        Packet::FileOpen {
            file_id: file,
            user_id: user,
            file_size: size,
            path: path.into(),
        }
    }

    fn close(file: u32, read: u64) -> Packet {
        Packet::FileClose {
            file_id: file,
            bytes_read: read,
            bytes_written: 0,
            read_ops: 3,
            write_ops: 0,
        }
    }

    #[test]
    fn joins_full_transfer() {
        let mut c = Collector::new();
        c.register_server(1, "syracuse");
        let mut bus = Bus::new();
        let mut rx = bus.subscribe(TRANSFER_TOPIC);
        c.ingest(env(1, 0.0, login(10)), &mut bus);
        c.ingest(env(1, 1.0, open(5, 10, "/ospool/ligo/f.gwf", 500)), &mut bus);
        c.ingest(env(1, 3.0, close(5, 500)), &mut bus);
        let msg = rx.try_recv(&bus).expect("one report");
        let r = Collector::parse_report(&msg).unwrap();
        assert_eq!(r.server, "syracuse");
        assert_eq!(r.client_host, "host-10");
        assert_eq!(r.protocol, "xrootd");
        assert_eq!(r.path, "/ospool/ligo/f.gwf");
        assert_eq!(r.bytes_read, 500);
        assert_eq!(r.opened_at, SimTime::from_secs_f64(1.0));
        assert_eq!(r.closed_at, SimTime::from_secs_f64(3.0));
        assert_eq!(r.experiment(), "ligo");
    }

    #[test]
    fn per_server_id_spaces() {
        // Same user/file ids on two servers must not collide.
        let mut c = Collector::new();
        let mut bus = Bus::new();
        let mut rx = bus.subscribe(TRANSFER_TOPIC);
        for s in [1u32, 2] {
            c.ingest(env(s, 0.0, login(1)), &mut bus);
            c.ingest(env(s, 0.5, open(1, 1, &format!("/ospool/e{s}/f"), 10)), &mut bus);
        }
        c.ingest(env(1, 1.0, close(1, 10)), &mut bus);
        c.ingest(env(2, 1.0, close(1, 10)), &mut bus);
        let r1 = Collector::parse_report(&rx.recv(&mut bus).unwrap()).unwrap();
        let r2 = Collector::parse_report(&rx.recv(&mut bus).unwrap()).unwrap();
        assert_eq!(r1.path, "/ospool/e1/f");
        assert_eq!(r2.path, "/ospool/e2/f");
    }

    #[test]
    fn orphan_close_counted_not_published() {
        let mut c = Collector::new();
        let mut bus = Bus::new();
        let mut rx = bus.subscribe(TRANSFER_TOPIC);
        c.ingest(env(1, 0.0, close(99, 5)), &mut bus);
        assert_eq!(c.stats.orphan_closes, 1);
        assert!(rx.try_recv(&bus).is_none());
    }

    #[test]
    fn missing_login_still_reports() {
        let mut c = Collector::new();
        let mut bus = Bus::new();
        let mut rx = bus.subscribe(TRANSFER_TOPIC);
        c.ingest(env(1, 0.0, open(5, 77, "/ospool/des/x", 10)), &mut bus);
        c.ingest(env(1, 1.0, close(5, 10)), &mut bus);
        assert_eq!(c.stats.unknown_users, 1);
        let r = Collector::parse_report(&rx.try_recv(&bus).unwrap()).unwrap();
        assert_eq!(r.client_host, "unknown");
        assert_eq!(r.path, "/ospool/des/x");
    }

    #[test]
    fn close_consumes_open() {
        let mut c = Collector::new();
        let mut bus = Bus::new();
        c.ingest(env(1, 0.0, login(1)), &mut bus);
        c.ingest(env(1, 0.1, open(5, 1, "/p", 10)), &mut bus);
        c.ingest(env(1, 0.2, close(5, 10)), &mut bus);
        c.ingest(env(1, 0.3, close(5, 10)), &mut bus);
        assert_eq!(c.stats.orphan_closes, 1, "double close is orphan");
    }

    #[test]
    fn ingest_datagram_roundtrip_and_garbage() {
        let mut c = Collector::new();
        let mut bus = Bus::new();
        let e = env(3, 0.0, login(1));
        c.ingest_datagram(&crate::monitoring::packets::encode(&e), &mut bus);
        assert_eq!(c.stats.packets, 1);
        c.ingest_datagram(b"garbage", &mut bus);
        assert_eq!(c.stats.decode_errors, 1);
    }

    #[test]
    fn expiry_bounds_state() {
        let mut c = Collector::new();
        c.state_ttl = Duration::from_secs(10);
        let mut bus = Bus::new();
        c.ingest(env(1, 0.0, login(1)), &mut bus);
        c.ingest(env(1, 0.0, open(5, 1, "/p", 10)), &mut bus);
        c.expire(SimTime::from_secs_f64(100.0));
        assert_eq!(c.stats.expired_entries, 2);
        // Close after expiry is an orphan.
        c.ingest(env(1, 101.0, close(5, 10)), &mut bus);
        assert_eq!(c.stats.orphan_closes, 1);
    }
}
