//! Monitoring aggregation — the "database for aggregation and
//! analytics" of Figure 3, which produced the paper's Table 1 (usage
//! by experiment), Table 2 (file-size percentiles) and Figure 4 (a
//! year of federation usage).
//!
//! File-size percentiles are estimated from a **log-spaced histogram**
//! ([`HIST_BINS`] bins over 1 B .. 10 TB). Binning is pluggable
//! ([`HistBackend`]): the pure-rust reference here, or the AOT
//! JAX/Pallas kernel (`artifacts/usage_hist.hlo.txt`) via
//! [`crate::runtime::HistAgg`] — both must agree bin-for-bin, which an
//! integration test asserts. A bounded reservoir of exact sizes is
//! kept alongside to quantify the histogram's approximation error.

use super::collector::Collector;
use super::bus::{Bus, Subscription};
use super::TransferReport;
use crate::util::stats;
use crate::util::{ByteSize, Pcg64};
use std::collections::BTreeMap;

/// Number of histogram bins (matches the L1 kernel's output shape).
pub const HIST_BINS: usize = 64;
/// Log-range covered: 1 B (log10 = 0) to 10 TB (log10 = 13).
pub const HIST_LOG_MIN: f64 = 0.0;
pub const HIST_LOG_MAX: f64 = 13.0;

/// Map a size to its bin index. Arithmetic is f32, mirroring the
/// Pallas kernel (`kernels/histogram.py`) bit-for-bit so the PJRT and
/// rust backends agree on every input.
pub fn size_to_bin_f(size: f64) -> usize {
    let lg = (size as f32).max(1.0).log10();
    let frac = (lg - HIST_LOG_MIN as f32) / (HIST_LOG_MAX - HIST_LOG_MIN) as f32;
    let idx = (frac * HIST_BINS as f32).floor();
    (idx.max(0.0) as usize).min(HIST_BINS - 1)
}

/// Map an integer byte count to its bin index.
pub fn size_to_bin(bytes: u64) -> usize {
    size_to_bin_f(bytes as f64)
}

/// Geometric midpoint size of a bin (for percentile readout).
pub fn bin_to_size(bin: usize) -> f64 {
    let width = (HIST_LOG_MAX - HIST_LOG_MIN) / HIST_BINS as f64;
    10f64.powf(HIST_LOG_MIN + (bin as f64 + 0.5) * width)
}

/// Batch histogram backend. `sizes` in bytes; returns per-bin counts
/// accumulated over the batch (length [`HIST_BINS`]).
pub trait HistBackend {
    fn histogram(&mut self, sizes: &[f64]) -> Vec<f32>;
}

/// Pure-rust reference binning — must match `usage_hist` in
/// `python/compile/model.py`.
pub struct RustHistBackend;

impl HistBackend for RustHistBackend {
    fn histogram(&mut self, sizes: &[f64]) -> Vec<f32> {
        let mut bins = vec![0f32; HIST_BINS];
        for &s in sizes {
            if s > 0.0 {
                bins[size_to_bin_f(s)] += 1.0;
            }
        }
        bins
    }
}

/// One experiment's accumulated usage.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ExperimentUsage {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub transfers: u64,
}

/// The aggregating store.
pub struct Aggregator<B: HistBackend = RustHistBackend> {
    by_experiment: BTreeMap<String, ExperimentUsage>,
    by_server: BTreeMap<String, ExperimentUsage>,
    /// bytes_read per week index (Fig 4's weekly series).
    weekly: BTreeMap<u64, u64>,
    /// Histogram of *file sizes* seen at file-close (Table 2 is over
    /// transferred files' sizes).
    hist: Vec<f32>,
    /// Batch buffer flushed through the backend.
    pending_sizes: Vec<f64>,
    /// Batch size the backend is invoked with (the AOT kernel's fixed
    /// shape).
    pub batch: usize,
    backend: B,
    /// Bounded exact-size reservoir (error measurement).
    reservoir: Vec<f64>,
    reservoir_seen: u64,
    reservoir_rng: Pcg64,
    pub reports: u64,
    pub ipv6_transfers: u64,
    pub http_transfers: u64,
}

pub const RESERVOIR_CAP: usize = 100_000;

impl Default for Aggregator<RustHistBackend> {
    fn default() -> Self {
        Aggregator::new(RustHistBackend)
    }
}

impl<B: HistBackend> Aggregator<B> {
    pub fn new(backend: B) -> Self {
        Aggregator {
            by_experiment: BTreeMap::new(),
            by_server: BTreeMap::new(),
            weekly: BTreeMap::new(),
            hist: vec![0f32; HIST_BINS],
            pending_sizes: Vec::new(),
            batch: 4096,
            backend,
            reservoir: Vec::new(),
            reservoir_seen: 0,
            reservoir_rng: Pcg64::new(0x5eed_a66, 17),
            reports: 0,
            ipv6_transfers: 0,
            http_transfers: 0,
        }
    }

    /// Ingest one joined transfer report.
    pub fn ingest(&mut self, r: &TransferReport) {
        self.reports += 1;
        let exp = self.by_experiment.entry(r.experiment().to_string()).or_default();
        exp.bytes_read += r.bytes_read;
        exp.bytes_written += r.bytes_written;
        exp.transfers += 1;
        let srv = self.by_server.entry(r.server.clone()).or_default();
        srv.bytes_read += r.bytes_read;
        srv.bytes_written += r.bytes_written;
        srv.transfers += 1;
        let week = r.closed_at.as_micros() / (7 * 86_400 * 1_000_000);
        *self.weekly.entry(week).or_default() += r.bytes_read;
        if r.ipv6 {
            self.ipv6_transfers += 1;
        }
        if r.protocol == "http" {
            self.http_transfers += 1;
        }
        // File-size accounting.
        self.pending_sizes.push(r.file_size as f64);
        if self.pending_sizes.len() >= self.batch {
            self.flush_hist();
        }
        // Reservoir sampling (Vitter's R).
        self.reservoir_seen += 1;
        if self.reservoir.len() < RESERVOIR_CAP {
            self.reservoir.push(r.file_size as f64);
        } else {
            let j = self.reservoir_rng.gen_range(0, self.reservoir_seen);
            if (j as usize) < RESERVOIR_CAP {
                self.reservoir[j as usize] = r.file_size as f64;
            }
        }
    }

    /// Drain a bus subscription into the store.
    pub fn consume(&mut self, bus: &mut Bus, sub: &mut Subscription) -> usize {
        let mut n = 0;
        while let Some(msg) = sub.recv(bus) {
            if let Some(report) = Collector::parse_report(&msg) {
                self.ingest(&report);
                n += 1;
            }
        }
        n
    }

    /// Flush any buffered sizes through the histogram backend.
    pub fn flush_hist(&mut self) {
        if self.pending_sizes.is_empty() {
            return;
        }
        let bins = self.backend.histogram(&self.pending_sizes);
        assert_eq!(bins.len(), HIST_BINS, "backend returned wrong shape");
        for (h, b) in self.hist.iter_mut().zip(bins) {
            *h += b;
        }
        self.pending_sizes.clear();
    }

    /// Table 1: usage by experiment, descending bytes_read.
    pub fn table1(&mut self) -> Vec<(String, ByteSize)> {
        let mut rows: Vec<(String, ByteSize)> = self
            .by_experiment
            .iter()
            .map(|(name, u)| (name.clone(), ByteSize(u.bytes_read)))
            .collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        rows
    }

    pub fn experiment_usage(&self, name: &str) -> Option<ExperimentUsage> {
        self.by_experiment.get(name).copied()
    }

    pub fn server_usage(&self) -> &BTreeMap<String, ExperimentUsage> {
        &self.by_server
    }

    /// Table 2: file-size percentiles estimated from the histogram.
    pub fn table2(&mut self, percentiles: &[f64]) -> Vec<(f64, ByteSize)> {
        self.flush_hist();
        let total: f64 = self.hist.iter().map(|&c| c as f64).sum();
        assert!(total > 0.0, "no samples aggregated");
        let mut out = Vec::with_capacity(percentiles.len());
        for &p in percentiles {
            let target = p / 100.0 * total;
            let mut cum = 0.0;
            let mut answer = bin_to_size(HIST_BINS - 1);
            for (bin, &c) in self.hist.iter().enumerate() {
                let c = c as f64;
                if cum + c >= target && c > 0.0 {
                    // Geometric interpolation within the bin.
                    let frac = ((target - cum) / c).clamp(0.0, 1.0);
                    let width = (HIST_LOG_MAX - HIST_LOG_MIN) / HIST_BINS as f64;
                    let lg = HIST_LOG_MIN + (bin as f64 + frac) * width;
                    answer = 10f64.powf(lg);
                    break;
                }
                cum += c;
            }
            out.push((p, ByteSize(answer.round() as u64)));
        }
        out
    }

    /// Exact percentiles from the reservoir (histogram error check).
    pub fn table2_exact(&mut self, percentiles: &[f64]) -> Vec<(f64, ByteSize)> {
        assert!(!self.reservoir.is_empty());
        let mut data = self.reservoir.clone();
        let vals = stats::percentiles(&mut data, percentiles);
        percentiles
            .iter()
            .zip(vals)
            .map(|(&p, v)| (p, ByteSize(v.round() as u64)))
            .collect()
    }

    /// Figure 4: (week index, bytes read) series, gaps filled with 0.
    pub fn weekly_series(&self) -> Vec<(u64, ByteSize)> {
        let Some((&first, _)) = self.weekly.iter().next() else {
            return Vec::new();
        };
        let (&last, _) = self.weekly.iter().next_back().expect("non-empty");
        (first..=last)
            .map(|w| (w, ByteSize(self.weekly.get(&w).copied().unwrap_or(0))))
            .collect()
    }

    /// Total bytes read across everything.
    pub fn total_bytes(&self) -> ByteSize {
        ByteSize(self.by_experiment.values().map(|u| u.bytes_read).sum())
    }

    pub fn histogram_snapshot(&mut self) -> Vec<f32> {
        self.flush_hist();
        self.hist.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitoring::collector::TRANSFER_TOPIC;
    use crate::util::SimTime;
    fn report(exp: &str, size: u64, week: u64) -> TransferReport {
        let closed = SimTime(week * 7 * 86_400 * 1_000_000 + 1);
        TransferReport {
            server: "syracuse".into(),
            client_host: "h".into(),
            protocol: "xrootd".into(),
            ipv6: false,
            path: format!("/ospool/{exp}/f-{size}"),
            file_size: size,
            bytes_read: size,
            bytes_written: 0,
            read_ops: 1,
            write_ops: 0,
            opened_at: SimTime(closed.as_micros().saturating_sub(10_000_000)),
            closed_at: closed,
        }
    }

    #[test]
    fn table1_sorted_by_usage() {
        let mut agg = Aggregator::default();
        for _ in 0..3 {
            agg.ingest(&report("ligo", 100, 0));
        }
        agg.ingest(&report("des", 1_000, 0));
        let t1 = agg.table1();
        assert_eq!(t1[0].0, "des");
        assert_eq!(t1[0].1, ByteSize(1_000));
        assert_eq!(t1[1].0, "ligo");
        assert_eq!(t1[1].1, ByteSize(300));
        assert_eq!(agg.total_bytes(), ByteSize(1_300));
    }

    #[test]
    fn histogram_binning_sane() {
        assert_eq!(size_to_bin(1), 0);
        assert!(size_to_bin(5_797) < size_to_bin(22_801_000));
        assert!(size_to_bin(22_801_000) < size_to_bin(2_335_000_000));
        assert_eq!(size_to_bin(u64::MAX), HIST_BINS - 1);
        // bin_to_size is a right inverse up to bin granularity.
        for bin in [0usize, 10, 33, 63] {
            assert_eq!(size_to_bin(bin_to_size(bin) as u64), bin);
        }
    }

    #[test]
    fn table2_percentiles_close_to_exact() {
        let mut agg = Aggregator::default();
        // Bimodal sizes: 1000 small + 1000 large.
        for i in 0..1000u64 {
            agg.ingest(&report("ligo", 10_000 + i, 0));
            agg.ingest(&report("ligo", 500_000_000 + i * 1000, 0));
        }
        // Percentiles chosen inside each mode — p50 sits exactly on
        // the bimodal boundary where exact linear interpolation
        // crosses the (empty) gap and no histogram can match it.
        let est = agg.table2(&[25.0, 75.0]);
        let exact = agg.table2_exact(&[25.0, 75.0]);
        for ((_, e), (_, x)) in est.iter().zip(&exact) {
            let ratio = e.as_f64() / x.as_f64();
            // Log-histogram with 64 bins over 13 decades: each bin is
            // 10^(13/64) ≈ 1.6×; estimate must fall within ~one bin.
            assert!(
                (0.55..1.8).contains(&ratio),
                "estimate {e} vs exact {x} (ratio {ratio})"
            );
        }
    }

    #[test]
    fn weekly_series_fills_gaps() {
        let mut agg = Aggregator::default();
        agg.ingest(&report("ligo", 100, 2));
        agg.ingest(&report("ligo", 300, 5));
        let series = agg.weekly_series();
        assert_eq!(series.len(), 4); // weeks 2..=5
        assert_eq!(series[0], (2, ByteSize(100)));
        assert_eq!(series[1], (3, ByteSize(0)));
        assert_eq!(series[3], (5, ByteSize(300)));
    }

    #[test]
    fn batch_flush_triggers_backend() {
        struct Counting(usize);
        impl HistBackend for Counting {
            fn histogram(&mut self, sizes: &[f64]) -> Vec<f32> {
                self.0 += 1;
                RustHistBackend.histogram(sizes)
            }
        }
        let mut agg = Aggregator::new(Counting(0));
        agg.batch = 10;
        for _ in 0..25 {
            agg.ingest(&report("ligo", 100, 0));
        }
        agg.flush_hist();
        // 25 sizes at batch 10 → backend ran 3 times (10+10+5).
        let calls = agg.backend.0;
        assert_eq!(calls, 3);
        let hist = agg.histogram_snapshot();
        assert_eq!(hist.iter().sum::<f32>(), 25.0);
    }

    #[test]
    fn consume_from_bus_roundtrip() {
        use crate::monitoring::collector::Collector;
        use crate::monitoring::packets::{Envelope, Packet, Protocol};
        let mut bus = Bus::new();
        let mut sub = bus.subscribe(TRANSFER_TOPIC);
        let mut coll = Collector::new();
        coll.register_server(1, "nebraska");
        coll.ingest(
            Envelope {
                server_id: 1,
                timestamp: SimTime(0),
                packet: Packet::UserLogin {
                    user_id: 1,
                    protocol: Protocol::Http,
                    ipv6: true,
                    client_host: "w".into(),
                },
            },
            &mut bus,
        );
        coll.ingest(
            Envelope {
                server_id: 1,
                timestamp: SimTime(10),
                packet: Packet::FileOpen {
                    file_id: 2,
                    user_id: 1,
                    file_size: 555,
                    path: "/ospool/nova/f".into(),
                },
            },
            &mut bus,
        );
        coll.ingest(
            Envelope {
                server_id: 1,
                timestamp: SimTime(20),
                packet: Packet::FileClose {
                    file_id: 2,
                    bytes_read: 555,
                    bytes_written: 0,
                    read_ops: 1,
                    write_ops: 0,
                },
            },
            &mut bus,
        );
        let mut agg = Aggregator::default();
        assert_eq!(agg.consume(&mut bus, &mut sub), 1);
        assert_eq!(agg.experiment_usage("nova").unwrap().bytes_read, 555);
        assert_eq!(agg.ipv6_transfers, 1);
        assert_eq!(agg.http_transfers, 1);
        assert_eq!(agg.server_usage()["nebraska"].transfers, 1);
    }

    #[test]
    fn reservoir_bounded() {
        let mut agg = Aggregator::default();
        for i in 0..(RESERVOIR_CAP + 500) {
            agg.ingest(&report("ligo", i as u64 + 1, 0));
        }
        assert_eq!(agg.reservoir.len(), RESERVOIR_CAP);
    }
}
