//! Minimal JSON support (offline substitute for serde_json —
//! DESIGN.md §2 row 19).
//!
//! The collector publishes each joined transfer as a JSON object on
//! the message bus, like the production OSG flow; consumers
//! (aggregator, live-mode subscribers, tests) parse it back. Only the
//! subset needed for those messages is implemented: objects, strings,
//! integers, floats, booleans.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (subset).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All numbers parse as f64; integer-valued floats print without
    /// a decimal point (u64-exact integers survive a round trip).
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Builder for JSON objects.
#[derive(Debug, Default)]
pub struct ObjBuilder(BTreeMap<String, Json>);

impl ObjBuilder {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn str(mut self, k: &str, v: impl Into<String>) -> Self {
        self.0.insert(k.into(), Json::Str(v.into()));
        self
    }
    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.0.insert(k.into(), Json::Num(v));
        self
    }
    pub fn int(mut self, k: &str, v: u64) -> Self {
        self.0.insert(k.into(), Json::Num(v as f64));
        self
    }
    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.0.insert(k.into(), Json::Bool(v));
        self
    }
    pub fn build(self) -> Json {
        Json::Obj(self.0)
    }
}

/// Serialize to compact JSON text.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                write!(out, "{}", *n as i64).unwrap();
            } else {
                write!(out, "{n}").unwrap();
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                write!(out, "\\u{:04x}", c as u32).unwrap();
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, PartialEq)]
pub struct JsonError(pub usize, pub String);

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.0, self.1)
    }
}

impl std::error::Error for JsonError {}

/// Parse JSON text.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let bytes = text.as_bytes();
    let mut pos = 0;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(JsonError(pos, "trailing data".into()));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return Err(JsonError(*pos, "unexpected end".into()));
    };
    match c {
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    _ => return Err(JsonError(*pos, "object key must be string".into())),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(JsonError(*pos, "expected ':'".into()));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(JsonError(*pos, "expected ',' or '}'".into())),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(JsonError(*pos, "expected ',' or ']'".into())),
                }
            }
        }
        b'"' => parse_string(b, pos).map(Json::Str),
        b't' => expect(b, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect(b, pos, "false").map(|_| Json::Bool(false)),
        b'n' => expect(b, pos, "null").map(|_| Json::Null),
        _ => parse_number(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, word: &str) -> Result<(), JsonError> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(())
    } else {
        Err(JsonError(*pos, format!("expected {word:?}")))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            return Err(JsonError(*pos, "unterminated string".into()));
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&esc) = b.get(*pos) else {
                    return Err(JsonError(*pos, "bad escape".into()));
                };
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'u' => {
                        let hex = b
                            .get(*pos..*pos + 4)
                            .ok_or_else(|| JsonError(*pos, "bad \\u".into()))?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex)
                                .map_err(|_| JsonError(*pos, "bad \\u".into()))?,
                            16,
                        )
                        .map_err(|_| JsonError(*pos, "bad \\u".into()))?;
                        *pos += 4;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(JsonError(*pos, "unknown escape".into())),
                }
            }
            _ => {
                // Continue multi-byte UTF-8 sequences verbatim.
                let start = *pos - 1;
                let len = utf8_len(c);
                let end = start + len;
                let chunk = b
                    .get(start..end)
                    .ok_or_else(|| JsonError(start, "bad utf-8".into()))?;
                out.push_str(
                    std::str::from_utf8(chunk)
                        .map_err(|_| JsonError(start, "bad utf-8".into()))?,
                );
                *pos = end;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).expect("ascii digits");
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| JsonError(start, format!("bad number {text:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let msg = ObjBuilder::new()
            .str("server", "syracuse")
            .str("path", "/ospool/ligo/f.gwf")
            .int("bytes_read", 2_335_000_000)
            .num("duration", 12.5)
            .bool("ipv6", false)
            .build();
        let text = to_string(&msg);
        let back = parse(&text).unwrap();
        assert_eq!(msg, back);
        assert_eq!(back.get("server").unwrap().as_str(), Some("syracuse"));
        assert_eq!(back.get("bytes_read").unwrap().as_u64(), Some(2_335_000_000));
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let text = to_string(&v);
        assert!(text.ends_with("\\u0001\""), "control char escaped: {text}");
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::Str("héllo 世界".into());
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn arrays_and_nesting() {
        let text = r#"{"a":[1,2.5,{"b":true},null],"c":"x"}"#;
        let v = parse(text).unwrap();
        assert_eq!(to_string(&v), text);
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(to_string(&Json::Num(42.0)), "42");
        assert_eq!(to_string(&Json::Num(42.5)), "42.5");
    }

    #[test]
    fn parse_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse(r#"{"a":}"#).is_err());
        assert!(parse("[1,2,]").is_err());
        assert!(parse(r#""unterminated"#).is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse("{1:2}").is_err());
    }

    #[test]
    fn whitespace_tolerated() {
        let v = parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(to_string(&v), r#"{"a":[1,2]}"#);
    }
}
