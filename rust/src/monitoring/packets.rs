//! Binary monitoring packet formats (paper §3.2).
//!
//! "Each StashCache cache sends a UDP packet for each file open, user
//! login, and file close":
//!
//! * **User Login** — "client hostname, the method of logging in, such
//!   as HTTP or xrootd protocol ... whether it was logged in with IPv6
//!   or IPv4. The user is later identified by a unique user ID number."
//! * **File Open** — "the file name, total file size, and the user ID
//!   which opened the file. The file is later referred to by a unique
//!   file ID number."
//! * **File Close** — "the total bytes read or written to the file, as
//!   well as the number of IO operations performed ... the file ID
//!   from the file open event."
//!
//! Wire format (network byte order, `byteorder`):
//!
//! ```text
//! header:  magic "SCMN" | version u8 | kind u8 | server_id u32 | t_us u64
//! login:   user_id u32 | proto u8 | ipv6 u8 | hostlen u16 | host...
//! open:    file_id u32 | user_id u32 | file_size u64 | pathlen u16 | path...
//! close:   file_id u32 | bytes_read u64 | bytes_written u64
//!          | read_ops u32 | write_ops u32
//! ```
//!
//! Live mode sends these over real UDP sockets; the simulator calls
//! the codecs directly, so both paths exercise identical parsing.

use crate::util::SimTime;
use byteorder::{BigEndian, ReadBytesExt, WriteBytesExt};
use std::io::{Cursor, Read, Write};

pub const MAGIC: &[u8; 4] = b"SCMN";
pub const VERSION: u8 = 1;

/// Login protocol field values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    Xrootd = 0,
    Http = 1,
}

impl Protocol {
    pub fn as_str(self) -> &'static str {
        match self {
            Protocol::Xrootd => "xrootd",
            Protocol::Http => "http",
        }
    }
}

/// A monitoring packet (decoded).
#[derive(Debug, Clone, PartialEq)]
pub enum Packet {
    UserLogin {
        user_id: u32,
        protocol: Protocol,
        ipv6: bool,
        client_host: String,
    },
    FileOpen {
        file_id: u32,
        user_id: u32,
        file_size: u64,
        path: String,
    },
    FileClose {
        file_id: u32,
        bytes_read: u64,
        bytes_written: u64,
        read_ops: u32,
        write_ops: u32,
    },
}

/// A packet plus its envelope (who sent it, when).
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    pub server_id: u32,
    pub timestamp: SimTime,
    pub packet: Packet,
}

/// Codec errors. Malformed datagrams must never panic the collector —
/// it ingests from the network.
#[derive(Debug, PartialEq)]
pub enum PacketError {
    Truncated,
    BadMagic,
    BadVersion(u8),
    BadKind(u8),
    BadUtf8,
    BadProtocol(u8),
}

impl std::fmt::Display for PacketError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PacketError::Truncated => write!(f, "datagram too short"),
            PacketError::BadMagic => write!(f, "bad magic"),
            PacketError::BadVersion(v) => write!(f, "unsupported version {v}"),
            PacketError::BadKind(k) => write!(f, "unknown packet kind {k}"),
            PacketError::BadUtf8 => write!(f, "invalid utf-8 in string field"),
            PacketError::BadProtocol(p) => write!(f, "bad protocol value {p}"),
        }
    }
}

impl std::error::Error for PacketError {}

impl From<std::io::Error> for PacketError {
    fn from(_: std::io::Error) -> Self {
        PacketError::Truncated
    }
}

const KIND_LOGIN: u8 = 0x75; // 'u'
const KIND_OPEN: u8 = 0x66; // 'f'
const KIND_CLOSE: u8 = 0x63; // 'c'

/// Encode an envelope into a datagram.
pub fn encode(env: &Envelope) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64);
    buf.write_all(MAGIC).unwrap();
    buf.write_u8(VERSION).unwrap();
    let kind = match env.packet {
        Packet::UserLogin { .. } => KIND_LOGIN,
        Packet::FileOpen { .. } => KIND_OPEN,
        Packet::FileClose { .. } => KIND_CLOSE,
    };
    buf.write_u8(kind).unwrap();
    buf.write_u32::<BigEndian>(env.server_id).unwrap();
    buf.write_u64::<BigEndian>(env.timestamp.as_micros()).unwrap();
    match &env.packet {
        Packet::UserLogin { user_id, protocol, ipv6, client_host } => {
            buf.write_u32::<BigEndian>(*user_id).unwrap();
            buf.write_u8(*protocol as u8).unwrap();
            buf.write_u8(u8::from(*ipv6)).unwrap();
            write_str(&mut buf, client_host);
        }
        Packet::FileOpen { file_id, user_id, file_size, path } => {
            buf.write_u32::<BigEndian>(*file_id).unwrap();
            buf.write_u32::<BigEndian>(*user_id).unwrap();
            buf.write_u64::<BigEndian>(*file_size).unwrap();
            write_str(&mut buf, path);
        }
        Packet::FileClose { file_id, bytes_read, bytes_written, read_ops, write_ops } => {
            buf.write_u32::<BigEndian>(*file_id).unwrap();
            buf.write_u64::<BigEndian>(*bytes_read).unwrap();
            buf.write_u64::<BigEndian>(*bytes_written).unwrap();
            buf.write_u32::<BigEndian>(*read_ops).unwrap();
            buf.write_u32::<BigEndian>(*write_ops).unwrap();
        }
    }
    buf
}

fn write_str(buf: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let len = bytes.len().min(u16::MAX as usize);
    buf.write_u16::<BigEndian>(len as u16).unwrap();
    buf.write_all(&bytes[..len]).unwrap();
}

fn read_str(cur: &mut Cursor<&[u8]>) -> Result<String, PacketError> {
    let len = cur.read_u16::<BigEndian>()? as usize;
    let mut bytes = vec![0u8; len];
    cur.read_exact(&mut bytes)?;
    String::from_utf8(bytes).map_err(|_| PacketError::BadUtf8)
}

/// Decode a datagram. Robust against truncation and garbage.
pub fn decode(datagram: &[u8]) -> Result<Envelope, PacketError> {
    let mut cur = Cursor::new(datagram);
    let mut magic = [0u8; 4];
    cur.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(PacketError::BadMagic);
    }
    let version = cur.read_u8()?;
    if version != VERSION {
        return Err(PacketError::BadVersion(version));
    }
    let kind = cur.read_u8()?;
    let server_id = cur.read_u32::<BigEndian>()?;
    let timestamp = SimTime(cur.read_u64::<BigEndian>()?);
    let packet = match kind {
        KIND_LOGIN => {
            let user_id = cur.read_u32::<BigEndian>()?;
            let proto = cur.read_u8()?;
            let protocol = match proto {
                0 => Protocol::Xrootd,
                1 => Protocol::Http,
                other => return Err(PacketError::BadProtocol(other)),
            };
            let ipv6 = cur.read_u8()? != 0;
            let client_host = read_str(&mut cur)?;
            Packet::UserLogin { user_id, protocol, ipv6, client_host }
        }
        KIND_OPEN => {
            let file_id = cur.read_u32::<BigEndian>()?;
            let user_id = cur.read_u32::<BigEndian>()?;
            let file_size = cur.read_u64::<BigEndian>()?;
            let path = read_str(&mut cur)?;
            Packet::FileOpen { file_id, user_id, file_size, path }
        }
        KIND_CLOSE => {
            let file_id = cur.read_u32::<BigEndian>()?;
            let bytes_read = cur.read_u64::<BigEndian>()?;
            let bytes_written = cur.read_u64::<BigEndian>()?;
            let read_ops = cur.read_u32::<BigEndian>()?;
            let write_ops = cur.read_u32::<BigEndian>()?;
            Packet::FileClose { file_id, bytes_read, bytes_written, read_ops, write_ops }
        }
        other => return Err(PacketError::BadKind(other)),
    };
    Ok(Envelope { server_id, timestamp, packet })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(p: Packet) {
        let env = Envelope {
            server_id: 7,
            timestamp: SimTime(123_456_789),
            packet: p,
        };
        let bytes = encode(&env);
        let back = decode(&bytes).unwrap();
        assert_eq!(env, back);
    }

    #[test]
    fn roundtrip_login() {
        roundtrip(Packet::UserLogin {
            user_id: 42,
            protocol: Protocol::Http,
            ipv6: true,
            client_host: "worker-07.syr.edu".into(),
        });
    }

    #[test]
    fn roundtrip_open() {
        roundtrip(Packet::FileOpen {
            file_id: 9,
            user_id: 42,
            file_size: 2_335_000_000,
            path: "/ospool/ligo/frames/H1.gwf".into(),
        });
    }

    #[test]
    fn roundtrip_close() {
        roundtrip(Packet::FileClose {
            file_id: 9,
            bytes_read: 2_335_000_000,
            bytes_written: 0,
            read_ops: 98,
            write_ops: 0,
        });
    }

    #[test]
    fn rejects_bad_magic() {
        assert_eq!(decode(b"XXXX\x01\x75"), Err(PacketError::BadMagic));
    }

    #[test]
    fn rejects_bad_version_and_kind() {
        let mut good = encode(&Envelope {
            server_id: 1,
            timestamp: SimTime(0),
            packet: Packet::FileClose {
                file_id: 1, bytes_read: 0, bytes_written: 0, read_ops: 0, write_ops: 0,
            },
        });
        good[4] = 99;
        assert_eq!(decode(&good), Err(PacketError::BadVersion(99)));
        good[4] = VERSION;
        good[5] = 0xff;
        assert_eq!(decode(&good), Err(PacketError::BadKind(0xff)));
    }

    #[test]
    fn truncation_never_panics() {
        let env = Envelope {
            server_id: 3,
            timestamp: SimTime(55),
            packet: Packet::FileOpen {
                file_id: 1,
                user_id: 2,
                file_size: 100,
                path: "/p".into(),
            },
        };
        let bytes = encode(&env);
        for cut in 0..bytes.len() {
            let r = decode(&bytes[..cut]);
            assert!(r.is_err(), "decoding {cut}-byte prefix should fail");
        }
    }

    #[test]
    fn garbage_fuzz_never_panics() {
        use crate::util::Pcg64;
        let mut rng = Pcg64::new(99, 99);
        for _ in 0..2_000 {
            let len = (rng.gen_range(0, 128)) as usize;
            let mut buf = vec![0u8; len];
            for b in &mut buf {
                *b = rng.gen_range(0, 256) as u8;
            }
            let _ = decode(&buf); // must not panic
        }
    }

    #[test]
    fn oversize_string_clamped() {
        let host = "h".repeat(70_000);
        let env = Envelope {
            server_id: 1,
            timestamp: SimTime(0),
            packet: Packet::UserLogin {
                user_id: 1,
                protocol: Protocol::Xrootd,
                ipv6: false,
                client_host: host,
            },
        };
        let bytes = encode(&env);
        let back = decode(&bytes).unwrap();
        if let Packet::UserLogin { client_host, .. } = back.packet {
            assert_eq!(client_host.len(), u16::MAX as usize);
        } else {
            panic!("wrong packet kind");
        }
    }
}
