//! The message bus between the collector and its consumers.
//!
//! Paper §3.2: "the collector ... sends a JSON message to the OSG
//! message bus. The OSG message bus distributes the file monitoring to
//! databases in the OSG and the Worldwide LHC Computing Grid."
//!
//! A small topic-based fan-out queue: publishers append to a topic,
//! each subscriber has an independent cursor (every subscriber sees
//! every message — the OSG *and* WLCG databases both get a copy).
//! Single-threaded by design; live mode wraps it in a mutex.

use std::collections::HashMap;

/// Per-topic message log.
#[derive(Debug, Default)]
struct Topic {
    messages: Vec<String>,
    subscribers: usize,
    /// Cursor positions of subscribers (index = subscriber id).
    cursors: Vec<usize>,
}

/// The bus.
#[derive(Debug, Default)]
pub struct Bus {
    topics: HashMap<String, Topic>,
    pub published: u64,
    /// Lifetime total of messages dropped by [`Bus::compact`] —
    /// individual compactions report their count to the caller, but
    /// until telemetry nothing accumulated them.
    pub compacted: u64,
}

/// A subscription handle: pull messages with
/// [`Subscription::try_recv`].
#[derive(Debug)]
pub struct Subscription {
    topic: String,
    id: usize,
}

impl Bus {
    pub fn new() -> Self {
        Bus::default()
    }

    /// Publish a message to a topic (creating it on first use).
    pub fn publish(&mut self, topic: &str, message: String) {
        self.published += 1;
        self.topics
            .entry(topic.to_string())
            .or_default()
            .messages
            .push(message);
    }

    /// Subscribe to a topic from its current tail (messages published
    /// before subscribing are not replayed, like a real bus).
    pub fn subscribe(&mut self, topic: &str) -> Subscription {
        let t = self.topics.entry(topic.to_string()).or_default();
        let id = t.subscribers;
        t.subscribers += 1;
        t.cursors.push(t.messages.len());
        Subscription {
            topic: topic.to_string(),
            id,
        }
    }

    /// Messages retained in a topic (monitoring the monitor).
    pub fn depth(&self, topic: &str) -> usize {
        self.topics.get(topic).map_or(0, |t| t.messages.len())
    }

    /// Drop messages all subscribers have consumed (bounds memory in
    /// long simulations). Returns how many were compacted away.
    pub fn compact(&mut self, topic: &str) -> usize {
        let Some(t) = self.topics.get_mut(topic) else {
            return 0;
        };
        let min_cursor = t.cursors.iter().copied().min().unwrap_or(t.messages.len());
        if min_cursor == 0 {
            return 0;
        }
        t.messages.drain(..min_cursor);
        for c in &mut t.cursors {
            *c -= min_cursor;
        }
        self.compacted += min_cursor as u64;
        min_cursor
    }

    /// Messages currently retained across every topic (the bus's
    /// total queue depth, for the telemetry registry).
    pub fn total_depth(&self) -> usize {
        self.topics.values().map(|t| t.messages.len()).sum()
    }
}

impl Subscription {
    /// Pull the next message, if any.
    pub fn try_recv(&mut self, bus: &Bus) -> Option<String> {
        let t = bus.topics.get(&self.topic)?;
        let cursor = t.cursors[self.id];
        let msg = t.messages.get(cursor)?.clone();
        // Interior-mutability-free design: the cursor lives in the
        // topic; we need a &mut Bus to advance it. Provide both APIs:
        // `try_recv` clones without advancing is surprising, so we
        // require the paired call below.
        Some(msg)
    }

    /// Pull and advance. The common consumption call.
    pub fn recv(&mut self, bus: &mut Bus) -> Option<String> {
        let t = bus.topics.get_mut(&self.topic)?;
        let cursor = &mut t.cursors[self.id];
        let msg = t.messages.get(*cursor)?.clone();
        *cursor += 1;
        Some(msg)
    }

    /// Drain everything pending.
    pub fn drain(&mut self, bus: &mut Bus) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(m) = self.recv(bus) {
            out.push(m);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fan_out_to_all_subscribers() {
        let mut bus = Bus::new();
        let mut osg = bus.subscribe("transfers");
        let mut wlcg = bus.subscribe("transfers");
        bus.publish("transfers", "m1".into());
        bus.publish("transfers", "m2".into());
        assert_eq!(osg.drain(&mut bus), vec!["m1", "m2"]);
        assert_eq!(wlcg.drain(&mut bus), vec!["m1", "m2"]);
        assert_eq!(osg.recv(&mut bus), None);
    }

    #[test]
    fn subscription_starts_at_tail() {
        let mut bus = Bus::new();
        bus.publish("t", "old".into());
        let mut sub = bus.subscribe("t");
        bus.publish("t", "new".into());
        assert_eq!(sub.drain(&mut bus), vec!["new"]);
    }

    #[test]
    fn topics_are_independent() {
        let mut bus = Bus::new();
        let mut a = bus.subscribe("a");
        let mut b = bus.subscribe("b");
        bus.publish("a", "for-a".into());
        assert_eq!(a.recv(&mut bus), Some("for-a".into()));
        assert_eq!(b.recv(&mut bus), None);
    }

    #[test]
    fn compact_respects_slowest_consumer() {
        let mut bus = Bus::new();
        let mut fast = bus.subscribe("t");
        let mut slow = bus.subscribe("t");
        for i in 0..10 {
            bus.publish("t", format!("m{i}"));
        }
        fast.drain(&mut bus);
        slow.recv(&mut bus); // slow consumed 1
        assert_eq!(bus.compact("t"), 1);
        assert_eq!(bus.depth("t"), 9);
        // Slow continues from the right place.
        assert_eq!(slow.recv(&mut bus), Some("m1".into()));
        // After slow catches up everything compacts.
        slow.drain(&mut bus);
        assert_eq!(bus.compact("t"), 9);
        assert_eq!(bus.depth("t"), 0);
        // The lifetime drop counter accumulated both compactions.
        assert_eq!(bus.compacted, 10);
        assert_eq!(bus.total_depth(), 0);
    }

    #[test]
    fn try_recv_peeks_without_advancing() {
        let mut bus = Bus::new();
        let mut s = bus.subscribe("t");
        bus.publish("t", "m".into());
        assert_eq!(s.try_recv(&bus), Some("m".into()));
        assert_eq!(s.try_recv(&bus), Some("m".into()));
        assert_eq!(s.recv(&mut bus), Some("m".into()));
        assert_eq!(s.try_recv(&bus), None);
    }
}
