//! Federation network topology.
//!
//! Builds the link graph the simulator runs on from a
//! [`FederationConfig`]: per site a border router joined to an
//! uncongested WAN core (star topology — contention lives at site
//! edges, matching the paper's per-site explanations in §5), plus
//! internal links for workers, the HTTP proxy, and the cache.
//!
//! ```text
//!                    ┌──────── WAN core (uncongested) ────────┐
//!            wan_gbps│                                         │wan_gbps
//!               [border s]                                [border o]
//!          ┌──────┬──┴────┐                                   └── origin_lan ── [origin]
//!   proxy_wan  worker_wan  cache_wan
//!       │          │          │
//!    [proxy]   [workers]   [cache]
//!       └─proxy_lan┘─cache_lan┘
//! ```
//!
//! RTTs come from great-circle distance between sites
//! ([`crate::geoip::rtt_ms_for_km`]) plus per-hop LAN latency.

use super::network::{LinkId, Network};
use crate::config::FederationConfig;
use crate::geoip::{haversine_km, rtt_ms_for_km};
use std::collections::HashMap;

/// A communication endpoint in the federation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Endpoint {
    /// A worker node at site `site_idx`.
    Worker(usize),
    /// The HTTP forward proxy at site `site_idx`.
    Proxy(usize),
    /// The StashCache cache at site `site_idx`.
    Cache(usize),
    /// Origin `origin_idx` (indexes `FederationConfig::origins`).
    Origin(usize),
}

/// Links of one site.
#[derive(Debug, Clone, Copy)]
struct SiteLinks {
    /// border ↔ WAN core.
    wan: LinkId,
    /// worker ↔ proxy (present iff the site has a proxy).
    proxy_lan: Option<LinkId>,
    /// proxy ↔ border.
    proxy_wan: Option<LinkId>,
    /// worker ↔ border.
    worker_wan: LinkId,
    /// worker ↔ cache (present iff the site has a cache).
    cache_lan: Option<LinkId>,
    /// cache ↔ border.
    cache_wan: Option<LinkId>,
}

/// A resolved route: the links a flow occupies and the connection RTT.
#[derive(Debug, Clone)]
pub struct Route {
    pub links: Vec<LinkId>,
    pub rtt_ms: f64,
}

/// The built topology: resolves endpoint pairs to routes.
pub struct Topology {
    site_links: Vec<SiteLinks>,
    /// Per-origin access link (the origin's data-transfer nodes).
    origin_lan: Vec<LinkId>,
    /// Site index of each origin.
    origin_site: Vec<usize>,
    site_names: Vec<String>,
    name_to_idx: HashMap<String, usize>,
    coords: Vec<(f64, f64)>,
    lan_rtt: Vec<f64>,
}

/// Capacity of each origin's data-transfer-node link (Gbit/s). The
/// Stash origin at Chicago serves many users concurrently (§4.1:
/// "There are many users of the filesystem, network, and data transfer
/// nodes during our tests"), so this is a real contention point shared
/// by all flows touching the origin.
pub const ORIGIN_LAN_GBPS: f64 = 10.0;

impl Topology {
    /// Build the link graph into `net` from the federation config.
    pub fn build(cfg: &FederationConfig, net: &mut Network) -> Topology {
        let mut site_links = Vec::with_capacity(cfg.sites.len());
        let mut site_names = Vec::new();
        let mut coords = Vec::new();
        let mut lan_rtt = Vec::new();
        let mut name_to_idx = HashMap::new();

        for (idx, s) in cfg.sites.iter().enumerate() {
            let l = &s.links;
            let links = SiteLinks {
                wan: net.add_link_gbps(l.wan_gbps),
                proxy_lan: s.proxy.map(|_| net.add_link_gbps(l.proxy_lan_gbps)),
                proxy_wan: s.proxy.map(|_| net.add_link_gbps(l.proxy_wan_gbps)),
                worker_wan: net.add_link_gbps(l.worker_wan_gbps),
                cache_lan: s.cache.map(|_| net.add_link_gbps(l.cache_lan_gbps)),
                cache_wan: s.cache.map(|_| net.add_link_gbps(l.cache_wan_gbps)),
            };
            site_links.push(links);
            name_to_idx.insert(s.name.clone(), idx);
            site_names.push(s.name.clone());
            coords.push((s.lat, s.lon));
            lan_rtt.push(l.lan_rtt_ms);
        }

        let mut origin_lan = Vec::new();
        let mut origin_site = Vec::new();
        for o in &cfg.origins {
            origin_lan.push(net.add_link_gbps(ORIGIN_LAN_GBPS));
            origin_site.push(name_to_idx[&o.site]);
        }

        Topology {
            site_links,
            origin_lan,
            origin_site,
            site_names,
            name_to_idx,
            coords,
            lan_rtt,
        }
    }

    pub fn site_index(&self, name: &str) -> Option<usize> {
        self.name_to_idx.get(name).copied()
    }

    pub fn site_name(&self, idx: usize) -> &str {
        &self.site_names[idx]
    }

    pub fn site_count(&self) -> usize {
        self.site_links.len()
    }

    pub fn origin_site(&self, origin_idx: usize) -> usize {
        self.origin_site[origin_idx]
    }

    /// The WAN edge link of a site (for Fig 5's border traffic counter).
    pub fn wan_link(&self, site_idx: usize) -> LinkId {
        self.site_links[site_idx].wan
    }

    /// Every link owned by one site (WAN edge, worker leg, proxy and
    /// cache legs where present). Sites share no links — only routes
    /// crossing the WAN touch two sites' link sets — which is why warm
    /// (same-site) traffic splits into per-site connected components
    /// in the allocator (see `netsim::network`); the topology tests
    /// pin this disjointness down.
    pub fn site_local_links(&self, site_idx: usize) -> Vec<LinkId> {
        let sl = &self.site_links[site_idx];
        let mut links = vec![sl.wan, sl.worker_wan];
        links.extend(sl.proxy_lan);
        links.extend(sl.proxy_wan);
        links.extend(sl.cache_lan);
        links.extend(sl.cache_wan);
        links
    }

    /// An origin's DTN access link (background-load attachment point).
    pub fn origin_lan_link(&self, origin_idx: usize) -> LinkId {
        self.origin_lan[origin_idx]
    }

    /// A cache site's WAN access link — the live-load signal the
    /// redirection layer reads. Panics if the site hosts no cache.
    pub fn cache_wan_link(&self, site_idx: usize) -> LinkId {
        self.site_links[site_idx]
            .cache_wan
            .expect("site has no cache")
    }

    /// A cache site's worker-facing LAN link. Together with
    /// [`Topology::cache_wan_link`] these are the cache's serving legs,
    /// which a [`crate::fault::FaultKind::CacheSlow`] gray failure
    /// degrades. Panics if the site hosts no cache.
    pub fn cache_lan_link(&self, site_idx: usize) -> LinkId {
        self.site_links[site_idx]
            .cache_lan
            .expect("site has no cache")
    }

    /// Great-circle distance between two sites (km).
    pub fn distance_km(&self, a: usize, b: usize) -> f64 {
        let (la, lo) = self.coords[a];
        let (lb, lob) = self.coords[b];
        haversine_km(la, lo, lb, lob)
    }

    fn wan_rtt_ms(&self, a: usize, b: usize) -> f64 {
        rtt_ms_for_km(self.distance_km(a, b))
    }

    fn endpoint_site(&self, e: Endpoint) -> usize {
        match e {
            Endpoint::Worker(s) | Endpoint::Proxy(s) | Endpoint::Cache(s) => s,
            Endpoint::Origin(o) => self.origin_site[o],
        }
    }

    /// Links from an endpoint up to its site border, plus LAN RTT.
    fn legs_to_border(&self, e: Endpoint) -> (Vec<LinkId>, f64) {
        let s = self.endpoint_site(e);
        let sl = &self.site_links[s];
        let rtt = self.lan_rtt[s];
        match e {
            Endpoint::Worker(_) => (vec![sl.worker_wan], rtt),
            Endpoint::Proxy(_) => (
                vec![sl.proxy_wan.expect("site has no proxy")],
                rtt,
            ),
            Endpoint::Cache(_) => (
                vec![sl.cache_wan.expect("site has no cache")],
                rtt,
            ),
            Endpoint::Origin(o) => (vec![self.origin_lan[o]], rtt),
        }
    }

    /// Resolve the route between two endpoints.
    ///
    /// Same-site special cases use direct LAN links where they exist
    /// (worker↔proxy via `proxy_lan`, worker↔cache via `cache_lan`);
    /// everything else goes border-to-border across the WAN core.
    pub fn route(&self, from: Endpoint, to: Endpoint) -> Route {
        assert_ne!(from, to, "route to self");
        let fs = self.endpoint_site(from);
        let ts = self.endpoint_site(to);

        if fs == ts {
            let sl = &self.site_links[fs];
            let lan = self.lan_rtt[fs];
            // Direct LAN shortcuts.
            match (from, to) {
                (Endpoint::Worker(_), Endpoint::Proxy(_))
                | (Endpoint::Proxy(_), Endpoint::Worker(_)) => {
                    return Route {
                        links: vec![sl.proxy_lan.expect("proxy_lan")],
                        rtt_ms: lan,
                    }
                }
                (Endpoint::Worker(_), Endpoint::Cache(_))
                | (Endpoint::Cache(_), Endpoint::Worker(_)) => {
                    return Route {
                        links: vec![sl.cache_lan.expect("cache_lan")],
                        rtt_ms: lan,
                    }
                }
                _ => {
                    // e.g. cache↔origin on the same campus: both legs
                    // to the border, no WAN crossing.
                    let (mut a, r1) = self.legs_to_border(from);
                    let (b, r2) = self.legs_to_border(to);
                    a.extend(b);
                    return Route {
                        links: a,
                        rtt_ms: r1 + r2,
                    };
                }
            }
        }

        let (mut links, r1) = self.legs_to_border(from);
        links.push(self.site_links[fs].wan);
        links.push(self.site_links[ts].wan);
        let (to_legs, r2) = self.legs_to_border(to);
        links.extend(to_legs);
        Route {
            links,
            rtt_ms: r1 + r2 + self.wan_rtt_ms(fs, ts),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;

    fn setup() -> (crate::config::FederationConfig, Network, Topology) {
        let cfg = paper_federation();
        let mut net = Network::new();
        let topo = Topology::build(&cfg, &mut net);
        (cfg, net, topo)
    }

    #[test]
    fn builds_expected_link_count() {
        let (cfg, net, topo) = setup();
        // Per site: wan + worker_wan always; proxy_lan+proxy_wan if proxy;
        // cache_lan+cache_wan if cache; plus one origin_lan per origin.
        let mut expected = 0;
        for s in &cfg.sites {
            expected += 2;
            if s.proxy.is_some() {
                expected += 2;
            }
            if s.cache.is_some() {
                expected += 2;
            }
        }
        expected += cfg.origins.len();
        assert_eq!(net.link_count(), expected);
        assert_eq!(topo.site_count(), cfg.sites.len());
    }

    #[test]
    fn worker_to_local_proxy_is_single_lan_link() {
        let (_, _, topo) = setup();
        let s = topo.site_index("syracuse").unwrap();
        let r = topo.route(Endpoint::Worker(s), Endpoint::Proxy(s));
        assert_eq!(r.links.len(), 1);
        assert!(r.rtt_ms < 1.0, "LAN rtt, got {}", r.rtt_ms);
    }

    #[test]
    fn worker_to_local_cache_is_single_lan_link() {
        let (_, _, topo) = setup();
        let s = topo.site_index("syracuse").unwrap();
        let r = topo.route(Endpoint::Worker(s), Endpoint::Cache(s));
        assert_eq!(r.links.len(), 1);
    }

    #[test]
    fn worker_to_remote_cache_crosses_wan() {
        let (_, _, topo) = setup();
        let col = topo.site_index("colorado").unwrap();
        let kc = topo.site_index("i2-kansascity").unwrap();
        let r = topo.route(Endpoint::Worker(col), Endpoint::Cache(kc));
        // worker_wan + wan(col) + wan(kc) + cache_wan(kc)
        assert_eq!(r.links.len(), 4);
        // Boulder to Kansas City is ~ 880 km → rtt ≳ 12 ms.
        assert!(r.rtt_ms > 8.0, "WAN rtt, got {}", r.rtt_ms);
    }

    #[test]
    fn proxy_to_origin_same_site_avoids_wan() {
        let (cfg, _, topo) = setup();
        let chi = topo.site_index("chicago").unwrap();
        let origin_idx = cfg
            .origins
            .iter()
            .position(|o| o.site == "chicago")
            .unwrap();
        let r = topo.route(Endpoint::Proxy(chi), Endpoint::Origin(origin_idx));
        // proxy_wan + origin_lan: no site wan links.
        assert_eq!(r.links.len(), 2);
        let wan = topo.wan_link(chi);
        assert!(!r.links.contains(&wan), "same-site route must skip WAN");
    }

    #[test]
    fn cache_to_origin_remote_path_shape() {
        let (cfg, _, topo) = setup();
        let syr = topo.site_index("syracuse").unwrap();
        let origin_idx = cfg.origins.iter().position(|o| o.site == "chicago").unwrap();
        let r = topo.route(Endpoint::Cache(syr), Endpoint::Origin(origin_idx));
        // cache_wan + wan(syr) + wan(chi) + origin_lan
        assert_eq!(r.links.len(), 4);
    }

    #[test]
    fn routes_are_symmetric_in_links() {
        let (_, _, topo) = setup();
        let a = topo.site_index("nebraska").unwrap();
        let b = topo.site_index("ucsd").unwrap();
        let r1 = topo.route(Endpoint::Worker(a), Endpoint::Cache(b));
        let mut l1 = r1.links.clone();
        let r2 = topo.route(Endpoint::Cache(b), Endpoint::Worker(a));
        let mut l2 = r2.links.clone();
        l1.sort();
        l2.sort();
        assert_eq!(l1, l2);
        assert!((r1.rtt_ms - r2.rtt_ms).abs() < 1e-12);
    }

    #[test]
    fn distances_sane() {
        let (_, _, topo) = setup();
        let chi = topo.site_index("chicago").unwrap();
        let ams = topo.site_index("amsterdam").unwrap();
        let d = topo.distance_km(chi, ams);
        assert!((6_000.0..7_500.0).contains(&d), "chicago-amsterdam {d}");
    }

    #[test]
    #[should_panic(expected = "no cache")]
    fn route_to_missing_cache_panics() {
        let (_, _, topo) = setup();
        let col = topo.site_index("colorado").unwrap();
        let syr = topo.site_index("syracuse").unwrap();
        let _ = topo.route(Endpoint::Worker(syr), Endpoint::Cache(col));
    }

    #[test]
    fn site_link_sets_are_disjoint() {
        // The allocator's component-locality win rests on this: two
        // sites share no links, so same-site (warm) serve routes at
        // distinct sites can never join one connected component.
        let (cfg, net, topo) = setup();
        let mut seen = vec![false; net.link_count()];
        let mut total = 0;
        for s in 0..topo.site_count() {
            for l in topo.site_local_links(s) {
                assert!(
                    !seen[l.0 as usize],
                    "link {l:?} appears in two sites' link sets"
                );
                seen[l.0 as usize] = true;
                total += 1;
            }
        }
        // Everything except the per-origin DTN links is site-owned.
        assert_eq!(total + cfg.origins.len(), net.link_count());
        // And a same-site worker↔cache serve route stays inside the
        // site's own link set.
        let syr = topo.site_index("syracuse").unwrap();
        let r = topo.route(Endpoint::Worker(syr), Endpoint::Cache(syr));
        let local = topo.site_local_links(syr);
        assert!(r.links.iter().all(|l| local.contains(l)));
    }
}
