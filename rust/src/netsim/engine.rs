//! Deterministic discrete-event queue.
//!
//! Events are ordered by `(time, sequence)` — the sequence number makes
//! simultaneous events FIFO, so runs are bit-reproducible regardless of
//! payload type. Popping advances the clock monotonically; scheduling
//! in the past is a logic error and panics.

use crate::util::{Duration, SimTime};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    // Reversed: BinaryHeap is a max-heap, we want earliest first.
    fn cmp(&self, other: &Self) -> Ordering {
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// A deterministic event queue with a virtual clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: SimTime::ZERO,
            seq: 0,
            popped: 0,
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `at`. Panics if `at` is in the
    /// past (events must not rewrite history).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        assert!(
            at >= self.now,
            "scheduling into the past: {at} < now {}",
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry {
            time: at,
            seq,
            event,
        });
    }

    /// Schedule `event` after a delay from now.
    pub fn schedule_in(&mut self, delay: Duration, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Time of the next event, if any.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the earliest event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let entry = self.heap.pop()?;
        debug_assert!(entry.time >= self.now, "heap returned past event");
        self.now = entry.time;
        self.popped += 1;
        Some((entry.time, entry.event))
    }

    /// Drain every pending event in `(time, seq)` order, returning the
    /// original scheduling key alongside each event. The shard planner
    /// uses this to extract pending arrivals with their exact serial
    /// tie-break keys. The clock and sequence counter are untouched;
    /// the queue is left empty.
    pub fn drain_sorted(&mut self) -> Vec<(SimTime, u64, E)> {
        let mut out: Vec<Entry<E>> = self.heap.drain().collect();
        out.sort_by(|a, b| (a.time, a.seq).cmp(&(b.time, b.seq)));
        out.into_iter().map(|e| (e.time, e.seq, e.event)).collect()
    }

    /// Re-insert entries previously removed by
    /// [`drain_sorted`](Self::drain_sorted), preserving their original
    /// `(time, seq)` keys. The epoch planner drains the whole queue to
    /// read the serial tie-break keys, ships a prefix into shards, and
    /// restores the left-behind tail here — so a later pop sees
    /// exactly the entry the serial run would have popped. Keys must
    /// predate the current sequence counter (they were issued by this
    /// queue) and must not be in the past.
    pub fn restore(&mut self, entries: Vec<(SimTime, u64, E)>) {
        for (time, seq, event) in entries {
            assert!(time >= self.now, "restoring a past entry: {time} < {}", self.now);
            assert!(seq < self.seq, "restoring a foreign key: seq {seq} never issued");
            self.heap.push(Entry { time, seq, event });
        }
    }

    /// Every pending entry as `(time, seq, event)` in `(time, seq)`
    /// order, without disturbing the queue. The model checker
    /// enumerates these as its "enabled timer" choices; the `(time,
    /// seq)` key is stable across replays and addresses the entry for
    /// [`take`](Self::take).
    pub(crate) fn pending_entries(&self) -> Vec<(SimTime, u64, E)>
    where
        E: Clone,
    {
        let mut out: Vec<(SimTime, u64, E)> = self
            .heap
            .iter()
            .map(|e| (e.time, e.seq, e.event.clone()))
            .collect();
        out.sort_by_key(|&(t, s, _)| (t, s));
        out
    }

    /// Remove the pending entry scheduled with key `(at, seq)` without
    /// advancing the clock. The model checker fires events out of time
    /// order, so the caller advances the clock explicitly with
    /// [`force_advance`](Self::force_advance). Returns `None` if no
    /// such entry is pending.
    pub(crate) fn take(&mut self, at: SimTime, seq: u64) -> Option<E> {
        let entries: Vec<Entry<E>> = self.heap.drain().collect();
        let mut found = None;
        let mut rest = Vec::with_capacity(entries.len());
        for e in entries {
            if found.is_none() && e.time == at && e.seq == seq {
                found = Some(e.event);
            } else {
                rest.push(e);
            }
        }
        self.heap = rest.into_iter().collect();
        if found.is_some() {
            self.popped += 1;
        }
        found
    }

    /// Advance the clock to `t`, even past pending entries. This is the
    /// model checker's time abstraction: a chosen event fires at the
    /// max of its own scheduled time and the current clocks, so entries
    /// that were *not* chosen may become past-dated — they later fire
    /// at whatever the clock has reached. Only backwards movement is an
    /// error.
    pub(crate) fn force_advance(&mut self, t: SimTime) {
        assert!(t >= self.now, "clock cannot move backwards");
        self.now = t;
    }

    /// Advance the clock without an event (e.g. synchronizing with an
    /// external completion source). Panics on backwards movement.
    pub fn advance_to(&mut self, t: SimTime) {
        assert!(t >= self.now, "clock cannot move backwards");
        if let Some(next) = self.peek_time() {
            assert!(
                t <= next,
                "advance_to({t}) would skip a scheduled event at {next}"
            );
        }
        self.now = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(30), "c");
        q.schedule_at(SimTime(10), "a");
        q.schedule_at(SimTime(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), SimTime(30));
        assert_eq!(q.events_processed(), 3);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(SimTime(5), i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(100), ());
        q.pop();
        q.schedule_in(Duration(50), ());
        assert_eq!(q.peek_time(), Some(SimTime(150)));
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_scheduling_panics() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10), ());
        q.pop();
        q.schedule_at(SimTime(5), ());
    }

    #[test]
    #[should_panic(expected = "would skip")]
    fn advance_past_event_panics() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10), ());
        q.advance_to(SimTime(11));
    }

    #[test]
    fn advance_to_moves_clock() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.advance_to(SimTime(42));
        assert_eq!(q.now(), SimTime(42));
    }

    #[test]
    fn take_removes_one_entry_without_moving_clock() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10), "a"); // seq 0
        q.schedule_at(SimTime(20), "b"); // seq 1
        q.schedule_at(SimTime(20), "c"); // seq 2
        let pending = q.pending_entries();
        assert_eq!(
            pending,
            vec![
                (SimTime(10), 0, "a"),
                (SimTime(20), 1, "b"),
                (SimTime(20), 2, "c"),
            ]
        );
        // Take the middle entry out of order: clock stays put, the
        // other two survive in order.
        assert_eq!(q.take(SimTime(20), 1), Some("b"));
        assert_eq!(q.take(SimTime(20), 1), None);
        assert_eq!(q.now(), SimTime::ZERO);
        assert_eq!(q.events_processed(), 1);
        assert_eq!(
            q.pending_entries(),
            vec![(SimTime(10), 0, "a"), (SimTime(20), 2, "c")]
        );
    }

    #[test]
    fn drain_then_restore_preserves_serial_keys() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10), "a"); // seq 0
        q.schedule_at(SimTime(20), "b"); // seq 1
        q.schedule_at(SimTime(20), "c"); // seq 2
        let mut drained = q.drain_sorted();
        assert!(q.is_empty());
        // Ship "a", restore the tail with its original keys.
        let shipped = drained.remove(0);
        assert_eq!(shipped, (SimTime(10), 0, "a"));
        q.restore(drained);
        // New scheduling continues the original sequence: FIFO ties
        // still resolve as if the queue had never been drained.
        q.schedule_at(SimTime(20), "d"); // seq 3
        assert_eq!(q.pop(), Some((SimTime(20), "b")));
        assert_eq!(q.pop(), Some((SimTime(20), "c")));
        assert_eq!(q.pop(), Some((SimTime(20), "d")));
    }

    #[test]
    fn force_advance_skips_pending_entries() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10), "late");
        q.force_advance(SimTime(50));
        assert_eq!(q.now(), SimTime(50));
        // The past-dated entry is still addressable by its key.
        assert_eq!(q.take(SimTime(10), 0), Some("late"));
    }
}
