//! Flow-level network model with **component-local incremental**
//! max-min fair bandwidth sharing.
//!
//! Each [`Link`] has a capacity in bytes/second. A [`Flow`] occupies a
//! path (set of links) and optionally carries a per-connection rate
//! ceiling (modelling a squid proxy's single-stream limit vs an XRootD
//! cache's multi-stream transfers). Whenever the flow set changes, the
//! allocator recomputes the **max-min fair** rate vector by progressive
//! water-filling: repeatedly saturate the most constrained link (or
//! flow ceiling) and freeze the flows it bottlenecks.
//!
//! ## Component locality
//!
//! Max-min fairness decomposes exactly over the connected components
//! of the link/flow graph (two links are connected when one flow
//! crosses both): a flow's rate depends only on the links it can reach
//! through shared links, because water-filling never moves capacity
//! between links that share no flow. The allocator exploits this:
//!
//! * links are grouped into **components** ([`Component`]), merged when
//!   a new flow spans several and re-derived (split) when a flow's
//!   departure may have disconnected one;
//! * a flow arrival/departure/link change re-waterfills **only the
//!   component it touches** — other components keep their rates,
//!   cached per-link aggregate rates, and projected completions;
//! * flows live in a generation-tagged **slab** (`Vec`-backed, ids
//!   never dangle) so the water-filling inner loops are index
//!   arithmetic, not hashing;
//! * each component keeps a **min-heap of projected completions**
//!   (rebuilt only when the component's rates change), and the global
//!   next-completion is the min over component heads — no O(flows)
//!   rescans;
//! * every link caches its **aggregate allocated rate** at fix time,
//!   so advancing the clock charges `bytes_carried` in O(links), not
//!   O(Σ member flows).
//!
//! In the federation's star-of-sites topologies (contention lives at
//! site edges) warm traffic splits into many small per-site components,
//! so the per-event allocator cost is O(affected component), not
//! O(everything) — see ARCHITECTURE.md for the complexity table.
//!
//! Completions are kinetic: each flow's completion instant is computed
//! when its rate is fixed and stays valid until the next rate change,
//! so the driver can interleave its own timer events with transfer
//! completions deterministically.

use crate::util::SimTime;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Handle to a link.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(pub u32);

/// Handle to an active flow: a slab slot in the low 32 bits, the
/// slot's generation in the high 32 bits. Handles to finished flows
/// never resolve (the generation advances when a slot is reused), and
/// comparing handles is **not** start-order — the allocator orders
/// flows by their internal start sequence, not by `FlowId`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

impl FlowId {
    fn new(slot: u32, gen: u32) -> FlowId {
        FlowId(((gen as u64) << 32) | slot as u64)
    }
    fn slot(self) -> usize {
        (self.0 & 0xffff_ffff) as usize
    }
    fn generation(self) -> u32 {
        (self.0 >> 32) as u32
    }
}

/// Specification of a new flow.
#[derive(Debug, Clone)]
pub struct FlowSpec {
    /// Links traversed (order irrelevant to the allocator).
    pub path: Vec<LinkId>,
    /// Payload size in bytes.
    pub bytes: u64,
    /// Optional per-connection rate ceiling (bytes/sec).
    pub rate_cap: Option<f64>,
}

/// Sentinel for "link belongs to no component" (no member flows).
const NO_COMP: u32 = u32::MAX;

#[derive(Debug)]
struct Link {
    capacity: f64, // bytes/sec
    /// Degradation factor in (0, 1]: effective capacity is
    /// `capacity * factor` (origin brownouts, failure injection).
    factor: f64,
    /// Severed links carry no flows and reject new ones until restored.
    up: bool,
    /// Active flows on this link. Always sorted by flow start
    /// sequence: new flows append (their sequence is the largest so
    /// far) and removals preserve order, so sortedness is maintained,
    /// never re-derived.
    flows: Vec<FlowId>,
    /// Cumulative bytes that have traversed this link.
    bytes_carried: f64,
    /// Sum of the allocated rates of the member flows, cached at fix
    /// time so clock advances charge `bytes_carried` in O(links).
    agg_rate: f64,
    /// Component this link currently belongs to (`NO_COMP` when it has
    /// no member flows).
    comp: u32,
}

#[derive(Debug)]
struct Flow {
    /// Start-order sequence number: the deterministic ordering key for
    /// every allocator iteration (slab slots are reused; `seq` never
    /// is).
    seq: u64,
    path: Vec<LinkId>,
    rate: f64,
    rate_cap: Option<f64>,
    started: SimTime,
    /// Remaining bytes as of `fixed_at`. Not decremented per segment:
    /// it is materialised lazily (`remaining - rate·Δt`) only when the
    /// flow's component is re-waterfilled or the flow is removed.
    remaining: f64,
    /// Instant `remaining` was last materialised (== the instant the
    /// current `rate` took effect).
    fixed_at: SimTime,
}

/// One slab slot: the generation advances every time the slot is
/// freed, so stale [`FlowId`]s stop resolving.
#[derive(Debug, Default)]
struct Slot {
    gen: u32,
    flow: Option<Flow>,
}

/// A connected component of the link/flow graph: the unit of
/// incremental re-allocation.
#[derive(Debug, Default)]
struct Component {
    /// Member links, ascending. A link is a member iff it carries at
    /// least one flow.
    links: Vec<u32>,
    /// Min-heap of `(eta µs, flow seq, flow slot)` — rebuilt whenever
    /// the component is re-waterfilled, so entries are never stale.
    heap: BinaryHeap<Reverse<(u64, u64, u32)>>,
    /// Rates stale: re-waterfill at the next fix-up.
    dirty: bool,
    /// Membership stale: a flow was removed, so the component may have
    /// split — re-derive connectivity before water-filling.
    stale: bool,
}

/// A completed transfer, as reported by [`Network::advance`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub flow: FlowId,
    pub at: SimTime,
    pub started: SimTime,
}

/// Lifetime allocator counters (perf observability; surfaced through
/// `EngineStats` → campaign/sweep reports and `--profile`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct AllocStats {
    /// Dirty components processed by fix-up passes. Counted per
    /// component (not per pass) so the total is invariant to how
    /// dirty work is batched — a sharded run that drains the same
    /// dirty markings across several networks sums to the serial
    /// count exactly.
    pub allocations: u64,
    /// Component water-fills run (the O(affected) unit of work).
    pub components_touched: u64,
    /// Flow rate assignments across those water-fills.
    pub flows_refixed: u64,
    /// Largest single component water-filled, in flows.
    pub peak_component: usize,
}

/// The link/flow state and allocator. Time never advances implicitly:
/// the driver calls [`Network::advance`] to move to a chosen instant.
#[derive(Debug, Default)]
pub struct Network {
    links: Vec<Link>,
    slots: Vec<Slot>,
    free_slots: Vec<u32>,
    /// Next flow start-order sequence number.
    next_seq: u64,
    /// Active flow count.
    active: usize,
    comps: Vec<Option<Component>>,
    free_comps: Vec<u32>,
    /// Any component dirty (cheap gate for the fix-up pass).
    any_dirty: bool,
    /// Last instant at which progress was reconciled.
    clock: SimTime,
    /// Water-filling scratch, indexed by link (reset per component).
    scratch_residual: Vec<f64>,
    scratch_active: Vec<usize>,
    /// Lifetime perf counters.
    pub stats: AllocStats,
}

impl Network {
    pub fn new() -> Self {
        Network::default()
    }

    /// Add a link with capacity in **Gbit/s** (the config unit);
    /// stored internally as bytes/sec.
    pub fn add_link_gbps(&mut self, gbps: f64) -> LinkId {
        assert!(gbps > 0.0 && gbps.is_finite());
        self.links.push(Link {
            capacity: gbps * 1e9 / 8.0,
            factor: 1.0,
            up: true,
            flows: Vec::new(),
            bytes_carried: 0.0,
            agg_rate: 0.0,
            comp: NO_COMP,
        });
        self.scratch_residual.push(0.0);
        self.scratch_active.push(0);
        LinkId(self.links.len() as u32 - 1)
    }

    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    pub fn active_flows(&self) -> usize {
        self.active
    }

    /// Cumulative bytes carried by a link (for the Fig 5 WAN counters).
    pub fn link_bytes_carried(&self, link: LinkId) -> f64 {
        self.links[link.0 as usize].bytes_carried
    }

    /// Credit bytes carried over a link directly — the shard barrier
    /// folds each shard network's per-link byte counters back into the
    /// parent network with this.
    pub(crate) fn add_link_bytes(&mut self, link: LinkId, bytes: f64) {
        self.links[link.0 as usize].bytes_carried += bytes;
    }

    /// A flow-less copy of this network for a shard: the same link
    /// array (ids, capacities, degradation factors, and up/down state
    /// all preserved, so shard components waterfill over the identical
    /// global link ids in the identical ascending order as the parent
    /// would) with no flows, no components, zeroed byte counters, fresh
    /// stats, and the clock pinned at `clock`. Water-filling a flow set
    /// here is therefore f64-bit-identical to water-filling the same
    /// set in the parent (PR 4's component exactness, across network
    /// instances).
    pub(crate) fn shard_clone_empty(&self, clock: SimTime) -> Network {
        assert!(clock >= self.clock, "shard clock behind parent");
        let links = self
            .links
            .iter()
            .map(|l| Link {
                capacity: l.capacity,
                factor: l.factor,
                up: l.up,
                flows: Vec::new(),
                bytes_carried: 0.0,
                agg_rate: 0.0,
                comp: NO_COMP,
            })
            .collect::<Vec<_>>();
        let n = links.len();
        Network {
            links,
            slots: Vec::new(),
            free_slots: Vec::new(),
            next_seq: 0,
            active: 0,
            comps: Vec::new(),
            free_comps: Vec::new(),
            any_dirty: false,
            clock,
            scratch_residual: vec![0.0; n],
            scratch_active: vec![0; n],
            stats: AllocStats::default(),
        }
    }

    /// Live aggregate allocated rate (bytes/s) crossing a link right
    /// now — the cached Σ of member-flow rates from the last fix.
    /// This is the per-cache load telemetry the redirection layer's
    /// `least-loaded` policy reads off each cache's WAN access link.
    pub fn link_aggregate_rate(&self, link: LinkId) -> f64 {
        self.links[link.0 as usize].agg_rate
    }

    /// Effective capacity (bytes/s) a link can move right now:
    /// nominal capacity scaled by its degradation factor, zero while
    /// severed. The epoch planner divides this by a flow count for its
    /// pessimistic completion bounds — max-min fairness never hands a
    /// flow less than `capacity / members` on any of its links.
    pub fn link_effective_capacity(&self, link: LinkId) -> f64 {
        let l = &self.links[link.0 as usize];
        if l.up {
            l.capacity * l.factor
        } else {
            0.0
        }
    }

    fn flow(&self, id: FlowId) -> Option<&Flow> {
        let s = self.slots.get(id.slot())?;
        if s.gen == id.generation() {
            s.flow.as_ref()
        } else {
            None
        }
    }

    /// Remaining bytes of a flow materialised at the current clock.
    fn remaining_now(&self, f: &Flow) -> f64 {
        let dt = (self.clock - f.fixed_at).as_secs_f64();
        (f.remaining - f.rate * dt).max(0.0)
    }

    /// Debug snapshot: (flow, remaining bytes, rate B/s, path), in
    /// start order.
    pub fn flows_snapshot(&mut self) -> Vec<(FlowId, f64, f64, Vec<LinkId>)> {
        self.fixup();
        let mut order: Vec<(u64, u32)> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.flow.is_some())
            .map(|(slot, s)| (s.flow.as_ref().expect("live flow").seq, slot as u32))
            .collect();
        order.sort_unstable();
        order
            .into_iter()
            .map(|(_, slot)| {
                let s = &self.slots[slot as usize];
                let f = s.flow.as_ref().expect("live flow");
                (
                    FlowId::new(slot, s.gen),
                    self.remaining_now(f),
                    f.rate,
                    f.path.clone(),
                )
            })
            .collect()
    }

    /// Current allocated rate of a flow (bytes/sec). Zero if unknown.
    pub fn flow_rate(&mut self, flow: FlowId) -> f64 {
        self.fixup();
        self.flow(flow).map(|f| f.rate).unwrap_or(0.0)
    }

    /// Start a flow at time `now` (must be >= the last event time).
    ///
    /// A path that crosses the same link more than once (e.g. a
    /// cache-relay streaming origin→cache→worker over the cache's WAN
    /// link in both directions) occupies it **once**: links are
    /// full-duplex, so the two directions do not share capacity.
    pub fn start_flow(&mut self, spec: FlowSpec, now: SimTime) -> FlowId {
        assert!(!spec.path.is_empty(), "flow with empty path");
        assert!(spec.bytes > 0, "flow with zero bytes");
        let mut path = spec.path;
        path.sort_unstable();
        path.dedup();
        for l in &path {
            assert!((l.0 as usize) < self.links.len(), "unknown link {l:?}");
            assert!(
                self.links[l.0 as usize].up,
                "starting a flow over a down link {l:?}"
            );
        }
        self.settle(now);
        let slot = match self.free_slots.pop() {
            Some(s) => s as usize,
            None => {
                self.slots.push(Slot::default());
                self.slots.len() - 1
            }
        };
        let id = FlowId::new(slot as u32, self.slots[slot].gen);
        let seq = self.next_seq;
        self.next_seq += 1;
        for l in &path {
            // `seq` is the largest so far: appending keeps the member
            // list sorted by start sequence.
            self.links[l.0 as usize].flows.push(id);
        }
        self.merge_components(&path);
        self.slots[slot].flow = Some(Flow {
            seq,
            path,
            rate: 0.0,
            rate_cap: spec.rate_cap,
            started: now,
            remaining: spec.bytes as f64,
            fixed_at: now,
        });
        self.active += 1;
        id
    }

    /// Abort a flow (e.g. failure injection). Returns bytes left.
    pub fn cancel_flow(&mut self, flow: FlowId, now: SimTime) -> Option<u64> {
        self.settle(now);
        self.flow(flow)?;
        let f = self.detach(flow.slot());
        Some(self.remaining_now(&f).ceil() as u64)
    }

    /// Declare a flow complete *now*, regardless of its remaining
    /// bytes. This is the model checker's time abstraction: it explores
    /// event *orderings*, not durations, so a chosen completion fires
    /// at the current clock instead of waiting out the transfer.
    /// Survivors re-allocate exactly as if the flow had finished on its
    /// own (detaching marks the component dirty, so any stale
    /// completion-heap entry is rebuilt before the next regular
    /// advance). Returns `None` for unknown or stale handles.
    pub(crate) fn force_complete(&mut self, flow: FlowId, now: SimTime) -> Option<Completion> {
        self.settle(now);
        self.flow(flow)?;
        let f = self.detach(flow.slot());
        Some(Completion {
            flow,
            at: now,
            started: f.started,
        })
    }

    /// Sever a link (failure injection): every flow crossing it is
    /// killed and returned (with its remaining bytes, in start order),
    /// surviving flows are re-allocated max-min fairly, and new flows
    /// may not use the link until [`Network::restore_link`].
    pub fn cut_link(&mut self, link: LinkId, now: SimTime) -> Vec<(FlowId, u64)> {
        self.settle(now);
        let li = link.0 as usize;
        // Member list is maintained in start order already.
        let ids = self.links[li].flows.clone();
        let mut killed = Vec::with_capacity(ids.len());
        for id in ids {
            let f = self.detach(id.slot());
            killed.push((id, self.remaining_now(&f).ceil() as u64));
        }
        self.links[li].up = false;
        killed
    }

    /// Bring a severed link back up (capacity and degradation factor
    /// are as they were).
    pub fn restore_link(&mut self, link: LinkId) {
        self.links[link.0 as usize].up = true;
    }

    pub fn link_is_up(&self, link: LinkId) -> bool {
        self.links[link.0 as usize].up
    }

    /// Scale a link's effective capacity by `factor` in (0, 1] —
    /// origin brownouts and partial degradations. `1.0` restores full
    /// capacity. Progress up to `now` is applied at the old rates
    /// first; the link's component is then re-allocated (other
    /// components are untouched).
    pub fn scale_link_capacity(&mut self, link: LinkId, factor: f64, now: SimTime) {
        assert!(
            factor > 0.0 && factor <= 1.0 && factor.is_finite(),
            "capacity factor must be in (0, 1], got {factor}"
        );
        self.settle(now);
        let li = link.0 as usize;
        self.links[li].factor = factor;
        let c = self.links[li].comp;
        if c != NO_COMP {
            // Rates change but membership cannot: no `stale`.
            self.comps[c as usize].as_mut().expect("live comp").dirty = true;
            self.any_dirty = true;
        }
    }

    /// Earliest projected completion instant, if any flow is active:
    /// the minimum over component heap heads, clamped to at least one
    /// microsecond past the clock so callers always make progress.
    ///
    /// Zero-rate policy (one place, one rule): allocation assigns
    /// every active flow a strictly positive rate — water-filling over
    /// positive effective capacities cannot do otherwise — so every
    /// flow has a finite projected completion. This is debug-asserted
    /// where rates are fixed ([`Network::fix_flow`]); a zero-rate flow
    /// would never complete and is an allocator bug, not a state to
    /// skip silently.
    pub fn next_completion(&mut self) -> Option<SimTime> {
        self.fixup();
        self.earliest_eta().map(|eta| SimTime(eta.0.max(self.clock.0 + 1)))
    }

    /// Minimum stored completion instant across components. O(number
    /// of components); heaps are exact after [`Network::fixup`].
    fn earliest_eta(&self) -> Option<SimTime> {
        self.comps
            .iter()
            .flatten()
            .filter_map(|c| c.heap.peek())
            .map(|&Reverse((eta, _, _))| SimTime(eta))
            .min()
    }

    /// Advance to `t`, applying transfer progress and collecting flows
    /// that finish at or before `t` (in deterministic start order).
    ///
    /// `t` should not exceed [`Network::next_completion`] by more than
    /// the 1 µs rounding slack; completions beyond `t` stay active.
    pub fn advance(&mut self, t: SimTime) -> Vec<Completion> {
        self.fixup();
        let mut done = Vec::new();
        // Flows may complete in cascades: when one finishes, the others
        // speed up. Process piecewise-constant segments. Due flows are
        // collected at the top so that a flow whose completion instant
        // was crossed by a settle (a new flow arriving after time
        // already passed) is retired promptly even when `t == clock` —
        // immediately when its component was untouched, or within the
        // 1 µs re-fix slack when the settle-time mutation re-filled it
        // (the re-fix clamps its fresh eta to clock+1).
        loop {
            let due = self.pop_due();
            if !due.is_empty() {
                for (_seq, id) in due {
                    let f = self.detach(id.slot());
                    done.push(Completion {
                        flow: id,
                        at: self.clock,
                        started: f.started,
                    });
                }
                // Survivors in the affected components re-fix (and get
                // fresh, strictly later completion instants).
                self.fixup();
                continue;
            }
            if self.clock >= t {
                break;
            }
            let seg_end = match self.earliest_eta() {
                Some(eta) if eta <= t => eta,
                _ => t,
            };
            // Guarantee forward progress (≥ 1 µs) even when an eta
            // rounds onto the current clock, and never overshoot `t`.
            self.charge_to(seg_end.max(SimTime(self.clock.0 + 1)).min(t));
        }
        done
    }

    /// Pop every flow whose stored completion instant is at or before
    /// the clock, across all components, sorted by start sequence.
    fn pop_due(&mut self) -> Vec<(u64, FlowId)> {
        let mut due: Vec<(u64, u32)> = Vec::new();
        let clock = self.clock.0;
        for comp in self.comps.iter_mut().flatten() {
            while let Some(&Reverse((eta, seq, slot))) = comp.heap.peek() {
                if eta > clock {
                    break;
                }
                comp.heap.pop();
                due.push((seq, slot));
            }
        }
        due.sort_unstable();
        due.into_iter()
            .map(|(seq, slot)| (seq, FlowId::new(slot, self.slots[slot as usize].gen)))
            .collect()
    }

    /// Remove a flow from the slab and every member list, and mark its
    /// component for re-allocation. A multi-link departure may have
    /// disconnected the component, so it is flagged for re-derivation;
    /// a single-link departure never can (the hot warm-traffic case),
    /// so it skips the BFS — at most pruning its link from the
    /// component if the link just lost its last flow (a flow-less link
    /// connects nothing).
    fn detach(&mut self, slot: usize) -> Flow {
        let f = self.slots[slot].flow.take().expect("detaching a live flow");
        self.slots[slot].gen = self.slots[slot].gen.wrapping_add(1);
        self.free_slots.push(slot as u32);
        self.active -= 1;
        for l in &f.path {
            let link = &mut self.links[l.0 as usize];
            let slots = &self.slots;
            let pos = link
                .flows
                .binary_search_by_key(&f.seq, |id| {
                    slots[id.slot()]
                        .flow
                        .as_ref()
                        .map(|m| m.seq)
                        .unwrap_or(f.seq) // the slot being detached
                })
                .expect("member list holds the flow");
            link.flows.remove(pos);
        }
        // All the flow's links are in one component by construction.
        let li = f.path[0].0 as usize;
        let c = self.links[li].comp;
        debug_assert_ne!(c, NO_COMP);
        let emptied = self.links[li].flows.is_empty();
        let comp = self.comps[c as usize].as_mut().expect("live comp");
        comp.dirty = true;
        if f.path.len() > 1 {
            comp.stale = true;
        } else if emptied && !comp.stale {
            comp.links.retain(|&x| x as usize != li);
            self.links[li].comp = NO_COMP;
            self.links[li].agg_rate = 0.0;
        }
        self.any_dirty = true;
        f
    }

    /// Reconcile to `now`: rates that changed at earlier instants take
    /// effect there (fix-up), then the clock advances charging the
    /// cached per-link aggregate rates.
    fn settle(&mut self, now: SimTime) {
        assert!(now >= self.clock, "network clock moved backwards");
        self.fixup();
        self.charge_to(now);
    }

    /// Advance the clock to `t`, charging each link's cached aggregate
    /// rate — O(links), not O(Σ member flows). Flow `remaining` is not
    /// touched: it is materialised lazily at the next re-fix.
    fn charge_to(&mut self, t: SimTime) {
        if t <= self.clock {
            return;
        }
        let dt = (t - self.clock).as_secs_f64();
        for link in &mut self.links {
            if link.agg_rate > 0.0 {
                link.bytes_carried += link.agg_rate * dt;
            }
        }
        self.clock = t;
    }

    /// Merge the components of `path` into one (a new flow connects
    /// them) and mark the result for re-allocation. Called after the
    /// flow was appended to the member lists, with no pending dirty
    /// components (every mutation settles first).
    fn merge_components(&mut self, path: &[LinkId]) {
        let mut target = NO_COMP;
        for l in path {
            let c = self.links[l.0 as usize].comp;
            if c != NO_COMP && (target == NO_COMP || c < target) {
                target = c;
            }
        }
        let target = if target == NO_COMP {
            self.alloc_comp()
        } else {
            target
        };
        for l in path {
            let c = self.links[l.0 as usize].comp;
            if c == target {
                continue;
            }
            if c == NO_COMP {
                self.links[l.0 as usize].comp = target;
                self.comps[target as usize].as_mut().expect("live comp").links.push(l.0);
            } else {
                // Absorb the other component wholesale (into the
                // lowest id, not by size — components stay small in
                // the star-of-sites topologies this models).
                let absorbed = self.comps[c as usize].take().expect("live comp");
                self.free_comps.push(c);
                for &li in &absorbed.links {
                    self.links[li as usize].comp = target;
                }
                self.comps[target as usize]
                    .as_mut()
                    .expect("live comp")
                    .links
                    .extend(absorbed.links);
            }
        }
        let comp = self.comps[target as usize].as_mut().expect("live comp");
        comp.links.sort_unstable();
        comp.links.dedup();
        comp.dirty = true;
        self.any_dirty = true;
    }

    fn alloc_comp(&mut self) -> u32 {
        match self.free_comps.pop() {
            Some(c) => {
                self.comps[c as usize] = Some(Component::default());
                c
            }
            None => {
                self.comps.push(Some(Component::default()));
                (self.comps.len() - 1) as u32
            }
        }
    }

    /// Re-allocate every dirty component (ascending id, deterministic):
    /// stale components are first split back into true connected
    /// components, then each is water-filled. Cost is O(affected
    /// components), never O(all flows).
    fn fixup(&mut self) {
        if !self.any_dirty {
            return;
        }
        self.any_dirty = false;
        for c in 0..self.comps.len() as u32 {
            let Some(comp) = &self.comps[c as usize] else {
                continue;
            };
            if !comp.dirty {
                continue;
            }
            self.stats.allocations += 1;
            if comp.stale {
                for part in self.restructure(c) {
                    self.waterfill(part);
                }
            } else {
                self.waterfill(c);
            }
        }
    }

    /// Re-derive connectivity among a stale component's links (flow
    /// removals may have disconnected it). Frees the old component and
    /// returns the replacement components, each marked dirty. Links
    /// left without flows drop out of the component structure (their
    /// aggregate rate is zeroed).
    fn restructure(&mut self, c: u32) -> Vec<u32> {
        let old = self.comps[c as usize].take().expect("live comp");
        self.free_comps.push(c);
        for &li in &old.links {
            self.links[li as usize].comp = NO_COMP;
            self.links[li as usize].agg_rate = 0.0;
        }
        let mut parts = Vec::new();
        // Each flow is expanded once (multi-link flows appear in
        // several member lists; the seen-set skips the repeats).
        let mut seen: std::collections::HashSet<u64> = std::collections::HashSet::new();
        for &seed in &old.links {
            if self.links[seed as usize].comp != NO_COMP
                || self.links[seed as usize].flows.is_empty()
            {
                continue;
            }
            let cid = self.alloc_comp();
            let mut group = vec![seed];
            self.links[seed as usize].comp = cid;
            let mut qi = 0;
            while qi < group.len() {
                let li = group[qi] as usize;
                qi += 1;
                let member_ids = self.links[li].flows.clone();
                for fid in member_ids {
                    let slots = &self.slots;
                    let f = slots[fid.slot()].flow.as_ref().expect("member flow is live");
                    if !seen.insert(f.seq) {
                        continue;
                    }
                    for pl in &f.path {
                        let pli = pl.0 as usize;
                        if self.links[pli].comp == NO_COMP {
                            self.links[pli].comp = cid;
                            group.push(pl.0);
                        }
                    }
                }
            }
            group.sort_unstable();
            let comp = self.comps[cid as usize].as_mut().expect("fresh comp");
            comp.links = group;
            comp.dirty = true;
            parts.push(cid);
        }
        parts
    }

    /// Max-min fair allocation of one component by progressive
    /// filling, identical round structure to a from-scratch global
    /// water-filling restricted to this component (max-min decomposes
    /// exactly over components, so the rates match a full rebuild
    /// bit-for-bit — property-tested below).
    ///
    /// Invariants established (checked by property tests):
    /// 1. no link carries more than its capacity (within 1e-6 rel.);
    /// 2. no flow exceeds its rate ceiling;
    /// 3. every flow is bottlenecked: it either sits at its ceiling or
    ///    traverses a saturated link where it has a maximal share.
    fn waterfill(&mut self, c: u32) {
        let comp_links =
            std::mem::take(&mut self.comps[c as usize].as_mut().expect("live comp").links);
        // Member flows: (seq, slot), merged from the per-link sorted
        // lists. A component died when its last flow left.
        let mut members: Vec<(u64, u32)> = Vec::new();
        for &li in &comp_links {
            for id in &self.links[li as usize].flows {
                let seq = self.slots[id.slot()].flow.as_ref().expect("live member").seq;
                members.push((seq, id.slot() as u32));
            }
        }
        members.sort_unstable();
        members.dedup();
        if members.is_empty() {
            for &li in &comp_links {
                self.links[li as usize].comp = NO_COMP;
                self.links[li as usize].agg_rate = 0.0;
            }
            self.comps[c as usize] = None;
            self.free_comps.push(c);
            return;
        }
        self.stats.components_touched += 1;
        self.stats.peak_component = self.stats.peak_component.max(members.len());

        // Materialise progress at the old rates up to the clock; the
        // new rates take effect from here.
        for &(_, slot) in &members {
            let clock = self.clock;
            let f = self.slots[slot as usize].flow.as_mut().expect("live member");
            let dt = (clock - f.fixed_at).as_secs_f64();
            f.remaining = (f.remaining - f.rate * dt).max(0.0);
            f.fixed_at = clock;
        }

        // Working copies (scratch indexed by link id; only this
        // component's entries are touched).
        for &li in &comp_links {
            let link = &mut self.links[li as usize];
            self.scratch_residual[li as usize] = link.capacity * link.factor;
            self.scratch_active[li as usize] = link.flows.len();
            link.agg_rate = 0.0;
        }

        let mut heap: Vec<Reverse<(u64, u64, u32)>> = Vec::with_capacity(members.len());
        let mut unfixed = members;
        while !unfixed.is_empty() {
            // Fair share offered by each link still carrying unfixed
            // flows.
            let mut bottleneck_share = f64::INFINITY;
            for &li in &comp_links {
                let li = li as usize;
                if self.scratch_active[li] > 0 {
                    bottleneck_share = bottleneck_share
                        .min(self.scratch_residual[li] / self.scratch_active[li] as f64);
                }
            }
            debug_assert!(bottleneck_share.is_finite());

            // Flows whose ceiling binds below the bottleneck share are
            // fixed at their ceiling first. `capped` inherits the sort
            // order of `unfixed`, so one binary-searched retain sweep
            // removes the whole round.
            let capped: Vec<(u64, u32)> = unfixed
                .iter()
                .copied()
                .filter(|&(_, slot)| {
                    self.slots[slot as usize]
                        .flow
                        .as_ref()
                        .expect("live member")
                        .rate_cap
                        .is_some_and(|cap| cap < bottleneck_share)
                })
                .collect();
            if !capped.is_empty() {
                for &(_, slot) in &capped {
                    let cap = self.slots[slot as usize]
                        .flow
                        .as_ref()
                        .expect("live member")
                        .rate_cap
                        .expect("cap exists");
                    self.fix_flow(slot, cap, &mut heap);
                }
                unfixed.retain(|x| capped.binary_search(x).is_err());
                continue; // shares changed; recompute bottleneck
            }

            // Otherwise saturate the bottleneck link(s): fix every
            // unfixed flow crossing a link that offers the minimum
            // share. Duplicates (a flow crossing two saturated links)
            // are removed by one sort+dedup instead of a `contains`
            // scan per push.
            let mut to_fix: Vec<(u64, u32)> = Vec::new();
            for &li in &comp_links {
                let li = li as usize;
                if self.scratch_active[li] > 0
                    && self.scratch_residual[li] / self.scratch_active[li] as f64
                        <= bottleneck_share * (1.0 + 1e-12)
                {
                    for id in &self.links[li].flows {
                        let seq = self.slots[id.slot()].flow.as_ref().expect("live member").seq;
                        let key = (seq, id.slot() as u32);
                        if unfixed.binary_search(&key).is_ok() {
                            to_fix.push(key);
                        }
                    }
                }
            }
            to_fix.sort_unstable();
            to_fix.dedup();
            debug_assert!(!to_fix.is_empty());
            for &(_, slot) in &to_fix {
                self.fix_flow(slot, bottleneck_share, &mut heap);
            }
            unfixed.retain(|x| to_fix.binary_search(x).is_err());
        }

        let comp = self.comps[c as usize].as_mut().expect("live comp");
        comp.links = comp_links;
        comp.heap = BinaryHeap::from(heap);
        comp.dirty = false;
        comp.stale = false;
    }

    /// Fix one flow's rate: update residual capacity and active counts
    /// on its path, accumulate each link's cached aggregate rate, and
    /// record the flow's projected completion.
    fn fix_flow(&mut self, slot: u32, rate: f64, heap: &mut Vec<Reverse<(u64, u64, u32)>>) {
        // See `next_completion` for the (single) zero-rate policy.
        debug_assert!(rate > 0.0, "allocated flow with zero rate");
        let Network { links, slots, clock, scratch_residual, scratch_active, stats, .. } = self;
        stats.flows_refixed += 1;
        let flow = slots[slot as usize].flow.as_mut().expect("live member");
        flow.rate = rate;
        // Round up to the next microsecond so the completion event
        // never lands before the flow actually finishes; for etas
        // below the clock's f64 resolution, force a 1 µs tick so
        // callers always make progress. The heap entry is the eta's
        // sole home: it stays valid until the component re-fills,
        // which rebuilds the heap.
        let eta_secs = clock.as_secs_f64() + flow.remaining / rate;
        let eta = ((eta_secs * 1e6).ceil() as u64).max(clock.0 + 1);
        heap.push(Reverse((eta, flow.seq, slot)));
        for l in &flow.path {
            let li = l.0 as usize;
            scratch_residual[li] = (scratch_residual[li] - rate).max(0.0);
            scratch_active[li] -= 1;
            links[li].agg_rate += rate;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net1() -> (Network, LinkId) {
        let mut n = Network::new();
        let l = n.add_link_gbps(8e-9 * 1000.0); // 1000 B/s for easy math
        (n, l)
    }

    #[test]
    fn single_flow_gets_full_capacity() {
        let (mut n, l) = net1();
        let f = n.start_flow(
            FlowSpec {
                path: vec![l],
                bytes: 1000,
                rate_cap: None,
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 1000.0).abs() < 1e-6);
        let t = n.next_completion().unwrap();
        assert_eq!(t, SimTime::from_secs_f64(1.0));
        let done = n.advance(t);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].flow, f);
        assert_eq!(n.active_flows(), 0);
    }

    #[test]
    fn two_flows_share_equally() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec {
            path: vec![l],
            bytes,
            rate_cap: None,
        };
        let f1 = n.start_flow(spec(1000), SimTime::ZERO);
        let f2 = n.start_flow(spec(1000), SimTime::ZERO);
        assert!((n.flow_rate(f1) - 500.0).abs() < 1e-6);
        assert!((n.flow_rate(f2) - 500.0).abs() < 1e-6);
        // Both finish at t=2s.
        let t = n.next_completion().unwrap();
        assert_eq!(t, SimTime::from_secs_f64(2.0));
        let done = n.advance(t);
        assert_eq!(done.len(), 2);
    }

    #[test]
    fn departure_speeds_up_survivor() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec {
            path: vec![l],
            bytes,
            rate_cap: None,
        };
        let _f1 = n.start_flow(spec(500), SimTime::ZERO);
        let f2 = n.start_flow(spec(1500), SimTime::ZERO);
        // f1 finishes at 1s (rate 500); f2 then has 1000 left at rate 1000.
        let t1 = n.next_completion().unwrap();
        assert_eq!(t1, SimTime::from_secs_f64(1.0));
        let done = n.advance(t1);
        assert_eq!(done.len(), 1);
        assert!((n.flow_rate(f2) - 1000.0).abs() < 1e-6);
        let t2 = n.next_completion().unwrap();
        assert_eq!(t2, SimTime::from_secs_f64(2.0));
    }

    #[test]
    fn cascade_completions_in_one_advance() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec {
            path: vec![l],
            bytes,
            rate_cap: None,
        };
        n.start_flow(spec(500), SimTime::ZERO);
        n.start_flow(spec(1500), SimTime::ZERO);
        // Advance straight to 2s: both complete, at 1s and 2s.
        let done = n.advance(SimTime::from_secs_f64(2.0));
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].at, SimTime::from_secs_f64(1.0));
        assert_eq!(done[1].at, SimTime::from_secs_f64(2.0));
    }

    #[test]
    fn rate_cap_binds() {
        let (mut n, l) = net1();
        let f = n.start_flow(
            FlowSpec {
                path: vec![l],
                bytes: 100,
                rate_cap: Some(100.0),
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 100.0).abs() < 1e-6);
        // Capped flow leaves headroom for an uncapped one.
        let g = n.start_flow(
            FlowSpec {
                path: vec![l],
                bytes: 900,
                rate_cap: None,
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 100.0).abs() < 1e-6);
        assert!((n.flow_rate(g) - 900.0).abs() < 1e-6);
    }

    #[test]
    fn multi_link_path_takes_min() {
        let mut n = Network::new();
        let fast = n.add_link_gbps(8e-9 * 1000.0);
        let slow = n.add_link_gbps(8e-9 * 250.0);
        let f = n.start_flow(
            FlowSpec {
                path: vec![fast, slow],
                bytes: 250,
                rate_cap: None,
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 250.0).abs() < 1e-6);
    }

    #[test]
    fn max_min_three_flows_two_links() {
        // Classic example: flows A (l1), B (l1+l2), C (l2).
        // l1 cap 1000, l2 cap 400: B gets 200 (l2 fair share), C 200,
        // A then gets 800.
        let mut n = Network::new();
        let l1 = n.add_link_gbps(8e-9 * 1000.0);
        let l2 = n.add_link_gbps(8e-9 * 400.0);
        let a = n.start_flow(
            FlowSpec { path: vec![l1], bytes: 10_000, rate_cap: None },
            SimTime::ZERO,
        );
        let b = n.start_flow(
            FlowSpec { path: vec![l1, l2], bytes: 10_000, rate_cap: None },
            SimTime::ZERO,
        );
        let c = n.start_flow(
            FlowSpec { path: vec![l2], bytes: 10_000, rate_cap: None },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(b) - 200.0).abs() < 1e-6, "b={}", n.flow_rate(b));
        assert!((n.flow_rate(c) - 200.0).abs() < 1e-6);
        assert!((n.flow_rate(a) - 800.0).abs() < 1e-6);
    }

    #[test]
    fn duplicate_links_in_path_count_once() {
        // The cache-relay pattern: origin→cache→worker crosses the
        // cache's WAN link twice; capacity must be charged once.
        let mut n = Network::new();
        let a = n.add_link_gbps(8e-9 * 1000.0);
        let b = n.add_link_gbps(8e-9 * 1000.0);
        let f = n.start_flow(
            FlowSpec {
                path: vec![a, b, a, b, a],
                bytes: 1000,
                rate_cap: None,
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 1000.0).abs() < 1e-6);
        // A second flow on link a shares fairly (no phantom members).
        let g = n.start_flow(
            FlowSpec { path: vec![a], bytes: 1000, rate_cap: None },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 500.0).abs() < 1e-6);
        assert!((n.flow_rate(g) - 500.0).abs() < 1e-6);
        // Completions drain cleanly (regression: duplicate entries
        // underflowed the allocator's active counters).
        while let Some(t) = n.next_completion() {
            n.advance(t);
        }
        assert_eq!(n.active_flows(), 0);
    }

    #[test]
    fn cancel_restores_capacity() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec { path: vec![l], bytes, rate_cap: None };
        let f1 = n.start_flow(spec(10_000), SimTime::ZERO);
        let f2 = n.start_flow(spec(10_000), SimTime::ZERO);
        n.advance(SimTime::from_secs_f64(1.0));
        let left = n.cancel_flow(f1, SimTime::from_secs_f64(1.0)).unwrap();
        assert_eq!(left, 10_000 - 500);
        assert!((n.flow_rate(f2) - 1000.0).abs() < 1e-6);
        assert!(n.cancel_flow(f1, SimTime::from_secs_f64(1.0)).is_none());
    }

    #[test]
    fn cut_link_kills_crossing_flows_and_blocks_new_ones() {
        let mut n = Network::new();
        let l1 = n.add_link_gbps(8e-9 * 1000.0);
        let l2 = n.add_link_gbps(8e-9 * 1000.0);
        let f = n.start_flow(
            FlowSpec { path: vec![l1], bytes: 1000, rate_cap: None },
            SimTime::ZERO,
        );
        let g = n.start_flow(
            FlowSpec { path: vec![l1, l2], bytes: 2000, rate_cap: None },
            SimTime::ZERO,
        );
        let h = n.start_flow(
            FlowSpec { path: vec![l2], bytes: 2000, rate_cap: None },
            SimTime::ZERO,
        );
        // Max-min gives every flow 500 B/s; at t=0.5 each moved 250 B.
        let killed = n.cut_link(l1, SimTime::from_secs_f64(0.5));
        assert_eq!(killed, vec![(f, 750), (g, 1750)]);
        assert!(!n.link_is_up(l1));
        assert_eq!(n.active_flows(), 1);
        // The survivor re-allocates to the full l2 capacity.
        assert!((n.flow_rate(h) - 1000.0).abs() < 1e-6);
        // Restore: new flows may use the link again.
        n.restore_link(l1);
        assert!(n.link_is_up(l1));
        let f2 = n.start_flow(
            FlowSpec { path: vec![l1], bytes: 1000, rate_cap: None },
            SimTime::from_secs_f64(0.5),
        );
        assert!((n.flow_rate(f2) - 1000.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "down link")]
    fn start_flow_over_cut_link_panics() {
        let (mut n, l) = net1();
        n.cut_link(l, SimTime::ZERO);
        n.start_flow(
            FlowSpec { path: vec![l], bytes: 10, rate_cap: None },
            SimTime::ZERO,
        );
    }

    #[test]
    fn degraded_link_slows_then_restores() {
        let (mut n, l) = net1();
        let f = n.start_flow(
            FlowSpec { path: vec![l], bytes: 1000, rate_cap: None },
            SimTime::ZERO,
        );
        n.scale_link_capacity(l, 0.5, SimTime::ZERO);
        assert!((n.flow_rate(f) - 500.0).abs() < 1e-6);
        assert_eq!(n.next_completion().unwrap(), SimTime::from_secs_f64(2.0));
        // Restore at t=1: 500 B left at full rate → done at 1.5 s.
        n.advance(SimTime::from_secs_f64(1.0));
        n.scale_link_capacity(l, 1.0, SimTime::from_secs_f64(1.0));
        assert!((n.flow_rate(f) - 1000.0).abs() < 1e-6);
        assert_eq!(n.next_completion().unwrap(), SimTime::from_secs_f64(1.5));
    }

    #[test]
    fn bytes_carried_accounting() {
        let (mut n, l) = net1();
        n.start_flow(
            FlowSpec { path: vec![l], bytes: 750, rate_cap: None },
            SimTime::ZERO,
        );
        n.advance(SimTime::from_secs_f64(0.5));
        assert!((n.link_bytes_carried(l) - 500.0).abs() < 1.0);
        n.advance(SimTime::from_secs_f64(1.0));
        assert!((n.link_bytes_carried(l) - 750.0).abs() < 1.0);
    }

    #[test]
    fn mid_flight_arrival_preserves_progress() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec { path: vec![l], bytes, rate_cap: None };
        let _f1 = n.start_flow(spec(1000), SimTime::ZERO);
        // At t=0.5, f1 has 500 left; f2 arrives, both at 500 B/s.
        let f2 = n.start_flow(spec(1000), SimTime::from_secs_f64(0.5));
        let t = n.next_completion().unwrap();
        assert_eq!(t, SimTime::from_secs_f64(1.5)); // f1: 500/500
        let done = n.advance(t);
        assert_eq!(done.len(), 1);
        // f2 then has 500 left at 1000 B/s.
        let t2 = n.next_completion().unwrap();
        assert_eq!(t2, SimTime::from_secs_f64(2.0));
        assert_eq!(n.advance(t2)[0].flow, f2);
    }

    #[test]
    fn disjoint_components_are_independent() {
        // Two single-link islands: events on one never re-fix the
        // other (the tentpole property, observable via the counters).
        let mut n = Network::new();
        let a = n.add_link_gbps(8e-9 * 1000.0);
        let b = n.add_link_gbps(8e-9 * 1000.0);
        let spec = |l, bytes| FlowSpec { path: vec![l], bytes, rate_cap: None };
        let fa = n.start_flow(spec(a, 10_000), SimTime::ZERO);
        let fb = n.start_flow(spec(b, 10_000), SimTime::ZERO);
        assert!((n.flow_rate(fa) - 1000.0).abs() < 1e-6);
        let refixed_before = n.stats.flows_refixed;
        // Churn on island a only.
        for i in 0..5u64 {
            let t = SimTime::from_secs_f64(0.1 * (i + 1) as f64);
            let f = n.start_flow(spec(a, 100), t);
            n.cancel_flow(f, t).unwrap();
        }
        let _ = n.flow_rate(fa);
        // Island b's flow was never re-fixed by a's churn.
        assert!((n.flow_rate(fb) - 1000.0).abs() < 1e-6);
        let refixed = n.stats.flows_refixed - refixed_before;
        // Each start re-fixes {fa, new}, each cancel re-fixes {fa}:
        // ~3 per churn cycle and never fb. A global (non-component)
        // allocator would re-fix both islands every op (≥ 25).
        assert!(refixed <= 20, "island b was touched: {refixed} re-fixes");
        assert!(n.stats.peak_component <= 2);
    }

    #[test]
    fn components_merge_and_split() {
        let mut n = Network::new();
        let a = n.add_link_gbps(8e-9 * 1000.0);
        let b = n.add_link_gbps(8e-9 * 1000.0);
        let spec = |path, bytes| FlowSpec { path, bytes, rate_cap: None };
        let fa = n.start_flow(spec(vec![a], 100_000), SimTime::ZERO);
        let fb = n.start_flow(spec(vec![b], 100_000), SimTime::ZERO);
        // A bridging flow merges the islands: all three now share.
        let bridge = n.start_flow(spec(vec![a, b], 100_000), SimTime::ZERO);
        assert!((n.flow_rate(fa) - 500.0).abs() < 1e-6);
        assert!((n.flow_rate(fb) - 500.0).abs() < 1e-6);
        assert!((n.flow_rate(bridge) - 500.0).abs() < 1e-6);
        assert_eq!(n.stats.peak_component, 3);
        // Removing the bridge splits them again; both islands recover
        // the full link.
        n.cancel_flow(bridge, SimTime::from_secs_f64(1.0)).unwrap();
        assert!((n.flow_rate(fa) - 1000.0).abs() < 1e-6);
        assert!((n.flow_rate(fb) - 1000.0).abs() < 1e-6);
        // Post-split churn on a must not re-fix fb: the start fixes
        // {fa, f}, the cancel re-fixes {fa} — never island b.
        let refixed_before = n.stats.flows_refixed;
        let f = n.start_flow(spec(vec![a], 100), SimTime::from_secs_f64(1.0));
        n.cancel_flow(f, SimTime::from_secs_f64(1.0)).unwrap();
        let _ = n.flow_rate(fa);
        assert!(n.stats.flows_refixed - refixed_before <= 4);
    }

    #[test]
    fn stale_flow_ids_never_resolve() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec { path: vec![l], bytes, rate_cap: None };
        let f1 = n.start_flow(spec(1000), SimTime::ZERO);
        n.cancel_flow(f1, SimTime::ZERO).unwrap();
        // The slot is reused; the old handle must not alias it.
        let f2 = n.start_flow(spec(1000), SimTime::ZERO);
        assert_eq!(f1.slot(), f2.slot(), "slab reuses the slot");
        assert_ne!(f1, f2);
        assert_eq!(n.flow_rate(f1), 0.0, "stale handle resolves to nothing");
        assert!(n.cancel_flow(f1, SimTime::ZERO).is_none());
        assert!((n.flow_rate(f2) - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn property_capacity_and_ceiling_respected() {
        use crate::util::prop::check;
        check("netsim invariants", 60, |g| {
            let mut n = Network::new();
            let n_links = g.usize(1, 5);
            let caps: Vec<f64> = (0..n_links).map(|_| g.f64(100.0, 10_000.0)).collect();
            let links: Vec<LinkId> = caps
                .iter()
                .map(|&c| n.add_link_gbps(8e-9 * c))
                .collect();
            let n_flows = g.usize(1, 12);
            let mut specs = Vec::new();
            for _ in 0..n_flows {
                let path_len = g.usize(1, n_links);
                let mut path: Vec<LinkId> = Vec::new();
                for _ in 0..path_len {
                    let l = *g.choose(&links);
                    if !path.contains(&l) {
                        path.push(l);
                    }
                }
                let cap = if g.bool() { Some(g.f64(10.0, 5_000.0)) } else { None };
                specs.push((path, cap));
            }
            let mut ids = Vec::new();
            for (path, cap) in &specs {
                ids.push(n.start_flow(
                    FlowSpec {
                        path: path.clone(),
                        bytes: 1_000_000,
                        rate_cap: *cap,
                    },
                    SimTime::ZERO,
                ));
            }
            // Invariant 1: per-link load <= capacity.
            let mut load = vec![0.0f64; n_links];
            for (id, (path, _)) in ids.iter().zip(&specs) {
                let rate = n.flow_rate(*id);
                for l in path {
                    load[l.0 as usize] += rate;
                }
            }
            for (i, &l) in load.iter().enumerate() {
                if l > caps[i] * (1.0 + 1e-6) {
                    return (false, format!("link {i} overloaded: {l} > {}", caps[i]));
                }
            }
            // Invariant 2: ceilings respected; rates positive.
            for (id, (_, cap)) in ids.iter().zip(&specs) {
                let rate = n.flow_rate(*id);
                if rate <= 0.0 {
                    return (false, format!("flow {id:?} has rate {rate}"));
                }
                if let Some(c) = cap {
                    if rate > c * (1.0 + 1e-9) {
                        return (false, format!("flow {id:?} exceeds cap: {rate} > {c}"));
                    }
                }
            }
            (true, String::new())
        });
    }

    #[test]
    fn property_work_conservation() {
        // Total completion time of k equal flows on one link equals
        // k * serial time (fair sharing conserves work).
        use crate::util::prop::check;
        check("work conservation", 30, |g| {
            let k = g.usize(1, 8) as u64;
            let bytes = g.u64(1_000, 1_000_000);
            let mut n = Network::new();
            let l = n.add_link_gbps(8e-9 * 1e6); // 1 MB/s
            for _ in 0..k {
                n.start_flow(
                    FlowSpec { path: vec![l], bytes, rate_cap: None },
                    SimTime::ZERO,
                );
            }
            let mut last = SimTime::ZERO;
            while let Some(t) = n.next_completion() {
                for c in n.advance(t) {
                    last = c.at;
                }
            }
            let expected = k as f64 * bytes as f64 / 1e6;
            let got = last.as_secs_f64();
            (
                (got - expected).abs() < 1e-3 + expected * 1e-6,
                format!("k={k} bytes={bytes} expected {expected} got {got}"),
            )
        });
    }

    /// The tentpole correctness bar: after an arbitrary op sequence,
    /// the incremental allocator's full rate vector equals a
    /// from-scratch allocation on a freshly rebuilt network — **exact
    /// equality**, not epsilon. (Max-min decomposes over components
    /// and the component water-fill is the only rate producer in both
    /// paths, so every intermediate f64 is the same.)
    #[test]
    fn property_incremental_equals_rebuild() {
        use crate::util::prop::check;
        check("incremental == from-scratch rebuild", 40, |g| {
            let n_links = g.usize(2, 8);
            let caps_gbps: Vec<f64> =
                (0..n_links).map(|_| 8e-9 * g.f64(100.0, 10_000.0)).collect();
            let mut n = Network::new();
            let links: Vec<LinkId> =
                caps_gbps.iter().map(|&c| n.add_link_gbps(c)).collect();
            // Live flows in start order: (id, path, cap).
            let mut live: Vec<(FlowId, Vec<LinkId>, Option<f64>)> = Vec::new();
            let mut now = SimTime::ZERO;
            let ops = g.usize(5, 40);
            for _ in 0..ops {
                match g.usize(0, 5) {
                    // start
                    0 | 1 => {
                        let up: Vec<LinkId> = links
                            .iter()
                            .copied()
                            .filter(|&l| n.link_is_up(l))
                            .collect();
                        if up.is_empty() {
                            continue;
                        }
                        let mut path = Vec::new();
                        for _ in 0..g.usize(1, 3.min(up.len())) {
                            let l = *g.choose(&up);
                            if !path.contains(&l) {
                                path.push(l);
                            }
                        }
                        let cap =
                            if g.bool() { Some(g.f64(10.0, 5_000.0)) } else { None };
                        let id = n.start_flow(
                            FlowSpec {
                                path: path.clone(),
                                bytes: g.u64(1_000, 10_000_000),
                                rate_cap: cap,
                            },
                            now,
                        );
                        live.push((id, path, cap));
                    }
                    // cancel
                    2 => {
                        if live.is_empty() {
                            continue;
                        }
                        let i = g.usize(0, live.len() - 1);
                        let (id, _, _) = live.remove(i);
                        n.cancel_flow(id, now).expect("live flow");
                    }
                    // cut + restore bookkeeping
                    3 => {
                        let l = *g.choose(&links);
                        if n.link_is_up(l) {
                            let killed = n.cut_link(l, now);
                            live.retain(|(id, _, _)| {
                                !killed.iter().any(|(k, _)| k == id)
                            });
                        } else {
                            n.restore_link(l);
                        }
                    }
                    // scale
                    4 => {
                        let l = *g.choose(&links);
                        n.scale_link_capacity(l, g.f64(0.1, 1.0), now);
                    }
                    // advance past the next completion(s)
                    _ => {
                        now += crate::util::Duration::from_micros(g.u64(1, 2_000_000));
                        for c in n.advance(now) {
                            live.retain(|(id, _, _)| *id != c.flow);
                        }
                    }
                }
            }
            // Rebuild: same links, same factors, the same surviving
            // flows in the same start order (bytes are irrelevant to
            // rates).
            let mut r = Network::new();
            let rlinks: Vec<LinkId> =
                caps_gbps.iter().map(|&c| r.add_link_gbps(c)).collect();
            for (i, &l) in rlinks.iter().enumerate() {
                let factor = n.links[i].factor;
                if factor != 1.0 {
                    r.scale_link_capacity(l, factor, SimTime::ZERO);
                }
            }
            let mut pairs = Vec::new();
            for (id, path, cap) in &live {
                let rid = r.start_flow(
                    FlowSpec {
                        path: path.clone(),
                        bytes: 1,
                        rate_cap: *cap,
                    },
                    SimTime::ZERO,
                );
                pairs.push((*id, rid));
            }
            for (id, rid) in pairs {
                let a = n.flow_rate(id);
                let b = r.flow_rate(rid);
                if a.to_bits() != b.to_bits() {
                    return (
                        false,
                        format!("flow {id:?}: incremental {a:?} != rebuild {b:?}"),
                    );
                }
            }
            (true, String::new())
        });
    }

    /// Satellite regression: carried-bytes accounting through the
    /// cached per-link aggregate rate matches the old per-member
    /// summation — on a multi-link scenario run to completion, each
    /// link carried the bytes of exactly the flows that crossed it
    /// (within the ≤1-byte-per-completion µs rounding slack both
    /// accountings share).
    #[test]
    fn bytes_carried_matches_per_member_summation() {
        let mut n = Network::new();
        let l1 = n.add_link_gbps(8e-9 * 1000.0);
        let l2 = n.add_link_gbps(8e-9 * 400.0);
        let l3 = n.add_link_gbps(8e-9 * 2000.0);
        let flows: Vec<(Vec<LinkId>, u64)> = vec![
            (vec![l1], 10_000),
            (vec![l1, l2], 4_000),
            (vec![l2], 6_000),
            (vec![l1, l3], 12_000),
            (vec![l3], 20_000),
        ];
        // Reference accounting: per-member summation at every rate
        // segment (the pre-refactor algorithm), driven via snapshots.
        let mut expected = vec![0.0f64; 3];
        let mut prev = SimTime::ZERO;
        for (path, bytes) in &flows {
            n.start_flow(
                FlowSpec { path: path.clone(), bytes: *bytes, rate_cap: None },
                SimTime::ZERO,
            );
        }
        loop {
            let snap = n.flows_snapshot();
            let Some(t) = n.next_completion() else { break };
            let dt = (t - prev).as_secs_f64();
            for (_, _, rate, path) in &snap {
                for l in path {
                    expected[l.0 as usize] += rate * dt;
                }
            }
            prev = t;
            n.advance(t);
        }
        for (i, l) in [l1, l2, l3].into_iter().enumerate() {
            let got = n.link_bytes_carried(l);
            assert!(
                (got - expected[i]).abs() <= 1e-6 * expected[i].max(1.0),
                "link {i}: cached-aggregate {got} vs per-member {e}",
                e = expected[i]
            );
            // And both equal the sum of crossing flows' payloads to
            // within the shared µs-rounding slack (≤ 1 byte/flow).
            let payload: u64 = flows
                .iter()
                .filter(|(p, _)| p.contains(&l))
                .map(|(_, b)| *b)
                .sum();
            assert!(
                (got - payload as f64).abs() < flows.len() as f64,
                "link {i}: carried {got} vs payload {payload}"
            );
        }
    }
}
