//! Flow-level network model with max-min fair bandwidth sharing.
//!
//! Each [`Link`] has a capacity in bytes/second. A [`Flow`] occupies a
//! path (set of links) and optionally carries a per-connection rate
//! ceiling (modelling a squid proxy's single-stream limit vs an XRootD
//! cache's multi-stream transfers). Whenever the flow set changes, the
//! allocator recomputes the **max-min fair** rate vector by progressive
//! water-filling: repeatedly saturate the most constrained link (or
//! flow ceiling) and freeze the flows it bottlenecks.
//!
//! Completions are kinetic: the earliest projected completion is
//! re-derived after every rate change, so the driver can interleave its
//! own timer events with transfer completions deterministically.

use crate::util::{SimTime};
use std::collections::HashMap;

/// Handle to a link.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(pub u32);

/// Handle to an active flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

/// Specification of a new flow.
#[derive(Debug, Clone)]
pub struct FlowSpec {
    /// Links traversed (order irrelevant to the allocator).
    pub path: Vec<LinkId>,
    /// Payload size in bytes.
    pub bytes: u64,
    /// Optional per-connection rate ceiling (bytes/sec).
    pub rate_cap: Option<f64>,
}

#[derive(Debug)]
struct Link {
    capacity: f64, // bytes/sec
    /// Degradation factor in (0, 1]: effective capacity is
    /// `capacity * factor` (origin brownouts, failure injection).
    factor: f64,
    /// Severed links carry no flows and reject new ones until restored.
    up: bool,
    /// Active flows on this link (kept sorted for determinism).
    flows: Vec<FlowId>,
    /// Cumulative bytes that have traversed this link.
    bytes_carried: f64,
}

#[derive(Debug)]
struct Flow {
    path: Vec<LinkId>,
    remaining: f64,
    rate: f64,
    rate_cap: Option<f64>,
    started: SimTime,
}

/// A completed transfer, as reported by [`Network::advance`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub flow: FlowId,
    pub at: SimTime,
    pub started: SimTime,
}

/// The link/flow state and allocator. Time never advances implicitly:
/// the driver calls [`Network::advance`] to move to a chosen instant.
#[derive(Debug, Default)]
pub struct Network {
    links: Vec<Link>,
    flows: HashMap<FlowId, Flow>,
    next_flow: u64,
    /// Last instant at which `remaining` was reconciled.
    clock: SimTime,
    /// Rates stale (flow set changed since last allocation)?
    dirty: bool,
    /// Lifetime counters for perf accounting.
    pub allocations: u64,
}

impl Network {
    pub fn new() -> Self {
        Network::default()
    }

    /// Add a link with capacity in **Gbit/s** (the config unit);
    /// stored internally as bytes/sec.
    pub fn add_link_gbps(&mut self, gbps: f64) -> LinkId {
        assert!(gbps > 0.0 && gbps.is_finite());
        self.links.push(Link {
            capacity: gbps * 1e9 / 8.0,
            factor: 1.0,
            up: true,
            flows: Vec::new(),
            bytes_carried: 0.0,
        });
        LinkId(self.links.len() as u32 - 1)
    }

    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Cumulative bytes carried by a link (for the Fig 5 WAN counters).
    pub fn link_bytes_carried(&self, link: LinkId) -> f64 {
        self.links[link.0 as usize].bytes_carried
    }

    /// Debug snapshot: (flow, remaining bytes, rate B/s, path).
    pub fn flows_snapshot(&mut self) -> Vec<(FlowId, f64, f64, Vec<LinkId>)> {
        self.reallocate_if_dirty();
        let mut v: Vec<_> = self
            .flows
            .iter()
            .map(|(&id, f)| (id, f.remaining, f.rate, f.path.clone()))
            .collect();
        v.sort_by_key(|e| e.0);
        v
    }

    /// Current allocated rate of a flow (bytes/sec). Zero if unknown.
    pub fn flow_rate(&mut self, flow: FlowId) -> f64 {
        self.reallocate_if_dirty();
        self.flows.get(&flow).map(|f| f.rate).unwrap_or(0.0)
    }

    /// Start a flow at time `now` (must be >= the last event time).
    ///
    /// A path that crosses the same link more than once (e.g. a
    /// cache-relay streaming origin→cache→worker over the cache's WAN
    /// link in both directions) occupies it **once**: links are
    /// full-duplex, so the two directions do not share capacity.
    pub fn start_flow(&mut self, spec: FlowSpec, now: SimTime) -> FlowId {
        assert!(!spec.path.is_empty(), "flow with empty path");
        assert!(spec.bytes > 0, "flow with zero bytes");
        let mut path = spec.path;
        path.sort_unstable();
        path.dedup();
        for l in &path {
            assert!((l.0 as usize) < self.links.len(), "unknown link {l:?}");
            assert!(
                self.links[l.0 as usize].up,
                "starting a flow over a down link {l:?}"
            );
        }
        self.reconcile(now);
        let id = FlowId(self.next_flow);
        self.next_flow += 1;
        for l in &path {
            self.links[l.0 as usize].flows.push(id);
        }
        self.flows.insert(
            id,
            Flow {
                path,
                remaining: spec.bytes as f64,
                rate: 0.0,
                rate_cap: spec.rate_cap,
                started: now,
            },
        );
        self.dirty = true;
        id
    }

    /// Abort a flow (e.g. failure injection). Returns bytes left.
    pub fn cancel_flow(&mut self, flow: FlowId, now: SimTime) -> Option<u64> {
        self.reconcile(now);
        let f = self.flows.remove(&flow)?;
        for l in &f.path {
            self.links[l.0 as usize].flows.retain(|&x| x != flow);
        }
        self.dirty = true;
        Some(f.remaining.ceil() as u64)
    }

    /// Sever a link (failure injection): every flow crossing it is
    /// killed and returned (with its remaining bytes, in `FlowId`
    /// order), surviving flows are re-allocated max-min fairly, and new
    /// flows may not use the link until [`Network::restore_link`].
    pub fn cut_link(&mut self, link: LinkId, now: SimTime) -> Vec<(FlowId, u64)> {
        self.reconcile(now);
        let li = link.0 as usize;
        let mut ids = self.links[li].flows.clone();
        ids.sort_unstable();
        let mut killed = Vec::with_capacity(ids.len());
        for id in ids {
            let f = self.flows.remove(&id).expect("flow on cut link");
            for l in &f.path {
                self.links[l.0 as usize].flows.retain(|&x| x != id);
            }
            killed.push((id, f.remaining.ceil() as u64));
            self.dirty = true;
        }
        self.links[li].up = false;
        killed
    }

    /// Bring a severed link back up (capacity and degradation factor
    /// are as they were).
    pub fn restore_link(&mut self, link: LinkId) {
        self.links[link.0 as usize].up = true;
    }

    pub fn link_is_up(&self, link: LinkId) -> bool {
        self.links[link.0 as usize].up
    }

    /// Scale a link's effective capacity by `factor` in (0, 1] —
    /// origin brownouts and partial degradations. `1.0` restores full
    /// capacity. Progress up to `now` is applied at the old rates
    /// first; active flows are then re-allocated.
    pub fn scale_link_capacity(&mut self, link: LinkId, factor: f64, now: SimTime) {
        assert!(
            factor > 0.0 && factor <= 1.0 && factor.is_finite(),
            "capacity factor must be in (0, 1], got {factor}"
        );
        self.reconcile(now);
        self.links[link.0 as usize].factor = factor;
        self.dirty = true;
    }

    /// Earliest projected completion time, if any flow is active.
    pub fn next_completion(&mut self) -> Option<SimTime> {
        self.reallocate_if_dirty();
        let mut best: Option<f64> = None;
        for f in self.flows.values() {
            debug_assert!(f.rate > 0.0, "allocated flow with zero rate");
            let eta = f.remaining / f.rate;
            best = Some(best.map_or(eta, |b: f64| b.min(eta)));
        }
        best.map(|eta| {
            // Round up to the next microsecond so the completion event
            // never lands before the flow actually finishes; for etas
            // below the clock's f64 resolution, force a 1 µs tick so
            // callers always make progress.
            let t = self.clock.as_secs_f64() + eta;
            SimTime(((t * 1e6).ceil() as u64).max(self.clock.0 + 1))
        })
    }

    /// Advance to `t`, applying transfer progress and collecting flows
    /// that finish at or before `t` (in deterministic FlowId order).
    ///
    /// `t` should not exceed [`Network::next_completion`] by more than
    /// the 1 µs rounding slack; completions beyond `t` stay active.
    pub fn advance(&mut self, t: SimTime) -> Vec<Completion> {
        self.reallocate_if_dirty();
        let mut done = Vec::new();
        // Flows may complete in cascades: when one finishes, the others
        // speed up. Process piecewise-constant segments. Finished flows
        // are collected at the top so that flows whose completion
        // instant was crossed by a reconcile (a new flow arriving after
        // time already passed) are retired even when `t == clock`.
        loop {
            let mut finished: Vec<FlowId> = self
                .flows
                .iter()
                .filter(|(_, f)| f.remaining < 1.0) // sub-byte epsilon
                .map(|(&id, _)| id)
                .collect();
            finished.sort_unstable();
            for id in finished {
                let f = self.flows.remove(&id).expect("flow exists");
                for l in &f.path {
                    self.links[l.0 as usize].flows.retain(|&x| x != id);
                }
                done.push(Completion {
                    flow: id,
                    at: self.clock,
                    started: f.started,
                });
                self.dirty = true;
            }
            self.reallocate_if_dirty();
            if self.clock >= t {
                break;
            }
            let seg_end = match self.earliest_eta() {
                Some(eta) if eta <= t => eta,
                _ => t,
            };
            // Guarantee forward progress (≥ 1 µs) even when an eta
            // rounds onto the current clock, and never overshoot `t`.
            self.apply_progress(seg_end.max(SimTime(self.clock.0 + 1)).min(t));
        }
        done
    }

    /// Earliest completion instant given current rates.
    fn earliest_eta(&self) -> Option<SimTime> {
        let mut best: Option<f64> = None;
        for f in self.flows.values() {
            if f.rate > 0.0 {
                let eta = f.remaining / f.rate;
                best = Some(best.map_or(eta, |b: f64| b.min(eta)));
            }
        }
        best.map(|eta| {
            SimTime((((self.clock.as_secs_f64() + eta) * 1e6).ceil() as u64).max(self.clock.0 + 1))
        })
    }

    /// Apply progress from `self.clock` to `t` at current rates.
    fn apply_progress(&mut self, t: SimTime) {
        if t <= self.clock {
            return;
        }
        let dt = (t - self.clock).as_secs_f64();
        for f in self.flows.values_mut() {
            f.remaining = (f.remaining - f.rate * dt).max(0.0);
        }
        for link in &mut self.links {
            let carried: f64 = link
                .flows
                .iter()
                .map(|id| self.flows[id].rate * dt)
                .sum();
            link.bytes_carried += carried;
        }
        self.clock = t;
    }

    /// Reconcile progress up to `now` (before mutating the flow set).
    fn reconcile(&mut self, now: SimTime) {
        assert!(now >= self.clock, "network clock moved backwards");
        self.reallocate_if_dirty();
        self.apply_progress(now);
    }

    fn reallocate_if_dirty(&mut self) {
        if self.dirty {
            self.reallocate();
            self.dirty = false;
        }
    }

    /// Max-min fair allocation by progressive filling.
    ///
    /// Invariants established (checked by property tests):
    /// 1. no link carries more than its capacity (within 1e-6 rel.);
    /// 2. no flow exceeds its rate ceiling;
    /// 3. every flow is bottlenecked: it either sits at its ceiling or
    ///    traverses a saturated link where it has a maximal share.
    fn reallocate(&mut self) {
        self.allocations += 1;
        if self.flows.is_empty() {
            return;
        }
        // Working copies.
        let mut residual: Vec<f64> = self.links.iter().map(|l| l.capacity * l.factor).collect();
        let mut active_on: Vec<usize> = self.links.iter().map(|l| l.flows.len()).collect();
        let mut unfixed: Vec<FlowId> = self.flows.keys().copied().collect();
        unfixed.sort_unstable(); // determinism

        while !unfixed.is_empty() {
            // Fair share offered by each link still carrying unfixed flows.
            let mut bottleneck_share = f64::INFINITY;
            for (i, _link) in self.links.iter().enumerate() {
                if active_on[i] > 0 {
                    bottleneck_share = bottleneck_share.min(residual[i] / active_on[i] as f64);
                }
            }
            debug_assert!(bottleneck_share.is_finite());

            // Flows whose ceiling binds below the bottleneck share are
            // fixed at their ceiling first. `capped` inherits the sort
            // order of `unfixed`, so one binary-searched retain sweep
            // removes the whole round — the per-flow `retain` here was
            // the O(n²) cost that capped the session engine at ~1k
            // concurrent transfers.
            let capped: Vec<FlowId> = unfixed
                .iter()
                .copied()
                .filter(|id| {
                    self.flows[id]
                        .rate_cap
                        .is_some_and(|c| c < bottleneck_share)
                })
                .collect();
            if !capped.is_empty() {
                for &id in &capped {
                    let cap = self.flows[&id].rate_cap.expect("cap exists");
                    self.fix_flow(id, cap, &mut residual, &mut active_on);
                }
                unfixed.retain(|x| capped.binary_search(x).is_err());
                continue; // shares changed; recompute bottleneck
            }

            // Otherwise saturate the bottleneck link(s): fix every
            // unfixed flow crossing a link that offers the minimum
            // share. Duplicates (a flow crossing two saturated links)
            // are removed by one sort+dedup instead of a `contains`
            // scan per push.
            let mut to_fix: Vec<FlowId> = Vec::new();
            for (i, _) in self.links.iter().enumerate() {
                if active_on[i] > 0
                    && residual[i] / active_on[i] as f64 <= bottleneck_share * (1.0 + 1e-12)
                {
                    for id in &self.links[i].flows {
                        if unfixed.binary_search(id).is_ok() {
                            to_fix.push(*id);
                        }
                    }
                }
            }
            to_fix.sort_unstable();
            to_fix.dedup();
            debug_assert!(!to_fix.is_empty());
            for &id in &to_fix {
                self.fix_flow(id, bottleneck_share, &mut residual, &mut active_on);
            }
            unfixed.retain(|x| to_fix.binary_search(x).is_err());
        }
    }

    fn fix_flow(
        &mut self,
        id: FlowId,
        rate: f64,
        residual: &mut [f64],
        active_on: &mut [usize],
    ) {
        let flow = self.flows.get_mut(&id).expect("flow exists");
        flow.rate = rate;
        for l in &flow.path {
            let i = l.0 as usize;
            residual[i] = (residual[i] - rate).max(0.0);
            active_on[i] -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net1() -> (Network, LinkId) {
        let mut n = Network::new();
        let l = n.add_link_gbps(8e-9 * 1000.0); // 1000 B/s for easy math
        (n, l)
    }

    #[test]
    fn single_flow_gets_full_capacity() {
        let (mut n, l) = net1();
        let f = n.start_flow(
            FlowSpec {
                path: vec![l],
                bytes: 1000,
                rate_cap: None,
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 1000.0).abs() < 1e-6);
        let t = n.next_completion().unwrap();
        assert_eq!(t, SimTime::from_secs_f64(1.0));
        let done = n.advance(t);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].flow, f);
        assert_eq!(n.active_flows(), 0);
    }

    #[test]
    fn two_flows_share_equally() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec {
            path: vec![l],
            bytes,
            rate_cap: None,
        };
        let f1 = n.start_flow(spec(1000), SimTime::ZERO);
        let f2 = n.start_flow(spec(1000), SimTime::ZERO);
        assert!((n.flow_rate(f1) - 500.0).abs() < 1e-6);
        assert!((n.flow_rate(f2) - 500.0).abs() < 1e-6);
        // Both finish at t=2s.
        let t = n.next_completion().unwrap();
        assert_eq!(t, SimTime::from_secs_f64(2.0));
        let done = n.advance(t);
        assert_eq!(done.len(), 2);
    }

    #[test]
    fn departure_speeds_up_survivor() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec {
            path: vec![l],
            bytes,
            rate_cap: None,
        };
        let _f1 = n.start_flow(spec(500), SimTime::ZERO);
        let f2 = n.start_flow(spec(1500), SimTime::ZERO);
        // f1 finishes at 1s (rate 500); f2 then has 1000 left at rate 1000.
        let t1 = n.next_completion().unwrap();
        assert_eq!(t1, SimTime::from_secs_f64(1.0));
        let done = n.advance(t1);
        assert_eq!(done.len(), 1);
        assert!((n.flow_rate(f2) - 1000.0).abs() < 1e-6);
        let t2 = n.next_completion().unwrap();
        assert_eq!(t2, SimTime::from_secs_f64(2.0));
    }

    #[test]
    fn cascade_completions_in_one_advance() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec {
            path: vec![l],
            bytes,
            rate_cap: None,
        };
        n.start_flow(spec(500), SimTime::ZERO);
        n.start_flow(spec(1500), SimTime::ZERO);
        // Advance straight to 2s: both complete, at 1s and 2s.
        let done = n.advance(SimTime::from_secs_f64(2.0));
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].at, SimTime::from_secs_f64(1.0));
        assert_eq!(done[1].at, SimTime::from_secs_f64(2.0));
    }

    #[test]
    fn rate_cap_binds() {
        let (mut n, l) = net1();
        let f = n.start_flow(
            FlowSpec {
                path: vec![l],
                bytes: 100,
                rate_cap: Some(100.0),
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 100.0).abs() < 1e-6);
        // Capped flow leaves headroom for an uncapped one.
        let g = n.start_flow(
            FlowSpec {
                path: vec![l],
                bytes: 900,
                rate_cap: None,
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 100.0).abs() < 1e-6);
        assert!((n.flow_rate(g) - 900.0).abs() < 1e-6);
    }

    #[test]
    fn multi_link_path_takes_min() {
        let mut n = Network::new();
        let fast = n.add_link_gbps(8e-9 * 1000.0);
        let slow = n.add_link_gbps(8e-9 * 250.0);
        let f = n.start_flow(
            FlowSpec {
                path: vec![fast, slow],
                bytes: 250,
                rate_cap: None,
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 250.0).abs() < 1e-6);
    }

    #[test]
    fn max_min_three_flows_two_links() {
        // Classic example: flows A (l1), B (l1+l2), C (l2).
        // l1 cap 1000, l2 cap 400: B gets 200 (l2 fair share), C 200,
        // A then gets 800.
        let mut n = Network::new();
        let l1 = n.add_link_gbps(8e-9 * 1000.0);
        let l2 = n.add_link_gbps(8e-9 * 400.0);
        let a = n.start_flow(
            FlowSpec { path: vec![l1], bytes: 10_000, rate_cap: None },
            SimTime::ZERO,
        );
        let b = n.start_flow(
            FlowSpec { path: vec![l1, l2], bytes: 10_000, rate_cap: None },
            SimTime::ZERO,
        );
        let c = n.start_flow(
            FlowSpec { path: vec![l2], bytes: 10_000, rate_cap: None },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(b) - 200.0).abs() < 1e-6, "b={}", n.flow_rate(b));
        assert!((n.flow_rate(c) - 200.0).abs() < 1e-6);
        assert!((n.flow_rate(a) - 800.0).abs() < 1e-6);
    }

    #[test]
    fn duplicate_links_in_path_count_once() {
        // The cache-relay pattern: origin→cache→worker crosses the
        // cache's WAN link twice; capacity must be charged once.
        let mut n = Network::new();
        let a = n.add_link_gbps(8e-9 * 1000.0);
        let b = n.add_link_gbps(8e-9 * 1000.0);
        let f = n.start_flow(
            FlowSpec {
                path: vec![a, b, a, b, a],
                bytes: 1000,
                rate_cap: None,
            },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 1000.0).abs() < 1e-6);
        // A second flow on link a shares fairly (no phantom members).
        let g = n.start_flow(
            FlowSpec { path: vec![a], bytes: 1000, rate_cap: None },
            SimTime::ZERO,
        );
        assert!((n.flow_rate(f) - 500.0).abs() < 1e-6);
        assert!((n.flow_rate(g) - 500.0).abs() < 1e-6);
        // Completions drain cleanly (regression: duplicate entries
        // underflowed the allocator's active counters).
        while let Some(t) = n.next_completion() {
            n.advance(t);
        }
        assert_eq!(n.active_flows(), 0);
    }

    #[test]
    fn cancel_restores_capacity() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec { path: vec![l], bytes, rate_cap: None };
        let f1 = n.start_flow(spec(10_000), SimTime::ZERO);
        let f2 = n.start_flow(spec(10_000), SimTime::ZERO);
        n.advance(SimTime::from_secs_f64(1.0));
        let left = n.cancel_flow(f1, SimTime::from_secs_f64(1.0)).unwrap();
        assert_eq!(left, 10_000 - 500);
        assert!((n.flow_rate(f2) - 1000.0).abs() < 1e-6);
        assert!(n.cancel_flow(f1, SimTime::from_secs_f64(1.0)).is_none());
    }

    #[test]
    fn cut_link_kills_crossing_flows_and_blocks_new_ones() {
        let mut n = Network::new();
        let l1 = n.add_link_gbps(8e-9 * 1000.0);
        let l2 = n.add_link_gbps(8e-9 * 1000.0);
        let f = n.start_flow(
            FlowSpec { path: vec![l1], bytes: 1000, rate_cap: None },
            SimTime::ZERO,
        );
        let g = n.start_flow(
            FlowSpec { path: vec![l1, l2], bytes: 2000, rate_cap: None },
            SimTime::ZERO,
        );
        let h = n.start_flow(
            FlowSpec { path: vec![l2], bytes: 2000, rate_cap: None },
            SimTime::ZERO,
        );
        // Max-min gives every flow 500 B/s; at t=0.5 each moved 250 B.
        let killed = n.cut_link(l1, SimTime::from_secs_f64(0.5));
        assert_eq!(killed, vec![(f, 750), (g, 1750)]);
        assert!(!n.link_is_up(l1));
        assert_eq!(n.active_flows(), 1);
        // The survivor re-allocates to the full l2 capacity.
        assert!((n.flow_rate(h) - 1000.0).abs() < 1e-6);
        // Restore: new flows may use the link again.
        n.restore_link(l1);
        assert!(n.link_is_up(l1));
        let f2 = n.start_flow(
            FlowSpec { path: vec![l1], bytes: 1000, rate_cap: None },
            SimTime::from_secs_f64(0.5),
        );
        assert!((n.flow_rate(f2) - 1000.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "down link")]
    fn start_flow_over_cut_link_panics() {
        let (mut n, l) = net1();
        n.cut_link(l, SimTime::ZERO);
        n.start_flow(
            FlowSpec { path: vec![l], bytes: 10, rate_cap: None },
            SimTime::ZERO,
        );
    }

    #[test]
    fn degraded_link_slows_then_restores() {
        let (mut n, l) = net1();
        let f = n.start_flow(
            FlowSpec { path: vec![l], bytes: 1000, rate_cap: None },
            SimTime::ZERO,
        );
        n.scale_link_capacity(l, 0.5, SimTime::ZERO);
        assert!((n.flow_rate(f) - 500.0).abs() < 1e-6);
        assert_eq!(n.next_completion().unwrap(), SimTime::from_secs_f64(2.0));
        // Restore at t=1: 500 B left at full rate → done at 1.5 s.
        n.advance(SimTime::from_secs_f64(1.0));
        n.scale_link_capacity(l, 1.0, SimTime::from_secs_f64(1.0));
        assert!((n.flow_rate(f) - 1000.0).abs() < 1e-6);
        assert_eq!(n.next_completion().unwrap(), SimTime::from_secs_f64(1.5));
    }

    #[test]
    fn bytes_carried_accounting() {
        let (mut n, l) = net1();
        n.start_flow(
            FlowSpec { path: vec![l], bytes: 750, rate_cap: None },
            SimTime::ZERO,
        );
        n.advance(SimTime::from_secs_f64(0.5));
        assert!((n.link_bytes_carried(l) - 500.0).abs() < 1.0);
        n.advance(SimTime::from_secs_f64(1.0));
        assert!((n.link_bytes_carried(l) - 750.0).abs() < 1.0);
    }

    #[test]
    fn mid_flight_arrival_preserves_progress() {
        let (mut n, l) = net1();
        let spec = |bytes| FlowSpec { path: vec![l], bytes, rate_cap: None };
        let _f1 = n.start_flow(spec(1000), SimTime::ZERO);
        // At t=0.5, f1 has 500 left; f2 arrives, both at 500 B/s.
        let f2 = n.start_flow(spec(1000), SimTime::from_secs_f64(0.5));
        let t = n.next_completion().unwrap();
        assert_eq!(t, SimTime::from_secs_f64(1.5)); // f1: 500/500
        let done = n.advance(t);
        assert_eq!(done.len(), 1);
        // f2 then has 500 left at 1000 B/s.
        let t2 = n.next_completion().unwrap();
        assert_eq!(t2, SimTime::from_secs_f64(2.0));
        assert_eq!(n.advance(t2)[0].flow, f2);
    }

    #[test]
    fn property_capacity_and_ceiling_respected() {
        use crate::util::prop::check;
        check("netsim invariants", 60, |g| {
            let mut n = Network::new();
            let n_links = g.usize(1, 5);
            let caps: Vec<f64> = (0..n_links).map(|_| g.f64(100.0, 10_000.0)).collect();
            let links: Vec<LinkId> = caps
                .iter()
                .map(|&c| n.add_link_gbps(8e-9 * c))
                .collect();
            let n_flows = g.usize(1, 12);
            let mut specs = Vec::new();
            for _ in 0..n_flows {
                let path_len = g.usize(1, n_links);
                let mut path: Vec<LinkId> = Vec::new();
                for _ in 0..path_len {
                    let l = *g.choose(&links);
                    if !path.contains(&l) {
                        path.push(l);
                    }
                }
                let cap = if g.bool() { Some(g.f64(10.0, 5_000.0)) } else { None };
                specs.push((path, cap));
            }
            for (path, cap) in &specs {
                n.start_flow(
                    FlowSpec {
                        path: path.clone(),
                        bytes: 1_000_000,
                        rate_cap: *cap,
                    },
                    SimTime::ZERO,
                );
            }
            // Invariant 1: per-link load <= capacity.
            let mut load = vec![0.0f64; n_links];
            let ids: Vec<FlowId> = n.flows.keys().copied().collect();
            for id in &ids {
                let rate = n.flow_rate(*id);
                let path = n.flows[id].path.clone();
                for l in path {
                    load[l.0 as usize] += rate;
                }
            }
            for (i, &l) in load.iter().enumerate() {
                if l > caps[i] * (1.0 + 1e-6) {
                    return (false, format!("link {i} overloaded: {l} > {}", caps[i]));
                }
            }
            // Invariant 2: ceilings respected; rates positive.
            for id in &ids {
                let f = &n.flows[id];
                if f.rate <= 0.0 {
                    return (false, format!("flow {id:?} has rate {}", f.rate));
                }
                if let Some(c) = f.rate_cap {
                    if f.rate > c * (1.0 + 1e-9) {
                        return (false, format!("flow {id:?} exceeds cap: {} > {c}", f.rate));
                    }
                }
            }
            (true, String::new())
        });
    }

    #[test]
    fn property_work_conservation() {
        // Total completion time of k equal flows on one link equals
        // k * serial time (fair sharing conserves work).
        use crate::util::prop::check;
        check("work conservation", 30, |g| {
            let k = g.usize(1, 8) as u64;
            let bytes = g.u64(1_000, 1_000_000);
            let mut n = Network::new();
            let l = n.add_link_gbps(8e-9 * 1e6); // 1 MB/s
            for _ in 0..k {
                n.start_flow(
                    FlowSpec { path: vec![l], bytes, rate_cap: None },
                    SimTime::ZERO,
                );
            }
            let mut last = SimTime::ZERO;
            while let Some(t) = n.next_completion() {
                for c in n.advance(t) {
                    last = c.at;
                }
            }
            let expected = k as f64 * bytes as f64 / 1e6;
            let got = last.as_secs_f64();
            (
                (got - expected).abs() < 1e-3 + expected * 1e-6,
                format!("k={k} bytes={bytes} expected {expected} got {got}"),
            )
        });
    }
}
