//! Flow-level discrete-event network simulator.
//!
//! The paper's evaluation ran on the production OSG WAN; this module is
//! the substitute substrate (DESIGN.md §2 row 1). It models the
//! federation's links as capacities shared max-min fairly among active
//! flows — the standard flow-level abstraction for TCP over long fat
//! networks — plus per-connection rate ceilings (squid's single-stream
//! limit vs XRootD's multi-stream transfers, the mechanism behind the
//! paper's large-file crossover).
//!
//! * [`engine`] — deterministic event queue over [`crate::util::SimTime`].
//! * [`network`] — links, flows, component-local incremental max-min
//!   rate allocation, completions. A flow arrival or departure
//!   re-allocates only the connected component of links it touches
//!   (O(affected), not O(everything)); see the module doc for the
//!   slab/heap/aggregate-rate machinery and ARCHITECTURE.md for the
//!   per-event complexity table.
//! * [`topology`] — builds the federation graph (workers, proxies,
//!   caches, borders, WAN core) from a [`crate::config::FederationConfig`]
//!   and answers path/RTT queries.

pub mod engine;
pub mod network;
pub mod topology;

pub use engine::EventQueue;
pub use network::{AllocStats, Completion, FlowId, FlowSpec, LinkId, Network};
pub use topology::{Endpoint, Route, Topology};
