//! Typed executors over the AOT artifacts, with the batch-padding
//! conventions of `python/compile/model.py`.

use super::loader::{Artifact, Runtime};
use crate::geoip::{CacheSite, GeoScoreBackend};
use crate::monitoring::aggregator::{HistBackend, HIST_BINS};
use anyhow::{ensure, Context, Result};

/// Fixed AOT shapes (keep in lock-step with `model.py`).
pub const GEO_CLIENTS: usize = 64;
pub const GEO_CACHES: usize = 16;
pub const HIST_N: usize = 4096;
pub const TRANSFER_N: usize = 256;

/// Load that guarantees a padded cache slot never wins a ranking.
const PAD_LOAD: f32 = 1e6;

// --- GeoScorer ---------------------------------------------------------------

/// Batched nearest-cache scorer backed by `geo_score.hlo.txt`
/// (haversine Pallas kernel + load penalty).
pub struct GeoScorer {
    artifact: Artifact,
    /// Executions performed (perf accounting).
    pub invocations: u64,
}

impl GeoScorer {
    pub fn load(rt: &Runtime) -> Result<Self> {
        Ok(GeoScorer {
            artifact: rt.load("geo_score")?,
            invocations: 0,
        })
    }

    /// Score up to 64 clients against up to 16 caches in one
    /// invocation; larger client batches loop. Returns
    /// `scores[client][cache]` (lower = better).
    pub fn score(
        &mut self,
        clients: &[(f64, f64)],
        caches: &[(f64, f64)],
        loads: &[f64],
    ) -> Result<Vec<Vec<f64>>> {
        ensure!(caches.len() == loads.len(), "caches/loads length mismatch");
        ensure!(
            caches.len() <= GEO_CACHES,
            "at most {GEO_CACHES} caches per artifact invocation (got {})",
            caches.len()
        );
        // Pad the cache table: coordinates (0,0), load PAD_LOAD.
        let mut cache_buf = vec![0f32; GEO_CACHES * 2];
        let mut load_buf = vec![PAD_LOAD; GEO_CACHES];
        for (i, &(lat, lon)) in caches.iter().enumerate() {
            cache_buf[i * 2] = lat as f32;
            cache_buf[i * 2 + 1] = lon as f32;
            load_buf[i] = loads[i] as f32;
        }
        let caches_lit = xla::Literal::vec1(&cache_buf).reshape(&[GEO_CACHES as i64, 2])?;
        let loads_lit = xla::Literal::vec1(&load_buf);

        let mut out = Vec::with_capacity(clients.len());
        for chunk in clients.chunks(GEO_CLIENTS) {
            let mut client_buf = vec![0f32; GEO_CLIENTS * 2];
            for (i, &(lat, lon)) in chunk.iter().enumerate() {
                client_buf[i * 2] = lat as f32;
                client_buf[i * 2 + 1] = lon as f32;
            }
            let clients_lit =
                xla::Literal::vec1(&client_buf).reshape(&[GEO_CLIENTS as i64, 2])?;
            let result = self
                .artifact
                .execute(&[clients_lit, caches_lit.clone(), loads_lit.clone()])
                .context("geo_score execution")?;
            self.invocations += 1;
            let scores = result.to_vec::<f32>()?;
            for row in 0..chunk.len() {
                out.push(
                    scores[row * GEO_CACHES..row * GEO_CACHES + caches.len()]
                        .iter()
                        .map(|&s| s as f64)
                        .collect(),
                );
            }
        }
        Ok(out)
    }
}

impl GeoScoreBackend for GeoScorer {
    fn score(
        &mut self,
        clients: &[(f64, f64)],
        caches: &[CacheSite],
        loads: &[f64],
    ) -> Vec<Vec<f64>> {
        let coords: Vec<(f64, f64)> = caches.iter().map(|c| (c.lat, c.lon)).collect();
        GeoScorer::score(self, clients, &coords, loads).expect("geo_score artifact execution")
    }
}

// --- HistAgg -----------------------------------------------------------------

/// Batched file-size histogram backed by `usage_hist.hlo.txt`
/// (one-hot reduction Pallas kernel).
pub struct HistAgg {
    artifact: Artifact,
    pub invocations: u64,
}

impl HistAgg {
    pub fn load(rt: &Runtime) -> Result<Self> {
        Ok(HistAgg {
            artifact: rt.load("usage_hist")?,
            invocations: 0,
        })
    }

    /// Bin a batch of sizes (any length; zero-padded per invocation —
    /// zeros land in no bin by the kernel's validity mask).
    pub fn histogram(&mut self, sizes: &[f64]) -> Result<Vec<f32>> {
        let mut bins = vec![0f32; HIST_BINS];
        for chunk in sizes.chunks(HIST_N) {
            let mut buf = vec![0f32; HIST_N];
            for (i, &s) in chunk.iter().enumerate() {
                buf[i] = s as f32;
            }
            let lit = xla::Literal::vec1(&buf);
            let out = self.artifact.execute(&[lit]).context("usage_hist execution")?;
            self.invocations += 1;
            for (b, v) in bins.iter_mut().zip(out.to_vec::<f32>()?) {
                *b += v;
            }
        }
        Ok(bins)
    }
}

impl HistBackend for HistAgg {
    fn histogram(&mut self, sizes: &[f64]) -> Vec<f32> {
        HistAgg::histogram(self, sizes).expect("usage_hist artifact execution")
    }
}

// --- TransferEst -------------------------------------------------------------

/// One transfer to price.
#[derive(Debug, Clone, Copy)]
pub struct TransferParams {
    pub bytes: f64,
    pub rtt_ms: f64,
    /// Bottleneck bandwidth, bytes/sec.
    pub bottleneck_bps: f64,
    pub streams: f64,
}

/// Batched transfer-time estimator backed by `transfer_est.hlo.txt`.
pub struct TransferEst {
    artifact: Artifact,
    pub invocations: u64,
}

impl TransferEst {
    pub fn load(rt: &Runtime) -> Result<Self> {
        Ok(TransferEst {
            artifact: rt.load("transfer_est")?,
            invocations: 0,
        })
    }

    /// Estimate durations (seconds) for a batch of transfers.
    pub fn estimate(&mut self, batch: &[TransferParams]) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(batch.len());
        for chunk in batch.chunks(TRANSFER_N) {
            let mut buf = vec![0f32; TRANSFER_N * 4];
            for (i, p) in chunk.iter().enumerate() {
                buf[i * 4] = p.bytes as f32;
                buf[i * 4 + 1] = p.rtt_ms as f32;
                buf[i * 4 + 2] = p.bottleneck_bps as f32;
                buf[i * 4 + 3] = p.streams as f32;
            }
            let lit = xla::Literal::vec1(&buf).reshape(&[TRANSFER_N as i64, 4])?;
            let result = self
                .artifact
                .execute(&[lit])
                .context("transfer_est execution")?;
            self.invocations += 1;
            let secs = result.to_vec::<f32>()?;
            out.extend(secs[..chunk.len()].iter().map(|&s| s as f64));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geoip::{haversine_km, RustGeoBackend, LOAD_PENALTY_KM};
    use crate::monitoring::aggregator::RustHistBackend;

    /// `None` on offline/stub builds: the caller skips the test.
    fn runtime() -> Option<Runtime> {
        Runtime::try_available()
    }

    #[test]
    fn geo_scorer_matches_rust_reference() {
        let Some(rt) = runtime() else {
            return;
        };
        let mut scorer = GeoScorer::load(&rt).unwrap();
        let clients = vec![(43.0392, -76.1351), (40.0076, -105.2659), (-33.9, 151.2)];
        let caches = vec![
            (40.8202, -96.7005),
            (41.7886, -87.5987),
            (52.3676, 4.9041),
        ];
        let loads = vec![0.1, 0.7, 0.0];
        let got = GeoScorer::score(&mut scorer, &clients, &caches, &loads).unwrap();
        for (ci, &(clat, clon)) in clients.iter().enumerate() {
            for (ki, &(klat, klon)) in caches.iter().enumerate() {
                let want = haversine_km(clat, clon, klat, klon) + loads[ki] * LOAD_PENALTY_KM;
                let rel = (got[ci][ki] - want).abs() / want.max(1.0);
                assert!(
                    rel < 1e-3,
                    "client {ci} cache {ki}: got {} want {want}",
                    got[ci][ki]
                );
            }
        }
        assert_eq!(scorer.invocations, 1);
    }

    #[test]
    fn geo_scorer_as_backend_in_nearest_cache() {
        use crate::config::defaults::paper_federation;
        use crate::geoip::NearestCache;
        let cfg = paper_federation();
        let Some(rt) = runtime() else {
            return;
        };
        let scorer = GeoScorer::load(&rt).unwrap();
        let caches: Vec<crate::geoip::CacheSite> = cfg
            .cache_sites()
            .map(|s| crate::geoip::CacheSite {
                name: s.name.clone(),
                lat: s.lat,
                lon: s.lon,
            })
            .collect();
        let mut pjrt_svc = NearestCache::with_backend(caches.clone(), scorer);
        let mut rust_svc = NearestCache::with_backend(caches, RustGeoBackend);
        for site in cfg.compute_sites() {
            let a = pjrt_svc.nearest(site.lat, site.lon);
            let b = rust_svc.nearest(site.lat, site.lon);
            assert_eq!(a.0, b.0, "PJRT and rust backends disagree at {}", site.name);
        }
    }

    #[test]
    fn geo_scorer_batch_larger_than_shape_loops() {
        let Some(rt) = runtime() else {
            return;
        };
        let mut scorer = GeoScorer::load(&rt).unwrap();
        let clients: Vec<(f64, f64)> = (0..130).map(|i| (i as f64 / 4.0, -100.0)).collect();
        let caches = vec![(40.0, -96.0)];
        let loads = vec![0.0];
        let got = GeoScorer::score(&mut scorer, &clients, &caches, &loads).unwrap();
        assert_eq!(got.len(), 130);
        assert_eq!(scorer.invocations, 3); // ceil(130/64)
        let want = haversine_km(10.0, -100.0, 40.0, -96.0);
        assert!((got[40][0] - want).abs() / want < 1e-3);
    }

    #[test]
    fn hist_agg_matches_rust_reference() {
        let Some(rt) = runtime() else {
            return;
        };
        let mut agg = HistAgg::load(&rt).unwrap();
        let mut rng = crate::util::Pcg64::new(5, 5);
        let sizes: Vec<f64> = (0..10_000)
            .map(|_| 10f64.powf(rng.gen_f64(0.0, 13.0)))
            .collect();
        let got = HistAgg::histogram(&mut agg, &sizes).unwrap();
        let want = RustHistBackend.histogram(&sizes);
        assert_eq!(got.len(), HIST_BINS);
        assert_eq!(got, want, "PJRT histogram != rust histogram");
        assert_eq!(agg.invocations, 3); // ceil(10000/4096)
    }

    #[test]
    fn transfer_est_matches_formula() {
        let Some(rt) = runtime() else {
            return;
        };
        let mut est = TransferEst::load(&rt).unwrap();
        let batch = vec![
            TransferParams { bytes: 2.335e9, rtt_ms: 20.0, bottleneck_bps: 1.25e8, streams: 8.0 },
            TransferParams { bytes: 5797.0, rtt_ms: 5.0, bottleneck_bps: 1.25e9, streams: 1.0 },
        ];
        let got = est.estimate(&batch).unwrap();
        for (g, p) in got.iter().zip(&batch) {
            // Mirror of kernels/ref.py transfer_est.
            let eff = p.streams / (p.streams + 2.0);
            let want = 3.0 * p.rtt_ms / 1e3 + p.bytes / (p.bottleneck_bps * eff).max(1.0);
            assert!((g - want).abs() / want < 1e-4, "got {g} want {want}");
        }
    }
}
