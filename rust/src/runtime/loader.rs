//! Artifact loading and compilation (one `PjRtLoadedExecutable` per
//! model, compiled once and reused on the hot path).

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$STASHCACHE_ARTIFACTS`, else
/// `./artifacts`, else `../artifacts` (tests run from the crate root;
/// binaries may run from `target/release`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("STASHCACHE_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    for candidate in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(candidate);
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// One compiled artifact.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with literal inputs; unwraps the 1-tuple the AOT step
    /// wraps results in (`return_tuple=True`).
    pub fn execute(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing artifact {:?}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(tuple.to_tuple1()?)
    }
}

/// The PJRT runtime: a CPU client plus the compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at the default artifacts dir.
    pub fn new() -> Result<Self> {
        Self::with_dir(artifacts_dir())
    }

    /// `Some(runtime)` when a PJRT client can be created *and* the AOT
    /// artifacts are on disk; `None` (with a note on stderr) otherwise.
    /// Offline builds link the `vendor/xla` stub, whose client creation
    /// always fails — PJRT-gated tests and benches use this to skip
    /// instead of failing.
    pub fn try_available() -> Option<Self> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!(
                "PJRT artifacts not found in {} (run `make artifacts`); skipping",
                dir.display()
            );
            return None;
        }
        match Self::new() {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("PJRT runtime unavailable: {e:#}; skipping");
                None
            }
        }
    }

    pub fn with_dir(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile `<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        Ok(Artifact {
            name: name.to_string(),
            exe,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Compiling artifacts requires `make artifacts` *and* real PJRT
    // bindings; both are absent in offline builds, so every test here
    // gates on `Runtime::try_available` and skips gracefully.

    #[test]
    fn artifacts_manifest_lists_models() {
        let Some(rt) = Runtime::try_available() else {
            return;
        };
        let manifest = std::fs::read_to_string(rt.dir().join("manifest.json"))
            .expect("manifest readable");
        for model in ["geo_score", "usage_hist", "transfer_est"] {
            assert!(
                manifest.contains(model),
                "manifest must list {model}: {manifest}"
            );
        }
    }

    #[test]
    fn loads_and_executes_geo_score() {
        let Some(rt) = Runtime::try_available() else {
            return;
        };
        let art = rt.load("geo_score").unwrap();
        let clients = xla::Literal::vec1(&vec![0f32; 64 * 2])
            .reshape(&[64, 2])
            .unwrap();
        let caches = xla::Literal::vec1(&vec![0f32; 16 * 2])
            .reshape(&[16, 2])
            .unwrap();
        let loads = xla::Literal::vec1(&vec![0f32; 16]);
        let out = art.execute(&[clients, caches, loads]).unwrap();
        let values = out.to_vec::<f32>().unwrap();
        assert_eq!(values.len(), 64 * 16);
        // All-zero coords, zero loads → zero scores.
        assert!(values.iter().all(|v| v.abs() < 1e-3));
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let Some(rt) = Runtime::try_available() else {
            return;
        };
        let err = match rt.load("no_such_model") {
            Err(e) => e,
            Ok(_) => panic!("expected missing-artifact error"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}
