//! PJRT runtime: executes the AOT-compiled JAX/Pallas artifacts.
//!
//! The rust coordinator never calls Python. At build time
//! `python/compile/aot.py` lowers the L2 model (which embeds the L1
//! Pallas kernels) to **HLO text** under `artifacts/`; this module
//! loads each artifact once, compiles it on the PJRT CPU client, and
//! exposes typed executors with the padding conventions of
//! `python/compile/model.py`:
//!
//! * [`GeoScorer`]    ← `geo_score.hlo.txt`    (64 clients × 16 caches)
//! * [`HistAgg`]      ← `usage_hist.hlo.txt`   (4096 sizes → 64 bins)
//! * [`TransferEst`]  ← `transfer_est.hlo.txt` (256 rows)
//!
//! Each executor also implements the corresponding backend trait
//! ([`crate::geoip::GeoScoreBackend`], [`crate::monitoring::aggregator::HistBackend`])
//! so the services can run PJRT-backed or pure-rust interchangeably —
//! integration tests assert both give the same answers.

pub mod executors;
pub mod loader;

pub use executors::{GeoScorer, HistAgg, TransferEst, TransferParams};
pub use loader::{artifacts_dir, Artifact, Runtime};
