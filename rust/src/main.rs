//! `stashcache` — CLI for the StashCache federation reproduction.
//!
//! ```text
//! stashcache topology                      # Fig 1/2: sites, caches, links
//! stashcache scenario [--sites a,b] [--repeats N] [--runtime pjrt|rust]
//! stashcache sweep [--preset proxy-vs-stash] [--threads N]  # parallel grid
//! stashcache check [--scenario NAME]        # model-check the session protocol
//! stashcache usage --days D [--jobs-per-hour J]
//! stashcache report --all --out-dir reports
//! stashcache init-config [path]            # write an example TOML
//! stashcache live-demo                     # real TCP/UDP federation on loopback
//! ```
//!
//! (The offline crate set has no clap — argument parsing is a small
//! hand-rolled module, DESIGN.md §2.)

mod cli;

fn main() {
    if let Err(e) = cli::run(std::env::args().skip(1).collect()) {
        // Usage first, error last, so the actual cause is the final
        // (most visible) line on stderr.
        eprintln!("{}", cli::usage());
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
