//! Federation telemetry: metrics registry, per-session phase tracing,
//! and O(1)-memory streaming rollups.
//!
//! The §3.2 monitoring pipeline observes *transfers*; this layer
//! observes the *machinery* — engine phases, caches, links, policies,
//! faults — the way the OSDF operations papers say a federation must
//! be run. The flow is:
//!
//! ```text
//!   session engine ──spans──▶ per-phase QuantileSketch  ┐
//!   completions  ──────────▶ windowed Rollup (bounded)  ├─▶ TelemetrySnapshot
//!   caches/links/faults ───▶ end-of-run gauges          ┘        │
//!                                                    ┌───────────┼───────────┐
//!                                              metrics.json   .prom       trace JSONL
//! ```
//!
//! **Off the bit-identity surface.** Everything recorded here is
//! either integer state (bucket counts, byte totals) or derived from
//! the record stream itself, folded in a deterministic order: serial
//! runs fold spans at transition time, and the terminal epoch
//! reconstructs the identical spans per completed session in the same
//! sorted completion order the record stream uses. Sketch merges are
//! commutative on integer state, so `run_threaded` at 1/2/8 threads
//! emits byte-identical telemetry — and nothing in this module touches
//! the RNG, the event queue, or the network, so record digests are
//! unchanged whether telemetry is on or off.

use crate::monitoring::json::{self, Json, ObjBuilder};
use crate::util::stats::QuantileSketch;
use crate::util::{Duration, SimTime};
use std::collections::{BTreeMap, VecDeque};

/// The session-engine phases a span can be attributed to.
///
/// `Failover` is synthetic: when a session is re-routed after a fault,
/// the retry wait it spends back in GeoResolve/ProxyLookup/
/// DirectConnect is attributed here instead, so recovery cost is
/// visible separately from first-try latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseLabel {
    GeoResolve,
    CacheCheck,
    JoinWait,
    FetchBegin,
    Transfer,
    Failover,
    DirectConnect,
    DirectFetch,
    ProxyLookup,
    ProxyConnect,
}

impl PhaseLabel {
    pub const ALL: [PhaseLabel; 10] = [
        PhaseLabel::GeoResolve,
        PhaseLabel::CacheCheck,
        PhaseLabel::JoinWait,
        PhaseLabel::FetchBegin,
        PhaseLabel::Transfer,
        PhaseLabel::Failover,
        PhaseLabel::DirectConnect,
        PhaseLabel::DirectFetch,
        PhaseLabel::ProxyLookup,
        PhaseLabel::ProxyConnect,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PhaseLabel::GeoResolve => "geo_resolve",
            PhaseLabel::CacheCheck => "cache_check",
            PhaseLabel::JoinWait => "join_wait",
            PhaseLabel::FetchBegin => "fetch_begin",
            PhaseLabel::Transfer => "transfer",
            PhaseLabel::Failover => "failover",
            PhaseLabel::DirectConnect => "direct_connect",
            PhaseLabel::DirectFetch => "direct_fetch",
            PhaseLabel::ProxyLookup => "proxy_lookup",
            PhaseLabel::ProxyConnect => "proxy_connect",
        }
    }
}

/// One attributed interval of a session's lifetime.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSpan {
    pub label: PhaseLabel,
    pub start: SimTime,
    pub dur: Duration,
}

/// A completed session's full span trace (raw site indices; resolved
/// to names when the snapshot is taken).
#[derive(Debug, Clone, PartialEq)]
pub struct SpanTrace {
    pub session: u64,
    pub site: usize,
    pub path: String,
    pub arrival: SimTime,
    pub completed: SimTime,
    pub bytes: u64,
    pub cache_site: Option<usize>,
    pub hit: bool,
    pub spans: Vec<PhaseSpan>,
}

/// Per-window completion counters of one cache's rollup series.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WindowCounts {
    pub completions: u64,
    pub hits: u64,
    pub bytes: u64,
}

impl WindowCounts {
    fn absorb(&mut self, other: WindowCounts) {
        self.completions += other.completions;
        self.hits += other.hits;
        self.bytes += other.bytes;
    }
}

/// Default rollup window: one sim-minute per bucket.
const ROLLUP_WINDOW_US: u64 = 60_000_000;
/// Windows per series before the whole rollup coarsens (doubling the
/// window, pair-merging counts) — bounds memory for year-long runs.
const ROLLUP_MAX_WINDOWS: usize = 256;
/// Key used for completions that never touched a cache (proxy relay,
/// direct-to-origin).
const ROLLUP_NO_CACHE: i64 = -1;

/// Windowed per-cache completion rollups with bounded memory.
///
/// Driven purely by the completion stream (never by wall-clock
/// polling), so serial and sharded runs — which retire the same
/// completions in the same order — produce identical series. All
/// counters are `u64`; coarsening pair-merges buckets and conserves
/// every count exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct Rollup {
    window_us: u64,
    by_cache: BTreeMap<i64, Vec<WindowCounts>>,
}

impl Default for Rollup {
    fn default() -> Self {
        Self::new()
    }
}

impl Rollup {
    pub fn new() -> Self {
        Rollup {
            window_us: ROLLUP_WINDOW_US,
            by_cache: BTreeMap::new(),
        }
    }

    pub fn window_secs(&self) -> f64 {
        self.window_us as f64 / 1_000_000.0
    }

    pub fn observe(&mut self, at: SimTime, cache_site: Option<i64>, bytes: u64, hit: bool) {
        while (at.as_micros() / self.window_us) as usize >= ROLLUP_MAX_WINDOWS {
            self.coarsen();
        }
        let idx = (at.as_micros() / self.window_us) as usize;
        let series = self
            .by_cache
            .entry(cache_site.unwrap_or(ROLLUP_NO_CACHE))
            .or_default();
        if idx >= series.len() {
            series.resize(idx + 1, WindowCounts::default());
        }
        let w = &mut series[idx];
        w.completions += 1;
        w.hits += hit as u64;
        w.bytes += bytes;
    }

    fn coarsen(&mut self) {
        self.window_us *= 2;
        for series in self.by_cache.values_mut() {
            let mut merged = Vec::with_capacity(series.len().div_ceil(2));
            for pair in series.chunks(2) {
                let mut w = pair[0];
                if let Some(&second) = pair.get(1) {
                    w.absorb(second);
                }
                merged.push(w);
            }
            *series = merged;
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (i64, &[WindowCounts])> {
        self.by_cache.iter().map(|(&k, v)| (k, v.as_slice()))
    }

    /// Total completions across every series (conservation witness).
    pub fn total_completions(&self) -> u64 {
        self.by_cache
            .values()
            .flatten()
            .map(|w| w.completions)
            .sum()
    }
}

/// The always-on telemetry state carried by the session engine.
#[derive(Debug, Clone)]
pub struct Telemetry {
    enabled: bool,
    trace_cap: usize,
    phases: Vec<QuantileSketch>,
    traces: VecDeque<SpanTrace>,
    rollup: Rollup,
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

impl Telemetry {
    pub fn new() -> Self {
        Telemetry {
            enabled: true,
            trace_cap: 0,
            phases: vec![QuantileSketch::new(); PhaseLabel::ALL.len()],
            traces: VecDeque::new(),
            rollup: Rollup::new(),
        }
    }

    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }
    pub fn enabled(&self) -> bool {
        self.enabled
    }
    /// `--trace N`: keep the last N completed sessions' full span
    /// traces in a bounded ring (0 = span tracing off).
    pub fn set_trace_cap(&mut self, cap: usize) {
        self.trace_cap = cap;
    }
    pub fn trace_enabled(&self) -> bool {
        self.enabled && self.trace_cap > 0
    }

    /// Fold one attributed span into its phase histogram.
    pub fn phase_span(&mut self, label: PhaseLabel, dur: Duration) {
        if self.enabled {
            self.phases[label as usize].push(dur.as_secs_f64());
        }
    }

    pub fn phase_sketch(&self, label: PhaseLabel) -> &QuantileSketch {
        &self.phases[label as usize]
    }

    /// Completion-driven rollup tick (called once per finished
    /// session, identically on the serial and epoch-merge paths).
    pub fn on_complete(&mut self, at: SimTime, cache_site: Option<usize>, bytes: u64, hit: bool) {
        if self.enabled {
            self.rollup
                .observe(at, cache_site.map(|s| s as i64), bytes, hit);
        }
    }

    pub fn rollup(&self) -> &Rollup {
        &self.rollup
    }

    /// Push a completed session's trace into the ring, evicting the
    /// oldest past `trace_cap`.
    pub fn push_trace(&mut self, trace: SpanTrace) {
        if self.trace_cap == 0 {
            return;
        }
        if self.traces.len() == self.trace_cap {
            self.traces.pop_front();
        }
        self.traces.push_back(trace);
    }

    pub fn traces(&self) -> impl Iterator<Item = &SpanTrace> {
        self.traces.iter()
    }
}

/// Named counters, gauges, and quantile-sketch histograms.
///
/// Keys are full series names including Prometheus-style labels
/// (`stashcache_cache_requests_total{cache="nebraska"}`), stored in
/// `BTreeMap`s so both export formats are byte-deterministic.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, QuantileSketch>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add to (or create) a counter.
    pub fn counter(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    /// Set a gauge to its current value.
    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Merge a sketch into (or install it as) a histogram series.
    pub fn histogram(&mut self, name: &str, sk: &QuantileSketch) {
        self.hists
            .entry(name.to_string())
            .or_insert_with(QuantileSketch::new)
            .merge(sk);
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }
    pub fn histogram_sketch(&self, name: &str) -> Option<&QuantileSketch> {
        self.hists.get(name)
    }

    /// Fold another registry in: counters add, histograms merge,
    /// gauges take the other side's value (point-in-time state has no
    /// meaningful sum — the last merged trial wins).
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, &v) in &other.counters {
            self.counter(k, v);
        }
        for (k, sk) in &other.hists {
            self.histogram(k, sk);
        }
        for (k, &v) in &other.gauges {
            self.gauges.insert(k.clone(), v);
        }
    }

    /// Prometheus-style text exposition. Histograms render as
    /// `summary` families with p50/p95/p99 quantile series.
    pub fn exposition(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut typed: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        let mut type_line = |out: &mut String, name: &str, kind: &str| {
            let family = name.split(['{', ' ']).next().unwrap_or(name);
            if typed.insert(family.to_string()) {
                let _ = writeln!(out, "# TYPE {family} {kind}");
            }
        };
        for (name, v) in &self.counters {
            type_line(&mut out, name, "counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            type_line(&mut out, name, "gauge");
            let _ = writeln!(out, "{name} {}", fmt_f64(*v));
        }
        for (name, sk) in &self.hists {
            type_line(&mut out, name, "summary");
            let (base, labels) = split_labels(name);
            for (q, qs) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                let _ = writeln!(
                    out,
                    "{base}{{{labels}quantile=\"{qs}\"}} {}",
                    fmt_f64(sk.quantile(q))
                );
            }
            let _ = writeln!(out, "{base}_count{{{labels}}} {}", sk.count());
        }
        out
    }

    fn json_obj(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, &v) in &self.counters {
            counters.insert(k.clone(), Json::Num(v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, &v) in &self.gauges {
            gauges.insert(k.clone(), Json::Num(v));
        }
        let mut hists = BTreeMap::new();
        for (k, sk) in &self.hists {
            hists.insert(k.clone(), sketch_json(sk));
        }
        let mut top = BTreeMap::new();
        top.insert("counters".to_string(), Json::Obj(counters));
        top.insert("gauges".to_string(), Json::Obj(gauges));
        top.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(top)
    }
}

/// `name{a="b"}` → `("name", "a=\"b\",")`; unlabeled → `("name", "")`.
/// The returned label fragment carries its own trailing comma so a
/// quantile label can always be appended.
fn split_labels(name: &str) -> (&str, String) {
    match name.split_once('{') {
        Some((base, rest)) => {
            let inner = rest.trim_end_matches('}');
            (base, format!("{inner},"))
        }
        None => (name, String::new()),
    }
}

/// Print an f64 the way `monitoring::json` does: integer-valued
/// floats without a decimal point, so text output is deterministic
/// and diff-friendly.
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn sketch_json(sk: &QuantileSketch) -> Json {
    ObjBuilder::new()
        .int("count", sk.count())
        .num("min", sk.min())
        .num("max", sk.max())
        .num("p50", sk.quantile(0.5))
        .num("p95", sk.quantile(0.95))
        .num("p99", sk.quantile(0.99))
        .num("approx_sum", sk.approx_sum())
        .build()
}

/// A completed session's trace with site indices resolved to names —
/// the JSONL row format `--trace` dumps.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRow {
    pub session: u64,
    pub site: String,
    pub path: String,
    pub arrival: SimTime,
    pub completed: SimTime,
    pub bytes: u64,
    pub cache: Option<String>,
    pub hit: bool,
    pub spans: Vec<PhaseSpan>,
}

/// The end-of-run export bundle a campaign returns: registry, phase
/// histograms, rollup series, resolved traces.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TelemetrySnapshot {
    pub registry: MetricsRegistry,
    /// `(phase name, sketch)` in [`PhaseLabel::ALL`] order.
    pub phases: Vec<(&'static str, QuantileSketch)>,
    pub rollup_window_secs: f64,
    /// `(cache label, windows)` — the label is a site name or
    /// `"(none)"` for proxy/direct completions.
    pub rollups: Vec<(String, Vec<WindowCounts>)>,
    pub traces: Vec<TraceRow>,
}

impl TelemetrySnapshot {
    pub fn phase_sketch(&self, name: &str) -> Option<&QuantileSketch> {
        self.phases.iter().find(|(n, _)| *n == name).map(|(_, s)| s)
    }

    /// Prometheus-style text exposition of the whole snapshot (the
    /// phase histograms are registered as `stashcache_phase_seconds`
    /// summaries, so the registry covers everything).
    pub fn exposition(&self) -> String {
        self.registry.exposition()
    }

    /// `metrics.json`: registry plus the windowed rollup series.
    pub fn to_json_string(&self) -> String {
        let Json::Obj(mut top) = self.registry.json_obj() else {
            unreachable!("registry json is an object");
        };
        let mut per_cache = BTreeMap::new();
        for (label, windows) in &self.rollups {
            let arr = windows
                .iter()
                .enumerate()
                .filter(|(_, w)| w.completions > 0)
                .map(|(i, w)| {
                    ObjBuilder::new()
                        .num("t_secs", i as f64 * self.rollup_window_secs)
                        .int("completions", w.completions)
                        .int("hits", w.hits)
                        .int("bytes", w.bytes)
                        .build()
                })
                .collect();
            per_cache.insert(label.clone(), Json::Arr(arr));
        }
        let mut rollups = BTreeMap::new();
        rollups.insert(
            "window_secs".to_string(),
            Json::Num(self.rollup_window_secs),
        );
        rollups.insert("per_cache".to_string(), Json::Obj(per_cache));
        top.insert("rollups".to_string(), Json::Obj(rollups));
        json::to_string(&Json::Obj(top))
    }

    /// One JSON object per line per traced session — the `--trace N`
    /// dump format.
    pub fn trace_jsonl(&self) -> String {
        let mut out = String::new();
        for t in &self.traces {
            let spans = t
                .spans
                .iter()
                .map(|s| {
                    ObjBuilder::new()
                        .str("phase", s.label.name())
                        .int("start_us", s.start.as_micros())
                        .int("dur_us", s.dur.as_micros())
                        .build()
                })
                .collect();
            let mut row = ObjBuilder::new()
                .int("session", t.session)
                .str("site", t.site.as_str())
                .str("path", t.path.as_str())
                .int("arrival_us", t.arrival.as_micros())
                .int("completed_us", t.completed.as_micros())
                .int("bytes", t.bytes)
                .bool("hit", t.hit);
            if let Some(cache) = &t.cache {
                row = row.str("cache", cache.as_str());
            }
            let Json::Obj(mut obj) = row.build() else {
                unreachable!("trace row is an object");
            };
            obj.insert("spans".to_string(), Json::Arr(spans));
            out.push_str(&json::to_string(&Json::Obj(obj)));
            out.push('\n');
        }
        out
    }

    /// Fold another snapshot in (sweep aggregation across trials):
    /// counters add, histograms and phase sketches merge, traces
    /// concatenate; rollup series are per-run time series and are
    /// kept from the first non-empty snapshot only.
    pub fn merge(&mut self, other: &TelemetrySnapshot) {
        self.registry.merge(&other.registry);
        if self.phases.is_empty() {
            self.phases = other.phases.clone();
        } else {
            for ((_, mine), (_, theirs)) in self.phases.iter_mut().zip(other.phases.iter()) {
                mine.merge(theirs);
            }
        }
        if self.rollups.is_empty() {
            self.rollup_window_secs = other.rollup_window_secs;
            self.rollups = other.rollups.clone();
        }
        self.traces.extend(other.traces.iter().cloned());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_exposition_is_deterministic_and_typed() {
        let mut reg = MetricsRegistry::new();
        reg.counter("stashcache_engine_events_total", 42);
        reg.counter("stashcache_cache_requests_total{cache=\"b\"}", 7);
        reg.counter("stashcache_cache_requests_total{cache=\"a\"}", 3);
        reg.gauge("stashcache_cache_hit_ratio{cache=\"a\"}", 0.75);
        let mut sk = QuantileSketch::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            sk.push(x);
        }
        reg.histogram("stashcache_phase_seconds{phase=\"transfer\"}", &sk);

        let text = reg.exposition();
        // Labeled series sort deterministically and share one TYPE line.
        let a = text
            .find("stashcache_cache_requests_total{cache=\"a\"} 3")
            .unwrap();
        let b = text
            .find("stashcache_cache_requests_total{cache=\"b\"} 7")
            .unwrap();
        assert!(a < b, "label order is sorted:\n{text}");
        assert_eq!(
            text.matches("# TYPE stashcache_cache_requests_total counter")
                .count(),
            1
        );
        assert!(text.contains("# TYPE stashcache_phase_seconds summary"));
        assert!(
            text.contains("stashcache_phase_seconds{phase=\"transfer\",quantile=\"0.5\"}"),
            "quantile label appended after existing labels:\n{text}"
        );
        assert!(text.contains("stashcache_phase_seconds_count{phase=\"transfer\"} 4"));
        assert!(text.contains("stashcache_cache_hit_ratio{cache=\"a\"} 0.75"));
        // Identical registry ⇒ identical bytes.
        assert_eq!(text, reg.clone().exposition());
    }

    #[test]
    fn registry_merge_semantics() {
        let mut a = MetricsRegistry::new();
        a.counter("c", 2);
        a.gauge("g", 1.0);
        let mut b = MetricsRegistry::new();
        b.counter("c", 3);
        b.counter("only_b", 1);
        b.gauge("g", 9.0);
        a.merge(&b);
        assert_eq!(a.counter_value("c"), 5);
        assert_eq!(a.counter_value("only_b"), 1);
        assert_eq!(a.gauge_value("g"), Some(9.0), "gauges: last merged wins");
    }

    #[test]
    fn rollup_coarsens_and_conserves() {
        let mut r = Rollup::new();
        // Far beyond 256 windows of 60 s: forces repeated coarsening.
        for i in 0..1_000u64 {
            let t = SimTime::from_secs_f64(i as f64 * 3_600.0);
            r.observe(t, Some((i % 3) as i64), 1_000 + i, i % 2 == 0);
        }
        assert_eq!(r.total_completions(), 1_000);
        for (_, series) in r.iter() {
            assert!(series.len() <= ROLLUP_MAX_WINDOWS);
        }
        assert!(r.window_secs() > 60.0, "window must have doubled");
        let bytes: u64 = r.iter().flat_map(|(_, s)| s).map(|w| w.bytes).sum();
        let expect: u64 = (0..1_000u64).map(|i| 1_000 + i).sum();
        assert_eq!(bytes, expect, "coarsening conserves bytes");
    }

    #[test]
    fn trace_ring_is_bounded_and_keeps_latest() {
        let mut tele = Telemetry::new();
        tele.set_trace_cap(3);
        for i in 0..10u64 {
            tele.push_trace(SpanTrace {
                session: i,
                site: 0,
                path: format!("/f/{i}"),
                arrival: SimTime::ZERO,
                completed: SimTime::from_secs_f64(i as f64),
                bytes: 1,
                cache_site: None,
                hit: false,
                spans: Vec::new(),
            });
        }
        let kept: Vec<u64> = tele.traces().map(|t| t.session).collect();
        assert_eq!(kept, vec![7, 8, 9], "ring keeps the last N sessions");
    }

    #[test]
    fn disabled_telemetry_records_nothing() {
        let mut tele = Telemetry::new();
        tele.set_enabled(false);
        tele.set_trace_cap(4);
        tele.phase_span(PhaseLabel::Transfer, Duration::from_secs(1));
        tele.on_complete(SimTime::from_secs_f64(1.0), Some(3), 100, true);
        assert!(tele.phase_sketch(PhaseLabel::Transfer).is_empty());
        assert_eq!(tele.rollup().total_completions(), 0);
        assert!(!tele.trace_enabled());
    }

    #[test]
    fn snapshot_jsonl_one_object_per_line() {
        let snap = TelemetrySnapshot {
            traces: vec![TraceRow {
                session: 5,
                site: "syracuse".into(),
                path: "/gwosc/x.dat".into(),
                arrival: SimTime::ZERO,
                completed: SimTime::from_secs_f64(2.0),
                bytes: 1024,
                cache: Some("syracuse".into()),
                hit: true,
                spans: vec![PhaseSpan {
                    label: PhaseLabel::Transfer,
                    start: SimTime::ZERO,
                    dur: Duration::from_secs(2),
                }],
            }],
            ..TelemetrySnapshot::default()
        };
        let jsonl = snap.trace_jsonl();
        assert_eq!(jsonl.lines().count(), 1);
        let parsed = json::parse(jsonl.lines().next().unwrap()).expect("valid JSON row");
        assert_eq!(parsed.get("session").and_then(Json::as_u64), Some(5));
        assert_eq!(
            parsed.get("cache").and_then(Json::as_str),
            Some("syracuse")
        );
        let Some(Json::Arr(spans)) = parsed.get("spans") else {
            panic!("spans array present");
        };
        assert_eq!(spans.len(), 1);
        assert_eq!(
            spans[0].get("phase").and_then(Json::as_str),
            Some("transfer")
        );
    }
}
