//! StashCache cache server (the XRootD caching proxy, from scratch).
//!
//! Paper §3: "Caches also use XRootD to capture data requests from
//! clients, download data from the origins, and to manage the cache
//! space. The caches receive data requests from the client, check the
//! local cache, and if necessary locate and download the requested
//! data from the origins."
//!
//! The store is **chunk-granular** ([`chunks::ChunkSet`]): CVMFS reads
//! 24 MB chunks and may fetch only portions of a file (§3.1), so a file
//! can be partially resident. Space is managed with high/low watermark
//! LRU eviction — "the resource provider can reclaim space in the cache
//! without worry of causing workflow failures" (§1): in-flight files
//! are pinned and never evicted mid-transfer.
//!
//! Concurrent misses for the same chunk coalesce onto one origin fetch
//! ([`CacheServer::begin_fetch`] returns the chunks that still need a
//! fetch; chunks already being fetched join the in-flight set).

pub mod chunks;

use crate::config::CacheConfig;
use crate::util::{ByteSize, SimTime};
use chunks::ChunkSet;
use std::collections::{BTreeSet, HashMap};

/// Per-file cache residency state.
#[derive(Debug)]
struct CachedFile {
    /// Which chunks are resident.
    resident: ChunkSet,
    /// Which chunks are currently being fetched from the origin.
    in_flight: ChunkSet,
    file_size: u64,
    /// Content version (origin mtime). A version change invalidates
    /// all resident chunks — the consistency behaviour CVMFS checksums
    /// give the production system.
    version: u64,
    last_access: SimTime,
    /// Monotone tiebreaker for equal `last_access`.
    access_seq: u64,
    /// Active transfers pinning this file (not evictable).
    pins: u32,
}

/// Counters the monitoring pipeline scrapes.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub bytes_served_hit: u64,
    pub bytes_served_miss: u64,
    pub bytes_fetched_origin: u64,
    pub requests: u64,
    pub whole_file_hits: u64,
    pub evictions: u64,
    pub bytes_evicted: u64,
    pub invalidations: u64,
}

/// A read request's plan: which bytes are already here, which chunk
/// ranges must come from the origin, and which are already on the way.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReadPlan {
    /// Bytes of the request satisfied from resident chunks.
    pub hit_bytes: u64,
    /// Bytes that miss (need origin traffic, counting whole chunks).
    pub miss_bytes: u64,
    /// Chunk indices this caller must fetch (not resident, not in flight).
    pub fetch: Vec<u64>,
    /// Chunk indices already being fetched by another request —
    /// the caller waits for them instead of re-fetching (coalescing).
    pub join: Vec<u64>,
}

/// One watermark-eviction sweep: when space was reclaimed, and how
/// much (monitoring/chaos reports correlate these with fault events).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvictionSweep {
    pub at: SimTime,
    /// Whole files evicted by this sweep.
    pub files: u32,
    pub bytes: u64,
}

/// The cache server state machine.
#[derive(Debug)]
pub struct CacheServer {
    pub name: String,
    pub cfg: CacheConfig,
    files: HashMap<String, CachedFile>,
    usage: u64,
    seq: u64,
    pub stats: CacheStats,
    /// Every eviction sweep, timestamped (empty until pressure).
    pub eviction_log: Vec<EvictionSweep>,
    /// Paths whose resident bytes are silently corrupted
    /// ([`crate::fault::FaultKind::DataCorrupt`]). The cache itself
    /// cannot tell — clients catch the damage at transfer end via the
    /// content digest, invalidate, and refetch. The marker dies with
    /// the residency (invalidate, eviction) or when fresh origin bytes
    /// are committed over it.
    poisoned: BTreeSet<String>,
}

impl CacheServer {
    pub fn new(name: impl Into<String>, cfg: CacheConfig) -> Self {
        CacheServer {
            name: name.into(),
            cfg,
            files: HashMap::new(),
            usage: 0,
            seq: 0,
            stats: CacheStats::default(),
            eviction_log: Vec::new(),
            poisoned: BTreeSet::new(),
        }
    }

    pub fn usage(&self) -> ByteSize {
        ByteSize(self.usage)
    }

    /// Load factor in [0, 1] (feeds the GeoIP load penalty).
    pub fn load_factor(&self) -> f64 {
        self.usage as f64 / self.cfg.capacity.as_u64() as f64
    }

    pub fn resident_files(&self) -> usize {
        self.files.len()
    }

    /// Current content version of a tracked file, if any (live callers
    /// use this to validate their byte store against version churn).
    pub fn version_of(&self, path: &str) -> Option<u64> {
        self.files.get(path).map(|f| f.version)
    }

    /// Is the whole file resident (and current)?
    pub fn contains_whole(&self, path: &str, version: u64) -> bool {
        self.files.get(path).is_some_and(|f| {
            f.version == version && f.resident.count_set() == f.resident.total_chunks()
        })
    }

    fn chunk_size(&self) -> u64 {
        self.cfg.chunk_size.as_u64().max(1)
    }

    /// Plan a read of `[offset, offset+len)` from `path` whose current
    /// origin metadata is `(file_size, version)`. Stale versions are
    /// invalidated here. Updates LRU recency and request stats.
    pub fn plan_read(
        &mut self,
        path: &str,
        offset: u64,
        len: u64,
        file_size: u64,
        version: u64,
        now: SimTime,
    ) -> ReadPlan {
        assert!(
            offset.checked_add(len).is_some_and(|e| e <= file_size),
            "read past EOF: {path} {offset}+{len} > {file_size}"
        );
        self.stats.requests += 1;
        let chunk = self.chunk_size();

        // Version check — stale content is dropped before planning.
        if let Some(f) = self.files.get(path) {
            if f.version != version {
                self.invalidate(path);
            }
        }

        let seq = self.bump_seq();
        let f = self
            .files
            .entry(path.to_string())
            .or_insert_with(|| CachedFile {
                resident: ChunkSet::new(file_size, chunk),
                in_flight: ChunkSet::new(file_size, chunk),
                file_size,
                version,
                last_access: now,
                access_seq: seq,
                pins: 0,
            });
        f.last_access = now;
        f.access_seq = seq;

        if len == 0 {
            return ReadPlan { hit_bytes: 0, miss_bytes: 0, fetch: vec![], join: vec![] };
        }
        let first = offset / chunk;
        let last = (offset + len - 1) / chunk;
        let mut plan = ReadPlan {
            hit_bytes: 0,
            miss_bytes: 0,
            fetch: Vec::new(),
            join: Vec::new(),
        };
        for c in first..=last {
            // Bytes of the request inside chunk c.
            let c_start = c * chunk;
            let c_end = (c_start + chunk).min(file_size);
            let lo = offset.max(c_start);
            let hi = (offset + len).min(c_end);
            let req_bytes = hi - lo;
            if f.resident.is_set(c) {
                plan.hit_bytes += req_bytes;
            } else {
                plan.miss_bytes += req_bytes;
                if f.in_flight.is_set(c) {
                    plan.join.push(c);
                } else {
                    plan.fetch.push(c);
                }
            }
        }
        if plan.miss_bytes == 0 {
            self.stats.whole_file_hits += 1;
        }
        plan
    }

    /// Mark chunks as being fetched and pin the file. `version` must
    /// match the entry the preceding [`Self::plan_read`] validated.
    /// The caller must later call [`Self::commit_chunks`] (success) or
    /// [`Self::abort_fetch`] (failure) exactly once, with the same
    /// version.
    pub fn begin_fetch(&mut self, path: &str, version: u64, chunk_ids: &[u64]) {
        let f = self.files.get_mut(path).expect("plan_read first");
        assert_eq!(
            f.version, version,
            "begin_fetch version mismatch for {path}"
        );
        for &c in chunk_ids {
            debug_assert!(!f.resident.is_set(c), "fetching resident chunk");
            f.in_flight.set(c);
        }
        f.pins += 1;
    }

    /// Chunks arrived from the origin: make them resident, account
    /// bytes, unpin, and run watermark eviction if needed.
    ///
    /// A commit whose entry was invalidated or superseded by a newer
    /// version while the fetch was in flight (concurrent version
    /// churn) is discarded: stale bytes never become resident under
    /// the new version, and the new version's pins are untouched.
    pub fn commit_chunks(&mut self, path: &str, version: u64, chunk_ids: &[u64], now: SimTime) {
        // Discard stale commits before any side effects (a no-op
        // commit must not perturb the LRU sequence counter).
        match self.files.get(path) {
            Some(f) if f.version == version => {}
            _ => return,
        }
        let chunk = self.chunk_size();
        let seq = self.bump_seq();
        let f = self.files.get_mut(path).expect("checked above");
        let mut added = 0u64;
        for &c in chunk_ids {
            f.in_flight.clear(c);
            if !f.resident.is_set(c) {
                f.resident.set(c);
                let c_start = c * chunk;
                added += (c_start + chunk).min(f.file_size) - c_start;
            }
        }
        f.pins = f.pins.saturating_sub(1);
        f.last_access = now;
        f.access_seq = seq;
        self.usage += added;
        self.stats.bytes_fetched_origin += added;
        if added > 0 {
            // Fresh origin bytes replace a poisoned copy.
            self.poisoned.remove(path);
        }
        self.maybe_evict(now);
    }

    /// Fetch failed: clear in-flight marks and unpin (a no-op if the
    /// entry was invalidated or superseded meanwhile).
    pub fn abort_fetch(&mut self, path: &str, version: u64, chunk_ids: &[u64]) {
        if let Some(f) = self.files.get_mut(path) {
            if f.version != version {
                return;
            }
            for &c in chunk_ids {
                f.in_flight.clear(c);
            }
            f.pins = f.pins.saturating_sub(1);
        }
    }

    /// Account bytes served to a client (hit or post-fetch).
    pub fn record_served(&mut self, hit_bytes: u64, miss_bytes: u64) {
        self.stats.bytes_served_hit += hit_bytes;
        self.stats.bytes_served_miss += miss_bytes;
    }

    /// Drop all residency for `path` (version change / admin purge /
    /// client-detected corruption).
    pub fn invalidate(&mut self, path: &str) {
        if let Some(f) = self.files.remove(path) {
            let freed = f.resident.resident_bytes();
            self.usage -= freed;
            self.stats.invalidations += 1;
            self.poisoned.remove(path);
        }
    }

    // --- silent corruption ([`crate::fault::FaultKind::DataCorrupt`]) ------

    /// Mark `path`'s resident copy as corrupted. A no-op when nothing
    /// is resident (there are no bytes to damage; a later fetch brings
    /// clean ones). Returns whether the marker was set.
    pub fn poison(&mut self, path: &str) -> bool {
        let has_bytes = self
            .files
            .get(path)
            .is_some_and(|f| f.resident.count_set() > 0);
        if has_bytes {
            self.poisoned.insert(path.to_string());
        }
        has_bytes
    }

    /// Is `path`'s resident copy corrupted? (What a client's digest
    /// check would report at transfer end.)
    pub fn is_poisoned(&self, path: &str) -> bool {
        self.poisoned.contains(path)
    }

    /// Currently poisoned paths, sorted (the model checker hashes
    /// these into the state fingerprint).
    pub fn poisoned_paths(&self) -> impl Iterator<Item = &str> {
        self.poisoned.iter().map(String::as_str)
    }

    /// Watermark eviction: when usage exceeds `high_watermark ×
    /// capacity`, evict whole files in LRU order (skipping pinned
    /// files) until usage falls to `low_watermark × capacity`. Each
    /// sweep is timestamped in [`CacheServer::eviction_log`] so reports
    /// can show *when* the resource provider reclaimed space.
    fn maybe_evict(&mut self, now: SimTime) {
        let cap = self.cfg.capacity.as_u64() as f64;
        let high = (self.cfg.high_watermark * cap) as u64;
        if self.usage <= high {
            return;
        }
        let low = (self.cfg.low_watermark * cap) as u64;
        // LRU order: (last_access, access_seq).
        let mut victims: Vec<(SimTime, u64, String)> = self
            .files
            .iter()
            .filter(|(_, f)| f.pins == 0)
            .map(|(p, f)| (f.last_access, f.access_seq, p.clone()))
            .collect();
        victims.sort();
        let mut sweep = EvictionSweep {
            at: now,
            files: 0,
            bytes: 0,
        };
        for (_, _, path) in victims {
            if self.usage <= low {
                break;
            }
            let f = self.files.remove(&path).expect("victim exists");
            let freed = f.resident.resident_bytes();
            self.usage -= freed;
            self.stats.evictions += 1;
            self.stats.bytes_evicted += freed;
            self.poisoned.remove(&path);
            sweep.files += 1;
            sweep.bytes += freed;
        }
        if sweep.files > 0 {
            self.eviction_log.push(sweep);
        }
    }

    fn bump_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Paths currently pinned by in-flight fetches (never evictable),
    /// sorted for determinism.
    pub fn pinned_paths(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .files
            .iter()
            .filter(|(_, f)| f.pins > 0)
            .map(|(p, _)| p.clone())
            .collect();
        v.sort();
        v
    }

    /// Expose (path → resident bytes) snapshot for reports/tests.
    pub fn residency_snapshot(&self) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> = self
            .files
            .iter()
            .map(|(p, f)| (p.clone(), f.resident.resident_bytes()))
            .collect();
        v.sort();
        v
    }

    /// Per-file reservation state: `(path, pins, in-flight chunk
    /// indices)` for every file with any pin or reserved chunk, sorted
    /// by path. The model checker hashes this into its canonical state
    /// snapshot and asserts it drains to empty at every terminal state
    /// — reserved chunks never leak across abort/failover.
    pub fn reservation_snapshot(&self) -> Vec<(String, u32, Vec<u64>)> {
        let mut v: Vec<(String, u32, Vec<u64>)> = self
            .files
            .iter()
            .filter(|(_, f)| f.pins > 0 || f.in_flight.count_set() > 0)
            .map(|(p, f)| (p.clone(), f.pins, f.in_flight.iter_set().collect()))
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(capacity: u64, chunk: u64) -> CacheConfig {
        CacheConfig {
            capacity: ByteSize(capacity),
            high_watermark: 0.9,
            low_watermark: 0.6,
            chunk_size: ByteSize(chunk),
            per_conn_gbps: 8.0,
        }
    }

    fn t(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn cold_read_is_all_miss() {
        let mut c = CacheServer::new("x", cfg(10_000, 100));
        let plan = c.plan_read("/f", 0, 250, 250, 1, t(0.0));
        assert_eq!(plan.hit_bytes, 0);
        assert_eq!(plan.miss_bytes, 250);
        assert_eq!(plan.fetch, vec![0, 1, 2]);
        assert!(plan.join.is_empty());
    }

    #[test]
    fn commit_makes_chunks_resident() {
        let mut c = CacheServer::new("x", cfg(10_000, 100));
        let plan = c.plan_read("/f", 0, 250, 250, 1, t(0.0));
        c.begin_fetch("/f", 1, &plan.fetch);
        c.commit_chunks("/f", 1, &plan.fetch, t(1.0));
        // Usage counts whole chunks, capped at file size: 100+100+50.
        assert_eq!(c.usage().as_u64(), 250);
        let plan2 = c.plan_read("/f", 0, 250, 250, 1, t(2.0));
        assert_eq!(plan2.hit_bytes, 250);
        assert_eq!(plan2.miss_bytes, 0);
        assert!(c.contains_whole("/f", 1));
    }

    #[test]
    fn partial_read_fetches_only_touched_chunks() {
        let mut c = CacheServer::new("x", cfg(100_000, 100));
        // Read bytes [150, 350) of a 1000-byte file: chunks 1, 2, 3.
        let plan = c.plan_read("/f", 150, 200, 1_000, 1, t(0.0));
        assert_eq!(plan.fetch, vec![1, 2, 3]);
        assert_eq!(plan.miss_bytes, 200);
    }

    #[test]
    fn concurrent_fetch_coalesces() {
        let mut c = CacheServer::new("x", cfg(10_000, 100));
        let p1 = c.plan_read("/f", 0, 200, 200, 1, t(0.0));
        c.begin_fetch("/f", 1, &p1.fetch);
        // Second reader while chunks are in flight.
        let p2 = c.plan_read("/f", 0, 200, 200, 1, t(0.1));
        assert!(p2.fetch.is_empty(), "no duplicate fetch");
        assert_eq!(p2.join, vec![0, 1]);
        c.commit_chunks("/f", 1, &p1.fetch, t(1.0));
        let p3 = c.plan_read("/f", 0, 200, 200, 1, t(2.0));
        assert_eq!(p3.hit_bytes, 200);
    }

    #[test]
    fn version_change_invalidates() {
        let mut c = CacheServer::new("x", cfg(10_000, 100));
        let p = c.plan_read("/f", 0, 100, 100, 1, t(0.0));
        c.begin_fetch("/f", 1, &p.fetch);
        c.commit_chunks("/f", 1, &p.fetch, t(1.0));
        assert_eq!(c.usage().as_u64(), 100);
        // Same path, new version.
        let p2 = c.plan_read("/f", 0, 100, 100, 2, t(2.0));
        assert_eq!(p2.miss_bytes, 100, "stale chunks dropped");
        assert_eq!(c.stats.invalidations, 1);
    }

    #[test]
    fn watermark_eviction_lru_order() {
        // capacity 1000, high 900, low 600, chunk 100.
        let mut c = CacheServer::new("x", cfg(1_000, 100));
        for (i, name) in ["/a", "/b", "/c", "/d"].iter().enumerate() {
            let p = c.plan_read(name, 0, 200, 200, 1, t(i as f64));
            c.begin_fetch(name, 1, &p.fetch);
            c.commit_chunks(name, 1, &p.fetch, t(i as f64 + 0.5));
        }
        assert_eq!(c.usage().as_u64(), 800); // under high mark, nothing evicted
        // Touch /a so /b becomes LRU.
        c.plan_read("/a", 0, 10, 200, 1, t(10.0));
        // Fifth file pushes usage to 1000 > 900 → evict to <= 600.
        let p = c.plan_read("/e", 0, 200, 200, 1, t(11.0));
        c.begin_fetch("/e", 1, &p.fetch);
        c.commit_chunks("/e", 1, &p.fetch, t(11.5));
        assert!(c.usage().as_u64() <= 600, "usage {}", c.usage());
        // /b and /c (oldest untouched) evicted; /a survived the touch.
        let snap = c.residency_snapshot();
        let names: Vec<&str> = snap.iter().map(|(p, _)| p.as_str()).collect();
        assert!(names.contains(&"/a"), "recently-touched survives: {names:?}");
        assert!(!names.contains(&"/b"), "LRU victim evicted: {names:?}");
        assert!(c.stats.evictions >= 2);
    }

    #[test]
    fn pinned_files_not_evicted() {
        let mut c = CacheServer::new("x", cfg(1_000, 100));
        // /a resident and pinned by an in-flight fetch of more chunks.
        let p = c.plan_read("/a", 0, 500, 1_000, 1, t(0.0));
        c.begin_fetch("/a", 1, &p.fetch);
        c.commit_chunks("/a", 1, &p.fetch, t(0.5));
        let p2 = c.plan_read("/a", 500, 100, 1_000, 1, t(0.6));
        c.begin_fetch("/a", 1, &p2.fetch); // pin /a
        // Fill with another file to cross the watermark.
        let p3 = c.plan_read("/b", 0, 500, 500, 1, t(1.0));
        c.begin_fetch("/b", 1, &p3.fetch);
        c.commit_chunks("/b", 1, &p3.fetch, t(1.5));
        // /a was LRU but pinned; /b itself is pinned-free after commit.
        let snap = c.residency_snapshot();
        assert!(snap.iter().any(|(p, _)| p == "/a"), "pinned file survives");
    }

    #[test]
    fn zero_len_read() {
        let mut c = CacheServer::new("x", cfg(1_000, 100));
        let p = c.plan_read("/f", 50, 0, 100, 1, t(0.0));
        assert_eq!(p, ReadPlan { hit_bytes: 0, miss_bytes: 0, fetch: vec![], join: vec![] });
    }

    #[test]
    #[should_panic(expected = "read past EOF")]
    fn read_past_eof_panics() {
        let mut c = CacheServer::new("x", cfg(1_000, 100));
        c.plan_read("/f", 90, 20, 100, 1, t(0.0));
    }

    #[test]
    fn stale_version_commit_discarded() {
        // Concurrent version churn: a v2 reader invalidates and starts
        // its own fetch while a v1 fetch is still in flight; the late
        // v1 commit must not pollute the v2 entry or steal its pin.
        let mut c = CacheServer::new("x", cfg(10_000, 100));
        let p1 = c.plan_read("/f", 0, 200, 200, 1, t(0.0));
        c.begin_fetch("/f", 1, &p1.fetch);
        let p2 = c.plan_read("/f", 0, 200, 200, 2, t(0.1));
        assert_eq!(p2.miss_bytes, 200, "v2 starts cold");
        c.begin_fetch("/f", 2, &p2.fetch);
        // v1 lands late: discarded.
        c.commit_chunks("/f", 1, &p1.fetch, t(0.2));
        assert_eq!(c.usage().as_u64(), 0, "stale bytes never become resident");
        let p3 = c.plan_read("/f", 0, 200, 200, 2, t(0.3));
        assert!(p3.fetch.is_empty(), "v2 fetch still owns the chunks");
        assert_eq!(p3.join, vec![0, 1]);
        // v2 commit proceeds normally.
        c.commit_chunks("/f", 2, &p2.fetch, t(0.4));
        assert!(c.contains_whole("/f", 2));
        assert_eq!(c.usage().as_u64(), 200);
    }

    #[test]
    fn abort_fetch_unpins_and_clears() {
        let mut c = CacheServer::new("x", cfg(1_000, 100));
        let p = c.plan_read("/f", 0, 100, 100, 1, t(0.0));
        c.begin_fetch("/f", 1, &p.fetch);
        c.abort_fetch("/f", 1, &p.fetch);
        // Chunks can be fetched again (not stuck in flight).
        let p2 = c.plan_read("/f", 0, 100, 100, 1, t(1.0));
        assert_eq!(p2.fetch, vec![0]);
        assert!(p2.join.is_empty());
    }

    #[test]
    fn eviction_log_records_when_space_was_reclaimed() {
        // capacity 1000, high 900, low 600, chunk 100: four 200-byte
        // files fit; a fifth at t=5 must trigger a timestamped sweep.
        let mut c = CacheServer::new("x", cfg(1_000, 100));
        for (i, name) in ["/a", "/b", "/c", "/d"].iter().enumerate() {
            let p = c.plan_read(name, 0, 200, 200, 1, t(i as f64));
            c.begin_fetch(name, 1, &p.fetch);
            c.commit_chunks(name, 1, &p.fetch, t(i as f64));
        }
        assert!(c.eviction_log.is_empty(), "no pressure yet");
        let p = c.plan_read("/e", 0, 200, 200, 1, t(5.0));
        c.begin_fetch("/e", 1, &p.fetch);
        c.commit_chunks("/e", 1, &p.fetch, t(5.0));
        assert_eq!(c.eviction_log.len(), 1);
        let sweep = c.eviction_log[0];
        assert_eq!(sweep.at, t(5.0), "sweep carries the commit instant");
        assert_eq!(sweep.files as u64, c.stats.evictions);
        assert_eq!(sweep.bytes, c.stats.bytes_evicted);
        assert!(sweep.bytes >= 400, "evicted to the low watermark");
    }

    #[test]
    fn property_invariants_under_randomized_op_sequences() {
        // The §1 operational claim as invariants, under arbitrary
        // interleavings of plan/begin_fetch/commit/abort:
        //  1. usage always equals the sum of resident chunk bytes;
        //  2. pinned (in-flight) files are never evicted;
        //  3. usage never exceeds capacity after `maybe_evict` ran.
        use crate::util::prop::check;
        check("cache chaos invariants", 40, |g| {
            // 10 files of 96..960 bytes (total 5280) against capacity
            // 4000 (high 3600 / low 2400): eviction pressure is
            // reachable, while the ≤2 concurrently pinned files
            // (≤1920 B) always fit under the low watermark.
            let chunk = 64u64;
            let capacity = 4_000u64;
            let mut c = CacheServer::new("p", cfg(capacity, chunk));
            let mut inflight: Vec<(String, Vec<u64>)> = Vec::new();
            let n_ops = g.usize(1, 50);
            for i in 0..n_ops {
                let now = t(i as f64);
                match g.usize(0, 3) {
                    0 if inflight.len() < 2 => {
                        let fnum = g.u64(0, 9);
                        let file = format!("/f{fnum}");
                        // 96..960 bytes against chunk 64: every file has
                        // a short 32-byte tail chunk.
                        let size = 96 * (fnum + 1);
                        let off = g.u64(0, size - 1);
                        let len = g.u64(0, size - off);
                        let p = c.plan_read(&file, off, len, size, 1, now);
                        if !p.fetch.is_empty() {
                            c.begin_fetch(&file, 1, &p.fetch);
                            inflight.push((file, p.fetch.clone()));
                        }
                    }
                    1 => {
                        if !inflight.is_empty() {
                            let (f, ch) = inflight.remove(0);
                            c.commit_chunks(&f, 1, &ch, now);
                        }
                    }
                    2 => {
                        if let Some((f, ch)) = inflight.pop() {
                            c.abort_fetch(&f, 1, &ch);
                        }
                    }
                    _ => {
                        // Zero-byte file: its single empty chunk through
                        // the full reserve → abort/commit cycle must
                        // never move usage (and never underflow it).
                        if !c.contains_whole("/zero", 1) {
                            c.plan_read("/zero", 0, 0, 0, 1, now);
                            c.begin_fetch("/zero", 1, &[0]);
                            if g.bool() {
                                c.commit_chunks("/zero", 1, &[0], now);
                            } else {
                                c.abort_fetch("/zero", 1, &[0]);
                            }
                        }
                    }
                }
                // Invariant 1: usage == sum of resident bytes.
                let sum: u64 = c.residency_snapshot().iter().map(|(_, b)| b).sum();
                if sum != c.usage().as_u64() {
                    return (
                        false,
                        format!("op {i}: sum {} != usage {}", sum, c.usage()),
                    );
                }
                // Invariant 2: every in-flight fetch still pins its
                // file (eviction must have skipped it).
                let pinned = c.pinned_paths();
                for (path, _) in &inflight {
                    if !pinned.contains(path) {
                        return (false, format!("op {i}: pinned {path} evicted"));
                    }
                }
                // Invariant 3: capacity respected after eviction.
                if c.usage().as_u64() > capacity {
                    return (
                        false,
                        format!("op {i}: usage {} > capacity {capacity}", c.usage()),
                    );
                }
            }
            (true, String::new())
        });
    }

    #[test]
    fn property_usage_equals_sum_of_residency() {
        use crate::util::prop::check;
        check("cache usage accounting", 40, |g| {
            let chunk = 100u64;
            let mut c = CacheServer::new("p", cfg(100_000, chunk));
            let n_ops = g.usize(1, 30);
            for i in 0..n_ops {
                let fnum = g.u64(0, 5);
                let file = format!("/f{fnum}");
                // Fixed size per file; f0 is zero bytes (one empty
                // chunk), the rest end in a short 50-byte tail chunk
                // (150·n % 100) — both interleaved with abort_fetch.
                let size = 150 * fnum;
                let (off, len) = if size == 0 {
                    (0, 0)
                } else {
                    let off = g.u64(0, size - 1);
                    (off, g.u64(0, size - off))
                };
                let now = t(i as f64);
                let p = c.plan_read(&file, off, len, size, 1, now);
                if !p.fetch.is_empty() {
                    c.begin_fetch(&file, 1, &p.fetch);
                    if g.bool() {
                        c.commit_chunks(&file, 1, &p.fetch, now);
                    } else {
                        c.abort_fetch(&file, 1, &p.fetch);
                    }
                } else if size == 0 && !c.contains_whole(&file, 1) {
                    // A zero-length read plans no fetch, so drive the
                    // empty chunk's reserve → abort/commit cycle
                    // directly.
                    c.begin_fetch(&file, 1, &[0]);
                    if g.bool() {
                        c.commit_chunks(&file, 1, &[0], now);
                    } else {
                        c.abort_fetch(&file, 1, &[0]);
                    }
                }
            }
            let sum: u64 = c.residency_snapshot().iter().map(|(_, b)| b).sum();
            (
                sum == c.usage().as_u64(),
                format!("sum {} != usage {}", sum, c.usage()),
            )
        });
    }

    #[test]
    fn zero_byte_and_short_tail_reserve_abort_commit() {
        let mut c = CacheServer::new("x", cfg(10_000, 100));
        // Zero-byte file: one empty chunk through reserve → abort →
        // re-reserve → commit. Usage must stay exactly zero throughout.
        c.plan_read("/zero", 0, 0, 0, 1, t(0.0));
        c.begin_fetch("/zero", 1, &[0]);
        c.abort_fetch("/zero", 1, &[0]);
        assert_eq!(c.usage().as_u64(), 0);
        assert!(c.reservation_snapshot().is_empty(), "abort unpins");
        c.begin_fetch("/zero", 1, &[0]);
        c.commit_chunks("/zero", 1, &[0], t(1.0));
        assert_eq!(c.usage().as_u64(), 0);
        assert!(c.contains_whole("/zero", 1), "empty file fully resident");

        // Short tail: 250 bytes over 100-byte chunks → the last chunk
        // holds 50 bytes. An aborted whole-file fetch leaves nothing.
        let p = c.plan_read("/tail", 0, 250, 250, 1, t(2.0));
        assert_eq!(p.fetch, vec![0, 1, 2]);
        c.begin_fetch("/tail", 1, &p.fetch);
        c.abort_fetch("/tail", 1, &p.fetch);
        let sum: u64 = c.residency_snapshot().iter().map(|(_, b)| b).sum();
        assert_eq!(sum, c.usage().as_u64());
        assert_eq!(c.usage().as_u64(), 0, "aborted fetch left bytes");

        // Re-fetch just the tail chunk: usage counts its true 50 bytes,
        // not a full chunk.
        let p2 = c.plan_read("/tail", 200, 50, 250, 1, t(3.0));
        assert_eq!(p2.fetch, vec![2]);
        c.begin_fetch("/tail", 1, &p2.fetch);
        c.commit_chunks("/tail", 1, &p2.fetch, t(4.0));
        assert_eq!(c.usage().as_u64(), 50);

        // Invalidation of both drains usage to zero without underflow.
        c.invalidate("/zero");
        c.invalidate("/tail");
        assert_eq!(c.usage().as_u64(), 0);
        assert!(c.residency_snapshot().is_empty());
    }
}
