//! Chunk residency bitmap.
//!
//! A file of `file_size` bytes split into fixed `chunk_size` chunks
//! (CVMFS uses 24 MB — paper §3.1); the set tracks which chunks are
//! resident in a cache. Backed by a `u64` bitmap, so multi-GB files at
//! 24 MB chunks cost a few dozen words.

/// Fixed-chunking bitmap over one file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkSet {
    words: Vec<u64>,
    total: u64,
    file_size: u64,
    chunk_size: u64,
    set_count: u64,
}

impl ChunkSet {
    /// Create an empty set for a file. Zero-byte files have one
    /// (empty) chunk so whole-file logic stays uniform.
    pub fn new(file_size: u64, chunk_size: u64) -> Self {
        assert!(chunk_size > 0, "chunk size must be positive");
        let total = if file_size == 0 {
            1
        } else {
            file_size.div_ceil(chunk_size)
        };
        ChunkSet {
            words: vec![0; total.div_ceil(64) as usize],
            total,
            file_size,
            chunk_size,
            set_count: 0,
        }
    }

    /// Number of chunks the file spans.
    pub fn total_chunks(&self) -> u64 {
        self.total
    }

    pub fn count_set(&self) -> u64 {
        self.set_count
    }

    pub fn is_set(&self, chunk: u64) -> bool {
        assert!(chunk < self.total, "chunk {chunk} out of {}", self.total);
        self.words[(chunk / 64) as usize] & (1 << (chunk % 64)) != 0
    }

    /// Mark a chunk resident. Idempotent.
    pub fn set(&mut self, chunk: u64) {
        assert!(chunk < self.total, "chunk {chunk} out of {}", self.total);
        let w = &mut self.words[(chunk / 64) as usize];
        let bit = 1 << (chunk % 64);
        if *w & bit == 0 {
            *w |= bit;
            self.set_count += 1;
        }
    }

    /// Clear a chunk. Idempotent.
    pub fn clear(&mut self, chunk: u64) {
        assert!(chunk < self.total, "chunk {chunk} out of {}", self.total);
        let w = &mut self.words[(chunk / 64) as usize];
        let bit = 1 << (chunk % 64);
        if *w & bit != 0 {
            *w &= !bit;
            self.set_count -= 1;
        }
    }

    /// Bytes of a chunk (the last chunk may be short).
    pub fn chunk_bytes(&self, chunk: u64) -> u64 {
        assert!(chunk < self.total);
        let start = chunk * self.chunk_size;
        (start + self.chunk_size).min(self.file_size) - start
    }

    /// Total bytes of resident chunks.
    pub fn resident_bytes(&self) -> u64 {
        if self.set_count == self.total {
            return self.file_size;
        }
        let mut bytes = self.set_count * self.chunk_size;
        // If the (short) last chunk is set, correct for its true size.
        if self.total > 0 && self.is_set(self.total - 1) {
            bytes = bytes - self.chunk_size + self.chunk_bytes(self.total - 1);
        }
        bytes
    }

    /// Iterate resident chunk indices.
    pub fn iter_set(&self) -> impl Iterator<Item = u64> + '_ {
        (0..self.total).filter(|&c| self.is_set(c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_count_rounding() {
        assert_eq!(ChunkSet::new(100, 100).total_chunks(), 1);
        assert_eq!(ChunkSet::new(101, 100).total_chunks(), 2);
        assert_eq!(ChunkSet::new(0, 100).total_chunks(), 1);
        assert_eq!(ChunkSet::new(1, 100).total_chunks(), 1);
        // > 64 chunks exercises multi-word bitmaps.
        assert_eq!(ChunkSet::new(100 * 200, 100).total_chunks(), 200);
    }

    #[test]
    fn set_clear_idempotent() {
        let mut s = ChunkSet::new(1_000, 100);
        s.set(3);
        s.set(3);
        assert_eq!(s.count_set(), 1);
        assert!(s.is_set(3));
        s.clear(3);
        s.clear(3);
        assert_eq!(s.count_set(), 0);
    }

    #[test]
    fn last_chunk_short() {
        let s = ChunkSet::new(250, 100);
        assert_eq!(s.chunk_bytes(0), 100);
        assert_eq!(s.chunk_bytes(2), 50);
    }

    #[test]
    fn resident_bytes_with_short_tail() {
        let mut s = ChunkSet::new(250, 100);
        s.set(2); // the short one
        assert_eq!(s.resident_bytes(), 50);
        s.set(0);
        assert_eq!(s.resident_bytes(), 150);
        s.set(1);
        assert_eq!(s.resident_bytes(), 250);
    }

    #[test]
    fn multiword_iteration() {
        let mut s = ChunkSet::new(100 * 130, 100);
        for c in [0u64, 63, 64, 65, 129] {
            s.set(c);
        }
        let got: Vec<u64> = s.iter_set().collect();
        assert_eq!(got, vec![0, 63, 64, 65, 129]);
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn out_of_range_panics() {
        ChunkSet::new(100, 100).is_set(1);
    }

    #[test]
    fn zero_byte_file_is_one_empty_chunk() {
        let mut s = ChunkSet::new(0, 100);
        assert_eq!(s.total_chunks(), 1);
        assert_eq!(s.chunk_bytes(0), 0);
        s.set(0);
        assert_eq!(s.count_set(), 1);
        assert_eq!(s.resident_bytes(), 0, "empty chunk carries no bytes");
        s.clear(0);
        assert_eq!(s.count_set(), 0);
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn property_resident_bytes_matches_manual_sum() {
        use crate::util::prop::check;
        check("chunkset byte accounting", 80, |g| {
            // file_size 0 (one empty chunk) is in range: set/clear on
            // it must account zero bytes, never underflow.
            let file_size = g.u64(0, 10_000);
            let chunk_size = g.u64(1, 500);
            let mut s = ChunkSet::new(file_size, chunk_size);
            for _ in 0..g.usize(0, 40) {
                let c = g.u64(0, s.total_chunks() - 1);
                if g.bool() {
                    s.set(c);
                } else {
                    s.clear(c);
                }
            }
            let manual: u64 = s.iter_set().map(|c| s.chunk_bytes(c)).sum();
            (
                manual == s.resident_bytes() && s.count_set() == s.iter_set().count() as u64,
                format!(
                    "file={file_size} chunk={chunk_size} manual={manual} got={}",
                    s.resident_bytes()
                ),
            )
        });
    }
}
