//! GeoIP service: nearest-cache selection.
//!
//! Paper §3: "The clients are responsible for finding the nearest cache
//! using GeoIP" — CVMFS ships a GeoIP API and `stashcp` reuses it. The
//! production service resolves a client IP to coordinates with a
//! MaxMind database; our substitute resolves a *site name* to the
//! coordinates of the paper's real locations (DESIGN.md §2 row 10).
//!
//! Distance scoring runs in two interchangeable implementations:
//! * [`haversine_km`] — the pure-rust reference;
//! * [`crate::runtime::GeoScorer`] — the AOT-compiled JAX/Pallas kernel
//!   (`artifacts/geo_score.hlo.txt`), used by the batch service.
//!
//! [`NearestCache`] ranks caches by great-circle distance plus a load
//! penalty, mirroring how the production GeoIP API breaks ties between
//! nearby caches.

use crate::config::FederationConfig;

/// Mean Earth radius (km), IUGG value — must match `kernels/ref.py`.
pub const EARTH_RADIUS_KM: f64 = 6_371.0088;

/// Great-circle distance between two (lat, lon) points in degrees.
pub fn haversine_km(lat1: f64, lon1: f64, lat2: f64, lon2: f64) -> f64 {
    let (phi1, phi2) = (lat1.to_radians(), lat2.to_radians());
    let dphi = (lat2 - lat1).to_radians();
    let dlambda = (lon2 - lon1).to_radians();
    let a = (dphi / 2.0).sin().powi(2) + phi1.cos() * phi2.cos() * (dlambda / 2.0).sin().powi(2);
    2.0 * EARTH_RADIUS_KM * a.sqrt().min(1.0).asin()
}

/// Speed-of-light-in-fiber RTT estimate for a great-circle distance,
/// plus a fixed routing/processing overhead. (~2/3 c, out and back.)
pub fn rtt_ms_for_km(km: f64) -> f64 {
    km / 100.0 + 4.0
}

/// A cache entry in the geo database.
#[derive(Debug, Clone)]
pub struct CacheSite {
    pub name: String,
    pub lat: f64,
    pub lon: f64,
}

/// Scoring backend: given client coordinates and the cache table,
/// produce a score per cache (lower = better). Implemented by the
/// pure-rust reference and by the PJRT-backed executor.
pub trait GeoScoreBackend {
    /// `clients`: (lat, lon) per client; `loads`: current load factor
    /// per cache in [0, 1]. Returns `scores[client][cache]`.
    fn score(
        &mut self,
        clients: &[(f64, f64)],
        caches: &[CacheSite],
        loads: &[f64],
    ) -> Vec<Vec<f64>>;
}

/// Pure-rust reference backend: distance + load penalty.
///
/// `score = distance_km + load * LOAD_PENALTY_KM` — a loaded cache is
/// only preferred while a less-loaded one is within `LOAD_PENALTY_KM`.
/// Must match `geo_score` in `python/compile/model.py` exactly.
pub struct RustGeoBackend;

/// Kilometres of distance one unit of load is worth.
pub const LOAD_PENALTY_KM: f64 = 1_500.0;

impl GeoScoreBackend for RustGeoBackend {
    fn score(
        &mut self,
        clients: &[(f64, f64)],
        caches: &[CacheSite],
        loads: &[f64],
    ) -> Vec<Vec<f64>> {
        assert_eq!(caches.len(), loads.len());
        clients
            .iter()
            .map(|&(lat, lon)| {
                caches
                    .iter()
                    .zip(loads)
                    .map(|(c, &load)| {
                        haversine_km(lat, lon, c.lat, c.lon) + load * LOAD_PENALTY_KM
                    })
                    .collect()
            })
            .collect()
    }
}

/// The nearest-cache service (the CVMFS GeoIP API substitute).
pub struct NearestCache<B: GeoScoreBackend> {
    caches: Vec<CacheSite>,
    backend: B,
    /// Lookups served (monitoring).
    pub lookups: u64,
}

impl NearestCache<RustGeoBackend> {
    /// Build from a federation config with the pure-rust backend.
    pub fn from_config(cfg: &FederationConfig) -> Self {
        let caches = cfg
            .cache_sites()
            .map(|s| CacheSite {
                name: s.name.clone(),
                lat: s.lat,
                lon: s.lon,
            })
            .collect();
        NearestCache {
            caches,
            backend: RustGeoBackend,
            lookups: 0,
        }
    }
}

impl<B: GeoScoreBackend> NearestCache<B> {
    pub fn with_backend(caches: Vec<CacheSite>, backend: B) -> Self {
        NearestCache {
            caches,
            backend,
            lookups: 0,
        }
    }

    pub fn caches(&self) -> &[CacheSite] {
        &self.caches
    }

    /// Rank all caches for one client: returns cache indices, best
    /// first, with their scores.
    pub fn rank(&mut self, lat: f64, lon: f64, loads: &[f64]) -> Vec<(usize, f64)> {
        self.lookups += 1;
        let scores = self.backend.score(&[(lat, lon)], &self.caches, loads);
        let mut ranked: Vec<(usize, f64)> = scores[0].iter().copied().enumerate().collect();
        // Stable ordering: score, then index (determinism when equal).
        ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        ranked
    }

    /// The single nearest cache for a client (unloaded).
    pub fn nearest(&mut self, lat: f64, lon: f64) -> (usize, f64) {
        let loads = vec![0.0; self.caches.len()];
        self.rank(lat, lon, &loads)[0]
    }

    /// Batch ranking for many clients at once — the shape served by the
    /// AOT kernel (64 clients × 16 caches per invocation).
    pub fn rank_batch(
        &mut self,
        clients: &[(f64, f64)],
        loads: &[f64],
    ) -> Vec<Vec<(usize, f64)>> {
        self.lookups += clients.len() as u64;
        let scores = self.backend.score(clients, &self.caches, loads);
        scores
            .into_iter()
            .map(|row| {
                let mut ranked: Vec<(usize, f64)> = row.into_iter().enumerate().collect();
                ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
                ranked
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;

    #[test]
    fn haversine_known_distances() {
        // Chicago (UChicago) to Lincoln NE — about 750 km.
        let d = haversine_km(41.7886, -87.5987, 40.8202, -96.7005);
        assert!((700.0..820.0).contains(&d), "chicago-lincoln {d} km");
        // Amsterdam to New York — about 5 860 km.
        let d = haversine_km(52.3676, 4.9041, 40.7128, -74.0060);
        assert!((5_700.0..6_000.0).contains(&d), "ams-nyc {d} km");
        // Zero distance.
        assert!(haversine_km(10.0, 20.0, 10.0, 20.0) < 1e-9);
    }

    #[test]
    fn haversine_symmetry() {
        use crate::util::prop::check;
        check("haversine symmetry + range", 100, |g| {
            let a = (g.f64(-89.0, 89.0), g.f64(-180.0, 180.0));
            let b = (g.f64(-89.0, 89.0), g.f64(-180.0, 180.0));
            let d1 = haversine_km(a.0, a.1, b.0, b.1);
            let d2 = haversine_km(b.0, b.1, a.0, a.1);
            let half_circumference = std::f64::consts::PI * EARTH_RADIUS_KM;
            (
                (d1 - d2).abs() < 1e-9 && (0.0..=half_circumference + 1.0).contains(&d1),
                format!("a={a:?} b={b:?} d1={d1} d2={d2}"),
            )
        });
    }

    #[test]
    fn syracuse_workers_pick_syracuse_cache() {
        let cfg = paper_federation();
        let mut svc = NearestCache::from_config(&cfg);
        let s = cfg.site("syracuse").unwrap();
        let (idx, score) = svc.nearest(s.lat, s.lon);
        assert_eq!(svc.caches()[idx].name, "syracuse");
        assert!(score < 1.0, "on-site cache at ~0 km, got {score}");
    }

    #[test]
    fn colorado_prefers_midwest_over_coasts() {
        let cfg = paper_federation();
        let mut svc = NearestCache::from_config(&cfg);
        let s = cfg.site("colorado").unwrap();
        let ranked = svc.rank(s.lat, s.lon, &vec![0.0; svc.caches().len()]);
        let best = svc.caches()[ranked[0].0].name.clone();
        assert!(
            best == "i2-kansascity" || best == "nebraska",
            "colorado nearest was {best}"
        );
        // Amsterdam must rank last from Colorado.
        let worst = &svc.caches()[ranked.last().unwrap().0].name;
        assert_eq!(worst, "amsterdam");
    }

    #[test]
    fn load_penalty_shifts_choice() {
        let cfg = paper_federation();
        let mut svc = NearestCache::from_config(&cfg);
        let s = cfg.site("colorado").unwrap();
        let n = svc.caches().len();
        let unloaded = svc.rank(s.lat, s.lon, &vec![0.0; n]);
        let best = unloaded[0].0;
        let second = unloaded[1].0;
        // Saturate the best cache; the second should win now.
        let mut loads = vec![0.0; n];
        loads[best] = 1.0;
        let reranked = svc.rank(s.lat, s.lon, &loads);
        assert_eq!(reranked[0].0, second);
    }

    #[test]
    fn batch_matches_single() {
        let cfg = paper_federation();
        let mut svc = NearestCache::from_config(&cfg);
        let clients: Vec<(f64, f64)> = cfg.compute_sites().map(|s| (s.lat, s.lon)).collect();
        let loads = vec![0.0; svc.caches().len()];
        let batch = svc.rank_batch(&clients, &loads);
        for (i, &(lat, lon)) in clients.iter().enumerate() {
            let single = svc.rank(lat, lon, &loads);
            assert_eq!(batch[i][0].0, single[0].0);
        }
    }

    #[test]
    fn rtt_estimate_monotone() {
        assert!(rtt_ms_for_km(0.0) < rtt_ms_for_km(100.0));
        assert!((rtt_ms_for_km(1000.0) - 14.0).abs() < 1e-9);
    }
}
