//! Hand-rolled CLI (no clap offline — DESIGN.md §2 row 15).

use anyhow::{bail, Context, Result};
use stashcache::config::{defaults, FederationConfig};
use stashcache::federation::{backend::GeoBackend, FedSim};
use stashcache::report::{self, paper};
use stashcache::sim::scenario::{self, ScenarioConfig};
use stashcache::sim::usage::UsageConfig;
use std::collections::HashMap;
use std::path::PathBuf;

/// Parsed flags: `--key value` pairs plus positionals.
#[derive(Debug, Default)]
pub struct Flags {
    flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Flags {
    pub fn parse(args: &[String]) -> Result<Self> {
        let mut out = Flags::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    out.flags.insert(key.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not a number")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn load_config(flags: &Flags) -> Result<FederationConfig> {
    match flags.get("config") {
        Some(path) => FederationConfig::from_file(std::path::Path::new(path)),
        None => Ok(defaults::paper_federation()),
    }
}

fn geo_backend(flags: &Flags) -> Result<GeoBackend> {
    match flags.get("runtime").unwrap_or("rust") {
        "rust" => Ok(GeoBackend::rust()),
        "pjrt" => GeoBackend::pjrt().context("loading PJRT geo_score artifact"),
        other => bail!("--runtime must be rust|pjrt, got {other:?}"),
    }
}

pub fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        print_help();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "topology" => cmd_topology(&flags),
        "scenario" => cmd_scenario(&flags),
        "usage" => cmd_usage(&flags),
        "report" => cmd_report(&flags),
        "init-config" => cmd_init_config(&flags),
        "live-demo" => cmd_live_demo(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `stashcache help`)"),
    }
}

fn print_help() {
    println!(
        "stashcache — StashCache federation reproduction (PEARC '19)\n\n\
         commands:\n\
           topology                         show sites, caches, proxies, origins\n\
           scenario [--sites a,b] [--repeats N] [--runtime rust|pjrt]\n\
                                            run the §4.1 benchmark (Figs 6-8, Table 3)\n\
           usage --days D [--jobs-per-hour J]\n\
                                            run a usage simulation (Tables 1-2, Fig 4)\n\
           report --all --out-dir DIR       regenerate every paper table/figure\n\
           init-config [PATH]               write an example federation TOML\n\
           live-demo                        run the real TCP/UDP federation on loopback\n\
         common flags:\n\
           --config PATH                    federation TOML (default: built-in paper topology)\n"
    );
}

fn cmd_topology(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let mut t = report::Table::new(
        format!("Federation {:?} (Figure 2 deployment)", cfg.name),
        &["Site", "Lat", "Lon", "Workers", "Cache", "Proxy", "WAN Gbps"],
    );
    for s in &cfg.sites {
        t.row(vec![
            s.name.clone(),
            format!("{:.3}", s.lat),
            format!("{:.3}", s.lon),
            s.worker_slots.to_string(),
            s.cache.map_or("-".into(), |c| c.capacity.to_string()),
            s.proxy.map_or("-".into(), |p| p.capacity.to_string()),
            format!("{:.0}", s.links.wan_gbps),
        ]);
    }
    println!("{}", t.render());
    let mut o = report::Table::new("Origins", &["Name", "Site", "Prefix"]);
    for org in &cfg.origins {
        o.row(vec![org.name.clone(), org.site.clone(), org.prefix.clone()]);
    }
    println!("{}", o.render());
    println!(
        "redirectors: {} (round-robin HA)\nworkload experiments: {}",
        cfg.redirector_instances,
        cfg.workload.experiments.len()
    );
    Ok(())
}

fn cmd_scenario(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let mut scenario_cfg = ScenarioConfig {
        repeats: flags.get_usize("repeats", 1)?,
        ..ScenarioConfig::default()
    };
    if let Some(sites) = flags.get("sites") {
        scenario_cfg.sites = sites.split(',').map(str::to_string).collect();
    }
    let mut fed = FedSim::build_with_backend(cfg, geo_backend(flags)?);
    let results = scenario::run_on(&mut fed, &scenario_cfg);
    println!("{}", paper::table3(&results).render());
    for site in &scenario_cfg.sites {
        let (chart, _) = paper::fig_site_performance(&results, site);
        println!("{chart}");
    }
    let (chart, _) = paper::fig8_small_file(&results);
    println!("{chart}");
    Ok(())
}

fn cmd_usage(flags: &Flags) -> Result<()> {
    let _cfg = load_config(flags)?;
    let ucfg = UsageConfig {
        days: flags.get_f64("days", 3.0)?,
        jobs_per_hour: Some(flags.get_f64("jobs-per-hour", 120.0)?),
        ..paper::default_usage_cfg()
    };
    let (t1, _) = paper::table1(&ucfg);
    println!("{}", t1.render());
    let (t2, _) = paper::table2(&ucfg);
    println!("{}", t2.render());
    Ok(())
}

fn cmd_report(flags: &Flags) -> Result<()> {
    let out_dir = PathBuf::from(flags.get("out-dir").unwrap_or("reports"));
    let all = flags.has("all");
    let which = flags.get("only").unwrap_or("");
    let want = |name: &str| all || which.split(',').any(|w| w == name);
    std::fs::create_dir_all(&out_dir)?;

    if want("table1") || want("table2") {
        let ucfg = paper::default_usage_cfg();
        if want("table1") {
            let (t, _) = paper::table1(&ucfg);
            report::write_artifact(&out_dir, "table1.txt", &t.render())?;
            report::write_artifact(&out_dir, "table1.csv", &t.to_csv())?;
            println!("{}", t.render());
        }
        if want("table2") {
            let (t, _) = paper::table2(&ucfg);
            report::write_artifact(&out_dir, "table2.txt", &t.render())?;
            report::write_artifact(&out_dir, "table2.csv", &t.to_csv())?;
            println!("{}", t.render());
        }
    }
    if want("table3") || want("fig6") || want("fig7") || want("fig8") {
        let results = paper::run_scenario();
        if want("table3") {
            let t = paper::table3(&results);
            report::write_artifact(&out_dir, "table3.txt", &t.render())?;
            report::write_artifact(&out_dir, "table3.csv", &t.to_csv())?;
            println!("{}", t.render());
        }
        for (fig, site) in [("fig6", "colorado"), ("fig7", "syracuse")] {
            if want(fig) {
                let (chart, csv) = paper::fig_site_performance(&results, site);
                report::write_artifact(&out_dir, &format!("{fig}_{site}.txt"), &chart)?;
                report::write_artifact(&out_dir, &format!("{fig}_{site}.csv"), &csv.to_csv())?;
                println!("{chart}");
            }
        }
        if want("fig8") {
            let (chart, csv) = paper::fig8_small_file(&results);
            report::write_artifact(&out_dir, "fig8.txt", &chart)?;
            report::write_artifact(&out_dir, "fig8.csv", &csv.to_csv())?;
            println!("{chart}");
        }
    }
    if want("fig4") {
        let (chart, csv) = paper::fig4(364.0, 0.6);
        report::write_artifact(&out_dir, "fig4.txt", &chart)?;
        report::write_artifact(&out_dir, "fig4.csv", &csv.to_csv())?;
        println!("{chart}");
    }
    if want("fig5") {
        let (chart, csv, _) = paper::fig5(2.0, 80.0);
        report::write_artifact(&out_dir, "fig5.txt", &chart)?;
        report::write_artifact(&out_dir, "fig5.csv", &csv.to_csv())?;
        println!("{chart}");
    }
    println!("reports written to {}", out_dir.display());
    Ok(())
}

fn cmd_init_config(flags: &Flags) -> Result<()> {
    let path = flags
        .positional
        .first()
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("federation.toml"));
    std::fs::write(&path, defaults::example_toml())?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_live_demo(_flags: &Flags) -> Result<()> {
    use stashcache::config::CacheConfig;
    use stashcache::live::{
        client::LiveCacheEndpoint, stashcp_live, CollectorDaemon, LiveCache, LiveOrigin,
        LiveRedirector,
    };
    use stashcache::util::ByteSize;

    println!("starting live federation on loopback...");
    let files: Vec<(&str, u64, u64)> = vec![
        ("/ospool/demo/input-a.dat", 4_000_000, 1),
        ("/ospool/demo/input-b.dat", 9_500_000, 1),
    ];
    let origin = LiveOrigin::start("stash-origin", "/ospool/demo", &files)?;
    println!("  origin      {}", origin.addr);
    let redirector =
        LiveRedirector::start(vec![("/ospool/demo".into(), origin.addr.clone())])?;
    println!("  redirector  {}", redirector.addr);
    let monitor = CollectorDaemon::start(vec![(0, "cache-nebraska".into()), (1, "cache-chicago".into())])?;
    println!("  collector   {} (UDP)", monitor.addr);

    let cache_cfg = CacheConfig {
        capacity: ByteSize::gb(1),
        chunk_size: ByteSize::mb(4),
        ..Default::default()
    };
    let c1 = LiveCache::start(
        "cache-nebraska",
        0,
        cache_cfg,
        redirector.addr.clone(),
        monitor.addr.clone(),
    )?;
    let c2 = LiveCache::start(
        "cache-chicago",
        1,
        cache_cfg,
        redirector.addr.clone(),
        monitor.addr.clone(),
    )?;
    println!("  caches      {} {}", c1.addr, c2.addr);

    let endpoints = vec![
        LiveCacheEndpoint {
            site: stashcache::geoip::CacheSite {
                name: "nebraska".into(),
                lat: 40.8202,
                lon: -96.7005,
            },
            addr: c1.addr.clone(),
        },
        LiveCacheEndpoint {
            site: stashcache::geoip::CacheSite {
                name: "chicago".into(),
                lat: 41.7886,
                lon: -87.5987,
            },
            addr: c2.addr.clone(),
        },
    ];
    for (path, size, _) in &files {
        for pass in ["cold", "hot "] {
            let t = stashcp_live(path, 39.7, -104.9, &endpoints)
                .map_err(|e| anyhow::anyhow!(e))?;
            println!(
                "  stashcp {path} ({size}B) via {}: {pass} verified={} in {:?}",
                t.cache_used, t.verified, t.wall
            );
        }
    }
    // Let the UDP close packets land.
    std::thread::sleep(std::time::Duration::from_millis(300));
    println!(
        "  monitoring: {} transfer reports, demo usage = {:?} bytes",
        monitor.reports(),
        monitor.experiment_bytes("demo")
    );
    println!("live demo OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse_forms() {
        let f = Flags::parse(&[
            "--days".into(),
            "3".into(),
            "--all".into(),
            "--out-dir=reports".into(),
            "pos".into(),
        ])
        .unwrap();
        assert_eq!(f.get_f64("days", 0.0).unwrap(), 3.0);
        assert!(f.has("all"));
        assert_eq!(f.get("out-dir"), Some("reports"));
        assert_eq!(f.positional, vec!["pos"]);
    }

    #[test]
    fn bad_number_errors() {
        let f = Flags::parse(&["--days".into(), "abc".into()]).unwrap();
        assert!(f.get_f64("days", 0.0).is_err());
    }
}
