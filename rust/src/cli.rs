//! Hand-rolled CLI (no clap offline — DESIGN.md §2 row 15).

use anyhow::{bail, Context, Result};
use stashcache::config::{defaults, FederationConfig};
use stashcache::experiment::{self, GridSpec};
use stashcache::fault::{FaultKind, FaultTimeline};
use stashcache::federation::{backend::GeoBackend, DownloadMethod, FedSim};
use stashcache::redirector::PolicyKind;
use stashcache::report::{self, paper};
use stashcache::sim::campaign::{self, CampaignConfig, CampaignResults};
use stashcache::sim::scenario::{self, ScenarioConfig};
use stashcache::sim::usage::UsageConfig;
use stashcache::telemetry::{MetricsRegistry, TelemetrySnapshot};
use stashcache::util::SimTime;
use std::collections::HashMap;
use std::path::PathBuf;

/// Parsed flags: `--key value` pairs plus positionals.
#[derive(Debug, Default)]
pub struct Flags {
    flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Flags {
    pub fn parse(args: &[String]) -> Result<Self> {
        let mut out = Flags::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    out.flags.insert(key.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not a number")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn load_config(flags: &Flags) -> Result<FederationConfig> {
    match flags.get("config") {
        Some(path) => FederationConfig::from_file(std::path::Path::new(path)),
        None => Ok(defaults::paper_federation()),
    }
}

/// `--policy NAME`: override the federation's cache-selection policy
/// (shared by `campaign` and `chaos`; sweeps use the `policies` axis).
fn apply_policy_flag(flags: &Flags, cfg: &mut FederationConfig) -> Result<()> {
    if let Some(name) = flags.get("policy") {
        cfg.redirection.policy = parse_policy(name)?;
    }
    Ok(())
}

/// `--deadline-factor F` / `--breaker on|off`: override the
/// federation's gray-failure defences (shared by `campaign` and
/// `chaos`; sweeps use the `deadline_factors`/`breakers` axes).
fn apply_resilience_flags(flags: &Flags, cfg: &mut FederationConfig) -> Result<()> {
    if flags.has("deadline-factor") {
        let f = flags.get_f64("deadline-factor", cfg.resilience.deadline_factor)?;
        if !f.is_finite() || f < 0.0 {
            bail!("--deadline-factor must be finite and >= 0, got {f}");
        }
        cfg.resilience.deadline_factor = f;
    }
    if let Some(v) = flags.get("breaker") {
        cfg.resilience.breaker = match v {
            "on" => true,
            "off" => false,
            other => bail!("--breaker must be on|off, got {other:?}"),
        };
    }
    Ok(())
}

fn parse_policy(name: &str) -> Result<PolicyKind> {
    PolicyKind::from_name(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown policy {name:?} ({})",
            stashcache::redirector::POLICY_NAMES
        )
    })
}

fn geo_backend(flags: &Flags) -> Result<GeoBackend> {
    match flags.get("runtime").unwrap_or("rust") {
        "rust" => Ok(GeoBackend::rust()),
        "pjrt" => GeoBackend::pjrt().context("loading PJRT geo_score artifact"),
        other => bail!("--runtime must be rust|pjrt, got {other:?}"),
    }
}

pub fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        print_help();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "topology" => cmd_topology(&flags),
        "scenario" => cmd_scenario(&flags),
        "campaign" => cmd_campaign(&flags),
        "chaos" => cmd_chaos(&flags),
        "check" => cmd_check(&flags),
        "sweep" => cmd_sweep(&flags),
        "usage" => cmd_usage(&flags),
        "report" => cmd_report(&flags),
        "init-config" => cmd_init_config(&flags),
        "live-demo" => cmd_live_demo(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `stashcache help`)"),
    }
}

/// The usage text. Printed to stdout for `help`, and to **stderr** by
/// `main` whenever a command fails (unknown subcommand, malformed
/// flags, runtime errors), ahead of the error itself, so scripts
/// always get usage next to a non-zero exit and the cause stays the
/// last line.
pub fn usage() -> String {
    "stashcache — StashCache federation reproduction (PEARC '19)\n\n\
     commands:\n\
       topology                         show sites, caches, proxies, origins\n\
       scenario [--sites a,b] [--repeats N] [--runtime rust|pjrt]\n\
                                        run the §4.1 benchmark (Figs 6-8, Table 3)\n\
       campaign [--jobs N] [--sites a,b] [--window SECS] [--zipf S]\n\
                [--catalog N] [--method stash|http] [--seed S]\n\
                [--experiment NAME] [--background N] [--profile]\n\
                [--policy nearest|least-loaded|consistent-hash|tiered]\n\
                [--deadline-factor F] [--breaker on|off]\n\
                [--threads N] [--metrics-out PATH] [--trace N]\n\
                                        run N concurrent Poisson/Zipf jobs through\n\
                                        the session engine (coalescing, contention);\n\
                                        --policy picks the cache-selection rule;\n\
                                        --threads shards the engine across cores,\n\
                                        bit-identical to serial (default 1);\n\
                                        --profile prints allocator + monitoring\n\
                                        counters; --metrics-out writes metrics PATH\n\
                                        (JSON) + PATH.prom (Prometheus exposition);\n\
                                        --trace N dumps the last N sessions' phase\n\
                                        spans as JSONL next to the metrics\n\
       chaos    [campaign flags incl. --metrics-out/--trace]\n\
                [--kill-cache SITE [--down-at S] [--up-at S]]\n\
                [--cut-wan SITE [--cut-at S] [--heal-at S]]\n\
                [--degrade-origin N [--factor F] [--degrade-at S] [--restore-at S]]\n\
                [--slow-cache SITE:FACTOR [--slow-at S] [--restore-slow-at S]]\n\
                [--kill-redirector N [--redir-down-at S] [--redir-up-at S]]\n\
                [--profile degraded]\n\
                                        campaign with mid-transfer faults; sessions\n\
                                        fail over; prints the availability report\n\
                                        (default: single-cache outage at peak load);\n\
                                        --slow-cache is a gray failure (no death\n\
                                        event — only --deadline-factor/--breaker\n\
                                        defences can react); --profile degraded is\n\
                                        the canned 20x-slow-cache drill\n\
       check    [--scenario NAME] [--max-transitions N] [--replay I,J,K]\n\
                                        exhaustively model-check the session\n\
                                        protocol on small-scope scenarios: every\n\
                                        event interleaving, lost-wakeup / slot /\n\
                                        reservation / byte invariants at every\n\
                                        state; prints a replayable counterexample\n\
                                        trace on violation (--replay re-runs one)\n\
       sweep    [--preset smoke|proxy-vs-stash|policy|resilience] [--grid PATH.toml]\n\
                [--threads N] [--reps N] [--seed S] [--out-dir DIR]\n\
                [--policy NAME | --policies a,b,c] [--profile]\n\
                [--deadline-factor F] [--breaker on|off]\n\
                [--metrics-out PATH]\n\
                                        run a deterministic parameter grid in\n\
                                        parallel; writes BENCH_sweep.json, CSVs and\n\
                                        the proxy-vs-StashCache frontier report;\n\
                                        --policies sweeps cache-selection rules\n\
                                        (the policy preset runs all four);\n\
                                        the resilience preset pairs breaker on/off\n\
                                        under a gray failure and adds\n\
                                        BENCH_resilience.json;\n\
                                        --profile prints allocator counters\n\
       usage --days D [--jobs-per-hour J]\n\
                                        run a usage simulation (Tables 1-2, Fig 4)\n\
       report --all --out-dir DIR       regenerate every paper table/figure\n\
       init-config [PATH]               write an example federation TOML\n\
       live-demo                        run the real TCP/UDP federation on loopback\n\
     common flags:\n\
       --config PATH                    federation TOML (default: built-in paper topology)\n"
        .to_string()
}

fn print_help() {
    println!("{}", usage());
}

fn cmd_topology(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let mut t = report::Table::new(
        format!("Federation {:?} (Figure 2 deployment)", cfg.name),
        &["Site", "Lat", "Lon", "Workers", "Cache", "Proxy", "WAN Gbps"],
    );
    for s in &cfg.sites {
        t.row(vec![
            s.name.clone(),
            format!("{:.3}", s.lat),
            format!("{:.3}", s.lon),
            s.worker_slots.to_string(),
            s.cache.map_or("-".into(), |c| c.capacity.to_string()),
            s.proxy.map_or("-".into(), |p| p.capacity.to_string()),
            format!("{:.0}", s.links.wan_gbps),
        ]);
    }
    println!("{}", t.render());
    let mut o = report::Table::new("Origins", &["Name", "Site", "Prefix"]);
    for org in &cfg.origins {
        o.row(vec![org.name.clone(), org.site.clone(), org.prefix.clone()]);
    }
    println!("{}", o.render());
    println!(
        "redirectors: {} (round-robin HA)\nworkload experiments: {}",
        cfg.redirector_instances,
        cfg.workload.experiments.len()
    );
    Ok(())
}

fn cmd_scenario(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    let mut scenario_cfg = ScenarioConfig {
        repeats: flags.get_usize("repeats", 1)?,
        ..ScenarioConfig::default()
    };
    if let Some(sites) = flags.get("sites") {
        scenario_cfg.sites = sites.split(',').map(str::to_string).collect();
    }
    let mut fed = FedSim::build_with_backend(cfg, geo_backend(flags)?);
    let results = scenario::run_on(&mut fed, &scenario_cfg);
    println!("{}", paper::table3(&results).render());
    for site in &scenario_cfg.sites {
        let (chart, _) = paper::fig_site_performance(&results, site);
        println!("{chart}");
    }
    let (chart, _) = paper::fig8_small_file(&results);
    println!("{chart}");
    Ok(())
}

/// Validate workload references against the federation: every site
/// exists (typos get a clean error, not a worker panic), sites carry a
/// proxy when the http method is in play, and the experiment is known.
/// Shared by `campaign`, `chaos`, and `sweep`.
fn validate_workload_refs(
    cfg: &FederationConfig,
    sites: &[String],
    needs_proxy: bool,
    experiment: &str,
) -> Result<()> {
    for name in sites {
        let site = cfg
            .site(name)
            .ok_or_else(|| anyhow::anyhow!("unknown site {name:?} (see `stashcache topology`)"))?;
        if needs_proxy && site.proxy.is_none() {
            bail!("site {name:?} has no HTTP proxy (required by the http method)");
        }
    }
    if !cfg.workload.experiments.iter().any(|e| e.name == experiment) {
        bail!(
            "unknown experiment {experiment:?} (known: {})",
            cfg.workload
                .experiments
                .iter()
                .map(|e| e.name.as_str())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    Ok(())
}

/// Parse the campaign knobs shared by `campaign` and `chaos`.
fn parse_campaign(flags: &Flags, cfg: &FederationConfig) -> Result<CampaignConfig> {
    let mut ccfg = CampaignConfig::default();
    if let Some(sites) = flags.get("sites") {
        ccfg.sites = sites.split(',').map(str::to_string).collect();
    }
    ccfg.method = match flags.get("method").unwrap_or("stash") {
        "stash" => DownloadMethod::Stash,
        "http" => DownloadMethod::HttpProxy,
        other => bail!("--method must be stash|http, got {other:?}"),
    };
    let mut seen = std::collections::HashSet::new();
    for name in &ccfg.sites {
        if !seen.insert(name.clone()) {
            bail!("duplicate site {name:?} in --sites");
        }
    }
    ccfg.jobs = flags.get_usize("jobs", ccfg.jobs)?;
    if ccfg.jobs == 0 {
        bail!("--jobs must be at least 1");
    }
    ccfg.arrival_window_secs = flags.get_f64("window", ccfg.arrival_window_secs)?;
    if ccfg.arrival_window_secs <= 0.0 {
        bail!("--window must be positive (seconds)");
    }
    ccfg.zipf_s = flags.get_f64("zipf", ccfg.zipf_s)?;
    ccfg.catalog_files = flags.get_usize("catalog", ccfg.catalog_files as usize)? as u64;
    ccfg.background_flows = flags.get_usize("background", ccfg.background_flows)?;
    ccfg.seed = flags.get_usize("seed", ccfg.seed as usize)? as u64;
    ccfg.trace = flags.get_usize("trace", ccfg.trace)?;
    if let Some(exp) = flags.get("experiment") {
        ccfg.experiment = exp.to_string();
    }
    validate_workload_refs(
        cfg,
        &ccfg.sites,
        ccfg.method == DownloadMethod::HttpProxy,
        &ccfg.experiment,
    )?;
    Ok(ccfg)
}

/// `--profile`: one allocator-counter line (component-local
/// incremental max-min — see netsim::AllocStats and ARCHITECTURE.md).
/// Shared by `campaign`/`chaos` (one run) and `sweep` (trial totals).
fn allocator_profile_line(
    passes: u64,
    components: u64,
    refixed: u64,
    events: u64,
    peak: usize,
) -> String {
    let per_event = if events == 0 {
        0.0
    } else {
        refixed as f64 / events as f64
    };
    format!(
        "allocator: {passes} passes | {components} components touched | \
         {refixed} flows re-fixed ({per_event:.2} per event) | peak component {peak} flows"
    )
}

/// `--profile`: one monitoring-pipeline line next to the allocator
/// counters — collector join health and bus queue state, read back
/// from the telemetry registry.
fn print_monitoring_profile(reg: &MetricsRegistry) {
    println!(
        "monitoring: {} packets → {} reports | {} orphan closes | {} expired | \
         bus: {} published, {} compacted, depth {}",
        reg.counter_value("stashcache_collector_packets_total"),
        reg.counter_value("stashcache_collector_reports_published_total"),
        reg.counter_value("stashcache_collector_orphan_closes_total"),
        reg.counter_value("stashcache_collector_expired_entries_total"),
        reg.counter_value("stashcache_bus_published_total"),
        reg.counter_value("stashcache_bus_compacted_total"),
        reg.gauge_value("stashcache_bus_queue_depth").unwrap_or(0.0) as u64,
    );
}

/// `--metrics-out PATH` / `--trace N` export: `PATH` gets the
/// metrics JSON, `PATH.prom` the Prometheus-style exposition, and
/// `PATH.trace.jsonl` (or `trace.jsonl` without `--metrics-out`) the
/// span traces when any were kept. Shared by campaign/chaos/sweep.
fn write_telemetry_outputs(flags: &Flags, snap: &TelemetrySnapshot) -> Result<()> {
    let mut written: Vec<PathBuf> = Vec::new();
    if let Some(path) = flags.get("metrics-out") {
        let json_path = PathBuf::from(path);
        std::fs::write(&json_path, snap.to_json_string())
            .with_context(|| format!("writing metrics {json_path:?}"))?;
        let prom_path = json_path.with_extension("prom");
        std::fs::write(&prom_path, snap.exposition())
            .with_context(|| format!("writing exposition {prom_path:?}"))?;
        written.push(json_path.clone());
        written.push(prom_path);
        if !snap.traces.is_empty() {
            let trace_path = json_path.with_extension("trace.jsonl");
            std::fs::write(&trace_path, snap.trace_jsonl())
                .with_context(|| format!("writing trace {trace_path:?}"))?;
            written.push(trace_path);
        }
    } else if !snap.traces.is_empty() {
        let trace_path = PathBuf::from("trace.jsonl");
        std::fs::write(&trace_path, snap.trace_jsonl())
            .with_context(|| format!("writing trace {trace_path:?}"))?;
        written.push(trace_path);
    }
    for p in written {
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn print_allocator_profile(results: &CampaignResults) {
    let e = &results.engine;
    println!(
        "{}",
        allocator_profile_line(
            e.allocator_passes,
            e.components_touched,
            e.flows_refixed,
            results.events_processed,
            e.peak_component,
        )
    );
    print_epoch_profile(&results.epochs);
}

/// `--profile`: the epoch planner's outcome counters — how much of
/// the run was sharded, how often planning ran, and why it bailed.
/// All zeros on a serial (`--threads 1`) run.
fn print_epoch_profile(ep: &stashcache::federation::driver::EpochStats) {
    println!(
        "epochs: {} planned, {} engaged | sessions: {} sharded, {} serial | \
         {} probes skipped",
        ep.epochs_planned, ep.epochs_engaged, ep.sessions_sharded, ep.sessions_serial,
        ep.plans_skipped,
    );
    let bails = [
        ("pending-fault", ep.bail_pending_fault),
        ("wan-coupled", ep.bail_wan_coupled),
        ("policy-unstable", ep.bail_policy_unstable),
        ("below-threshold", ep.bail_below_threshold),
        ("resilience", ep.bail_resilience),
        ("other", ep.bail_other),
    ];
    let parts: Vec<String> = bails
        .iter()
        .filter(|(_, n)| *n > 0)
        .map(|(k, n)| format!("{k} {n}"))
        .collect();
    if !parts.is_empty() {
        println!("epoch bails: {}", parts.join(" | "));
    }
}

/// Render the per-site table and summary lines for a finished campaign.
fn print_campaign(ccfg: &CampaignConfig, results: &CampaignResults, wall: f64) {
    let mut per_site = report::Table::new(
        format!("Campaign: {} jobs, {} sites", ccfg.jobs, ccfg.sites.len()),
        &["Site", "Jobs", "Mean s", "p95 s", "Hit %"],
    );
    for site in &ccfg.sites {
        let recs: Vec<_> = results.records.iter().filter(|r| &r.site == site).collect();
        if recs.is_empty() {
            continue;
        }
        let mut secs: Vec<f64> = recs
            .iter()
            .map(|r| r.record.duration.as_secs_f64())
            .collect();
        let mean = stashcache::util::stats::mean(&secs);
        let p95 = stashcache::util::stats::percentiles(&mut secs, &[95.0])[0];
        let hits = recs.iter().filter(|r| r.record.cache_hit).count();
        per_site.row(vec![
            site.clone(),
            recs.len().to_string(),
            format!("{mean:.2}"),
            format!("{p95:.2}"),
            format!("{:.0}", 100.0 * hits as f64 / recs.len() as f64),
        ]);
    }
    println!("{}", per_site.render());

    let ps = results.duration_percentiles(&[50.0, 95.0, 99.0]);
    println!(
        "downloads {} | peak concurrent {} | coalesced joins {} | makespan {}",
        results.records.len(),
        results.peak_concurrent,
        results.coalesced_joins,
        results.makespan,
    );
    println!(
        "aggregate {:.0} Mbps | p50 {:.2}s p95 {:.2}s p99 {:.2}s",
        results.aggregate_mbps(),
        ps[0],
        ps[1],
        ps[2],
    );
    println!(
        "engine: {} events in {wall:.3}s wall = {:.0} events/s",
        results.events_processed,
        results.events_processed as f64 / wall.max(1e-9),
    );
}

fn cmd_campaign(flags: &Flags) -> Result<()> {
    let mut cfg = load_config(flags)?;
    apply_policy_flag(flags, &mut cfg)?;
    apply_resilience_flags(flags, &mut cfg)?;
    let ccfg = parse_campaign(flags, &cfg)?;
    // Default 1 = today's serial path byte-for-byte; N > 1 shards the
    // session engine across OS threads with bit-identical results.
    let threads = flags.get_usize("threads", 1)?.max(1);
    let wall_start = std::time::Instant::now();
    let results = campaign::run_threads(cfg, &ccfg, threads);
    print_campaign(&ccfg, &results, wall_start.elapsed().as_secs_f64());
    println!("{}", paper::phase_latency_table(&results.telemetry).render());
    if flags.has("profile") {
        print_allocator_profile(&results);
        print_monitoring_profile(&results.telemetry.registry);
    }
    write_telemetry_outputs(flags, &results.telemetry)?;
    Ok(())
}

/// `stashcache chaos`: a campaign with mid-transfer faults. With no
/// fault flags, runs the canonical drill — the first campaign site's
/// nearest cache dies at mid-window and never comes back; every
/// session fails over (or falls back to the origin) and the run still
/// completes every download.
fn cmd_chaos(flags: &Flags) -> Result<()> {
    let mut cfg = load_config(flags)?;
    apply_policy_flag(flags, &mut cfg)?;
    apply_resilience_flags(flags, &mut cfg)?;
    let ccfg = parse_campaign(flags, &cfg)?;
    // `--profile` doubles as the fault-profile selector here: bare
    // `--profile` (parsed as "true") keeps its campaign meaning of
    // allocator counters, `--profile degraded` picks the gray-failure
    // drill instead of the canonical kill drill.
    let (degraded, show_profile) = match flags.get("profile") {
        None => (false, false),
        Some("true") => (false, true),
        Some("degraded") => (true, false),
        Some(other) => bail!("--profile takes no value or `degraded`, got {other:?}"),
    };
    let mut fed = FedSim::build_with_backend(cfg, geo_backend(flags)?);
    let window = ccfg.arrival_window_secs;
    let mut faults = FaultTimeline::new();

    if let Some(site) = flags.get("kill-cache") {
        let idx = fed
            .topo
            .site_index(site)
            .ok_or_else(|| anyhow::anyhow!("unknown site {site:?}"))?;
        if !fed.caches.contains_key(&idx) {
            bail!("site {site:?} has no cache (see `stashcache topology`)");
        }
        let down_at = flags.get_f64("down-at", window * 0.5)?;
        let down = SimTime::from_secs_f64(down_at);
        if flags.has("up-at") {
            let up_at = flags.get_f64("up-at", 0.0)?;
            if up_at <= down_at {
                bail!("--up-at ({up_at}) must be after --down-at ({down_at})");
            }
            faults.cache_outage(idx, down, SimTime::from_secs_f64(up_at));
        } else {
            // No recovery: the cache stays dark for the whole run.
            faults.push(down, FaultKind::CacheDown { site: idx });
        }
    }
    if let Some(site) = flags.get("cut-wan") {
        let idx = fed
            .topo
            .site_index(site)
            .ok_or_else(|| anyhow::anyhow!("unknown site {site:?}"))?;
        let cut_at = flags.get_f64("cut-at", window * 0.25)?;
        let heal_at = flags.get_f64("heal-at", window * 0.75)?;
        if heal_at <= cut_at {
            bail!("--heal-at ({heal_at}) must be after --cut-at ({cut_at})");
        }
        faults.link_outage(
            fed.topo.wan_link(idx),
            SimTime::from_secs_f64(cut_at),
            SimTime::from_secs_f64(heal_at),
        );
    }
    if flags.has("degrade-origin") {
        let origin = flags.get_usize("degrade-origin", 0)?;
        if origin >= fed.origins.len() {
            bail!("origin index {origin} out of range (have {})", fed.origins.len());
        }
        let factor = flags.get_f64("factor", 0.1)?;
        if factor <= 0.0 || factor > 1.0 {
            bail!("--factor must be in (0, 1], got {factor}");
        }
        let degrade_at = flags.get_f64("degrade-at", 0.0)?;
        let restore_at = flags.get_f64("restore-at", window * 2.0)?;
        if restore_at <= degrade_at {
            bail!("--restore-at ({restore_at}) must be after --degrade-at ({degrade_at})");
        }
        faults.origin_brownout(
            origin,
            factor,
            SimTime::from_secs_f64(degrade_at),
            SimTime::from_secs_f64(restore_at),
        );
    }
    if let Some(spec) = flags.get("slow-cache") {
        // `SITE:FACTOR`, e.g. `--slow-cache syracuse:0.05` — the cache
        // keeps answering but serves at FACTOR of its provisioned rate.
        // A gray failure: no death event fires, so only the deadline /
        // breaker defences can route sessions around it.
        let (site, factor) = spec.split_once(':').ok_or_else(|| {
            anyhow::anyhow!("--slow-cache wants SITE:FACTOR, got {spec:?}")
        })?;
        let idx = fed
            .topo
            .site_index(site)
            .ok_or_else(|| anyhow::anyhow!("unknown site {site:?}"))?;
        if !fed.caches.contains_key(&idx) {
            bail!("site {site:?} has no cache (see `stashcache topology`)");
        }
        let factor: f64 = factor
            .parse()
            .with_context(|| format!("--slow-cache factor {factor:?} is not a number"))?;
        if factor <= 0.0 || factor > 1.0 {
            bail!("--slow-cache factor must be in (0, 1], got {factor}");
        }
        let slow_at = flags.get_f64("slow-at", window * 0.1)?;
        faults.push(
            SimTime::from_secs_f64(slow_at),
            FaultKind::CacheSlow { site: idx, factor },
        );
        if flags.has("restore-slow-at") {
            let restore_at = flags.get_f64("restore-slow-at", 0.0)?;
            if restore_at <= slow_at {
                bail!("--restore-slow-at ({restore_at}) must be after --slow-at ({slow_at})");
            }
            faults.push(
                SimTime::from_secs_f64(restore_at),
                FaultKind::CacheRestored { site: idx },
            );
        }
    }
    if flags.has("kill-redirector") {
        let instance = flags.get_usize("kill-redirector", 0)?;
        if instance >= fed.redirectors.instances.len() {
            bail!(
                "redirector index {instance} out of range (have {})",
                fed.redirectors.instances.len()
            );
        }
        let down_at = flags.get_f64("redir-down-at", 0.0)?;
        let up_at = flags.get_f64("redir-up-at", window)?;
        if up_at <= down_at {
            bail!("--redir-up-at ({up_at}) must be after --redir-down-at ({down_at})");
        }
        faults.redirector_outage(
            instance,
            SimTime::from_secs_f64(down_at),
            SimTime::from_secs_f64(up_at),
        );
    }
    if degraded {
        // The gray-failure drill: the first campaign site's nearest
        // cache slows to 5% of its rate early in the window and never
        // recovers. Pair with --deadline-factor / --breaker on to
        // watch the defences route sessions around it.
        let first_site = fed
            .topo
            .site_index(&ccfg.sites[0])
            .expect("site validated above");
        let victim = fed.nearest_cache_site(first_site);
        println!(
            "profile degraded: cache {} slows to 5% at t={:.1}s (no recovery)\n",
            fed.topo.site_name(victim),
            window * 0.1,
        );
        faults.push(
            SimTime::from_secs_f64(window * 0.1),
            FaultKind::CacheSlow {
                site: victim,
                factor: 0.05,
            },
        );
    }
    if faults.is_empty() {
        // The canonical drill: single-cache outage at peak load.
        let first_site = fed
            .topo
            .site_index(&ccfg.sites[0])
            .expect("site validated above");
        let victim = fed.nearest_cache_site(first_site);
        println!(
            "no fault flags given: killing cache {} at t={:.1}s (no recovery)\n",
            fed.topo.site_name(victim),
            window * 0.5,
        );
        faults.push(
            SimTime::from_secs_f64(window * 0.5),
            FaultKind::CacheDown { site: victim },
        );
    }

    let threads = flags.get_usize("threads", 1)?.max(1);
    let wall_start = std::time::Instant::now();
    let results = campaign::run_on_with_faults_threads(&mut fed, &ccfg, &faults, threads);
    print_campaign(&ccfg, &results.campaign, wall_start.elapsed().as_secs_f64());
    println!(
        "{}",
        paper::phase_latency_table(&results.campaign.telemetry).render()
    );
    if show_profile {
        print_allocator_profile(&results.campaign);
        print_monitoring_profile(&results.campaign.telemetry.registry);
    }
    write_telemetry_outputs(flags, &results.campaign.telemetry)?;
    println!("\nfault log:");
    for ev in &results.fault_log {
        println!("  {} {:?}", ev.at, ev.kind);
    }
    if fed.pending_faults() > 0 {
        println!(
            "  ({} scheduled fault(s) fell after the last completion and were not applied)",
            fed.pending_faults()
        );
    }
    if fed.resilience_armed() {
        println!(
            "resilience: {} deadline expir(y/ies) | {} corruption(s) detected",
            results.campaign.engine.deadline_expiries,
            results.campaign.engine.corruptions_detected,
        );
        if let Some(b) = &fed.breaker {
            println!(
                "breaker: {} trip(s) | {} reopen(s) | {} recover(y/ies) | {} cache(s) open at end",
                b.trips,
                b.reopens,
                b.recoveries,
                b.open_count(fed.now),
            );
        }
    }
    println!();
    println!("{}", paper::availability_table(&results.availability).render());
    // When space was reclaimed (the §1 claim is that this never breaks
    // a workflow — correlate these instants with the fault log above).
    let mut cache_sites: Vec<usize> = fed.caches.keys().copied().collect();
    cache_sites.sort_unstable();
    for site in cache_sites {
        let cache = &fed.caches[&site];
        if cache.eviction_log.is_empty() {
            continue;
        }
        let bytes: u64 = cache.eviction_log.iter().map(|s| s.bytes).sum();
        let files: u32 = cache.eviction_log.iter().map(|s| s.files).sum();
        println!(
            "evictions at {}: {} sweeps ({} files, {}) between {} and {}",
            fed.topo.site_name(site),
            cache.eviction_log.len(),
            files,
            stashcache::util::ByteSize(bytes),
            cache.eviction_log.first().expect("non-empty").at,
            cache.eviction_log.last().expect("non-empty").at,
        );
    }
    Ok(())
}

/// `stashcache check`: exhaustively model-check the session protocol
/// on the built-in small-scope scenarios (see the `mc` module). Every
/// event interleaving of each tiny scenario is explored; the five
/// global invariants are asserted at every reached state; a violation
/// prints the full event trace plus the choice-index list that
/// `--replay` re-runs step by step. Exits non-zero on any violation.
fn cmd_check(flags: &Flags) -> Result<()> {
    use stashcache::mc::{builtin_scenarios, check_scenario, replay_trace};

    let filter = flags.get("scenario");
    let max = flags.get_usize("max-transitions", 200_000)?;
    let scenarios: Vec<_> = builtin_scenarios()
        .iter()
        .filter(|s| filter.is_none_or(|f| f == s.name))
        .collect();
    if scenarios.is_empty() {
        bail!(
            "unknown scenario {:?} (known: {})",
            filter.unwrap_or(""),
            builtin_scenarios()
                .iter()
                .map(|s| s.name)
                .collect::<Vec<_>>()
                .join(", ")
        );
    }

    if let Some(list) = flags.get("replay") {
        if scenarios.len() != 1 {
            bail!("--replay needs --scenario NAME to pick the scenario to re-run");
        }
        let choices = list
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .with_context(|| format!("--replay index {s:?} is not an integer"))
            })
            .collect::<Result<Vec<_>>>()?;
        let sc = scenarios[0];
        println!("replaying {} ({} steps):", sc.name, choices.len());
        let (trace, error) = replay_trace(sc, &choices);
        for line in &trace {
            println!("  {line}");
        }
        return match error {
            Some(msg) => bail!("replay failed: {msg}"),
            None => {
                println!("replay OK: every invariant held at every step");
                Ok(())
            }
        };
    }

    let mut failed = false;
    for sc in scenarios {
        println!("{}: {}", sc.name, sc.summary);
        let wall = std::time::Instant::now();
        let r = check_scenario(sc, max);
        println!(
            "  {} states | {} transitions | {} terminal state(s) | depth {}{} | {:.2}s",
            r.states,
            r.transitions,
            r.terminals,
            r.max_depth,
            if r.truncated {
                " | TRUNCATED (raise --max-transitions)"
            } else {
                ""
            },
            wall.elapsed().as_secs_f64(),
        );
        if let Some(v) = &r.violation {
            failed = true;
            let replay: Vec<String> = v.choices.iter().map(usize::to_string).collect();
            let replay = replay.join(",");
            println!("\n  VIOLATION: {}", v.invariant);
            println!("  counterexample ({} event(s)):", v.trace.len());
            for line in &v.trace {
                println!("    {line}");
            }
            println!(
                "  replay with: stashcache check --scenario {} --replay {replay}",
                sc.name
            );
            let path = format!("mc_counterexample_{}.txt", sc.name);
            let mut body = format!(
                "scenario: {}\ninvariant: {}\nreplay: {replay}\n\n",
                sc.name, v.invariant
            );
            for line in &v.trace {
                body.push_str(line);
                body.push('\n');
            }
            std::fs::write(&path, &body)
                .with_context(|| format!("writing counterexample {path:?}"))?;
            println!("  wrote {path}");
        }
    }
    if failed {
        bail!("model check found invariant violations");
    }
    println!("model check OK: every invariant held on every explored interleaving");
    Ok(())
}

/// `stashcache sweep`: expand a parameter grid into trials, run them
/// across OS threads (bit-identical to a single-threaded run), print
/// the per-cell summary + frontier, and write the sweep artifacts
/// (`BENCH_sweep.json`, CSVs, markdown frontier) into `--out-dir`
/// (default: the current directory, so CI gets a root artifact).
fn cmd_sweep(flags: &Flags) -> Result<()> {
    let cfg = load_config(flags)?;
    if flags.has("grid") && flags.has("preset") {
        bail!("--grid and --preset are mutually exclusive; pick one");
    }
    let mut grid = match flags.get("grid") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading grid TOML {path:?}"))?;
            GridSpec::from_toml(&text)?
        }
        None => match flags.get("preset").unwrap_or("smoke") {
            "smoke" => GridSpec::smoke(),
            "proxy-vs-stash" => GridSpec::proxy_vs_stash(),
            "policy" => GridSpec::policy_smoke(),
            "resilience" => GridSpec::resilience(),
            other => {
                bail!("--preset must be smoke|proxy-vs-stash|policy|resilience, got {other:?}")
            }
        },
    };
    if flags.has("reps") {
        grid.reps = flags.get_usize("reps", grid.reps)?;
    }
    if flags.has("seed") {
        grid.root_seed = flags.get_usize("seed", grid.root_seed as usize)? as u64;
    }
    if flags.has("policy") && flags.has("policies") {
        bail!("--policy and --policies are mutually exclusive; pick one");
    }
    if let Some(name) = flags.get("policy") {
        // Convenience alias: a single-policy sweep.
        grid.policies = vec![parse_policy(name)?];
    }
    if let Some(list) = flags.get("policies") {
        grid.policies = list
            .split(',')
            .map(parse_policy)
            .collect::<Result<Vec<_>>>()?;
    }
    // Convenience aliases: collapse a resilience axis to one value
    // (grid TOMLs use the `deadline_factors` / `breakers` arrays).
    if flags.has("deadline-factor") {
        grid.deadline_factors = vec![flags.get_f64("deadline-factor", 0.0)?];
    }
    if let Some(v) = flags.get("breaker") {
        grid.breakers = match v {
            "on" => vec![true],
            "off" => vec![false],
            other => bail!("--breaker must be on|off, got {other:?}"),
        };
    }
    grid.validate()?;
    validate_workload_refs(
        &cfg,
        &grid.sites,
        grid.methods.contains(&DownloadMethod::HttpProxy),
        &grid.experiment,
    )?;
    // Default to every core — trials are hermetic, so the pool scales
    // until the grid runs out of work (the runner caps workers at the
    // trial count).
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads = flags.get_usize("threads", default_threads)?.max(1);

    println!(
        "sweep {:?}: {} trials ({} cells × {} rep(s)){} on {} thread(s)",
        grid.name,
        grid.trial_count(),
        grid.trial_count() / grid.reps,
        grid.reps,
        if grid.table3_cell { " + Table 3 cell" } else { "" },
        threads,
    );
    let wall_start = std::time::Instant::now();
    let results = experiment::run_grid(&cfg, &grid, threads);
    let wall = wall_start.elapsed().as_secs_f64();

    println!("{}", experiment::artifact::cells_table(&results).render());
    println!("{}", paper::frontier_table(&results).render());
    if grid.policies.len() > 1 {
        println!("{}", paper::policy_table(&results).render());
    }
    if grid.breakers.len() > 1 {
        println!("{}", paper::resilience_table(&results).render());
    }
    if let Some(t3) = &results.table3 {
        println!("{}", paper::sweep_table3(t3).render());
    }
    let events: u64 = results.trials.iter().map(|t| t.events_processed).sum();
    println!(
        "{} downloads | {} engine events in {wall:.2}s wall = {:.0} events/s across {threads} thread(s)",
        results.total_downloads(),
        events,
        events as f64 / wall.max(1e-9),
    );
    if flags.has("profile") {
        let passes: u64 = results.trials.iter().map(|t| t.allocator_passes).sum();
        let comps: u64 = results.trials.iter().map(|t| t.components_touched).sum();
        let refixed: u64 = results.trials.iter().map(|t| t.flows_refixed).sum();
        let peak = results
            .trials
            .iter()
            .map(|t| t.peak_component)
            .max()
            .unwrap_or(0);
        println!("{}", allocator_profile_line(passes, comps, refixed, events, peak));
    }

    // Merge every trial's telemetry (counters add, sketches merge) in
    // grid order, so the sweep's export covers the whole grid.
    let mut merged = TelemetrySnapshot::default();
    for t in &results.trials {
        merged.merge(&t.telemetry);
    }
    write_telemetry_outputs(flags, &merged)?;

    let out_dir = PathBuf::from(flags.get("out-dir").unwrap_or("."));
    let written = experiment::artifact::write_all(&out_dir, &results)?;
    for path in written {
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_usage(flags: &Flags) -> Result<()> {
    let _cfg = load_config(flags)?;
    let ucfg = UsageConfig {
        days: flags.get_f64("days", 3.0)?,
        jobs_per_hour: Some(flags.get_f64("jobs-per-hour", 120.0)?),
        ..paper::default_usage_cfg()
    };
    let (t1, _) = paper::table1(&ucfg);
    println!("{}", t1.render());
    let (t2, _) = paper::table2(&ucfg);
    println!("{}", t2.render());
    Ok(())
}

fn cmd_report(flags: &Flags) -> Result<()> {
    let out_dir = PathBuf::from(flags.get("out-dir").unwrap_or("reports"));
    let all = flags.has("all");
    let which = flags.get("only").unwrap_or("");
    let want = |name: &str| all || which.split(',').any(|w| w == name);
    std::fs::create_dir_all(&out_dir)?;

    if want("table1") || want("table2") {
        let ucfg = paper::default_usage_cfg();
        if want("table1") {
            let (t, _) = paper::table1(&ucfg);
            report::write_artifact(&out_dir, "table1.txt", &t.render())?;
            report::write_artifact(&out_dir, "table1.csv", &t.to_csv())?;
            println!("{}", t.render());
        }
        if want("table2") {
            let (t, _) = paper::table2(&ucfg);
            report::write_artifact(&out_dir, "table2.txt", &t.render())?;
            report::write_artifact(&out_dir, "table2.csv", &t.to_csv())?;
            println!("{}", t.render());
        }
    }
    if want("table3") || want("fig6") || want("fig7") || want("fig8") {
        let results = paper::run_scenario();
        if want("table3") {
            let t = paper::table3(&results);
            report::write_artifact(&out_dir, "table3.txt", &t.render())?;
            report::write_artifact(&out_dir, "table3.csv", &t.to_csv())?;
            println!("{}", t.render());
        }
        for (fig, site) in [("fig6", "colorado"), ("fig7", "syracuse")] {
            if want(fig) {
                let (chart, csv) = paper::fig_site_performance(&results, site);
                report::write_artifact(&out_dir, &format!("{fig}_{site}.txt"), &chart)?;
                report::write_artifact(&out_dir, &format!("{fig}_{site}.csv"), &csv.to_csv())?;
                println!("{chart}");
            }
        }
        if want("fig8") {
            let (chart, csv) = paper::fig8_small_file(&results);
            report::write_artifact(&out_dir, "fig8.txt", &chart)?;
            report::write_artifact(&out_dir, "fig8.csv", &csv.to_csv())?;
            println!("{chart}");
        }
    }
    if want("fig4") {
        let (chart, csv) = paper::fig4(364.0, 0.6);
        report::write_artifact(&out_dir, "fig4.txt", &chart)?;
        report::write_artifact(&out_dir, "fig4.csv", &csv.to_csv())?;
        println!("{chart}");
    }
    if want("fig5") {
        let (chart, csv, _) = paper::fig5(2.0, 80.0);
        report::write_artifact(&out_dir, "fig5.txt", &chart)?;
        report::write_artifact(&out_dir, "fig5.csv", &csv.to_csv())?;
        println!("{chart}");
    }
    println!("reports written to {}", out_dir.display());
    Ok(())
}

fn cmd_init_config(flags: &Flags) -> Result<()> {
    let path = flags
        .positional
        .first()
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("federation.toml"));
    std::fs::write(&path, defaults::example_toml())?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_live_demo(_flags: &Flags) -> Result<()> {
    use stashcache::config::CacheConfig;
    use stashcache::live::{
        client::LiveCacheEndpoint, stashcp_live, CollectorDaemon, LiveCache, LiveOrigin,
        LiveRedirector,
    };
    use stashcache::util::ByteSize;

    println!("starting live federation on loopback...");
    let files: Vec<(&str, u64, u64)> = vec![
        ("/ospool/demo/input-a.dat", 4_000_000, 1),
        ("/ospool/demo/input-b.dat", 9_500_000, 1),
    ];
    let origin = LiveOrigin::start("stash-origin", "/ospool/demo", &files)?;
    println!("  origin      {}", origin.addr);
    let redirector =
        LiveRedirector::start(vec![("/ospool/demo".into(), origin.addr.clone())])?;
    println!("  redirector  {}", redirector.addr);
    let monitor = CollectorDaemon::start(vec![(0, "cache-nebraska".into()), (1, "cache-chicago".into())])?;
    println!("  collector   {} (UDP)", monitor.addr);

    let cache_cfg = CacheConfig {
        capacity: ByteSize::gb(1),
        chunk_size: ByteSize::mb(4),
        ..Default::default()
    };
    let c1 = LiveCache::start(
        "cache-nebraska",
        0,
        cache_cfg,
        redirector.addr.clone(),
        monitor.addr.clone(),
    )?;
    let c2 = LiveCache::start(
        "cache-chicago",
        1,
        cache_cfg,
        redirector.addr.clone(),
        monitor.addr.clone(),
    )?;
    println!("  caches      {} {}", c1.addr, c2.addr);

    let endpoints = vec![
        LiveCacheEndpoint {
            site: stashcache::geoip::CacheSite {
                name: "nebraska".into(),
                lat: 40.8202,
                lon: -96.7005,
            },
            addr: c1.addr.clone(),
        },
        LiveCacheEndpoint {
            site: stashcache::geoip::CacheSite {
                name: "chicago".into(),
                lat: 41.7886,
                lon: -87.5987,
            },
            addr: c2.addr.clone(),
        },
    ];
    for (path, size, _) in &files {
        for pass in ["cold", "hot "] {
            let t = stashcp_live(path, 39.7, -104.9, &endpoints)
                .map_err(|e| anyhow::anyhow!(e))?;
            println!(
                "  stashcp {path} ({size}B) via {}: {pass} verified={} in {:?}",
                t.cache_used, t.verified, t.wall
            );
        }
    }
    // Let the UDP close packets land.
    std::thread::sleep(std::time::Duration::from_millis(300));
    println!(
        "  monitoring: {} transfer reports, demo usage = {:?} bytes",
        monitor.reports(),
        monitor.experiment_bytes("demo")
    );
    println!("live demo OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse_forms() {
        let f = Flags::parse(&[
            "--days".into(),
            "3".into(),
            "--all".into(),
            "--out-dir=reports".into(),
            "pos".into(),
        ])
        .unwrap();
        assert_eq!(f.get_f64("days", 0.0).unwrap(), 3.0);
        assert!(f.has("all"));
        assert_eq!(f.get("out-dir"), Some("reports"));
        assert_eq!(f.positional, vec!["pos"]);
    }

    #[test]
    fn bad_number_errors() {
        let f = Flags::parse(&["--days".into(), "abc".into()]).unwrap();
        assert!(f.get_f64("days", 0.0).is_err());
    }
}
