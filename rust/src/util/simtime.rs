//! Simulated time. Integer microseconds since simulation epoch, so the
//! discrete-event engine is exactly deterministic (no float drift in
//! event ordering).

use std::fmt;

/// A point in simulated time (microseconds since epoch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

/// A span of simulated time (microseconds).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Duration(pub u64);

pub const MICROS_PER_SEC: u64 = 1_000_000;

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs_f64(s: f64) -> Self {
        SimTime((s * MICROS_PER_SEC as f64).round() as u64)
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / MICROS_PER_SEC as f64
    }
    pub fn as_micros(self) -> u64 {
        self.0
    }
    pub fn saturating_sub(self, other: SimTime) -> Duration {
        Duration(self.0.saturating_sub(other.0))
    }
}

impl Duration {
    pub const ZERO: Duration = Duration(0);

    pub fn from_secs_f64(s: f64) -> Self {
        assert!(s >= 0.0 && s.is_finite(), "negative/NaN duration: {s}");
        Duration((s * MICROS_PER_SEC as f64).round() as u64)
    }
    pub const fn from_secs(s: u64) -> Self {
        Duration(s * MICROS_PER_SEC)
    }
    pub const fn from_millis(ms: u64) -> Self {
        Duration(ms * 1_000)
    }
    pub const fn from_micros(us: u64) -> Self {
        Duration(us)
    }
    pub const fn from_mins(m: u64) -> Self {
        Duration(m * 60 * MICROS_PER_SEC)
    }
    pub const fn from_hours(h: u64) -> Self {
        Duration(h * 3_600 * MICROS_PER_SEC)
    }
    pub const fn from_days(d: u64) -> Self {
        Duration(d * 86_400 * MICROS_PER_SEC)
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / MICROS_PER_SEC as f64
    }
    pub fn as_micros(self) -> u64 {
        self.0
    }
}

impl std::ops::Add<Duration> for SimTime {
    type Output = SimTime;
    fn add(self, rhs: Duration) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign<Duration> for SimTime {
    fn add_assign(&mut self, rhs: Duration) {
        self.0 += rhs.0;
    }
}

impl std::ops::Add for Duration {
    type Output = Duration;
    fn add(self, rhs: Duration) -> Duration {
        Duration(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for Duration {
    fn add_assign(&mut self, rhs: Duration) {
        self.0 += rhs.0;
    }
}

impl std::ops::Sub for SimTime {
    type Output = Duration;
    /// Panics if `rhs` is later than `self` (events out of order).
    fn sub(self, rhs: SimTime) -> Duration {
        Duration(
            self.0
                .checked_sub(rhs.0)
                .expect("SimTime subtraction underflow"),
        )
    }
}

impl std::ops::Mul<u64> for Duration {
    type Output = Duration;
    fn mul(self, rhs: u64) -> Duration {
        Duration(self.0 * rhs)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t={:.6}s", self.as_secs_f64())
    }
}

impl fmt::Display for Duration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.as_secs_f64();
        if s < 1e-3 {
            write!(f, "{:.1}us", self.0 as f64)
        } else if s < 1.0 {
            write!(f, "{:.3}ms", s * 1e3)
        } else if s < 120.0 {
            write!(f, "{s:.3}s")
        } else if s < 7200.0 {
            write!(f, "{:.1}min", s / 60.0)
        } else if s < 86_400.0 * 2.0 {
            write!(f, "{:.1}h", s / 3600.0)
        } else {
            write!(f, "{:.1}d", s / 86_400.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        let t = SimTime::from_secs_f64(1.5);
        assert_eq!(t.0, 1_500_000);
        assert!((t.as_secs_f64() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn ordering_is_integer_exact() {
        let a = SimTime::from_secs_f64(0.1) + Duration::from_secs_f64(0.2);
        let b = SimTime::from_secs_f64(0.3);
        assert_eq!(a, b); // would fail with raw f64
    }

    #[test]
    fn arithmetic() {
        let t = SimTime::ZERO + Duration::from_millis(250) + Duration::from_micros(1);
        assert_eq!(t.0, 250_001);
        assert_eq!((t - SimTime::ZERO).as_micros(), 250_001);
        assert_eq!(Duration::from_secs(2) * 3, Duration::from_secs(6));
        assert_eq!(Duration::from_days(1).as_micros(), 86_400 * MICROS_PER_SEC);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        let _ = SimTime(0) - SimTime(1);
    }

    #[test]
    fn display_forms() {
        assert_eq!(Duration::from_micros(5).to_string(), "5.0us");
        assert_eq!(Duration::from_millis(12).to_string(), "12.000ms");
        assert_eq!(Duration::from_secs(3).to_string(), "3.000s");
        assert_eq!(Duration::from_mins(10).to_string(), "10.0min");
        assert_eq!(Duration::from_days(3).to_string(), "3.0d");
    }
}
