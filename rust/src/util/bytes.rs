//! Byte quantities with human-friendly parsing and formatting.
//!
//! The paper reports sizes in mixed units (5.797KB, 467.852MB, 2.335GB,
//! 1.079PB); this module provides exact-u64 storage with the decimal
//! (SI) units the paper uses.

use std::fmt;
use std::str::FromStr;

/// A byte quantity. Stored exactly as `u64` bytes.
///
/// Formatting follows the paper's convention: decimal units (1 KB =
/// 1000 B), three fractional digits, largest unit that keeps the
/// mantissa >= 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct ByteSize(pub u64);

pub const KB: u64 = 1_000;
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;
pub const TB: u64 = 1_000_000_000_000;
pub const PB: u64 = 1_000_000_000_000_000;

impl ByteSize {
    pub const fn bytes(n: u64) -> Self {
        ByteSize(n)
    }
    pub const fn kb(n: u64) -> Self {
        ByteSize(n * KB)
    }
    pub const fn mb(n: u64) -> Self {
        ByteSize(n * MB)
    }
    pub const fn gb(n: u64) -> Self {
        ByteSize(n * GB)
    }
    pub const fn tb(n: u64) -> Self {
        ByteSize(n * TB)
    }

    /// Construct from a fractional count of a unit, e.g. `from_f64(2.335, GB)`.
    pub fn from_f64(value: f64, unit: u64) -> Self {
        ByteSize((value * unit as f64).round() as u64)
    }

    pub const fn as_u64(self) -> u64 {
        self.0
    }
    pub fn as_f64(self) -> f64 {
        self.0 as f64
    }

    /// Saturating subtraction.
    pub fn saturating_sub(self, other: ByteSize) -> ByteSize {
        ByteSize(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for ByteSize {
    type Output = ByteSize;
    fn add(self, rhs: ByteSize) -> ByteSize {
        ByteSize(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for ByteSize {
    fn add_assign(&mut self, rhs: ByteSize) {
        self.0 += rhs.0;
    }
}

impl std::ops::Sub for ByteSize {
    type Output = ByteSize;
    fn sub(self, rhs: ByteSize) -> ByteSize {
        ByteSize(self.0 - rhs.0)
    }
}

impl std::iter::Sum for ByteSize {
    fn sum<I: Iterator<Item = ByteSize>>(iter: I) -> ByteSize {
        ByteSize(iter.map(|b| b.0).sum())
    }
}

impl fmt::Display for ByteSize {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.0;
        let (value, unit) = if b >= PB {
            (b as f64 / PB as f64, "PB")
        } else if b >= TB {
            (b as f64 / TB as f64, "TB")
        } else if b >= GB {
            (b as f64 / GB as f64, "GB")
        } else if b >= MB {
            (b as f64 / MB as f64, "MB")
        } else if b >= KB {
            (b as f64 / KB as f64, "KB")
        } else {
            return write!(f, "{b}B");
        };
        write!(f, "{value:.3}{unit}")
    }
}

/// Error parsing a byte-size string.
#[derive(Debug, PartialEq)]
pub struct ParseByteSizeError(pub String);

impl fmt::Display for ParseByteSizeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid byte size {:?}", self.0)
    }
}

impl std::error::Error for ParseByteSizeError {}

impl FromStr for ByteSize {
    type Err = ParseByteSizeError;

    /// Parses `"2.335GB"`, `"24MB"`, `"512 KB"`, `"97"` (bytes).
    /// Units are decimal (SI); `KiB`/`MiB`/`GiB` binary forms are also
    /// accepted for config convenience.
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        let t = s.trim();
        let split = t
            .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-'))
            .unwrap_or(t.len());
        let (num, unit) = t.split_at(split);
        let value: f64 = num
            .trim()
            .parse()
            .map_err(|_| ParseByteSizeError(s.to_string()))?;
        if value < 0.0 {
            return Err(ParseByteSizeError(s.to_string()));
        }
        let mult = match unit.trim().to_ascii_lowercase().as_str() {
            "" | "b" => 1,
            "kb" | "k" => KB,
            "mb" | "m" => MB,
            "gb" | "g" => GB,
            "tb" | "t" => TB,
            "pb" | "p" => PB,
            "kib" => 1 << 10,
            "mib" => 1 << 20,
            "gib" => 1 << 30,
            "tib" => 1u64 << 40,
            _ => return Err(ParseByteSizeError(s.to_string())),
        };
        Ok(ByteSize((value * mult as f64).round() as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_plain_bytes() {
        assert_eq!("97".parse::<ByteSize>().unwrap(), ByteSize(97));
        assert_eq!("0".parse::<ByteSize>().unwrap(), ByteSize(0));
    }

    #[test]
    fn parse_si_units() {
        assert_eq!("5.797KB".parse::<ByteSize>().unwrap(), ByteSize(5_797));
        assert_eq!("24MB".parse::<ByteSize>().unwrap(), ByteSize(24 * MB));
        assert_eq!(
            "2.335GB".parse::<ByteSize>().unwrap(),
            ByteSize(2_335_000_000)
        );
        assert_eq!("1.079PB".parse::<ByteSize>().unwrap(), ByteSize(1_079 * TB));
    }

    #[test]
    fn parse_binary_units() {
        assert_eq!("1KiB".parse::<ByteSize>().unwrap(), ByteSize(1024));
        assert_eq!("2MiB".parse::<ByteSize>().unwrap(), ByteSize(2 << 20));
    }

    #[test]
    fn parse_whitespace_and_case() {
        assert_eq!(" 512 kb ".parse::<ByteSize>().unwrap(), ByteSize(512 * KB));
        assert_eq!("10gb".parse::<ByteSize>().unwrap(), ByteSize(10 * GB));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!("".parse::<ByteSize>().is_err());
        assert!("12QB".parse::<ByteSize>().is_err());
        assert!("-5MB".parse::<ByteSize>().is_err());
        assert!("MB".parse::<ByteSize>().is_err());
    }

    #[test]
    fn display_matches_paper_style() {
        assert_eq!(ByteSize(5_797).to_string(), "5.797KB");
        assert_eq!(ByteSize(467_852_000).to_string(), "467.852MB");
        assert_eq!(ByteSize(2_335_000_000).to_string(), "2.335GB");
        assert_eq!(ByteSize(1_079 * TB).to_string(), "1.079PB");
        assert_eq!(ByteSize(12).to_string(), "12B");
    }

    #[test]
    fn roundtrip_display_parse() {
        for &n in &[0u64, 1, 999, 5_797, 24 * MB, 2_335_000_000, 10 * GB] {
            let shown = ByteSize(n).to_string();
            let back: ByteSize = shown.parse().unwrap();
            // Display rounds to 3 digits; allow 0.1% slack.
            let err = (back.0 as i128 - n as i128).unsigned_abs() as u64;
            assert!(err <= n / 1000 + 1, "{n} -> {shown} -> {back:?}");
        }
    }

    #[test]
    fn arithmetic() {
        assert_eq!(ByteSize::mb(1) + ByteSize::kb(500), ByteSize(1_500_000));
        assert_eq!(ByteSize::gb(1).saturating_sub(ByteSize::tb(1)), ByteSize(0));
        let total: ByteSize = [ByteSize::kb(1), ByteSize::kb(2)].into_iter().sum();
        assert_eq!(total, ByteSize::kb(3));
    }
}
