//! Deterministic PCG-XSH-RR 64/32-based PRNG (two streams combined for a
//! 64-bit output). The offline crate set has no `rand`; every stochastic
//! component (workload generation, jitter, failure injection) draws from
//! this generator so whole-federation runs are reproducible from a seed.

/// A 64-bit-output permuted congruential generator.
///
/// This is PCG-XSL-RR 128/64 ("pcg64") with the standard multiplier and
/// a caller-chosen stream. Passes practical statistical needs for
/// simulation workloads; not cryptographic.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Distinct streams
    /// with the same seed are independent, which lets each simulated
    /// component own a private RNG derived from the run seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut pcg = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        pcg.next_u64();
        pcg.state = pcg.state.wrapping_add(seed as u128);
        pcg.next_u64();
        pcg
    }

    /// Derive a child generator for a named subcomponent.
    pub fn fork(&mut self, label: &str) -> Pcg64 {
        let h = crate::util::fnv1a(label.as_bytes());
        Pcg64::new(self.next_u64() ^ h, h | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range {lo}..{hi}");
        let span = hi - lo;
        // Lemire's method with rejection for unbiased bounded integers.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (span as u128);
            let l = m as u64;
            if l >= span.wrapping_neg() % span {
                return lo + (m >> 64) as u64;
            }
        }
    }

    /// Uniform float in `[lo, hi)`.
    pub fn gen_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// `true` with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (one value; discards pair partner
    /// to keep the call stateless).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given parameters of the underlying normal.
    pub fn gen_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gen_normal()).exp()
    }

    /// Exponential with the given rate (mean `1/rate`).
    pub fn gen_exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.next_f64().max(1e-300).ln() / rate
    }

    /// Choose a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(0, items.len() as u64) as usize]
    }

    /// Sample an index from unnormalised weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all weights zero");
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(0, i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1, 0);
        let mut b = Pcg64::new(2, 0);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(1, 0);
        let mut b = Pcg64::new(1, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_is_deterministic_and_distinct() {
        let mut root1 = Pcg64::new(9, 9);
        let mut root2 = Pcg64::new(9, 9);
        let mut c1 = root1.fork("cache");
        let mut c2 = root2.fork("cache");
        assert_eq!(c1.next_u64(), c2.next_u64());
        let mut r1 = Pcg64::new(9, 9);
        let mut o = r1.fork("origin");
        let mut c = Pcg64::new(9, 9).fork("cache");
        assert_ne!(o.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(3, 3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Pcg64::new(4, 4);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = rng.gen_range(5, 15);
            assert!((5..15).contains(&v));
            seen[(v - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range hit");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5, 5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg64::new(6, 6);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.5..3.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(7, 7);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffled order changed");
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg64::new(8, 8);
        let n = 50_000;
        let mean = (0..n).map(|_| rng.gen_exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
