//! Descriptive statistics used by the monitoring aggregator and the
//! report generators: percentiles (Table 2), means, and a streaming
//! Welford accumulator for transfer-rate summaries.

/// Percentile of a sample by linear interpolation between closest ranks
/// (the same convention as `numpy.percentile(..., method="linear")`,
/// which the paper's analysis notebooks used).
///
/// `p` in `[0, 100]`. Panics on empty input.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p));
    debug_assert!(
        sorted.windows(2).all(|w| w[0] <= w[1]),
        "input must be sorted"
    );
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Compute several percentiles at once over unsorted data.
pub fn percentiles(data: &mut [f64], ps: &[f64]) -> Vec<f64> {
    data.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile data"));
    ps.iter().map(|&p| percentile(data, p)).collect()
}

/// Inverse standard-normal CDF (probit), Acklam's rational
/// approximation — relative error < 1.15e-9 over (0, 1).
pub fn probit(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "probit domain: {p}");
    let p = p.clamp(1e-300, 1.0 - 1e-16);
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

pub fn mean(data: &[f64]) -> f64 {
    assert!(!data.is_empty());
    data.iter().sum::<f64>() / data.len() as f64
}

pub fn geometric_mean(data: &[f64]) -> f64 {
    assert!(!data.is_empty());
    let log_sum: f64 = data.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / data.len() as f64).exp()
}

/// Sample standard deviation (n−1 denominator, Bessel-corrected).
/// Zero for fewer than two samples. Panics on empty input.
pub fn stddev(data: &[f64]) -> f64 {
    assert!(!data.is_empty());
    if data.len() < 2 {
        return 0.0;
    }
    let m = mean(data);
    let ss: f64 = data.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (data.len() - 1) as f64).sqrt()
}

/// `(mean, half-width)` of a normal-approximation confidence interval
/// at level `confidence` in (0, 1): `mean ± z · s / √n` with
/// `z = probit((1 + confidence) / 2)`. The half-width is zero for
/// fewer than two samples (no spread information). Multi-rep sweep
/// cells report `mean ± half`.
pub fn confidence_interval(data: &[f64], confidence: f64) -> (f64, f64) {
    assert!(
        confidence > 0.0 && confidence < 1.0,
        "confidence must be in (0, 1), got {confidence}"
    );
    let m = mean(data);
    if data.len() < 2 {
        return (m, 0.0);
    }
    let z = probit((1.0 + confidence) / 2.0);
    let half = z * stddev(data) / (data.len() as f64).sqrt();
    (m, half)
}

/// Streaming mean/variance/min/max (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Sum of squared deviations from the mean (Welford's "M2" term;
    /// `variance() == m2() / count()`). Exposed so parallel reductions
    /// can be checked against hand-computed values.
    pub fn m2(&self) -> f64 {
        self.m2
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Rebuild an accumulator from previously extracted parts — the
    /// inverse of the accessors, for shipping summaries across threads
    /// (or serialization boundaries) without the raw samples.
    pub fn from_parts(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> Self {
        Welford { n, mean, m2, min, max }
    }

    /// Population variance. Zero for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge another accumulator (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let d = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&d, 0.0), 1.0);
        assert_eq!(percentile(&d, 100.0), 4.0);
        assert_eq!(percentile(&d, 50.0), 2.5);
        assert!((percentile(&d, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 95.0), 7.0);
    }

    #[test]
    fn percentiles_sorts() {
        let mut d = [3.0, 1.0, 2.0];
        let ps = percentiles(&mut d, &[0.0, 50.0, 100.0]);
        assert_eq!(ps, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn percentile_empty_panics() {
        percentile(&[], 50.0);
    }

    #[test]
    fn probit_known_values() {
        assert!((probit(0.5)).abs() < 1e-9);
        assert!((probit(0.975) - 1.959964).abs() < 1e-5);
        assert!((probit(0.025) + 1.959964).abs() < 1e-5);
        assert!((probit(0.8413447) - 1.0).abs() < 1e-4);
        assert!(probit(1e-10) < -6.0);
    }

    #[test]
    fn probit_inverts_normal_cdf() {
        // Φ(probit(p)) ≈ p via the error-function-free check: sample
        // the normal via Box-Muller and compare empirical quantiles.
        use crate::util::Pcg64;
        let mut rng = Pcg64::new(3, 3);
        let mut xs: Vec<f64> = (0..200_000).map(|_| rng.gen_normal()).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.1, 0.25, 0.5, 0.9] {
            let emp = xs[(p * xs.len() as f64) as usize];
            assert!((probit(p) - emp).abs() < 0.02, "p={p}: {} vs {emp}", probit(p));
        }
    }

    #[test]
    fn means() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((geometric_mean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_known_values() {
        // Classic textbook sample: sample stddev = sqrt(32/7).
        let d = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&d) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        // Constant data has zero spread; singleton reports zero.
        assert_eq!(stddev(&[3.0, 3.0, 3.0]), 0.0);
        assert_eq!(stddev(&[42.0]), 0.0);
    }

    #[test]
    fn confidence_interval_known_values() {
        // mean 12, sample stddev 2, n = 3:
        // half = 1.959964 * 2 / sqrt(3) = 2.263172...
        let d = [10.0, 12.0, 14.0];
        let (m, half) = confidence_interval(&d, 0.95);
        assert!((m - 12.0).abs() < 1e-12);
        assert!((half - 1.959964 * 2.0 / 3.0f64.sqrt()).abs() < 1e-4);
        // Wider level ⇒ wider interval.
        let (_, half99) = confidence_interval(&d, 0.99);
        assert!(half99 > half);
        // One sample ⇒ degenerate interval.
        assert_eq!(confidence_interval(&[5.0], 0.95), (5.0, 0.0));
    }

    #[test]
    #[should_panic(expected = "confidence must be in (0, 1)")]
    fn confidence_level_domain_checked() {
        confidence_interval(&[1.0, 2.0], 1.0);
    }

    #[test]
    fn welford_matches_direct() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut w = Welford::new();
        for &x in &data {
            w.push(x);
        }
        let m = mean(&data);
        let var = data.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / data.len() as f64;
        assert!((w.mean() - m).abs() < 1e-9);
        assert!((w.variance() - var).abs() < 1e-9);
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let data: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64).collect();
        let mut whole = Welford::new();
        for &x in &data {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &data[..400] {
            a.push(x);
        }
        for &x in &data[400..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-6);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn welford_merge_known_values() {
        // Hand-computed Chan et al. merge, exact in f64:
        //   left  = {1, 3}:  n=2, mean=2, M2=2
        //   right = {4, 8}:  n=2, mean=6, M2=8
        //   union = {1,3,4,8}: n=4, mean=4, M2 = 9+1+0+16 = 26
        let mut left = Welford::new();
        left.push(1.0);
        left.push(3.0);
        let mut right = Welford::new();
        right.push(4.0);
        right.push(8.0);
        assert_eq!((left.count(), left.mean(), left.m2()), (2, 2.0, 2.0));
        assert_eq!((right.count(), right.mean(), right.m2()), (2, 6.0, 8.0));
        left.merge(&right);
        assert_eq!(left.count(), 4);
        assert_eq!(left.mean(), 4.0);
        assert_eq!(left.m2(), 26.0);
        assert_eq!(left.min(), 1.0);
        assert_eq!(left.max(), 8.0);
    }

    #[test]
    fn welford_merge_is_order_independent() {
        // The shard-merge reduction must not depend on which shard's
        // summary arrives first: A∪B == B∪A for these exact parts.
        let a = Welford::from_parts(2, 2.0, 2.0, 1.0, 3.0);
        let b = Welford::from_parts(2, 6.0, 8.0, 4.0, 8.0);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.count(), ba.count());
        assert_eq!(ab.mean(), ba.mean());
        assert_eq!(ab.m2(), ba.m2());
        assert_eq!((ab.min(), ab.max()), (ba.min(), ba.max()));
    }

    #[test]
    fn welford_from_parts_round_trips() {
        let mut w = Welford::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.push(x);
        }
        let r = Welford::from_parts(w.count(), w.mean(), w.m2(), w.min(), w.max());
        assert_eq!(r.count(), w.count());
        assert_eq!(r.mean(), w.mean());
        assert_eq!(r.m2(), w.m2());
        assert_eq!(r.variance(), w.variance());
        assert_eq!((r.min(), r.max()), (w.min(), w.max()));
        // Merging into an empty accumulator is the identity.
        let mut empty = Welford::new();
        empty.merge(&r);
        assert_eq!(empty.mean(), w.mean());
        assert_eq!(empty.m2(), w.m2());
        let mut back = r.clone();
        back.merge(&Welford::new());
        assert_eq!(back.count(), w.count());
    }
}
