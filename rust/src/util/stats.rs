//! Descriptive statistics used by the monitoring aggregator and the
//! report generators: percentiles (Table 2), means, and a streaming
//! Welford accumulator for transfer-rate summaries.

/// Percentile of a sample by linear interpolation between closest ranks
/// (the same convention as `numpy.percentile(..., method="linear")`,
/// which the paper's analysis notebooks used).
///
/// `p` in `[0, 100]`. Panics on empty input.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p));
    debug_assert!(
        sorted.windows(2).all(|w| w[0] <= w[1]),
        "input must be sorted"
    );
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Compute several percentiles at once over unsorted data.
///
/// NaN samples are excluded before ranking (a zero-duration transfer
/// divides 0 bytes by 0 seconds and yields NaN rates; one bad sample
/// must not take down a whole campaign report). Panics only when no
/// finite-orderable sample remains (empty or all-NaN input).
pub fn percentiles(data: &mut [f64], ps: &[f64]) -> Vec<f64> {
    // Partition NaNs to the tail, then sort the clean prefix with the
    // IEEE total order (deterministic, never panics).
    let mut clean = data.len();
    let mut i = 0;
    while i < clean {
        if data[i].is_nan() {
            clean -= 1;
            data.swap(i, clean);
        } else {
            i += 1;
        }
    }
    let (prefix, _) = data.split_at_mut(clean);
    prefix.sort_by(|a, b| a.total_cmp(b));
    assert!(!prefix.is_empty(), "percentiles of empty/all-NaN sample");
    ps.iter().map(|&p| percentile(prefix, p)).collect()
}

/// Inverse standard-normal CDF (probit), Acklam's rational
/// approximation — relative error < 1.15e-9 over (0, 1).
pub fn probit(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "probit domain: {p}");
    let p = p.clamp(1e-300, 1.0 - 1e-16);
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

pub fn mean(data: &[f64]) -> f64 {
    assert!(!data.is_empty());
    data.iter().sum::<f64>() / data.len() as f64
}

pub fn geometric_mean(data: &[f64]) -> f64 {
    assert!(!data.is_empty());
    let log_sum: f64 = data.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / data.len() as f64).exp()
}

/// Sample standard deviation (n−1 denominator, Bessel-corrected).
/// Zero for fewer than two samples. Panics on empty input.
pub fn stddev(data: &[f64]) -> f64 {
    assert!(!data.is_empty());
    if data.len() < 2 {
        return 0.0;
    }
    let m = mean(data);
    let ss: f64 = data.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (data.len() - 1) as f64).sqrt()
}

/// `(mean, half-width)` of a normal-approximation confidence interval
/// at level `confidence` in (0, 1): `mean ± z · s / √n` with
/// `z = probit((1 + confidence) / 2)`. The half-width is zero for
/// fewer than two samples (no spread information). Multi-rep sweep
/// cells report `mean ± half`.
pub fn confidence_interval(data: &[f64], confidence: f64) -> (f64, f64) {
    assert!(
        confidence > 0.0 && confidence < 1.0,
        "confidence must be in (0, 1), got {confidence}"
    );
    let m = mean(data);
    if data.len() < 2 {
        return (m, 0.0);
    }
    let z = probit((1.0 + confidence) / 2.0);
    let half = z * stddev(data) / (data.len() as f64).sqrt();
    (m, half)
}

/// Streaming mean/variance/min/max (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Sum of squared deviations from the mean (Welford's "M2" term;
    /// `variance() == m2() / count()`). Exposed so parallel reductions
    /// can be checked against hand-computed values.
    pub fn m2(&self) -> f64 {
        self.m2
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Rebuild an accumulator from previously extracted parts — the
    /// inverse of the accessors, for shipping summaries across threads
    /// (or serialization boundaries) without the raw samples.
    pub fn from_parts(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> Self {
        Welford { n, mean, m2, min, max }
    }

    /// Population variance. Zero for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge another accumulator (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Buckets per octave in [`QuantileSketch`]: bucket boundaries grow by
/// γ = 2^(1/64) ≈ 1.0109, so within-bucket linear interpolation is
/// accurate to ~0.55% relative — comfortably inside the telemetry
/// layer's 2% acceptance band against exact [`percentile`].
const SKETCH_BUCKETS_PER_OCTAVE: f64 = 64.0;
/// `1 / ln γ`: multiply `ln x` by this to get the bucket index.
const SKETCH_INV_LN_GAMMA: f64 = SKETCH_BUCKETS_PER_OCTAVE / std::f64::consts::LN_2;
/// Bucket-index clamp. `e^(-2048/92.33) ≈ 2.4e-10` and
/// `e^(6143/92.33) ≈ 7e28`, so everything from sub-nanosecond
/// durations to astronomical byte counts lands inside the range;
/// values beyond it saturate into the edge buckets.
const SKETCH_MIN_IDX: i32 = -2048;
const SKETCH_MAX_IDX: i32 = 6143;

/// Online quantile sketch: a log-bucketed counting histogram with
/// bounded memory (one `u64` per occupied bucket) that answers
/// p50/p95/p99 without retaining samples.
///
/// Two properties matter for the telemetry layer:
///
/// * **Mergeable and order-independent** — the state is integer bucket
///   counts plus exact min/max, so `merge` is commutative and
///   associative and a sharded run folds to bit-identical state in any
///   order. Deliberately *no* running f64 sum is kept: float addition
///   is non-associative, and a sum would put the sketch back on the
///   bit-identity surface. [`Self::approx_sum`] derives a
///   deterministic total from the counts instead.
/// * **Bounded error** — buckets are geometric with ratio 2^(1/64)
///   (~1.1% wide); [`Self::quantile`] interpolates linearly inside the
///   winning bucket and clamps to the observed `[min, max]`.
///
/// Non-positive and NaN samples (zero-length phases are common) are
/// counted in a dedicated zero bucket so `n` still matches the number
/// of observations.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    n: u64,
    zeros: u64,
    min: f64,
    max: f64,
    /// Index of `counts[0]` on the global bucket scale (empty ⇒ unset).
    offset: i32,
    counts: Vec<u64>,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantileSketch {
    pub fn new() -> Self {
        QuantileSketch {
            n: 0,
            zeros: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            offset: 0,
            counts: Vec::new(),
        }
    }

    fn bucket_index(x: f64) -> i32 {
        // f64→i32 casts saturate, so +∞ clamps to SKETCH_MAX_IDX here.
        ((x.ln() * SKETCH_INV_LN_GAMMA).floor() as i32).clamp(SKETCH_MIN_IDX, SKETCH_MAX_IDX)
    }

    fn bucket_lo(idx: i32) -> f64 {
        (idx as f64 / SKETCH_INV_LN_GAMMA).exp()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        if x.is_nan() || x <= 0.0 {
            // Zero-length spans (and degenerate NaN rates) count as 0.
            self.zeros += 1;
            self.min = self.min.min(0.0);
            self.max = self.max.max(0.0);
            return;
        }
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.bump(Self::bucket_index(x), 1);
    }

    fn bump(&mut self, idx: i32, by: u64) {
        if self.counts.is_empty() {
            self.offset = idx;
            self.counts.push(by);
            return;
        }
        if idx < self.offset {
            let pad = (self.offset - idx) as usize;
            self.counts.splice(0..0, std::iter::repeat(0).take(pad));
            self.offset = idx;
        }
        let i = (idx - self.offset) as usize;
        if i >= self.counts.len() {
            self.counts.resize(i + 1, 0);
        }
        self.counts[i] += by;
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
    /// Exact observed minimum (0.0 if any non-positive sample landed).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile estimate, `q` in `[0, 1]` (numpy-linear rank
    /// convention, like [`percentile`]). Returns 0.0 on an empty
    /// sketch so always-on exports never panic.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile domain: {q}");
        if self.n == 0 {
            return 0.0;
        }
        let rank = q * (self.n - 1) as f64;
        if (rank as u64) < self.zeros || self.zeros == self.n {
            return 0.0;
        }
        let mut cum = self.zeros as f64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if rank < cum + c as f64 {
                let idx = self.offset + i as i32;
                let lo = Self::bucket_lo(idx);
                let hi = Self::bucket_lo(idx + 1);
                let frac = (rank - cum) / c as f64;
                return (lo + (hi - lo) * frac).clamp(self.min.max(0.0), self.max);
            }
            cum += c as f64;
        }
        self.max
    }

    /// Deterministic approximate total: Σ count · bucket-midpoint.
    /// Derived purely from the integer state, so it is identical no
    /// matter how the sketch was sharded and merged (unlike a running
    /// f64 sum). Relative error is bounded by the bucket half-width.
    pub fn approx_sum(&self) -> f64 {
        let mut sum = 0.0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let idx = self.offset + i as i32;
            sum += c as f64 * 0.5 * (Self::bucket_lo(idx) + Self::bucket_lo(idx + 1));
        }
        sum
    }

    /// Merge another sketch (commutative, associative, exact on the
    /// integer state — the shard-fold reduction).
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.n == 0 {
            return;
        }
        self.n += other.n;
        self.zeros += other.zeros;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (i, &c) in other.counts.iter().enumerate() {
            if c > 0 {
                self.bump(other.offset + i as i32, c);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let d = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&d, 0.0), 1.0);
        assert_eq!(percentile(&d, 100.0), 4.0);
        assert_eq!(percentile(&d, 50.0), 2.5);
        assert!((percentile(&d, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 95.0), 7.0);
    }

    #[test]
    fn percentiles_sorts() {
        let mut d = [3.0, 1.0, 2.0];
        let ps = percentiles(&mut d, &[0.0, 50.0, 100.0]);
        assert_eq!(ps, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn percentile_empty_panics() {
        percentile(&[], 50.0);
    }

    #[test]
    fn probit_known_values() {
        assert!((probit(0.5)).abs() < 1e-9);
        assert!((probit(0.975) - 1.959964).abs() < 1e-5);
        assert!((probit(0.025) + 1.959964).abs() < 1e-5);
        assert!((probit(0.8413447) - 1.0).abs() < 1e-4);
        assert!(probit(1e-10) < -6.0);
    }

    #[test]
    fn probit_inverts_normal_cdf() {
        // Φ(probit(p)) ≈ p via the error-function-free check: sample
        // the normal via Box-Muller and compare empirical quantiles.
        use crate::util::Pcg64;
        let mut rng = Pcg64::new(3, 3);
        let mut xs: Vec<f64> = (0..200_000).map(|_| rng.gen_normal()).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.1, 0.25, 0.5, 0.9] {
            let emp = xs[(p * xs.len() as f64) as usize];
            assert!((probit(p) - emp).abs() < 0.02, "p={p}: {} vs {emp}", probit(p));
        }
    }

    #[test]
    fn means() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((geometric_mean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_known_values() {
        // Classic textbook sample: sample stddev = sqrt(32/7).
        let d = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&d) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        // Constant data has zero spread; singleton reports zero.
        assert_eq!(stddev(&[3.0, 3.0, 3.0]), 0.0);
        assert_eq!(stddev(&[42.0]), 0.0);
    }

    #[test]
    fn confidence_interval_known_values() {
        // mean 12, sample stddev 2, n = 3:
        // half = 1.959964 * 2 / sqrt(3) = 2.263172...
        let d = [10.0, 12.0, 14.0];
        let (m, half) = confidence_interval(&d, 0.95);
        assert!((m - 12.0).abs() < 1e-12);
        assert!((half - 1.959964 * 2.0 / 3.0f64.sqrt()).abs() < 1e-4);
        // Wider level ⇒ wider interval.
        let (_, half99) = confidence_interval(&d, 0.99);
        assert!(half99 > half);
        // One sample ⇒ degenerate interval.
        assert_eq!(confidence_interval(&[5.0], 0.95), (5.0, 0.0));
    }

    #[test]
    #[should_panic(expected = "confidence must be in (0, 1)")]
    fn confidence_level_domain_checked() {
        confidence_interval(&[1.0, 2.0], 1.0);
    }

    #[test]
    fn welford_matches_direct() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut w = Welford::new();
        for &x in &data {
            w.push(x);
        }
        let m = mean(&data);
        let var = data.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / data.len() as f64;
        assert!((w.mean() - m).abs() < 1e-9);
        assert!((w.variance() - var).abs() < 1e-9);
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let data: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64).collect();
        let mut whole = Welford::new();
        for &x in &data {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &data[..400] {
            a.push(x);
        }
        for &x in &data[400..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-6);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn welford_merge_known_values() {
        // Hand-computed Chan et al. merge, exact in f64:
        //   left  = {1, 3}:  n=2, mean=2, M2=2
        //   right = {4, 8}:  n=2, mean=6, M2=8
        //   union = {1,3,4,8}: n=4, mean=4, M2 = 9+1+0+16 = 26
        let mut left = Welford::new();
        left.push(1.0);
        left.push(3.0);
        let mut right = Welford::new();
        right.push(4.0);
        right.push(8.0);
        assert_eq!((left.count(), left.mean(), left.m2()), (2, 2.0, 2.0));
        assert_eq!((right.count(), right.mean(), right.m2()), (2, 6.0, 8.0));
        left.merge(&right);
        assert_eq!(left.count(), 4);
        assert_eq!(left.mean(), 4.0);
        assert_eq!(left.m2(), 26.0);
        assert_eq!(left.min(), 1.0);
        assert_eq!(left.max(), 8.0);
    }

    #[test]
    fn welford_merge_is_order_independent() {
        // The shard-merge reduction must not depend on which shard's
        // summary arrives first: A∪B == B∪A for these exact parts.
        let a = Welford::from_parts(2, 2.0, 2.0, 1.0, 3.0);
        let b = Welford::from_parts(2, 6.0, 8.0, 4.0, 8.0);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.count(), ba.count());
        assert_eq!(ab.mean(), ba.mean());
        assert_eq!(ab.m2(), ba.m2());
        assert_eq!((ab.min(), ab.max()), (ba.min(), ba.max()));
    }

    #[test]
    fn percentiles_skip_nan_samples() {
        // Regression: a zero-duration transfer produces a NaN rate
        // (0 bytes / 0 s); percentiles used to panic in the sort
        // comparator. The NaN must be dropped, not ranked.
        let mut rates = [120.0, 80.0, 0.0 / 0.0_f64, 100.0];
        let ps = percentiles(&mut rates, &[0.0, 50.0, 100.0]);
        assert_eq!(ps, vec![80.0, 100.0, 120.0]);
    }

    #[test]
    #[should_panic(expected = "empty/all-NaN")]
    fn percentiles_all_nan_panics() {
        let mut d = [f64::NAN, f64::NAN];
        percentiles(&mut d, &[50.0]);
    }

    #[test]
    fn sketch_matches_exact_percentile_within_2pct() {
        // The telemetry acceptance fixture: 10k samples from two very
        // different shapes, sketch vs exact numpy-linear percentile.
        use crate::util::Pcg64;
        let mut rng = Pcg64::new(11, 7);
        let lognormal: Vec<f64> = (0..10_000)
            .map(|_| (rng.gen_normal() * 1.5 - 2.0).exp())
            .collect();
        let uniform: Vec<f64> = (0..10_000).map(|_| rng.gen_f64(0.01, 100.0)).collect();
        for data in [&lognormal, &uniform] {
            let mut sk = QuantileSketch::new();
            for &x in data.iter() {
                sk.push(x);
            }
            let mut sorted = data.clone();
            sorted.sort_by(f64::total_cmp);
            for p in [10.0, 50.0, 90.0, 95.0, 99.0] {
                let exact = percentile(&sorted, p);
                let approx = sk.quantile(p / 100.0);
                assert!(
                    (approx - exact).abs() <= 0.02 * exact.abs(),
                    "p{p}: sketch {approx} vs exact {exact}"
                );
            }
            assert_eq!(sk.count(), 10_000);
            assert_eq!(sk.min(), sorted[0]);
            assert_eq!(sk.max(), sorted[sorted.len() - 1]);
        }
    }

    #[test]
    fn sketch_merge_equals_sequential_and_commutes() {
        use crate::util::Pcg64;
        let mut rng = Pcg64::new(5, 9);
        let data: Vec<f64> = (0..4_000).map(|_| rng.gen_f64(0.0, 500.0)).collect();
        let mut whole = QuantileSketch::new();
        for &x in &data {
            whole.push(x);
        }
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        for &x in &data[..1_500] {
            a.push(x);
        }
        for &x in &data[1_500..] {
            b.push(x);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        // Exact state equality, both orders — this is what makes
        // shard-merged telemetry bit-identical to serial.
        assert_eq!(ab, whole);
        assert_eq!(ba, whole);
        // Merging an empty sketch is the identity in both directions.
        let mut e = QuantileSketch::new();
        e.merge(&whole);
        assert_eq!(e, whole);
        let mut w2 = whole.clone();
        w2.merge(&QuantileSketch::new());
        assert_eq!(w2, whole);
    }

    #[test]
    fn sketch_zero_and_nan_samples_count_without_poisoning() {
        let mut sk = QuantileSketch::new();
        sk.push(0.0); // a zero-length phase span
        sk.push(f64::NAN); // a degenerate rate sample
        for x in [4.0, 5.0, 6.0] {
            sk.push(x);
        }
        assert_eq!(sk.count(), 5);
        assert_eq!(sk.min(), 0.0);
        assert_eq!(sk.max(), 6.0);
        assert_eq!(sk.quantile(0.0), 0.0);
        let p99 = sk.quantile(0.99);
        assert!(p99 > 5.0 && p99 <= 6.0, "p99 {p99}");
        // Empty sketch exports zeros rather than panicking.
        let empty = QuantileSketch::new();
        assert_eq!(empty.quantile(0.5), 0.0);
        assert_eq!((empty.min(), empty.max()), (0.0, 0.0));
    }

    #[test]
    fn sketch_approx_sum_tracks_true_sum() {
        let data: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.37).collect();
        let mut sk = QuantileSketch::new();
        for &x in &data {
            sk.push(x);
        }
        let truth: f64 = data.iter().sum();
        assert!(
            (sk.approx_sum() - truth).abs() <= 0.01 * truth,
            "approx {} vs {}",
            sk.approx_sum(),
            truth
        );
    }

    #[test]
    fn welford_from_parts_round_trips() {
        let mut w = Welford::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.push(x);
        }
        let r = Welford::from_parts(w.count(), w.mean(), w.m2(), w.min(), w.max());
        assert_eq!(r.count(), w.count());
        assert_eq!(r.mean(), w.mean());
        assert_eq!(r.m2(), w.m2());
        assert_eq!(r.variance(), w.variance());
        assert_eq!((r.min(), r.max()), (w.min(), w.max()));
        // Merging into an empty accumulator is the identity.
        let mut empty = Welford::new();
        empty.merge(&r);
        assert_eq!(empty.mean(), w.mean());
        assert_eq!(empty.m2(), w.m2());
        let mut back = r.clone();
        back.merge(&Welford::new());
        assert_eq!(back.count(), w.count());
    }
}
