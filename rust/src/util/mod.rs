//! Shared utility substrate: byte sizes, simulated time, deterministic
//! RNG, Zipf sampling, descriptive statistics, FNV hashing, and a
//! miniature property-testing framework (the offline environment has
//! no proptest; see DESIGN.md §2 row 18).

pub mod bytes;
pub mod pcg;
pub mod prop;
pub mod simtime;
pub mod stats;
pub mod zipf;

pub use bytes::ByteSize;
pub use pcg::Pcg64;
pub use simtime::{Duration, SimTime};
pub use zipf::Zipf;

/// Streaming 64-bit FNV-1a hasher (seed derivation, record digests).
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Self {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

/// One-shot FNV-1a of a byte string.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // Published FNV-1a/64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn streaming_equals_one_shot() {
        let mut h = Fnv1a::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a(b"foobar"));
    }
}
