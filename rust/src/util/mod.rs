//! Shared utility substrate: byte sizes, simulated time, deterministic
//! RNG, Zipf sampling, descriptive statistics, and a miniature
//! property-testing framework (the offline environment has no proptest;
//! see DESIGN.md §2 row 18).

pub mod bytes;
pub mod pcg;
pub mod prop;
pub mod simtime;
pub mod stats;
pub mod zipf;

pub use bytes::ByteSize;
pub use pcg::Pcg64;
pub use simtime::{Duration, SimTime};
pub use zipf::Zipf;
