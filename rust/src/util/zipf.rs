//! Zipf-distributed sampling for file popularity.
//!
//! Cache-effectiveness in the paper rests on reuse: jobs at a site
//! re-request the same inputs, so a cache converts WAN transfers into
//! LAN transfers (Fig 5). Scientific data-access popularity is
//! classically Zipf-like; the workload generator draws file indices
//! from this distribution.

use super::pcg::Pcg64;

/// Sampler for `P(k) ∝ 1 / (k+1)^s` over `k ∈ [0, n)`.
///
/// Uses an exact precomputed CDF with binary-search inversion:
/// O(n) memory once, O(log n) per sample, exact probabilities. The
/// federation catalogs are at most a few million files, so the table
/// is small; building it is a one-time cost per workload.
#[derive(Debug, Clone)]
pub struct Zipf {
    /// cdf[k] = P(X <= k), strictly increasing, cdf[n-1] == 1.
    cdf: Vec<f64>,
}

impl Zipf {
    /// `n` — number of items; `s` — exponent (`s = 0` is uniform).
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1, "zipf over empty catalog");
        assert!(s >= 0.0 && s.is_finite(), "invalid exponent {s}");
        let n = usize::try_from(n).expect("catalog too large");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += ((k + 1) as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Number of items in the catalog.
    pub fn len(&self) -> u64 {
        self.cdf.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        false // n >= 1 enforced at construction
    }

    /// Exact probability of item `k`.
    pub fn pmf(&self, k: u64) -> f64 {
        let k = k as usize;
        assert!(k < self.cdf.len());
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    /// Draw an item index in `[0, n)`; index 0 is the most popular.
    pub fn sample(&self, rng: &mut Pcg64) -> u64 {
        let u = rng.next_f64();
        // First k with cdf[k] >= u.
        self.cdf.partition_point(|&c| c < u) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn histogram(n: u64, s: f64, draws: usize) -> Vec<usize> {
        let z = Zipf::new(n, s);
        let mut rng = Pcg64::new(11, 11);
        let mut counts = vec![0usize; n as usize];
        for _ in 0..draws {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        counts
    }

    #[test]
    fn in_range() {
        let z = Zipf::new(100, 1.1);
        let mut rng = Pcg64::new(1, 1);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 100);
        }
    }

    #[test]
    fn rank_one_dominates() {
        let counts = histogram(1000, 1.0, 100_000);
        assert!(counts[0] > counts[10] && counts[10] > counts[100]);
    }

    #[test]
    fn ratio_matches_exponent() {
        // For s=1, P(1)/P(2) = 2.
        let counts = histogram(100, 1.0, 400_000);
        let ratio = counts[0] as f64 / counts[1] as f64;
        assert!((1.7..2.3).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn pmf_sums_to_one_and_matches_sampling() {
        let z = Zipf::new(50, 0.9);
        let total: f64 = (0..50).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
        let counts = histogram(50, 0.9, 200_000);
        for k in [0u64, 1, 5, 20] {
            let expected = z.pmf(k) * 200_000.0;
            let got = counts[k as usize] as f64;
            assert!(
                (got - expected).abs() < 5.0 * expected.sqrt().max(10.0),
                "k={k} expected {expected:.0} got {got}"
            );
        }
    }

    #[test]
    fn s_zero_is_uniform() {
        let counts = histogram(10, 0.0, 100_000);
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn single_item_catalog() {
        let z = Zipf::new(1, 1.2);
        let mut rng = Pcg64::new(2, 2);
        assert_eq!(z.sample(&mut rng), 0);
        assert_eq!(z.len(), 1);
    }

    #[test]
    fn non_integral_exponent() {
        let counts = histogram(50, 0.8, 100_000);
        assert!(counts[0] > counts[5]);
    }
}
