//! Miniature property-based testing framework.
//!
//! The offline crate set has no `proptest`, so this module provides the
//! subset the test suite needs: seeded random case generation, a
//! configurable number of cases, and greedy shrinking of failing inputs
//! (halving for integers, prefix/element shrinking for vectors).
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this offline image)
//! use stashcache::util::prop::check;
//! check("add commutes", 200, |g| {
//!     let a = g.u64(0, 1_000);
//!     let b = g.u64(0, 1_000);
//!     (a + b == b + a, format!("a={a} b={b}"))
//! });
//! ```

use super::pcg::Pcg64;

/// Value source handed to each property run. Records the draws so a
/// failing case can be replayed while shrinking.
pub struct Gen {
    rng: Pcg64,
    /// When `Some`, draws are served from this tape instead of the RNG
    /// (used during shrinking); missing entries fall back to minimum.
    tape: Option<Vec<u64>>,
    cursor: usize,
    /// Draws made during this run (raw u64s before range mapping).
    pub trace: Vec<u64>,
}

impl Gen {
    fn from_rng(rng: Pcg64) -> Self {
        Gen {
            rng,
            tape: None,
            cursor: 0,
            trace: Vec::new(),
        }
    }

    fn from_tape(tape: Vec<u64>) -> Self {
        Gen {
            rng: Pcg64::new(0, 0),
            tape: Some(tape),
            cursor: 0,
            trace: Vec::new(),
        }
    }

    fn draw(&mut self) -> u64 {
        let v = match &self.tape {
            Some(t) => t.get(self.cursor).copied().unwrap_or(0),
            None => self.rng.next_u64(),
        };
        self.cursor += 1;
        self.trace.push(v);
        v
    }

    /// Integer in `[lo, hi]` inclusive.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let span = hi - lo + 1;
        if span == 0 {
            // full range
            return self.draw();
        }
        lo + self.draw() % span
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.u64(0, (hi - lo) as u64) as i64
    }

    /// Float in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let unit = (self.draw() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        lo + unit * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.draw() & 1 == 1
    }

    /// Vector of `len in [0, max_len]` values from `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }
}

/// Outcome of one property evaluation: pass/fail plus a human-readable
/// rendering of the case for the failure report.
pub type Outcome = (bool, String);

/// Run `cases` random evaluations of `property`. On failure, shrink the
/// underlying draw tape and panic with the smallest failing case found.
pub fn check(name: &str, cases: usize, mut property: impl FnMut(&mut Gen) -> Outcome) {
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xc0ffee_u64);
    let mut root = Pcg64::new(seed, 0x5eed);
    for case in 0..cases {
        let mut g = Gen::from_rng(root.fork(&format!("{name}:{case}")));
        let (ok, rendered) = property(&mut g);
        if !ok {
            let tape = g.trace.clone();
            let (min_tape, min_render) = shrink(tape, rendered, &mut property);
            panic!(
                "property {name:?} failed (case {case}, seed {seed}):\n  \
                 minimal case: {min_render}\n  tape: {min_tape:?}\n  \
                 re-run with PROP_SEED={seed}"
            );
        }
    }
}

/// Greedy tape shrinking: try truncating the tape, zeroing entries, and
/// halving entries, keeping any mutation that still fails.
fn shrink(
    mut tape: Vec<u64>,
    mut rendered: String,
    property: &mut impl FnMut(&mut Gen) -> Outcome,
) -> (Vec<u64>, String) {
    let fails = |t: &[u64], property: &mut dyn FnMut(&mut Gen) -> Outcome| -> Option<String> {
        let mut g = Gen::from_tape(t.to_vec());
        let (ok, r) = property(&mut g);
        if ok {
            None
        } else {
            Some(r)
        }
    };
    let mut improved = true;
    let mut budget = 2_000usize;
    while improved && budget > 0 {
        improved = false;
        // Truncate from the end.
        while tape.len() > 1 {
            let t: Vec<u64> = tape[..tape.len() - 1].to_vec();
            match fails(&t, property) {
                Some(r) => {
                    tape = t;
                    rendered = r;
                    improved = true;
                }
                None => break,
            }
        }
        // Zero, then halve, each entry.
        for i in 0..tape.len() {
            budget = budget.saturating_sub(1);
            if tape[i] == 0 {
                continue;
            }
            for candidate in [0, tape[i] / 2, tape[i] - 1] {
                if candidate >= tape[i] {
                    continue;
                }
                let mut t = tape.clone();
                t[i] = candidate;
                if let Some(r) = fails(&t, property) {
                    tape = t;
                    rendered = r;
                    improved = true;
                    break;
                }
            }
        }
    }
    (tape, rendered)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        check("sum is monotone", 100, |g| {
            let a = g.u64(0, 1000);
            let b = g.u64(0, 1000);
            (a + b >= a, format!("a={a} b={b}"))
        });
    }

    #[test]
    fn failing_property_panics_with_shrunk_case() {
        let result = std::panic::catch_unwind(|| {
            check("all u64 < 100 (false)", 500, |g| {
                let x = g.u64(0, 10_000);
                (x < 100, format!("x={x}"))
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal case"), "{msg}");
        // Shrinker should reach the boundary value exactly.
        assert!(msg.contains("x=100"), "shrunk to boundary: {msg}");
    }

    #[test]
    fn vec_generator_respects_len() {
        check("vec len bound", 100, |g| {
            let v = g.vec(16, |g| g.u64(0, 9));
            (
                v.len() <= 16 && v.iter().all(|&x| x < 10),
                format!("{v:?}"),
            )
        });
    }

    #[test]
    fn tape_replay_is_exact() {
        let mut g1 = Gen::from_rng(Pcg64::new(1, 1));
        let a1 = g1.u64(0, 1_000_000);
        let b1 = g1.f64(0.0, 1.0);
        let tape = g1.trace.clone();
        let mut g2 = Gen::from_tape(tape);
        assert_eq!(g2.u64(0, 1_000_000), a1);
        assert_eq!(g2.f64(0.0, 1.0), b1);
    }
}
