//! Sweep artifacts: `BENCH_sweep.json`, CSVs, and the markdown
//! frontier report.
//!
//! Everything here is a pure function of [`SweepResults`], so the
//! artifact bytes inherit the runner's determinism guarantee — the
//! acceptance test compares the JSON string of a 1-thread and an
//! N-thread run directly. Rendering goes through [`crate::report`]
//! (`Table` for CSV/markdown, [`crate::report::paper`] for the
//! frontier and Table 3 views) so `cargo bench`, the CLI, and CI all
//! emit identical bytes.

use super::grid::method_name;
use super::summary::SweepResults;
use crate::report::{self, paper, Table};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Escape a string for JSON (Rust's `{:?}` is close but emits
/// `\u{...}` braced escapes, which JSON rejects).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Canonical JSON artifact (hand-rolled — no serde offline).
pub fn sweep_json(results: &SweepResults) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"bench\": \"sweep\",");
    let _ = writeln!(out, "  \"grid\": {},", json_str(&results.grid.name));
    let _ = writeln!(out, "  \"root_seed\": {},", results.grid.root_seed);
    let _ = writeln!(out, "  \"reps\": {},", results.grid.reps);
    let _ = writeln!(out, "  \"total_downloads\": {},", results.total_downloads());
    out.push_str("  \"trials\": [\n");
    for (i, t) in results.trials.iter().enumerate() {
        // Seeds and digests are full-width u64s: emit them as JSON
        // *strings*, since bare numbers above 2^53 get silently
        // rounded by double-based JSON consumers (jq, JavaScript) —
        // fatal for "re-run this cell with the seed from the
        // artifact" and for digest comparison.
        let _ = write!(
            out,
            "    {{\"index\": {}, \"cell\": {}, \"rep\": {}, \"seed\": \"{}\", \
             \"downloads\": {}, \"hit_ratio\": {:.6}, \"origin_bytes\": {}, \
             \"aggregate_mbps\": {:.4}, \"p50_s\": {:.6}, \"p95_s\": {:.6}, \
             \"p99_s\": {:.6}, \"makespan_s\": {:.6}, \"peak_concurrent\": {}, \
             \"coalesced_joins\": {}, \"faults_applied\": {}, \"failovers\": {}, \
             \"direct_fallbacks\": {}, \"deadline_expiries\": {}, \
             \"corruptions_detected\": {}, \"events\": {}, \"allocator_passes\": {}, \
             \"components_touched\": {}, \"flows_refixed\": {}, \
             \"peak_component\": {}, \"records_digest\": \"{}\"}}",
            t.spec.index,
            json_str(&t.spec.cell.label()),
            t.spec.rep,
            t.spec.seed,
            t.downloads,
            t.hit_ratio,
            t.origin_bytes,
            t.aggregate_mbps,
            t.p50_s,
            t.p95_s,
            t.p99_s,
            t.makespan_s,
            t.peak_concurrent,
            t.coalesced_joins,
            t.faults_applied,
            t.failovers,
            t.direct_fallbacks,
            t.deadline_expiries,
            t.corruptions_detected,
            t.events_processed,
            t.allocator_passes,
            t.components_touched,
            t.flows_refixed,
            t.peak_component,
            t.records_digest,
        );
        out.push_str(if i + 1 < results.trials.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"cells\": [\n");
    for (i, c) in results.cells.iter().enumerate() {
        let m = |out: &mut String, name: &str, metric: &super::summary::Metric, last: bool| {
            let _ = write!(
                out,
                "\"{name}\": {{\"mean\": {:.6}, \"stddev\": {:.6}, \"ci95\": {:.6}}}{}",
                metric.mean,
                metric.stddev,
                metric.ci95,
                if last { "" } else { ", " },
            );
        };
        let _ = write!(
            out,
            "    {{\"cell\": {}, \"method\": {}, \"policy\": {}, \"reps\": {}, ",
            json_str(&c.cell.label()),
            json_str(method_name(c.cell.method)),
            json_str(c.cell.policy.name()),
            c.reps,
        );
        m(&mut out, "hit_ratio", &c.hit_ratio, false);
        m(&mut out, "origin_gb", &c.origin_gb, false);
        m(&mut out, "aggregate_mbps", &c.aggregate_mbps, false);
        m(&mut out, "p50_s", &c.p50_s, false);
        m(&mut out, "p95_s", &c.p95_s, false);
        m(&mut out, "p99_s", &c.p99_s, false);
        m(&mut out, "failovers", &c.failovers, false);
        m(&mut out, "deadline_expiries", &c.deadline_expiries, true);
        out.push('}');
        out.push_str(if i + 1 < results.cells.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]");
    if let Some(t3) = &results.table3 {
        out.push_str(",\n  \"table3\": [\n");
        for (i, row) in t3.rows.iter().enumerate() {
            let fmt = |v: Option<f64>| match v {
                Some(x) => format!("{x:.4}"),
                None => "null".to_string(),
            };
            let _ = write!(
                out,
                "    {{\"site\": {}, \"pct_2_3gb\": {}, \"pct_10gb\": {}}}",
                json_str(&row.site),
                fmt(row.pct_2_3gb),
                fmt(row.pct_10gb),
            );
            out.push_str(if i + 1 < t3.rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]");
    }
    out.push_str("\n}\n");
    out
}

/// Canonical resilience artifact (`BENCH_resilience.json`): the
/// breaker-off/on cell pairs of [`paper::resilience_table`] as data.
/// Cells are paired on [`CellKey::resilience_pair_label`] — everything
/// but the breaker bit — so each pair compares identical workload,
/// fault schedule, policy, and deadline settings. The pair list is
/// empty when the grid swept only one breaker setting.
///
/// [`CellKey::resilience_pair_label`]:
///     crate::experiment::grid::CellKey::resilience_pair_label
pub fn resilience_json(results: &SweepResults) -> String {
    let mut pairs = Vec::new();
    for off in results.cells.iter().filter(|c| !c.cell.breaker) {
        let Some(on) = results.cells.iter().find(|c| {
            c.cell.breaker && c.cell.resilience_pair_label() == off.cell.resilience_pair_label()
        }) else {
            continue;
        };
        pairs.push((off, on));
    }
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"bench\": \"resilience\",");
    let _ = writeln!(out, "  \"grid\": {},", json_str(&results.grid.name));
    let _ = writeln!(out, "  \"root_seed\": {},", results.grid.root_seed);
    out.push_str("  \"pairs\": [\n");
    for (i, (off, on)) in pairs.iter().enumerate() {
        let side = |out: &mut String, name: &str, c: &super::summary::CellSummary| {
            let _ = write!(
                out,
                "\"{name}\": {{\"aggregate_mbps\": {:.4}, \"p99_s\": {:.6}, \
                 \"origin_gb\": {:.6}, \"failovers\": {:.2}, \
                 \"deadline_expiries\": {:.2}}}",
                c.aggregate_mbps.mean,
                c.p99_s.mean,
                c.origin_gb.mean,
                c.failovers.mean,
                c.deadline_expiries.mean,
            );
        };
        let gain = if off.aggregate_mbps.mean > 0.0 {
            (on.aggregate_mbps.mean - off.aggregate_mbps.mean) / off.aggregate_mbps.mean * 100.0
        } else {
            0.0
        };
        let _ = write!(
            out,
            "    {{\"cell\": {}, \"faults\": {}, ",
            json_str(&off.cell.resilience_pair_label()),
            json_str(off.cell.fault_profile.name()),
        );
        side(&mut out, "off", off);
        out.push_str(", ");
        side(&mut out, "on", on);
        let _ = write!(out, ", \"goodput_gain_pct\": {gain:.4}}}");
        out.push_str(if i + 1 < pairs.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// Per-trial flat table (CSV artifact).
pub fn trials_table(results: &SweepResults) -> Table {
    let mut t = Table::new(
        format!("Sweep {:?}: trials", results.grid.name),
        &[
            "index", "method", "cap", "jobs", "window_s", "zipf", "sizes", "faults", "policy",
            "deadline", "breaker", "rep", "seed", "downloads", "hit_ratio", "origin_bytes",
            "aggregate_mbps", "p50_s", "p95_s", "p99_s", "failovers", "expiries", "digest",
        ],
    );
    for o in &results.trials {
        let c = &o.spec.cell;
        t.row(vec![
            o.spec.index.to_string(),
            method_name(c.method).to_string(),
            format!("{:.2}", c.capacity_scale),
            c.jobs.to_string(),
            format!("{:.1}", c.arrival_window_secs),
            format!("{:.2}", c.zipf_s),
            c.size_profile.name().to_string(),
            c.fault_profile.name().to_string(),
            c.policy.name().to_string(),
            format!("{:.2}", c.deadline_factor),
            if c.breaker { "on" } else { "off" }.to_string(),
            o.spec.rep.to_string(),
            o.spec.seed.to_string(),
            o.downloads.to_string(),
            format!("{:.4}", o.hit_ratio),
            o.origin_bytes.to_string(),
            format!("{:.2}", o.aggregate_mbps),
            format!("{:.4}", o.p50_s),
            format!("{:.4}", o.p95_s),
            format!("{:.4}", o.p99_s),
            o.failovers.to_string(),
            o.deadline_expiries.to_string(),
            o.records_digest.to_string(),
        ]);
    }
    t
}

/// Per-cell summary table (`mean ± ci95`; CSV + terminal artifact).
pub fn cells_table(results: &SweepResults) -> Table {
    let mut t = Table::new(
        format!(
            "Sweep {:?}: {} cells × {} rep(s)",
            results.grid.name,
            results.cells.len(),
            results.grid.reps,
        ),
        &[
            "method", "cap", "jobs", "window_s", "zipf", "sizes", "faults", "policy", "deadline",
            "breaker", "hit%", "origin GB", "Mbps", "±ci95", "p50 s", "p95 s", "p99 s",
            "failovers", "expiries",
        ],
    );
    for c in &results.cells {
        let k = &c.cell;
        t.row(vec![
            method_name(k.method).to_string(),
            format!("{:.2}", k.capacity_scale),
            k.jobs.to_string(),
            format!("{:.1}", k.arrival_window_secs),
            format!("{:.2}", k.zipf_s),
            k.size_profile.name().to_string(),
            k.fault_profile.name().to_string(),
            k.policy.name().to_string(),
            format!("{:.2}", k.deadline_factor),
            if k.breaker { "on" } else { "off" }.to_string(),
            format!("{:.1}", 100.0 * c.hit_ratio.mean),
            format!("{:.2}", c.origin_gb.mean),
            format!("{:.0}", c.aggregate_mbps.mean),
            format!("{:.0}", c.aggregate_mbps.ci95),
            format!("{:.2}", c.p50_s.mean),
            format!("{:.2}", c.p95_s.mean),
            format!("{:.2}", c.p99_s.mean),
            format!("{:.1}", c.failovers.mean),
            format!("{:.1}", c.deadline_expiries.mean),
        ]);
    }
    t
}

/// Write every sweep artifact under `dir`; returns the paths written.
///
/// `BENCH_sweep.json` lands directly in `dir` — CI runs the sweep from
/// the repository root so the JSON is a root artifact.
pub fn write_all(dir: &Path, results: &SweepResults) -> std::io::Result<Vec<PathBuf>> {
    let mut written = Vec::new();
    let mut emit = |name: &str, content: String| -> std::io::Result<()> {
        report::write_artifact(dir, name, &content)?;
        written.push(dir.join(name));
        Ok(())
    };
    emit("BENCH_sweep.json", sweep_json(results))?;
    emit("sweep_trials.csv", trials_table(results).to_csv())?;
    emit("sweep_cells.csv", cells_table(results).to_csv())?;
    let mut frontier = format!(
        "# Sweep {:?}: HTTP proxy vs StashCache frontier\n\n",
        results.grid.name
    );
    frontier.push_str(&paper::frontier_table(results).to_markdown());
    if results.grid.policies.len() > 1 {
        // The redirection-policy comparison (same workload, different
        // cache-selection rule) rides next to the method frontier.
        frontier.push('\n');
        frontier.push_str(&paper::policy_table(results).to_markdown());
    }
    if results.grid.breakers.len() > 1 {
        // Breaker-on/off pairs exist: emit the resilience comparison
        // as both machine-readable JSON and a markdown table.
        frontier.push('\n');
        frontier.push_str(&paper::resilience_table(results).to_markdown());
        emit("BENCH_resilience.json", resilience_json(results))?;
    }
    if let Some(t3) = &results.table3 {
        frontier.push('\n');
        frontier.push_str(&paper::sweep_table3(t3).to_markdown());
    }
    emit("sweep_frontier.md", frontier)?;
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;
    use crate::experiment::grid::GridSpec;
    use crate::experiment::runner::run_grid;
    use crate::federation::DownloadMethod;

    fn small_results() -> SweepResults {
        let grid = GridSpec {
            jobs: vec![4],
            reps: 1,
            capacity_scales: vec![1.0],
            methods: vec![DownloadMethod::Stash, DownloadMethod::HttpProxy],
            fault_profiles: vec![crate::experiment::grid::FaultProfile::None],
            catalog_files: 16,
            background_flows: 0,
            ..GridSpec::smoke()
        };
        run_grid(&paper_federation(), &grid, 1)
    }

    #[test]
    fn json_carries_every_trial_and_cell() {
        let r = small_results();
        let json = sweep_json(&r);
        assert!(json.contains("\"bench\": \"sweep\""));
        assert_eq!(json.matches("\"index\":").count(), r.trials.len());
        assert!(json.contains("records_digest"));
        // Purely a function of the results: rendering twice is stable.
        assert_eq!(json, sweep_json(&r));
    }

    #[test]
    fn json_strings_escape_properly() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("a\nb\tc"), "\"a\\nb\\tc\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
        // Non-ASCII passes through un-escaped (valid UTF-8 JSON).
        assert_eq!(json_str("café"), "\"café\"");
    }

    #[test]
    fn resilience_json_pairs_breaker_twins() {
        let grid = GridSpec {
            jobs: vec![6],
            reps: 1,
            capacity_scales: vec![1.0],
            methods: vec![DownloadMethod::Stash],
            fault_profiles: vec![crate::experiment::grid::FaultProfile::Degraded],
            deadline_factors: vec![3.0],
            breakers: vec![false, true],
            arrival_windows: vec![4.0],
            catalog_files: 16,
            background_flows: 0,
            ..GridSpec::smoke()
        };
        let r = run_grid(&paper_federation(), &grid, 1);
        let json = resilience_json(&r);
        assert!(json.contains("\"bench\": \"resilience\""));
        // One off-cell, one on-cell ⇒ exactly one pair.
        assert_eq!(json.matches("goodput_gain_pct").count(), 1);
        assert!(json.contains("\"faults\": \"degraded\""));
        // Pure function of the results: rendering twice is stable.
        assert_eq!(json, resilience_json(&r));
    }

    #[test]
    fn tables_have_one_row_per_item() {
        let r = small_results();
        assert_eq!(trials_table(&r).rows.len(), r.trials.len());
        assert_eq!(cells_table(&r).rows.len(), r.cells.len());
        let csv = trials_table(&r).to_csv();
        assert!(csv.lines().count() == r.trials.len() + 1);
    }
}
