//! Experiment lab: deterministic parallel parameter sweeps.
//!
//! The paper's headline result is a *comparison* — HTTP proxies vs
//! StashCache (§5) — but a single campaign explores one point of a
//! much larger space. This layer turns that point into a **frontier**:
//!
//! * [`grid`] — parameter axes (client method, cache capacity scale,
//!   client count, Poisson window, Zipf skew, file-size mix, fault
//!   profile, redirection policy) expanded into a cartesian product of
//!   [`grid::TrialSpec`]s with stateless per-trial seeds.
//! * [`runner`] — a work-stealing pool of OS threads executing trials
//!   through the existing [`crate::sim::campaign`] engine; each trial
//!   owns its federation, so an N-thread run is bit-identical to a
//!   1-thread run.
//! * [`summary`] — per-trial metric vectors folded into per-cell
//!   `mean ± CI` summaries via [`crate::util::stats`].
//! * [`artifact`] — `BENCH_sweep.json`, CSVs, and the markdown
//!   proxy-vs-StashCache frontier report.
//!
//! Drive it from the CLI: `stashcache sweep --preset proxy-vs-stash
//! --threads 8` (or `--grid sweep.toml`). The `proxy-vs-stash` preset
//! reproduces the §4.1 Table 3 scenario as one cell of the grid, so
//! the paper's comparison appears in context — surrounded by the
//! capacity/concurrency/size-mix frontier the paper could not run.
//!
//! This is the repo's first real OS-thread parallelism: simulation
//! stays single-threaded and deterministic *inside* a trial, and the
//! lab saturates cores *across* trials.

pub mod artifact;
pub mod grid;
pub mod runner;
pub mod summary;

pub use grid::{CellKey, FaultProfile, GridSpec, SizeProfile, TrialSpec};
pub use runner::run_grid;
pub use summary::{CellSummary, SweepResults, Table3Cell, TrialOutcome};
