//! Trial → cell aggregation.
//!
//! A [`TrialOutcome`] is the flat metric vector of one campaign run
//! (hit ratio, origin bytes, aggregate Mbps, duration percentiles,
//! fault counters) plus a FNV digest of every [`TransferRecord`] —
//! the digest is what makes "bit-identical across thread counts"
//! cheap to assert. [`summarize`] folds reps of the same cell into a
//! [`CellSummary`] of `mean ± CI` metrics via
//! [`crate::util::stats::confidence_interval`].
//!
//! [`TransferRecord`]: crate::client::TransferRecord

use super::grid::{CellKey, GridSpec, TrialSpec};
use crate::client::Method;
use crate::federation::FedSim;
use crate::sim::campaign::{CampaignRecord, CampaignResults};
use crate::telemetry::TelemetrySnapshot;
use crate::util::stats;

/// Measured metrics of one finished trial.
#[derive(Debug, Clone, PartialEq)]
pub struct TrialOutcome {
    pub spec: TrialSpec,
    /// Completed downloads (== the cell's job × files count).
    pub downloads: usize,
    /// Fraction of downloads served by an already-warm cache/proxy.
    pub hit_ratio: f64,
    /// Bytes the caches and proxies pulled from origins upstream.
    pub origin_bytes: u64,
    pub aggregate_mbps: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub makespan_s: f64,
    pub peak_concurrent: usize,
    pub coalesced_joins: u64,
    /// Fault events the engine applied during this trial.
    pub faults_applied: u64,
    pub failovers: u64,
    pub direct_fallbacks: u64,
    /// Transfer deadlines that expired (gray-failure failovers).
    pub deadline_expiries: u64,
    /// Digest-check failures caught at transfer end.
    pub corruptions_detected: u64,
    pub events_processed: u64,
    /// Allocator counters (see `netsim::AllocStats`): passes run,
    /// component water-fills, flow rate assignments, and the largest
    /// component — the O(affected) observability the perf pass tracks.
    pub allocator_passes: u64,
    pub components_touched: u64,
    pub flows_refixed: u64,
    pub peak_component: usize,
    /// FNV-1a over every transfer record (order, paths, bytes,
    /// methods, hit flags, durations) — two runs agree on this iff
    /// they produced identical records in identical order.
    pub records_digest: u64,
    /// The trial's telemetry export bundle (sweeps merge these across
    /// trials for `--metrics-out`).
    pub telemetry: TelemetrySnapshot,
}

fn method_tag(method: Method) -> u64 {
    match method {
        Method::Cvmfs => 0,
        Method::Xrootd => 1,
        Method::HttpCache => 2,
        Method::HttpProxy => 3,
        Method::HttpOrigin => 4,
    }
}

/// Order-sensitive digest of a campaign's full record stream.
pub fn digest_records(records: &[CampaignRecord]) -> u64 {
    let mut h = crate::util::Fnv1a::new();
    for r in records {
        h.write_u64(r.session);
        h.write(r.site.as_bytes());
        h.write_u64(r.arrival.as_micros());
        h.write(r.record.path.as_bytes());
        h.write_u64(r.record.bytes);
        h.write_u64(method_tag(r.record.method));
        h.write_u64(r.record.cache_hit as u64);
        h.write_u64(r.record.duration.as_micros());
    }
    h.finish()
}

/// Reduce one campaign (plus the federation it ran on, for the
/// cache/proxy upstream counters) to a [`TrialOutcome`].
pub fn outcome_of(spec: &TrialSpec, results: &CampaignResults, fed: &FedSim) -> TrialOutcome {
    let downloads = results.records.len();
    let hits = results
        .records
        .iter()
        .filter(|r| r.record.cache_hit)
        .count();
    let origin_bytes: u64 = fed
        .caches
        .values()
        .map(|c| c.stats.bytes_fetched_origin)
        .sum::<u64>()
        + fed
            .proxies
            .values()
            .map(|p| p.stats.bytes_fetched_upstream)
            .sum::<u64>();
    let ps = results.duration_percentiles(&[50.0, 95.0, 99.0]);
    TrialOutcome {
        spec: spec.clone(),
        downloads,
        hit_ratio: if downloads == 0 {
            0.0
        } else {
            hits as f64 / downloads as f64
        },
        origin_bytes,
        aggregate_mbps: results.aggregate_mbps(),
        p50_s: ps[0],
        p95_s: ps[1],
        p99_s: ps[2],
        makespan_s: results.makespan.as_secs_f64(),
        peak_concurrent: results.peak_concurrent,
        coalesced_joins: results.coalesced_joins,
        faults_applied: results.engine.faults_applied,
        failovers: results.engine.failovers,
        direct_fallbacks: results.engine.direct_fallbacks,
        deadline_expiries: results.engine.deadline_expiries,
        corruptions_detected: results.engine.corruptions_detected,
        events_processed: results.events_processed,
        allocator_passes: results.engine.allocator_passes,
        components_touched: results.engine.components_touched,
        flows_refixed: results.engine.flows_refixed,
        peak_component: results.engine.peak_component,
        records_digest: digest_records(&results.records),
        telemetry: results.telemetry.clone(),
    }
}

/// `mean ± ci95` (plus the sample stddev) of one metric over a cell's
/// reps. `ci95` is zero for single-rep cells.
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub mean: f64,
    pub stddev: f64,
    pub ci95: f64,
}

impl Metric {
    fn of(samples: &[f64]) -> Metric {
        let (mean, ci95) = stats::confidence_interval(samples, 0.95);
        Metric {
            mean,
            stddev: stats::stddev(samples),
            ci95,
        }
    }
}

/// Aggregated metrics of one grid cell across its reps.
#[derive(Debug, Clone, PartialEq)]
pub struct CellSummary {
    pub cell: CellKey,
    pub reps: usize,
    pub hit_ratio: Metric,
    pub origin_gb: Metric,
    pub aggregate_mbps: Metric,
    pub p50_s: Metric,
    pub p95_s: Metric,
    pub p99_s: Metric,
    pub failovers: Metric,
    pub deadline_expiries: Metric,
}

/// One row of the §4.1 Table 3 cell (percent difference in download
/// time, StashCache vs HTTP proxy; negative ⇒ StashCache faster).
#[derive(Debug, Clone, PartialEq)]
pub struct Table3Row {
    pub site: String,
    pub pct_2_3gb: Option<f64>,
    pub pct_10gb: Option<f64>,
}

/// The §4.1 serial scenario reproduced as one cell of the grid.
#[derive(Debug, Clone, PartialEq)]
pub struct Table3Cell {
    pub rows: Vec<Table3Row>,
}

/// A finished sweep: the grid, every trial in grid order, per-cell
/// summaries, and (optionally) the Table 3 scenario cell.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepResults {
    pub grid: GridSpec,
    pub trials: Vec<TrialOutcome>,
    pub cells: Vec<CellSummary>,
    pub table3: Option<Table3Cell>,
}

impl SweepResults {
    /// Total downloads completed across every trial.
    pub fn total_downloads(&self) -> usize {
        self.trials.iter().map(|t| t.downloads).sum()
    }
}

/// Fold trials (grid order, reps adjacent) into per-cell summaries.
pub fn summarize(
    grid: &GridSpec,
    trials: Vec<TrialOutcome>,
    table3: Option<Table3Cell>,
) -> SweepResults {
    let mut cells: Vec<CellSummary> = Vec::new();
    let mut i = 0;
    while i < trials.len() {
        let cell = trials[i].spec.cell.clone();
        let mut j = i;
        while j < trials.len() && trials[j].spec.cell == cell {
            j += 1;
        }
        let reps = &trials[i..j];
        let col = |f: &dyn Fn(&TrialOutcome) -> f64| -> Vec<f64> { reps.iter().map(f).collect() };
        cells.push(CellSummary {
            cell,
            reps: reps.len(),
            hit_ratio: Metric::of(&col(&|t| t.hit_ratio)),
            origin_gb: Metric::of(&col(&|t| t.origin_bytes as f64 / 1e9)),
            aggregate_mbps: Metric::of(&col(&|t| t.aggregate_mbps)),
            p50_s: Metric::of(&col(&|t| t.p50_s)),
            p95_s: Metric::of(&col(&|t| t.p95_s)),
            p99_s: Metric::of(&col(&|t| t.p99_s)),
            failovers: Metric::of(&col(&|t| t.failovers as f64)),
            deadline_expiries: Metric::of(&col(&|t| t.deadline_expiries as f64)),
        });
        i = j;
    }
    SweepResults {
        grid: grid.clone(),
        trials,
        cells,
        table3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;
    use crate::experiment::grid::GridSpec;
    use crate::experiment::runner::execute_trial;

    #[test]
    fn digest_is_order_and_content_sensitive() {
        let base = paper_federation();
        let grid = GridSpec {
            jobs: vec![4],
            reps: 2,
            capacity_scales: vec![1.0],
            fault_profiles: vec![crate::experiment::grid::FaultProfile::None],
            methods: vec![crate::federation::DownloadMethod::Stash],
            ..GridSpec::smoke()
        };
        let trials = grid.trials();
        let a = execute_trial(&base, &grid, &trials[0]);
        let b = execute_trial(&base, &grid, &trials[1]);
        assert_ne!(
            a.records_digest, b.records_digest,
            "different seeds give different digests"
        );
    }

    #[test]
    fn summarize_groups_adjacent_reps() {
        let base = paper_federation();
        let grid = GridSpec {
            jobs: vec![4, 8],
            reps: 2,
            capacity_scales: vec![1.0],
            fault_profiles: vec![crate::experiment::grid::FaultProfile::None],
            methods: vec![crate::federation::DownloadMethod::Stash],
            catalog_files: 16,
            background_flows: 0,
            ..GridSpec::smoke()
        };
        let outcomes: Vec<TrialOutcome> = grid
            .trials()
            .iter()
            .map(|t| execute_trial(&base, &grid, t))
            .collect();
        let r = summarize(&grid, outcomes, None);
        assert_eq!(r.trials.len(), 4);
        assert_eq!(r.cells.len(), 2, "two cells of two reps each");
        for c in &r.cells {
            assert_eq!(c.reps, 2);
            // Multi-rep cells carry a spread (possibly zero) and the
            // mean lies within the observed sample range.
            assert!(c.aggregate_mbps.mean > 0.0);
            assert!(c.aggregate_mbps.ci95 >= 0.0);
        }
        assert_eq!(r.total_downloads(), 4 + 4 + 8 + 8);
    }
}
