//! Parallel trial execution: a work-stealing pool of OS threads.
//!
//! Each trial is hermetic — it clones the base [`FederationConfig`],
//! applies its cell's capacity scale and size profile, builds its own
//! [`FedSim`], and runs one campaign (optionally under a fault
//! timeline) through the deterministic session engine. Because no
//! state is shared between trials, execution order cannot influence
//! results: workers pull trial indices from a shared atomic counter
//! (idle threads steal whatever work is left), write outcomes into
//! per-trial slots, and the slot order restores grid order. A grid run
//! on one thread and on N threads is therefore **bit-identical**, which
//! `tests/experiment_sweep.rs` asserts over records, summaries, and
//! the JSON artifact.

use super::grid::{FaultProfile, GridSpec, TrialSpec};
use super::summary::{self, SweepResults, Table3Cell, Table3Row, TrialOutcome};
use crate::config::defaults::COMPUTE_SITES;
use crate::config::FederationConfig;
use crate::fault::{FaultKind, FaultTimeline};
use crate::federation::FedSim;
use crate::sim::campaign::{self, CampaignConfig, CampaignResults};
use crate::sim::scenario::{self, ScenarioConfig};
use crate::util::{ByteSize, SimTime};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Execute every trial of `grid` on `threads` OS threads (1 ⇒ inline
/// on the caller's thread) and aggregate into [`SweepResults`].
pub fn run_grid(base: &FederationConfig, grid: &GridSpec, threads: usize) -> SweepResults {
    grid.validate().expect("invalid grid");
    let trials = grid.trials();
    let n = trials.len();
    let workers = threads.max(1).min(n.max(1));

    let (outcomes, table3): (Vec<TrialOutcome>, Option<Table3Cell>) = if workers <= 1 {
        let outcomes = trials
            .iter()
            .map(|spec| execute_trial(base, grid, spec))
            .collect();
        // The Table 3 cell is the §4.1 serial scenario — one
        // deterministic run, independent of the campaign trials.
        (outcomes, grid.table3_cell.then(|| table3_cell(base)))
    } else {
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<TrialOutcome>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let table3_slot: Mutex<Option<Table3Cell>> = Mutex::new(None);
        std::thread::scope(|scope| {
            if grid.table3_cell {
                // The scenario is independent of every campaign trial;
                // overlap it with the pool instead of paying its full
                // runtime after the barrier.
                scope.spawn(|| {
                    *table3_slot.lock().expect("table3 lock") = Some(table3_cell(base));
                });
            }
            for _ in 0..workers {
                scope.spawn(|| loop {
                    // Dynamic scheduling: finished workers steal the
                    // next unclaimed trial, so a long cell never
                    // serialises the rest of the grid.
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = execute_trial(base, grid, &trials[i]);
                    *slots[i].lock().expect("slot lock") = Some(out);
                });
            }
        });
        let outcomes = slots
            .into_iter()
            .map(|slot| slot.into_inner().expect("slot lock").expect("trial ran"))
            .collect();
        (outcomes, table3_slot.into_inner().expect("table3 lock"))
    };

    summary::summarize(grid, outcomes, table3)
}

/// Run one trial: config surgery, federation build, campaign.
pub fn execute_trial(
    base: &FederationConfig,
    grid: &GridSpec,
    spec: &TrialSpec,
) -> TrialOutcome {
    let mut cfg = base.clone();
    let scale = spec.cell.capacity_scale;
    if (scale - 1.0).abs() > 1e-12 {
        // The axis constrains *both* storage tiers, so a cap=0.25
        // frontier cell compares a quarter-size cache against a
        // quarter-size proxy — not a shrunken cache vs a full proxy.
        for site in &mut cfg.sites {
            if let Some(cache) = &mut site.cache {
                let scaled = (cache.capacity.as_f64() * scale).round() as u64;
                // Keep the config valid: a cache can never be smaller
                // than one chunk.
                cache.capacity = ByteSize(scaled.max(cache.chunk_size.as_u64()));
            }
            if let Some(proxy) = &mut site.proxy {
                let scaled = (proxy.capacity.as_f64() * scale).round() as u64;
                // A proxy smaller than its own max object thrashes
                // meaninglessly; clamp there.
                proxy.capacity = ByteSize(scaled.max(proxy.max_object.as_u64()));
            }
        }
    }
    spec.cell.size_profile.apply(&mut cfg.workload);
    // The cell's redirection policy (cache-selection rule).
    cfg.redirection.policy = spec.cell.policy;
    // The cell's resilience knobs (gray-failure defences): transfer
    // deadlines and the per-cache circuit breaker.
    cfg.resilience.deadline_factor = spec.cell.deadline_factor;
    cfg.resilience.breaker = spec.cell.breaker;

    let mut fed = FedSim::build(cfg);
    let ccfg = CampaignConfig {
        sites: grid.sites.clone(),
        jobs: spec.cell.jobs,
        arrival_window_secs: spec.cell.arrival_window_secs,
        files_per_job: grid.files_per_job,
        catalog_files: grid.catalog_files,
        zipf_s: spec.cell.zipf_s,
        experiment: grid.experiment.clone(),
        background_flows: grid.background_flows,
        method: spec.cell.method,
        seed: spec.seed,
        ..CampaignConfig::default()
    };

    let window = spec.cell.arrival_window_secs;
    let results: CampaignResults = match spec.cell.fault_profile {
        FaultProfile::None => campaign::run_on(&mut fed, &ccfg),
        FaultProfile::CacheOutage => {
            let first = fed
                .topo
                .site_index(&grid.sites[0])
                .unwrap_or_else(|| panic!("unknown grid site {}", grid.sites[0]));
            let victim = fed.nearest_cache_site(first);
            let mut faults = FaultTimeline::new();
            faults.push(
                SimTime::from_secs_f64(window * 0.5),
                FaultKind::CacheDown { site: victim },
            );
            campaign::run_on_with_faults(&mut fed, &ccfg, &faults).campaign
        }
        FaultProfile::OriginBrownout => {
            let mut faults = FaultTimeline::new();
            faults.origin_brownout(
                0,
                0.25,
                SimTime::from_secs_f64(window * 0.1),
                SimTime::from_secs_f64(window * 0.9),
            );
            campaign::run_on_with_faults(&mut fed, &ccfg, &faults).campaign
        }
        FaultProfile::Degraded => {
            // Gray failure: the first site's nearest cache slows to 5%
            // of its serving capacity early in the window and never
            // recovers. No death event fires, so only the cell's
            // deadline/breaker settings can route sessions around it.
            let first = fed
                .topo
                .site_index(&grid.sites[0])
                .unwrap_or_else(|| panic!("unknown grid site {}", grid.sites[0]));
            let victim = fed.nearest_cache_site(first);
            let mut faults = FaultTimeline::new();
            faults.push(
                SimTime::from_secs_f64(window * 0.1),
                FaultKind::CacheSlow {
                    site: victim,
                    factor: 0.05,
                },
            );
            campaign::run_on_with_faults(&mut fed, &ccfg, &faults).campaign
        }
    };

    summary::outcome_of(spec, &results, &fed)
}

/// The §4.1 serial DAGMan scenario, reduced to its Table 3 cells.
pub fn table3_cell(base: &FederationConfig) -> Table3Cell {
    let results = scenario::run(base.clone(), &ScenarioConfig::default());
    Table3Cell {
        rows: COMPUTE_SITES
            .iter()
            .map(|site| Table3Row {
                site: site.to_string(),
                pct_2_3gb: results.pct_difference(site, "p95"),
                pct_10gb: results.pct_difference(site, "f10g"),
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;
    use crate::federation::DownloadMethod;

    fn tiny_grid() -> GridSpec {
        GridSpec {
            name: "tiny".into(),
            reps: 2,
            methods: vec![DownloadMethod::Stash],
            capacity_scales: vec![1.0],
            jobs: vec![6],
            arrival_windows: vec![10.0],
            zipf_s: vec![1.1],
            size_profiles: vec![super::super::grid::SizeProfile::Paper],
            fault_profiles: vec![FaultProfile::None],
            sites: vec!["syracuse".into(), "nebraska".into()],
            catalog_files: 16,
            background_flows: 0,
            table3_cell: false,
            ..GridSpec::smoke()
        }
    }

    #[test]
    fn trial_is_hermetic_and_deterministic() {
        let base = paper_federation();
        let grid = tiny_grid();
        let trials = grid.trials();
        let a = execute_trial(&base, &grid, &trials[0]);
        let b = execute_trial(&base, &grid, &trials[0]);
        assert_eq!(a, b, "same spec, fresh federations ⇒ identical outcome");
        assert_eq!(a.downloads, 6);
        assert!(a.records_digest != 0);
        // Different rep ⇒ different seed ⇒ different records.
        let c = execute_trial(&base, &grid, &trials[1]);
        assert_ne!(a.records_digest, c.records_digest);
    }

    #[test]
    fn pool_runs_every_trial_once() {
        let base = paper_federation();
        let grid = tiny_grid();
        let r = run_grid(&base, &grid, 3);
        assert_eq!(r.trials.len(), grid.trial_count());
        for (i, t) in r.trials.iter().enumerate() {
            assert_eq!(t.spec.index, i, "grid order restored");
            assert_eq!(t.downloads, 6);
        }
    }

    #[test]
    fn fault_profile_cells_fail_over() {
        let base = paper_federation();
        let grid = GridSpec {
            fault_profiles: vec![FaultProfile::CacheOutage],
            jobs: vec![12],
            arrival_windows: vec![4.0],
            reps: 1,
            ..tiny_grid()
        };
        let r = run_grid(&base, &grid, 2);
        assert_eq!(r.trials.len(), 1);
        let t = &r.trials[0];
        assert_eq!(t.downloads, 12, "every job completes despite the outage");
    }

    #[test]
    fn degraded_profile_completes_with_deadlines_and_breaker_armed() {
        let base = paper_federation();
        let grid = GridSpec {
            fault_profiles: vec![FaultProfile::Degraded],
            deadline_factors: vec![3.0],
            breakers: vec![true],
            jobs: vec![12],
            arrival_windows: vec![4.0],
            reps: 1,
            ..tiny_grid()
        };
        let r = run_grid(&base, &grid, 2);
        assert_eq!(r.trials.len(), 1);
        let t = &r.trials[0];
        assert_eq!(
            t.downloads, 12,
            "every job completes despite the 20x-slow cache"
        );
    }
}
