//! Parameter grids: axes → cartesian product of [`TrialSpec`]s.
//!
//! A [`GridSpec`] names one value list per experimental axis (client
//! method, cache capacity scale, client count, arrival window, Zipf
//! skew, file-size mix, fault profile, redirection policy) plus the
//! shared knobs every trial inherits (sites, catalog, background
//! load). `trials()` expands the cartesian product, `reps` innermost,
//! into a flat list of fully-resolved [`TrialSpec`]s.
//!
//! Every trial's campaign seed is **stateless**: a pure hash of the
//! root seed, the cell's workload label (excluding the method *and*
//! the redirection policy), and the repetition index. Adding an axis
//! value, reordering axes, or changing `reps` never perturbs the seed
//! (and therefore the result) of any other trial — the same property
//! the campaign layer gives per-site RNG streams — and the stash/http
//! twins of a cell, like its policy variants, share a seed so the
//! frontier and the policy table compare on identical workload draws.

use crate::config::toml::{self, Value};
use crate::federation::DownloadMethod;
use crate::redirector::policy::{PolicyKind, ALL_POLICIES};
use anyhow::{anyhow, bail, Context, Result};

/// Named file-size mixes a cell can run under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SizeProfile {
    /// The calibrated Table 2 mixture (the default workload).
    Paper,
    /// Software/conditions-style traffic: mostly KB–MB objects (the
    /// regime §6 says HTTP proxies are optimized for).
    Small,
    /// Analysis-dataset traffic: multi-GB files dominate (the regime
    /// StashCache exists for).
    Large,
}

impl SizeProfile {
    pub fn name(self) -> &'static str {
        match self {
            SizeProfile::Paper => "paper",
            SizeProfile::Small => "small",
            SizeProfile::Large => "large",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "paper" => Some(SizeProfile::Paper),
            "small" => Some(SizeProfile::Small),
            "large" => Some(SizeProfile::Large),
            _ => None,
        }
    }

    /// Override the workload's size distribution (no-op for `Paper`).
    pub fn apply(self, workload: &mut crate::config::WorkloadConfig) {
        use crate::config::schema::SizeDistribution;
        use crate::util::bytes::{GB, KB, MB};
        match self {
            SizeProfile::Paper => {}
            SizeProfile::Small => {
                workload.size_dist = SizeDistribution {
                    components: vec![
                        (0.40, (64.0 * KB as f64).ln(), 1.2),
                        (0.50, (8.0 * MB as f64).ln(), 0.8),
                        (0.10, (128.0 * MB as f64).ln(), 0.3),
                    ],
                    min: crate::util::ByteSize(512),
                    max: crate::util::ByteSize::gb(1),
                };
            }
            SizeProfile::Large => {
                workload.size_dist = SizeDistribution {
                    components: vec![
                        (0.10, (476.0 * MB as f64).ln(), 0.10),
                        (0.60, (2.335 * GB as f64).ln(), 0.05),
                        (0.30, (6.0 * GB as f64).ln(), 0.20),
                    ],
                    min: crate::util::ByteSize::mb(1),
                    max: crate::util::ByteSize::gb(10),
                };
            }
        }
    }
}

/// Named fault schedules a cell can run under. Instants are fractions
/// of the cell's arrival window, so one profile scales across cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultProfile {
    /// No faults (the timeline stays empty, so the run is
    /// timing-identical to a plain campaign).
    None,
    /// The first campaign site's nearest cache dies at half the
    /// arrival window and never recovers (the canonical chaos drill).
    CacheOutage,
    /// Origin 0's DTN capacity drops to 25% from 0.1·window to
    /// 0.9·window.
    OriginBrownout,
    /// Gray failure: the first campaign site's nearest cache degrades
    /// to 5% of its serving capacity (≈20× slower) at 0.1·window and
    /// never recovers — no death event, so only transfer deadlines and
    /// the circuit breaker get sessions off it.
    Degraded,
}

impl FaultProfile {
    pub fn name(self) -> &'static str {
        match self {
            FaultProfile::None => "none",
            FaultProfile::CacheOutage => "cache-outage",
            FaultProfile::OriginBrownout => "origin-brownout",
            FaultProfile::Degraded => "degraded",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "none" => Some(FaultProfile::None),
            "cache-outage" => Some(FaultProfile::CacheOutage),
            "origin-brownout" => Some(FaultProfile::OriginBrownout),
            "degraded" => Some(FaultProfile::Degraded),
            _ => None,
        }
    }
}

/// Canonical short name of a download method (axis values + labels).
pub fn method_name(method: DownloadMethod) -> &'static str {
    match method {
        DownloadMethod::Stash => "stash",
        DownloadMethod::HttpProxy => "http",
    }
}

pub fn method_from_name(name: &str) -> Option<DownloadMethod> {
    match name {
        "stash" => Some(DownloadMethod::Stash),
        "http" => Some(DownloadMethod::HttpProxy),
        _ => None,
    }
}

/// One point of the grid: the axis values a trial resolves to.
#[derive(Debug, Clone, PartialEq)]
pub struct CellKey {
    pub method: DownloadMethod,
    /// Multiplier on every cache's configured capacity.
    pub capacity_scale: f64,
    /// Campaign job count (clients).
    pub jobs: usize,
    /// Poisson arrival window in seconds (rate = jobs / window).
    pub arrival_window_secs: f64,
    pub zipf_s: f64,
    pub size_profile: SizeProfile,
    pub fault_profile: FaultProfile,
    /// Redirection policy the federation runs this cell under.
    pub policy: PolicyKind,
    /// Transfer-deadline multiplier this cell runs under (0 = off).
    pub deadline_factor: f64,
    /// Circuit breaker armed for this cell?
    pub breaker: bool,
}

impl CellKey {
    /// Canonical label of the cell's workload axes — everything except
    /// the method, the policy, and the resilience knobs. The policy
    /// comparison table pairs cells on this (same workload, different
    /// placement rule), and the breaker-on/off variants of a cell hash
    /// it for their shared seed: resilience settings never perturb the
    /// workload realization they are measured against.
    pub fn workload_label(&self) -> String {
        format!(
            "cap={:.2} jobs={} window={:.1} zipf={:.2} sizes={} faults={}",
            self.capacity_scale,
            self.jobs,
            self.arrival_window_secs,
            self.zipf_s,
            self.size_profile.name(),
            self.fault_profile.name(),
        )
    }

    /// Canonical label of the cell *excluding* the method axis — the
    /// key the frontier report pairs proxy and StashCache cells on
    /// (twins share the policy and resilience knobs, so those are part
    /// of this label).
    pub fn base_label(&self) -> String {
        format!(
            "{} policy={} deadline={:.2} breaker={}",
            self.workload_label(),
            self.policy.name(),
            self.deadline_factor,
            if self.breaker { "on" } else { "off" },
        )
    }

    /// Canonical label of the full cell (seed material + report rows).
    pub fn label(&self) -> String {
        format!("method={} {}", method_name(self.method), self.base_label())
    }

    /// Pairing key of the resilience table: everything except the
    /// breaker axis, so the breaker-on and breaker-off runs of one
    /// cell (identical workload seed, identical fault schedule) land
    /// in one row.
    pub fn resilience_pair_label(&self) -> String {
        format!(
            "method={} {} policy={} deadline={:.2}",
            method_name(self.method),
            self.workload_label(),
            self.policy.name(),
            self.deadline_factor,
        )
    }
}

/// One fully-resolved trial: a cell, a repetition, and its seed.
#[derive(Debug, Clone, PartialEq)]
pub struct TrialSpec {
    /// Position in grid order (result slot, independent of execution
    /// order).
    pub index: usize,
    pub cell: CellKey,
    pub rep: usize,
    /// Campaign seed, derived statelessly from the root seed.
    pub seed: u64,
}

/// SplitMix64 finalizer (good avalanche over the XOR-combined inputs).
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Stateless per-trial seed: pure in (root, workload axes, rep).
///
/// Deliberately hashes [`CellKey::workload_label`] — *excluding* the
/// method and the redirection policy — so the stash/http twins of a
/// frontier pair **and** every policy variant of a cell run the
/// **identical workload realization** (same Poisson arrivals, same
/// Zipf file draws). The frontier's %Δ and the policy table's
/// origin-byte gaps then measure the method/policy, not workload-draw
/// noise, exactly like §4.1's four-passes-per-file design. (The label
/// format predates the policy axis, so pre-policy cells keep their
/// historical seeds.)
pub fn trial_seed(root_seed: u64, cell: &CellKey, rep: usize) -> u64 {
    let cell_hash = crate::util::fnv1a(cell.workload_label().as_bytes());
    splitmix64(root_seed ^ cell_hash ^ splitmix64(rep as u64 + 1))
}

/// The sweep description: one value list per axis plus shared knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct GridSpec {
    pub name: String,
    pub root_seed: u64,
    /// Repetitions per cell (seeds differ per rep).
    pub reps: usize,
    // Axes.
    pub methods: Vec<DownloadMethod>,
    pub capacity_scales: Vec<f64>,
    pub jobs: Vec<usize>,
    pub arrival_windows: Vec<f64>,
    pub zipf_s: Vec<f64>,
    pub size_profiles: Vec<SizeProfile>,
    pub fault_profiles: Vec<FaultProfile>,
    /// Redirection policies (cache-selection rules) to sweep.
    pub policies: Vec<PolicyKind>,
    /// Transfer-deadline multipliers to sweep (0 = deadlines off).
    pub deadline_factors: Vec<f64>,
    /// Circuit-breaker settings to sweep (`[false, true]` gives the
    /// resilience table its breaker-on/off pairs).
    pub breakers: Vec<bool>,
    // Shared trial knobs.
    pub sites: Vec<String>,
    pub experiment: String,
    pub catalog_files: u64,
    pub files_per_job: (u64, u64),
    pub background_flows: usize,
    /// Also run the §4.1 serial scenario once and report its Table 3
    /// cells next to the campaign cells.
    pub table3_cell: bool,
}

impl GridSpec {
    /// A small default grid for smoke runs and CI: 2 methods ×
    /// 2 capacities × 2 job counts × 2 fault profiles = 16 trials.
    pub fn smoke() -> Self {
        GridSpec {
            name: "smoke".into(),
            root_seed: 20190728,
            reps: 1,
            methods: vec![DownloadMethod::Stash, DownloadMethod::HttpProxy],
            capacity_scales: vec![0.25, 1.0],
            jobs: vec![8, 32],
            arrival_windows: vec![20.0],
            zipf_s: vec![1.1],
            size_profiles: vec![SizeProfile::Paper],
            fault_profiles: vec![FaultProfile::None, FaultProfile::CacheOutage],
            policies: vec![PolicyKind::Nearest],
            deadline_factors: vec![0.0],
            breakers: vec![false],
            sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
            experiment: "gwosc".into(),
            catalog_files: 64,
            files_per_job: (1, 1),
            background_flows: 1,
            table3_cell: false,
        }
    }

    /// The redirection-policy smoke preset: every cache-selection
    /// policy × both client methods on one Zipf-skewed shared-
    /// namespace cell. Three compute sites each with a local cache
    /// pull hot files from one catalog, so `nearest` fetches a hot
    /// file from the origin once *per site* while `consistent-hash`
    /// converges the federation on one cache and fetches it once —
    /// the frontier and policy tables surface the origin-byte gap.
    pub fn policy_smoke() -> Self {
        GridSpec {
            name: "policy".into(),
            root_seed: 20190728,
            reps: 1,
            methods: vec![DownloadMethod::Stash, DownloadMethod::HttpProxy],
            capacity_scales: vec![1.0],
            jobs: vec![30],
            arrival_windows: vec![10.0],
            zipf_s: vec![1.3],
            size_profiles: vec![SizeProfile::Paper],
            fault_profiles: vec![FaultProfile::None],
            policies: ALL_POLICIES.to_vec(),
            deadline_factors: vec![0.0],
            breakers: vec![false],
            sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
            experiment: "gwosc".into(),
            catalog_files: 12,
            files_per_job: (1, 1),
            background_flows: 1,
            table3_cell: false,
        }
    }

    /// The headline preset: the paper's proxy-vs-StashCache comparison
    /// as a frontier over job count and file-size mix, with the §4.1
    /// Table 3 scenario reproduced as one cell of the grid.
    pub fn proxy_vs_stash() -> Self {
        GridSpec {
            name: "proxy-vs-stash".into(),
            root_seed: 20190728,
            reps: 2,
            methods: vec![DownloadMethod::Stash, DownloadMethod::HttpProxy],
            capacity_scales: vec![1.0],
            jobs: vec![16, 64],
            arrival_windows: vec![30.0],
            zipf_s: vec![1.1],
            size_profiles: vec![SizeProfile::Paper, SizeProfile::Small],
            fault_profiles: vec![FaultProfile::None],
            policies: vec![PolicyKind::Nearest],
            deadline_factors: vec![0.0],
            breakers: vec![false],
            sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
            experiment: "gwosc".into(),
            catalog_files: 128,
            files_per_job: (1, 1),
            background_flows: 1,
            table3_cell: true,
        }
    }

    /// The gray-failure resilience preset: a no-fault baseline and a
    /// degraded-cache cell (first site's nearest cache 20× slower, no
    /// death event), each run with transfer deadlines armed and the
    /// breaker both off and on. The breaker twins share a workload
    /// seed and a fault schedule, so the resilience table isolates
    /// what the breaker buys: breaker-on goodput must beat breaker-off
    /// under the identical gray failure.
    pub fn resilience() -> Self {
        GridSpec {
            name: "resilience".into(),
            root_seed: 20190728,
            reps: 1,
            methods: vec![DownloadMethod::Stash],
            capacity_scales: vec![1.0],
            jobs: vec![48],
            arrival_windows: vec![20.0],
            zipf_s: vec![1.1],
            size_profiles: vec![SizeProfile::Paper],
            fault_profiles: vec![FaultProfile::None, FaultProfile::Degraded],
            policies: vec![PolicyKind::Nearest],
            deadline_factors: vec![3.0],
            breakers: vec![false, true],
            sites: vec!["syracuse".into(), "nebraska".into(), "chicago".into()],
            experiment: "gwosc".into(),
            catalog_files: 64,
            files_per_job: (1, 1),
            background_flows: 1,
            table3_cell: false,
        }
    }

    /// Number of campaign trials the grid expands to.
    pub fn trial_count(&self) -> usize {
        self.methods.len()
            * self.capacity_scales.len()
            * self.jobs.len()
            * self.arrival_windows.len()
            * self.zipf_s.len()
            * self.size_profiles.len()
            * self.fault_profiles.len()
            * self.policies.len()
            * self.deadline_factors.len()
            * self.breakers.len()
            * self.reps
    }

    /// Expand the cartesian product into grid order (`reps` innermost).
    pub fn trials(&self) -> Vec<TrialSpec> {
        let mut out = Vec::with_capacity(self.trial_count());
        let mut index = 0;
        for &method in &self.methods {
            for &capacity_scale in &self.capacity_scales {
                for &jobs in &self.jobs {
                    for &arrival_window_secs in &self.arrival_windows {
                        for &zipf_s in &self.zipf_s {
                            for &size_profile in &self.size_profiles {
                                for &fault_profile in &self.fault_profiles {
                                    for &policy in &self.policies {
                                        for &deadline_factor in &self.deadline_factors {
                                            for &breaker in &self.breakers {
                                                let cell = CellKey {
                                                    method,
                                                    capacity_scale,
                                                    jobs,
                                                    arrival_window_secs,
                                                    zipf_s,
                                                    size_profile,
                                                    fault_profile,
                                                    policy,
                                                    deadline_factor,
                                                    breaker,
                                                };
                                                for rep in 0..self.reps {
                                                    out.push(TrialSpec {
                                                        index,
                                                        cell: cell.clone(),
                                                        rep,
                                                        seed: trial_seed(
                                                            self.root_seed,
                                                            &cell,
                                                            rep,
                                                        ),
                                                    });
                                                    index += 1;
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Structural sanity (axes non-empty, values in range).
    pub fn validate(&self) -> Result<()> {
        if self.reps == 0 {
            bail!("grid reps must be >= 1");
        }
        for (axis, empty) in [
            ("methods", self.methods.is_empty()),
            ("capacity_scales", self.capacity_scales.is_empty()),
            ("jobs", self.jobs.is_empty()),
            ("arrival_window_secs", self.arrival_windows.is_empty()),
            ("zipf_s", self.zipf_s.is_empty()),
            ("size_profiles", self.size_profiles.is_empty()),
            ("fault_profiles", self.fault_profiles.is_empty()),
            ("policies", self.policies.is_empty()),
            ("deadline_factors", self.deadline_factors.is_empty()),
            ("breakers", self.breakers.is_empty()),
        ] {
            if empty {
                bail!("grid axis {axis:?} is empty");
            }
        }
        if self.capacity_scales.iter().any(|&s| s <= 0.0) {
            bail!("capacity scales must be positive");
        }
        if self.jobs.iter().any(|&j| j == 0) {
            bail!("job counts must be >= 1");
        }
        if self.arrival_windows.iter().any(|&w| w <= 0.0) {
            bail!("arrival windows must be positive seconds");
        }
        if self.zipf_s.iter().any(|&z| z < 0.0) {
            bail!("zipf skew must be >= 0");
        }
        if self
            .deadline_factors
            .iter()
            .any(|&f| !f.is_finite() || f < 0.0)
        {
            bail!("deadline factors must be finite and >= 0 (0 disables deadlines)");
        }
        // Duplicate axis values would replay identical cell labels —
        // and therefore identical stateless seeds — corrupting cell
        // statistics (zero-variance "reps") and the frontier pairing.
        let unique = |mut labels: Vec<String>, axis: &str| -> Result<()> {
            let n = labels.len();
            labels.sort_unstable();
            labels.dedup();
            if labels.len() != n {
                bail!("duplicate values in grid axis {axis:?}");
            }
            Ok(())
        };
        unique(
            self.methods.iter().map(|&m| method_name(m).to_string()).collect(),
            "methods",
        )?;
        unique(
            self.capacity_scales.iter().map(|s| format!("{s:.2}")).collect(),
            "capacity_scales",
        )?;
        unique(self.jobs.iter().map(|j| j.to_string()).collect(), "jobs")?;
        unique(
            self.arrival_windows.iter().map(|w| format!("{w:.1}")).collect(),
            "arrival_window_secs",
        )?;
        unique(
            self.zipf_s.iter().map(|z| format!("{z:.2}")).collect(),
            "zipf_s",
        )?;
        unique(
            self.size_profiles.iter().map(|p| p.name().to_string()).collect(),
            "size_profiles",
        )?;
        unique(
            self.fault_profiles.iter().map(|p| p.name().to_string()).collect(),
            "fault_profiles",
        )?;
        unique(
            self.policies.iter().map(|p| p.name().to_string()).collect(),
            "policies",
        )?;
        unique(
            self.deadline_factors.iter().map(|f| format!("{f:.2}")).collect(),
            "deadline_factors",
        )?;
        unique(
            self.breakers.iter().map(|b| b.to_string()).collect(),
            "breakers",
        )?;
        if self.sites.is_empty() {
            bail!("grid has no sites");
        }
        let mut names: Vec<&String> = self.sites.iter().collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != self.sites.len() {
            bail!("duplicate sites in grid");
        }
        if self.files_per_job.0 == 0 || self.files_per_job.0 > self.files_per_job.1 {
            bail!("files_per_job range invalid");
        }
        if self.catalog_files == 0 {
            bail!("catalog_files must be >= 1");
        }
        Ok(())
    }

    /// Parse a grid from a `[sweep]` TOML table (axes as arrays).
    ///
    /// Strict: unknown keys, wrong-typed values, and negative integers
    /// are errors — never silently replaced by defaults. Omitted keys
    /// inherit the [`GridSpec::smoke`] baseline.
    pub fn from_toml(text: &str) -> Result<Self> {
        const KNOWN_KEYS: [&str; 19] = [
            "name", "seed", "reps", "methods", "capacity_scales", "jobs",
            "arrival_window_secs", "zipf_s", "size_profiles", "fault_profiles", "policies",
            "deadline_factors", "breakers", "sites", "experiment", "catalog_files",
            "files_per_job", "background_flows", "table3_cell",
        ];
        let root = toml::parse(text).map_err(|e| anyhow!("{e}"))?;
        let sweep = root
            .get("sweep")
            .and_then(Value::as_table)
            .ok_or_else(|| anyhow!("grid TOML needs a [sweep] table"))?;
        for key in sweep.keys() {
            if !KNOWN_KEYS.contains(&key.as_str()) {
                bail!(
                    "unknown key {key:?} in [sweep] (known: {})",
                    KNOWN_KEYS.join(", ")
                );
            }
        }
        let mut grid = GridSpec::smoke();
        if let Some(v) = sweep.get("name") {
            grid.name = req_str(v, "name")?;
        }
        if let Some(v) = sweep.get("seed") {
            grid.root_seed = req_uint(v, "seed")?;
        }
        if let Some(v) = sweep.get("reps") {
            grid.reps = req_uint(v, "reps")? as usize;
        }
        if let Some(v) = sweep.get("methods") {
            grid.methods = req_array(v, "methods")?
                .iter()
                .map(|v| {
                    let name = req_str(v, "methods entry")?;
                    method_from_name(&name)
                        .ok_or_else(|| anyhow!("unknown method {name:?} (stash|http)"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = sweep.get("capacity_scales") {
            grid.capacity_scales = float_array(v, "capacity_scales")?;
        }
        if let Some(v) = sweep.get("jobs") {
            grid.jobs = req_array(v, "jobs")?
                .iter()
                .map(|v| req_uint(v, "jobs entry").map(|i| i as usize))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = sweep.get("arrival_window_secs") {
            grid.arrival_windows = float_array(v, "arrival_window_secs")?;
        }
        if let Some(v) = sweep.get("zipf_s") {
            grid.zipf_s = float_array(v, "zipf_s")?;
        }
        if let Some(v) = sweep.get("size_profiles") {
            grid.size_profiles = req_array(v, "size_profiles")?
                .iter()
                .map(|v| {
                    let name = req_str(v, "size_profiles entry")?;
                    SizeProfile::from_name(&name)
                        .ok_or_else(|| anyhow!("unknown size profile {name:?} (paper|small|large)"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = sweep.get("fault_profiles") {
            grid.fault_profiles = req_array(v, "fault_profiles")?
                .iter()
                .map(|v| {
                    let name = req_str(v, "fault_profiles entry")?;
                    FaultProfile::from_name(&name).ok_or_else(|| {
                        anyhow!(
                            "unknown fault profile {name:?} \
                             (none|cache-outage|origin-brownout|degraded)"
                        )
                    })
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = sweep.get("policies") {
            grid.policies = req_array(v, "policies")?
                .iter()
                .map(|v| {
                    let name = req_str(v, "policies entry")?;
                    PolicyKind::from_name(&name).ok_or_else(|| {
                        anyhow!(
                            "unknown redirection policy {name:?} ({})",
                            crate::redirector::POLICY_NAMES
                        )
                    })
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = sweep.get("deadline_factors") {
            grid.deadline_factors = float_array(v, "deadline_factors")?;
        }
        if let Some(v) = sweep.get("breakers") {
            grid.breakers = req_array(v, "breakers")?
                .iter()
                .map(|v| {
                    v.as_bool()
                        .ok_or_else(|| anyhow!("breakers entries must be booleans"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = sweep.get("sites") {
            grid.sites = req_array(v, "sites")?
                .iter()
                .map(|v| req_str(v, "sites entry"))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = sweep.get("experiment") {
            grid.experiment = req_str(v, "experiment")?;
        }
        if let Some(v) = sweep.get("catalog_files") {
            grid.catalog_files = req_uint(v, "catalog_files")?;
        }
        if let Some(v) = sweep.get("files_per_job") {
            let items = req_array(v, "files_per_job")?;
            if items.len() != 2 {
                bail!("files_per_job must be [lo, hi]");
            }
            grid.files_per_job = (
                req_uint(&items[0], "files_per_job lo")?,
                req_uint(&items[1], "files_per_job hi")?,
            );
        }
        if let Some(v) = sweep.get("background_flows") {
            grid.background_flows = req_uint(v, "background_flows")? as usize;
        }
        if let Some(v) = sweep.get("table3_cell") {
            grid.table3_cell = v
                .as_bool()
                .ok_or_else(|| anyhow!("table3_cell must be a boolean"))?;
        }
        grid.validate().context("invalid sweep grid")?;
        Ok(grid)
    }
}

fn req_str(v: &Value, what: &str) -> Result<String> {
    v.as_str()
        .map(str::to_string)
        .ok_or_else(|| anyhow!("{what} must be a string"))
}

fn req_uint(v: &Value, what: &str) -> Result<u64> {
    let i = v.as_int().ok_or_else(|| anyhow!("{what} must be an integer"))?;
    if i < 0 {
        bail!("{what} must be non-negative, got {i}");
    }
    Ok(i as u64)
}

fn req_array<'a>(v: &'a Value, what: &str) -> Result<&'a [Value]> {
    v.as_array().ok_or_else(|| anyhow!("{what} must be an array"))
}

fn float_array(v: &Value, what: &str) -> Result<Vec<f64>> {
    req_array(v, what)?
        .iter()
        .map(|v| {
            v.as_float()
                .ok_or_else(|| anyhow!("{what} entries must be numbers"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_expansion_counts_and_orders() {
        let grid = GridSpec {
            reps: 2,
            ..GridSpec::smoke()
        };
        let trials = grid.trials();
        assert_eq!(trials.len(), grid.trial_count());
        assert_eq!(trials.len(), 2 * 2 * 2 * 1 * 1 * 1 * 2 * 2);
        // Indices are grid positions; reps of one cell are adjacent.
        for (i, t) in trials.iter().enumerate() {
            assert_eq!(t.index, i);
        }
        assert_eq!(trials[0].cell, trials[1].cell);
        assert_eq!(trials[0].rep, 0);
        assert_eq!(trials[1].rep, 1);
        assert_ne!(trials[0].seed, trials[1].seed, "reps draw distinct seeds");
    }

    #[test]
    fn trial_seeds_are_stateless() {
        let grid = GridSpec::smoke();
        let trials = grid.trials();
        // Extending an axis must not change existing cells' seeds.
        let bigger = GridSpec {
            jobs: vec![8, 32, 128],
            ..grid.clone()
        };
        let bigger_trials = bigger.trials();
        for t in &trials {
            let same = bigger_trials
                .iter()
                .find(|b| b.cell == t.cell && b.rep == t.rep)
                .expect("cell survives axis extension");
            assert_eq!(same.seed, t.seed, "seed perturbed for {}", t.cell.label());
        }
    }

    #[test]
    fn frontier_twins_share_workload_seeds() {
        // The stash and http variants of one cell must draw the same
        // arrivals/files: identical seed, per rep.
        let grid = GridSpec {
            reps: 2,
            ..GridSpec::smoke()
        };
        let trials = grid.trials();
        for t in trials.iter().filter(|t| t.cell.method == DownloadMethod::Stash) {
            let twin = trials
                .iter()
                .find(|o| {
                    o.cell.method == DownloadMethod::HttpProxy
                        && o.cell.base_label() == t.cell.base_label()
                        && o.rep == t.rep
                })
                .expect("http twin exists");
            assert_eq!(t.seed, twin.seed, "pair {} rep {}", t.cell.base_label(), t.rep);
        }
    }

    #[test]
    fn policy_axis_expands_and_shares_workload_seeds() {
        let grid = GridSpec {
            policies: ALL_POLICIES.to_vec(),
            ..GridSpec::smoke()
        };
        let trials = grid.trials();
        assert_eq!(trials.len(), GridSpec::smoke().trial_count() * 4);
        // Every policy variant of a cell draws the identical workload:
        // same seed, distinct full label.
        for t in trials.iter().filter(|t| t.cell.policy == PolicyKind::Nearest) {
            for other in ALL_POLICIES.into_iter().filter(|&p| p != PolicyKind::Nearest) {
                let variant = trials
                    .iter()
                    .find(|o| {
                        o.cell.policy == other
                            && o.cell.method == t.cell.method
                            && o.cell.workload_label() == t.cell.workload_label()
                            && o.rep == t.rep
                    })
                    .expect("policy variant exists");
                assert_eq!(t.seed, variant.seed, "workload seed shared across policies");
                assert_ne!(t.cell.label(), variant.cell.label());
            }
        }
    }

    #[test]
    fn policies_parse_from_toml() {
        let grid =
            GridSpec::from_toml("[sweep]\npolicies = [\"nearest\", \"consistent-hash\"]\n")
                .unwrap();
        assert_eq!(
            grid.policies,
            vec![PolicyKind::Nearest, PolicyKind::ConsistentHash]
        );
        assert!(GridSpec::from_toml("[sweep]\npolicies = [\"geo\"]\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\npolicies = []\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\npolicies = [\"nearest\", \"nearest\"]\n").is_err());
    }

    #[test]
    fn policy_smoke_preset_validates() {
        let grid = GridSpec::policy_smoke();
        grid.validate().unwrap();
        assert_eq!(grid.trial_count(), 2 * 4, "4 policies × stash/http");
    }

    #[test]
    fn resilience_axes_expand_and_share_workload_seeds() {
        let grid = GridSpec {
            deadline_factors: vec![3.0],
            breakers: vec![false, true],
            ..GridSpec::smoke()
        };
        let trials = grid.trials();
        assert_eq!(trials.len(), GridSpec::smoke().trial_count() * 2);
        // The breaker-on twin of every cell draws the identical
        // workload (same seed) — the resilience table's pairing rests
        // on this — while the full label still distinguishes them.
        for t in trials.iter().filter(|t| !t.cell.breaker) {
            let twin = trials
                .iter()
                .find(|o| {
                    o.cell.breaker
                        && o.cell.resilience_pair_label() == t.cell.resilience_pair_label()
                        && o.rep == t.rep
                })
                .expect("breaker twin exists");
            assert_eq!(t.seed, twin.seed, "workload seed shared across breaker");
            assert_ne!(t.cell.label(), twin.cell.label());
        }
        GridSpec::resilience().validate().unwrap();
    }

    #[test]
    fn resilience_axes_parse_from_toml() {
        let g = GridSpec::from_toml(
            "[sweep]\ndeadline_factors = [2.0]\nbreakers = [false, true]\n",
        )
        .unwrap();
        assert_eq!(g.deadline_factors, vec![2.0]);
        assert_eq!(g.breakers, vec![false, true]);
        assert!(GridSpec::from_toml("[sweep]\nfault_profiles = [\"degraded\"]\n").is_ok());
        assert!(GridSpec::from_toml("[sweep]\nbreakers = [1]\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\nbreakers = []\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\ndeadline_factors = [-1.0]\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\nbreakers = [true, true]\n").is_err());
    }

    #[test]
    fn labels_distinguish_cells() {
        let grid = GridSpec::smoke();
        let trials = grid.trials();
        let mut labels: Vec<String> = trials.iter().map(|t| t.cell.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), grid.trial_count() / grid.reps);
    }

    #[test]
    fn toml_round_trip() {
        let text = r#"
            [sweep]
            name = "custom"
            seed = 7
            reps = 3
            methods = ["stash", "http"]
            capacity_scales = [0.5, 1.0]
            jobs = [4]
            arrival_window_secs = [10.0]
            zipf_s = [1.3]
            size_profiles = ["paper", "large"]
            fault_profiles = ["none"]
            sites = ["syracuse", "chicago"]
            experiment = "gwosc"
            catalog_files = 32
            files_per_job = [1, 2]
            background_flows = 0
            table3_cell = true
        "#;
        let grid = GridSpec::from_toml(text).unwrap();
        assert_eq!(grid.name, "custom");
        assert_eq!(grid.root_seed, 7);
        assert_eq!(grid.reps, 3);
        assert_eq!(grid.methods.len(), 2);
        assert_eq!(grid.capacity_scales, vec![0.5, 1.0]);
        assert_eq!(grid.size_profiles, vec![SizeProfile::Paper, SizeProfile::Large]);
        assert_eq!(grid.files_per_job, (1, 2));
        assert!(grid.table3_cell);
        assert_eq!(grid.trial_count(), 2 * 2 * 2 * 3);
    }

    #[test]
    fn duplicate_axis_values_rejected() {
        let grid = GridSpec {
            jobs: vec![8, 8],
            ..GridSpec::smoke()
        };
        assert!(grid.validate().is_err(), "repeated jobs value");
        // Values that collide in the cell *label* (the seed material)
        // are duplicates too, even if not bit-equal.
        let grid = GridSpec {
            zipf_s: vec![1.111, 1.112],
            ..GridSpec::smoke()
        };
        assert!(grid.validate().is_err(), "label-colliding zipf values");
        assert!(GridSpec::smoke().validate().is_ok());
    }

    #[test]
    fn toml_rejects_bad_axes() {
        assert!(GridSpec::from_toml("[sweep]\nmethods = [\"ftp\"]\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\nsize_profiles = [\"huge\"]\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\njobs = []\n").is_err());
        assert!(GridSpec::from_toml("no sweep table = 1\n").is_err());
    }

    #[test]
    fn toml_is_strict_about_keys_types_and_signs() {
        // Negative integers must not wrap into huge unsigned values.
        assert!(GridSpec::from_toml("[sweep]\nreps = -1\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\njobs = [-4]\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\ncatalog_files = -1\n").is_err());
        // Wrong-typed scalars error instead of silently keeping the
        // smoke default.
        assert!(GridSpec::from_toml("[sweep]\nreps = \"3\"\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\ntable3_cell = 1\n").is_err());
        assert!(GridSpec::from_toml("[sweep]\nmethods = \"stash\"\n").is_err());
        // Misspelled keys error instead of being ignored.
        let e = GridSpec::from_toml("[sweep]\ncapacity_scale = [0.5]\n").unwrap_err();
        assert!(e.to_string().contains("unknown key"), "{e}");
    }

    #[test]
    fn size_profiles_keep_weights_normalised() {
        // FederationConfig::validate requires Σw == 1 for the mixture.
        for p in [SizeProfile::Paper, SizeProfile::Small, SizeProfile::Large] {
            let mut w = crate::config::defaults::paper_workload();
            p.apply(&mut w);
            let total: f64 = w.size_dist.components.iter().map(|c| c.0).sum();
            assert!((total - 1.0).abs() < 1e-9, "{}: Σw = {total}", p.name());
        }
    }
}
