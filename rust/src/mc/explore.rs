//! The exhaustive explorer: stateless BFS over choice-index prefixes.
//!
//! The federation is not cloneable (it owns a `Box<dyn
//! RedirectionPolicy>`), so instead of snapshotting states the search
//! re-materialises each node by rebuilding the scenario and replaying
//! the choice-index prefix that first reached it. Builders are pure
//! and the engine is deterministic, so replay is exact; breadth-first
//! order keeps prefixes (and therefore counterexample traces) short.
//!
//! Engine `assert!`/`debug_assert!` failures inside a fired transition
//! are caught with `catch_unwind` and reported as violations carrying
//! the full event trace — the checker treats the engine's own internal
//! assertions as invariants too.

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use crate::federation::driver::{McChoice, SessionEngine};
use crate::federation::session::Phase;
use crate::federation::FedSim;

use super::scenario::Scenario;
use super::snapshot::state_hash;

/// A counterexample: which invariant broke, the numbered event trace
/// from the initial state, and the replayable choice-index list.
#[derive(Debug, Clone)]
pub struct Violation {
    /// What went wrong (invariant text or engine panic message).
    pub invariant: String,
    /// Human-readable event descriptions, one per fired choice.
    pub trace: Vec<String>,
    /// Choice indices to feed back via `check --replay`.
    pub choices: Vec<usize>,
}

/// Outcome of exhaustively exploring one scenario.
#[derive(Debug, Clone)]
pub struct CheckReport {
    pub scenario: &'static str,
    /// Distinct states reached (hash-deduplicated), including the root.
    pub states: usize,
    /// Transitions fired (edges explored, including re-entries into
    /// already-visited states).
    pub transitions: usize,
    /// Distinct terminal states (all sessions finished).
    pub terminals: usize,
    /// Longest choice-prefix among first visits.
    pub max_depth: usize,
    /// True if the transition budget ran out before the frontier
    /// drained (liveness is then skipped — safety still holds for the
    /// explored prefix).
    pub truncated: bool,
    pub violation: Option<Violation>,
}

/// What one fired transition produced, evaluated inside the
/// `catch_unwind` boundary so engine panics become violations.
struct Fired {
    hash: u64,
    n_choices: usize,
    outstanding: usize,
    violation: Option<String>,
}

fn eval_state(fed: &FedSim, engine: &SessionEngine) -> Fired {
    let outstanding = engine.outstanding();
    let mut violation = per_state_violation(fed, engine);
    if violation.is_none() && outstanding == 0 {
        violation = terminal_violation(fed, engine);
    }
    Fired {
        hash: state_hash(fed, engine),
        n_choices: engine.mc_choices(fed).len(),
        outstanding,
        violation,
    }
}

/// Rebuild the scenario and replay a choice-index prefix. Panics (and
/// is expected to be wrapped in `catch_unwind`) if the engine trips an
/// assertion or the prefix diverges — the latter would mean a
/// non-deterministic builder, itself a bug worth surfacing.
fn replay(sc: &Scenario, prefix: &[usize]) -> (FedSim, SessionEngine) {
    let (mut fed, mut engine) = sc.build();
    for (step, &i) in prefix.iter().enumerate() {
        let choices = engine.mc_choices(&fed);
        let choice = choices
            .get(i)
            .unwrap_or_else(|| {
                panic!(
                    "replay diverged at step {step}: choice {i} of {} — \
                     scenario builder is not deterministic",
                    choices.len()
                )
            })
            .clone();
        engine.mc_fire(&mut fed, choice);
    }
    (fed, engine)
}

/// Exhaustively explore `sc`, firing at most `max_transitions` edges.
pub fn check_scenario(sc: &Scenario, max_transitions: usize) -> CheckReport {
    let mut report = CheckReport {
        scenario: sc.name,
        states: 0,
        transitions: 0,
        terminals: 0,
        max_depth: 0,
        truncated: false,
        violation: None,
    };

    // Root node.
    let root = match catch_unwind(AssertUnwindSafe(|| {
        let (fed, engine) = replay(sc, &[]);
        eval_state(&fed, &engine)
    })) {
        Ok(f) => f,
        Err(payload) => {
            report.violation = Some(build_violation(sc, vec![], panic_msg(payload)));
            return report;
        }
    };
    if let Some(msg) = root.violation {
        report.violation = Some(build_violation(sc, vec![], msg));
        return report;
    }

    // First choice-prefix that reached each visited state hash.
    let mut prefix_of: HashMap<u64, Vec<usize>> = HashMap::new();
    // Explored edges, for the liveness pass.
    let mut succ: HashMap<u64, Vec<u64>> = HashMap::new();
    let mut terminal_set: HashSet<u64> = HashSet::new();
    let mut frontier: VecDeque<(u64, usize)> = VecDeque::new();

    prefix_of.insert(root.hash, vec![]);
    report.states = 1;
    if root.outstanding == 0 {
        terminal_set.insert(root.hash);
        report.terminals = 1;
    } else if root.n_choices == 0 {
        report.violation = Some(build_violation(
            sc,
            vec![],
            "deadlock: sessions outstanding but no event enabled".into(),
        ));
        return report;
    } else {
        frontier.push_back((root.hash, root.n_choices));
    }

    'search: while let Some((hash, n_choices)) = frontier.pop_front() {
        let prefix = prefix_of[&hash].clone();
        for i in 0..n_choices {
            if report.transitions >= max_transitions {
                report.truncated = true;
                break 'search;
            }
            report.transitions += 1;

            let fired = catch_unwind(AssertUnwindSafe(|| {
                let (mut fed, mut engine) = replay(sc, &prefix);
                let choice = engine.mc_choices(&fed)[i].clone();
                engine.mc_fire(&mut fed, choice);
                eval_state(&fed, &engine)
            }));

            let mut next = prefix.clone();
            next.push(i);
            let fired = match fired {
                Ok(f) => f,
                Err(payload) => {
                    report.violation = Some(build_violation(sc, next, panic_msg(payload)));
                    break 'search;
                }
            };
            if let Some(msg) = fired.violation {
                report.violation = Some(build_violation(sc, next, msg));
                break 'search;
            }
            if fired.outstanding > 0 && fired.n_choices == 0 {
                report.violation = Some(build_violation(
                    sc,
                    next,
                    "deadlock: sessions outstanding but no event enabled".into(),
                ));
                break 'search;
            }

            succ.entry(hash).or_default().push(fired.hash);
            if !prefix_of.contains_key(&fired.hash) {
                report.states += 1;
                report.max_depth = report.max_depth.max(next.len());
                if fired.outstanding == 0 {
                    // Terminal states are not expanded: the run is
                    // over; late-scheduled faults firing into a drained
                    // federation are uninteresting.
                    terminal_set.insert(fired.hash);
                    report.terminals += 1;
                }
                prefix_of.insert(fired.hash, next);
                if fired.outstanding > 0 {
                    frontier.push_back((fired.hash, fired.n_choices));
                }
            }
        }
    }

    // Liveness: every explored state must be able to reach a terminal
    // state. Only meaningful when the graph is complete.
    if report.violation.is_none() && !report.truncated {
        if let Some(stuck) = unreaching_state(&prefix_of, &succ, &terminal_set) {
            let prefix = prefix_of[&stuck].clone();
            report.violation = Some(build_violation(
                sc,
                prefix,
                "liveness: state cannot reach any terminal state \
                 (lost wakeup or livelock)"
                    .into(),
            ));
        }
    }

    report
}

/// Reverse reachability from the terminal set; returns a state that
/// cannot reach termination (shortest first-visit prefix preferred).
fn unreaching_state(
    prefix_of: &HashMap<u64, Vec<usize>>,
    succ: &HashMap<u64, Vec<u64>>,
    terminal_set: &HashSet<u64>,
) -> Option<u64> {
    let mut rev: HashMap<u64, Vec<u64>> = HashMap::new();
    for (&from, outs) in succ {
        for &to in outs {
            rev.entry(to).or_default().push(from);
        }
    }
    let mut reaching: HashSet<u64> = terminal_set.clone();
    let mut queue: VecDeque<u64> = terminal_set.iter().copied().collect();
    while let Some(s) = queue.pop_front() {
        if let Some(preds) = rev.get(&s) {
            for &p in preds {
                if reaching.insert(p) {
                    queue.push_back(p);
                }
            }
        }
    }
    prefix_of
        .keys()
        .filter(|h| !reaching.contains(h))
        .min_by_key(|h| prefix_of[h].len())
        .copied()
}

/// Re-run a choice list step by step, describing each fired event.
/// Returns the trace lines plus an error if a step panicked, diverged,
/// or landed in a state violating an invariant.
pub fn replay_trace(sc: &Scenario, choices: &[usize]) -> (Vec<String>, Option<String>) {
    // The trace accumulates *across* the unwind boundary so a panicking
    // final step still yields the lines before it.
    let lines = Mutex::new(Vec::new());
    let result = catch_unwind(AssertUnwindSafe(|| {
        let (mut fed, mut engine) = sc.build();
        for (step, &i) in choices.iter().enumerate() {
            let enabled = engine.mc_choices(&fed);
            let choice = match enabled.get(i) {
                Some(c) => c.clone(),
                None => {
                    return Some(format!(
                        "step {step}: choice index {i} out of range \
                         ({} events enabled)",
                        enabled.len()
                    ));
                }
            };
            lines
                .lock()
                .unwrap()
                .push(format!("{step:3}. {}", describe(&choice, &fed, &engine)));
            engine.mc_fire(&mut fed, choice);
            if let Some(msg) = per_state_violation(&fed, &engine) {
                return Some(format!("invariant violated after step {step}: {msg}"));
            }
            if engine.outstanding() == 0 {
                if let Some(msg) = terminal_violation(&fed, &engine) {
                    return Some(format!("terminal invariant violated after step {step}: {msg}"));
                }
            }
        }
        None
    }));
    let error = match result {
        Ok(e) => e,
        Err(payload) => Some(format!("engine panic: {}", panic_msg(payload))),
    };
    (lines.into_inner().unwrap(), error)
}

/// Build a violation report by replaying and describing the trace.
fn build_violation(sc: &Scenario, choices: Vec<usize>, invariant: String) -> Violation {
    let (trace, _) = replay_trace(sc, &choices);
    Violation {
        invariant,
        trace,
        choices,
    }
}

fn describe(c: &McChoice, fed: &FedSim, engine: &SessionEngine) -> String {
    match c {
        McChoice::Timer { session, .. } => {
            let s = engine.session(*session);
            match s.phase {
                Phase::Pending => format!("session {} arrives", session.0),
                p => format!("session {} timer fires in {:?}", session.0, p),
            }
        }
        McChoice::Flow { flow, owner } => {
            let s = engine.session(*owner);
            format!(
                "flow {} of session {} completes (in {:?})",
                flow.0, owner.0, s.phase
            )
        }
        McChoice::Fault => match fed.peek_fault() {
            Some(ev) => format!("fault applies: {:?}", ev.kind),
            None => "fault applies".to_string(),
        },
    }
}

fn panic_msg(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

// ---------------------------------------------------------------------
// Invariants
// ---------------------------------------------------------------------

/// Invariants that must hold in *every* reached state: waiter
/// symmetry, cache-slot accounting, and byte accounting.
fn per_state_violation(fed: &FedSim, engine: &SessionEngine) -> Option<String> {
    // 1. Waiter symmetry. Every listed waiter is parked in JoinWait on
    // exactly that key, and every JoinWait session is listed exactly
    // once (count equality rules out double listing).
    let mut listed = 0usize;
    for ((site, path), ids) in engine.waiters() {
        if ids.is_empty() {
            return Some(format!("empty waiter list left under ({site}, {path})"));
        }
        for id in ids {
            listed += 1;
            let s = engine.session(*id);
            if s.phase != Phase::JoinWait {
                return Some(format!(
                    "stale waiter: session {} listed under ({site}, {path}) \
                     but is in {:?}",
                    id.0, s.phase
                ));
            }
            let key = s.waiting_on.as_ref().map(|(ws, wp)| (*ws, wp.as_str()));
            if key != Some((*site, path.as_str())) {
                return Some(format!(
                    "waiter key mismatch: session {} listed under \
                     ({site}, {path}) but waiting_on {:?}",
                    id.0, s.waiting_on
                ));
            }
        }
    }
    let mut parked = 0usize;
    for s in engine.sessions() {
        let in_join = s.phase == Phase::JoinWait;
        if in_join != s.waiting_on.is_some() {
            return Some(format!(
                "session {} is in {:?} but waiting_on is {:?}",
                s.id.0, s.phase, s.waiting_on
            ));
        }
        parked += in_join as usize;
    }
    if listed != parked {
        return Some(format!(
            "waiter-list entries ({listed}) != sessions parked in JoinWait ({parked})"
        ));
    }

    // 2. Slot accounting: cache_in_flight[site] == live assigned sessions.
    let mut live: HashMap<usize, u64> = HashMap::new();
    for s in engine.sessions() {
        if s.phase != Phase::Done {
            if let Some(site) = s.cache_site {
                *live.entry(site).or_insert(0) += 1;
            }
        }
    }
    for (&site, &n) in engine.cache_in_flight() {
        let expect = live.remove(&site).unwrap_or(0);
        if n != expect {
            return Some(format!(
                "cache_in_flight[{site}] is {n} but {expect} unfinished \
                 sessions are assigned to that cache"
            ));
        }
    }
    if let Some((&site, &n)) = live.iter().next() {
        return Some(format!(
            "{n} unfinished sessions assigned to cache {site} but no \
             cache_in_flight entry"
        ));
    }

    // 3. Byte accounting: usage == Σ resident chunk bytes, per cache.
    for (&site, cache) in &fed.caches {
        let sum: u64 = cache.residency_snapshot().iter().map(|(_, b)| b).sum();
        if sum != cache.usage().as_u64() {
            return Some(format!(
                "cache {site}: usage {} != sum of residency {sum}",
                cache.usage().as_u64()
            ));
        }
    }

    None
}

/// Invariants that must hold once every session has finished: all
/// bytes delivered, all bookkeeping drained, no leaked reservations.
fn terminal_violation(fed: &FedSim, engine: &SessionEngine) -> Option<String> {
    for s in engine.sessions() {
        if s.phase != Phase::Done {
            return Some(format!(
                "terminal state but session {} is in {:?}",
                s.id.0, s.phase
            ));
        }
        match &s.record {
            Some(r) if r.bytes == s.file.size.as_u64() => {}
            Some(r) => {
                return Some(format!(
                    "bytes not conserved: session {} delivered {} of {} bytes",
                    s.id.0,
                    r.bytes,
                    s.file.size.as_u64()
                ));
            }
            None => {
                return Some(format!("session {} is Done without a record", s.id.0));
            }
        }
    }
    if !engine.waiters().is_empty() {
        return Some(format!(
            "waiter lists not drained at termination: {:?}",
            engine.waiters().keys().collect::<Vec<_>>()
        ));
    }
    if !engine.flow_owners().is_empty() {
        return Some(format!(
            "flow ownership not drained at termination: {:?}",
            engine.flow_owners().keys().collect::<Vec<_>>()
        ));
    }
    if let Some((&site, &n)) = engine.cache_in_flight().iter().find(|&(_, &n)| n > 0) {
        return Some(format!(
            "cache_in_flight[{site}] is {n} at termination"
        ));
    }
    for (&site, cache) in &fed.caches {
        let leaked = cache.reservation_snapshot();
        if !leaked.is_empty() {
            return Some(format!(
                "cache {site} leaked reservations at termination: {leaked:?}"
            ));
        }
    }
    None
}
