//! Small-scope model checker for the session protocol.
//!
//! The session state machine (GeoResolve → CacheCheck →
//! FetchBegin/JoinWait → Transfer, plus failover and direct-origin
//! paths) interacts with faults, policy reroutes, and chunk
//! reservation — the classic breeding ground for lost-wakeup and
//! leaked-reservation bugs that randomized chaos runs only *sample*.
//! This module explores them *exhaustively*, in the small-scope spirit
//! of TLC and the machine-check exemplar: tiny scenarios (2–3
//! sessions, a cache or two, a fault pair), every event interleaving,
//! global invariants asserted at every reached state.
//!
//! ## How it works
//!
//! The deterministic engine always fires the virtual-time minimum of
//! its three event sources (timer queue, network completions, fault
//! schedule). [`SessionEngine::mc_choices`] exposes that arbitration
//! as a *choice list* — every enabled event — and
//! [`SessionEngine::mc_fire`] fires any one of them, clamping its
//! instant to the clocks already reached. Time is thereby abstracted
//! away: the checker explores event *orderings*, not durations, which
//! is exactly the space where wakeup/reservation protocols break.
//!
//! The explorer ([`explore`]) runs a depth-first search over choice
//! sequences. States are deduplicated by a canonical, time-free hash
//! ([`snapshot`]) over session phases, waiter lists, per-cache
//! residency/reservations, in-flight counts, link state, and the
//! fault schedule. Because the federation owns a `Box<dyn
//! RedirectionPolicy>` (not cloneable), the search is *stateless*:
//! each node is re-materialised by rebuilding the scenario and
//! replaying its choice-index prefix — builders are deterministic, so
//! replay is exact.
//!
//! ## Invariants
//!
//! Checked at **every** reached state:
//!
//! 1. **Waiter symmetry** — every id in a waiter list is a session
//!    parked in `JoinWait` on exactly that key, and every `JoinWait`
//!    session appears in exactly one list (no stale waiters, no lost
//!    parks).
//! 2. **Slot accounting** — `cache_in_flight[site]` equals the number
//!    of unfinished sessions assigned to `site` (every exit path
//!    releases its slot).
//! 3. **Byte accounting** — each cache's `usage` equals the sum of its
//!    resident chunk bytes.
//!
//! Checked at every **terminal** state (all sessions finished):
//!
//! 4. **Termination & conservation** — every session is `Done` with a
//!    record of exactly `file.size` bytes; waiter lists, flow
//!    ownership, and in-flight counts have drained to zero.
//! 5. **No leaked reservations** — every cache's pins and in-flight
//!    chunk bits are empty ([`crate::cache::CacheServer::reservation_snapshot`]).
//!
//! Liveness — *every session terminates* — is checked globally after
//! the search: every explored state must reach some terminal state in
//! the explored graph (reverse reachability); a state that cannot is a
//! lost wakeup or livelock, and a state with sessions outstanding but
//! no enabled event is a deadlock.
//!
//! ## Counterexamples
//!
//! A violation (invariant failure, engine panic, deadlock, or
//! unreachable termination) is reported as the full event trace from
//! the initial state — one numbered line per fired event — plus the
//! replayable choice-index list; `stashcache check --scenario NAME
//! --replay I,J,K` re-runs it step by step.
//!
//! [`SessionEngine::mc_choices`]: crate::federation::driver::SessionEngine::mc_choices
//! [`SessionEngine::mc_fire`]: crate::federation::driver::SessionEngine::mc_fire

pub mod explore;
pub mod scenario;
pub mod snapshot;

pub use explore::{check_scenario, replay_trace, CheckReport, Violation};
pub use scenario::{builtin_scenarios, Scenario};
