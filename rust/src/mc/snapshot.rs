//! Canonical, time-free state hashing for the explorer's visited set.
//!
//! Two states hash equal iff the protocol cannot tell them apart: the
//! snapshot covers session phases (with transfer kind), cache
//! assignment, retry/failover/join counters (capped — a retry loop
//! past the cap is behaviourally a self-loop), exclusion sets, waiter
//! lists, per-cache in-flight session counts, per-cache residency,
//! reservation, and poison state, link up/down state, which caches are
//! down, the length of the remaining fault schedule (the schedule
//! itself is fixed per scenario, so its suffix is determined by its
//! length), and — when the breaker is armed — each cache's health
//! score plus whether it currently admits clients. Clocks, sequence
//! numbers, and monitoring/RNG state are deliberately excluded: under
//! the checker's time abstraction they never influence which events
//! are enabled or what firing them does. The breaker's raw
//! `open_until` instant is a clock and is projected down to the one
//! bit the protocol observes (`admits` at the current instant);
//! likewise stale deadline generations are excluded — a stale
//! [`crate::federation::driver::EngineEvent::Deadline`] fires as a
//! pure no-op, a self-loop the search closes over.

use crate::federation::driver::SessionEngine;
use crate::federation::session::{Phase, Xfer};
use crate::federation::FedSim;
use crate::netsim::LinkId;

/// FNV-1a, 64-bit — tiny, dependency-free, and stable across runs
/// (unlike `DefaultHasher`, which is randomly seeded per process).
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn byte(&mut self, b: u8) {
        self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }

    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        for &b in s.as_bytes() {
            self.byte(b);
        }
    }
}

/// Counters above this cap hash alike: a session polling its Nth retry
/// behaves exactly like its (N+1)th, so folding them into one state
/// turns unbounded retry loops into self-loop edges the search can
/// close over.
const COUNTER_CAP: u32 = 9;

fn phase_code(p: Phase) -> u64 {
    match p {
        Phase::Pending => 0,
        Phase::GeoResolve => 1,
        Phase::CacheCheck => 2,
        Phase::FetchBegin => 3,
        Phase::JoinWait => 4,
        Phase::ProxyLookup => 5,
        Phase::ProxyConnect => 6,
        Phase::DirectConnect => 7,
        Phase::DirectFetch => 8,
        Phase::Transfer(Xfer::StashServe) => 9,
        Phase::Transfer(Xfer::StashFetch) => 10,
        Phase::Transfer(Xfer::ProxyRelay) => 11,
        Phase::Transfer(Xfer::DirectOrigin) => 12,
        Phase::Done => 13,
    }
}

/// Hash the protocol-relevant state of `(fed, engine)`.
pub fn state_hash(fed: &FedSim, engine: &SessionEngine) -> u64 {
    let mut h = Fnv::new();

    // Sessions, in id order.
    h.u64(engine.sessions().len() as u64);
    for s in engine.sessions() {
        h.u64(phase_code(s.phase));
        h.u64(s.cache_site.map_or(0, |c| c as u64 + 1));
        h.u64(s.retries.min(COUNTER_CAP) as u64);
        h.u64(s.failovers.min(COUNTER_CAP) as u64);
        h.u64(s.joins.min(COUNTER_CAP) as u64);
        let mut excluded = s.excluded_caches.clone();
        excluded.sort_unstable();
        h.u64(excluded.len() as u64);
        for e in excluded {
            h.u64(e as u64);
        }
        h.u64(s.direct as u64);
        h.u64(s.flow.is_some() as u64);
        match &s.waiting_on {
            Some((site, path)) => {
                h.u64(*site as u64 + 1);
                h.str(path);
            }
            None => h.u64(0),
        }
        h.u64(s.record.is_some() as u64);
    }

    // Waiter lists, key-sorted.
    let mut waiter_keys: Vec<&(usize, String)> = engine.waiters().keys().collect();
    waiter_keys.sort();
    h.u64(waiter_keys.len() as u64);
    for key in waiter_keys {
        h.u64(key.0 as u64);
        h.str(&key.1);
        for id in &engine.waiters()[key] {
            h.u64(id.0);
        }
    }

    // Per-cache in-flight session counts (zero entries are identical
    // to absent ones — a drained slot must not split states).
    let mut in_flight: Vec<(usize, u64)> = engine
        .cache_in_flight()
        .iter()
        .filter(|&(_, &n)| n > 0)
        .map(|(&s, &n)| (s, n))
        .collect();
    in_flight.sort_unstable();
    h.u64(in_flight.len() as u64);
    for (site, n) in in_flight {
        h.u64(site as u64);
        h.u64(n);
    }

    // Cache content: usage, residency, reservations — site-sorted.
    let mut cache_sites: Vec<usize> = fed.caches.keys().copied().collect();
    cache_sites.sort_unstable();
    for site in cache_sites {
        let cache = &fed.caches[&site];
        h.u64(site as u64);
        h.u64(cache.usage().as_u64());
        for (path, bytes) in cache.residency_snapshot() {
            h.str(&path);
            h.u64(bytes);
        }
        for (path, pins, chunks) in cache.reservation_snapshot() {
            h.str(&path);
            h.u64(pins as u64);
            for c in chunks {
                h.u64(c);
            }
        }
        let poisoned: Vec<&str> = cache.poisoned_paths().collect();
        h.u64(poisoned.len() as u64);
        for path in poisoned {
            h.str(path);
        }
        h.u64(fed.faults.is_cache_down(site) as u64);
    }

    // Link up/down bitmap and the remaining fault schedule length.
    for i in 0..fed.net.link_count() {
        h.byte(fed.net.link_is_up(LinkId(i as u32)) as u8);
    }
    h.u64(fed.pending_faults() as u64);
    h.u64(engine.outstanding() as u64);

    // Breaker health, site-sorted. The EWMA score is a deterministic
    // fold of the outcome stream; the trip instant is reduced to the
    // admit/eject bit at the current clock (see the module doc).
    if let Some(b) = &fed.breaker {
        let fp = b.fingerprint();
        h.u64(fp.len() as u64);
        for (site, score_bits, until) in fp {
            h.u64(site as u64);
            h.u64(score_bits);
            h.byte((until != u64::MAX) as u8);
            h.byte(b.admits(site, fed.now) as u8);
        }
    }

    h.0
}
