//! Built-in small-scope scenarios: the hit/miss/join × cache-death ×
//! link-cut family.
//!
//! Each scenario is a deterministic *builder* for a tiny run on the
//! paper federation: 2–3 sessions, one victim cache, a fault pair. The
//! explorer rebuilds the scenario from scratch for every choice-prefix
//! replay, so builders must be pure functions of nothing — the
//! federation seed is fixed and no background flows are started (every
//! network flow then belongs to a session, so the enabled-event set is
//! exactly the protocol's own events).

use crate::config::defaults::paper_federation;
use crate::fault::{FaultKind, FaultTimeline};
use crate::federation::driver::SessionEngine;
use crate::federation::{DownloadMethod, FedSim};
use crate::sim::workload::FileRef;
use crate::util::{ByteSize, SimTime};

/// A named model-checking scenario.
pub struct Scenario {
    pub name: &'static str,
    pub summary: &'static str,
    build: fn() -> (FedSim, SessionEngine),
}

impl Scenario {
    /// Materialise a fresh copy of the initial state (federation with
    /// faults scheduled + engine with sessions spawned).
    pub fn build(&self) -> (FedSim, SessionEngine) {
        (self.build)()
    }
}

/// The built-in scenario family. Every entry is exhaustively explored
/// by `stashcache check` and the `model_check` integration test.
pub fn builtin_scenarios() -> &'static [Scenario] {
    &[
        Scenario {
            name: "join-cache-death",
            summary: "3 sessions coalesce on one file at one cache; the cache \
                      dies and recovers mid-protocol (JoinWait wake/abort paths)",
            build: build_join_cache_death,
        },
        Scenario {
            name: "miss-failover",
            summary: "2 cold-miss sessions; their cache dies with no recovery \
                      (failover + reservation-abort paths)",
            build: build_miss_failover,
        },
        Scenario {
            name: "hit-link-cut",
            summary: "2 warmed-hit sessions behind a thin WAN; the link is cut \
                      and healed (serve-abort, direct-fallback, retry-poll paths)",
            build: build_hit_link_cut,
        },
        Scenario {
            name: "slow-cache-timeout",
            summary: "2 sessions coalesce at a cache degraded 20x with transfer \
                      deadlines armed and the breaker on (deadline failover, \
                      stale-deadline no-ops, breaker trip/ejection paths)",
            build: build_slow_cache_timeout,
        },
    ]
}

fn file(path: &str, bytes: u64) -> FileRef {
    FileRef {
        path: path.into(),
        size: ByteSize(bytes),
        version: 1,
    }
}

fn fed() -> FedSim {
    FedSim::build(paper_federation())
}

fn secs(s: f64) -> SimTime {
    SimTime::from_secs_f64(s)
}

/// Three sessions race for the same cold file at Syracuse's local
/// cache; the cache dies and later recovers. Depending on the
/// interleaving the fault lands before the first plan, between plan
/// and fetch start, mid-fetch (aborting the owner and waking joiners),
/// or after the commit — every JoinWait entry/exit path is reachable.
fn build_join_cache_death() -> (FedSim, SessionEngine) {
    let mut fed = fed();
    let site = fed.topo.site_index("syracuse").expect("paper site");
    let mut faults = FaultTimeline::new();
    faults.push(secs(1.0), FaultKind::CacheDown { site });
    faults.push(secs(2.0), FaultKind::CacheUp { site });
    fed.inject_faults(&faults)
        .expect("scenario faults fit the paper federation");

    let mut engine = SessionEngine::new(fed.now);
    let f = file("/ospool/des/data/mc-join.dat", 512 * 1024 * 1024);
    for _ in 0..3 {
        engine.spawn_at(&mut fed, fed.now, site, f.clone(), DownloadMethod::Stash);
    }
    (fed, engine)
}

/// Two sessions cold-miss different files at the same cache; the cache
/// dies and never recovers. Both must fail over to the next-nearest
/// cache (or direct-origin) on every interleaving, and the dead
/// cache's reservations must drain.
fn build_miss_failover() -> (FedSim, SessionEngine) {
    let mut fed = fed();
    let site = fed.topo.site_index("syracuse").expect("paper site");
    let mut faults = FaultTimeline::new();
    faults.push(secs(1.0), FaultKind::CacheDown { site });
    fed.inject_faults(&faults)
        .expect("scenario faults fit the paper federation");

    let mut engine = SessionEngine::new(fed.now);
    let fa = file("/ospool/des/data/mc-miss-a.dat", 256 * 1024 * 1024);
    let fb = file("/ospool/des/data/mc-miss-b.dat", 128 * 1024 * 1024);
    engine.spawn_at(&mut fed, fed.now, site, fa, DownloadMethod::Stash);
    engine.spawn_at(&mut fed, fed.now, site, fb, DownloadMethod::Stash);
    (fed, engine)
}

/// Two sessions read a file already fully resident at Bellarmine's
/// nearest cache (pre-warmed by a serial download), then Bellarmine's
/// WAN link is cut and healed. The serve path crosses that link, so
/// interleavings cover clean hits, mid-serve aborts, failovers whose
/// alternative caches are equally unreachable, the direct-origin
/// fallback, and its `DIRECT_RETRY_BACKOFF` poll loop until the heal.
fn build_hit_link_cut() -> (FedSim, SessionEngine) {
    let mut fed = fed();
    let site = fed.topo.site_index("bellarmine").expect("paper site");
    let f = file("/ospool/des/data/mc-hit.dat", 64 * 1024 * 1024);
    // Pre-warm: one serial download makes the file wholly resident at
    // the nearest cache, so the checked sessions start from a hit.
    let warm = fed.download(site, &f, DownloadMethod::Stash);
    assert_eq!(warm.bytes, f.size.as_u64());

    let wan = fed.topo.wan_link(site);
    let mut faults = FaultTimeline::new();
    // Past-dated instants (the warm-up advanced the clock) are fine:
    // the checker clamps every firing to the clocks already reached.
    faults.push(secs(1.0), FaultKind::LinkCut { link: wan });
    faults.push(secs(2.0), FaultKind::LinkRestored { link: wan });
    fed.inject_faults(&faults)
        .expect("scenario faults fit the paper federation");

    let mut engine = SessionEngine::new(fed.now);
    engine.spawn_at(&mut fed, fed.now, site, f.clone(), DownloadMethod::Stash);
    engine.spawn_at(&mut fed, fed.now, site, f, DownloadMethod::Stash);
    (fed, engine)
}

/// Two sessions coalesce on one cold file while their cache is
/// degraded 20× (a gray failure: the cache stays nominally up).
/// Transfer deadlines are armed and the breaker is on, so the checker
/// interleaves deadline expiries against flow completions, fault
/// firings, and JoinWait wakes: it covers deadline-driven mid-fetch
/// aborts (owner cancelled, joiner woken then failed over), JoinWait
/// deadline expiry, stale-deadline no-ops racing the transfer they
/// guarded, and breaker trips ejecting the slow cache from the very
/// candidate sets the failover re-resolution consults.
fn build_slow_cache_timeout() -> (FedSim, SessionEngine) {
    let mut cfg = paper_federation();
    cfg.resilience.deadline_factor = 2.0;
    cfg.resilience.breaker = true;
    cfg.resilience.breaker_alpha = 0.5;
    cfg.resilience.breaker_threshold = 0.6;
    cfg.resilience.breaker_cooldown_secs = 5.0;
    let mut fed = FedSim::build(cfg);
    let site = fed.topo.site_index("syracuse").expect("paper site");
    let mut faults = FaultTimeline::new();
    faults.push(secs(1.0), FaultKind::CacheSlow { site, factor: 0.05 });
    faults.push(secs(3.0), FaultKind::CacheRestored { site });
    fed.inject_faults(&faults)
        .expect("scenario faults fit the paper federation");

    let mut engine = SessionEngine::new(fed.now);
    let f = file("/ospool/des/data/mc-slow.dat", 256 * 1024 * 1024);
    engine.spawn_at(&mut fed, fed.now, site, f.clone(), DownloadMethod::Stash);
    engine.spawn_at(&mut fed, fed.now, site, f, DownloadMethod::Stash);
    (fed, engine)
}
