//! Pluggable geo-scoring backend: pure rust or the PJRT artifact.
//!
//! The CLI's `--runtime pjrt|rust` flag selects which one the
//! federation uses; both produce identical rankings (asserted by
//! `runtime::executors` tests), so simulations are reproducible
//! either way and the PJRT path is exercised end-to-end.

use crate::geoip::{CacheSite, GeoScoreBackend, RustGeoBackend};
use crate::runtime::{GeoScorer, Runtime};

/// Backend selection for [`crate::federation::FedSim`].
pub enum GeoBackend {
    Rust(RustGeoBackend),
    Pjrt(Box<GeoScorer>),
}

impl GeoBackend {
    pub fn rust() -> Self {
        GeoBackend::Rust(RustGeoBackend)
    }

    /// Load the AOT `geo_score` artifact (requires `make artifacts`).
    pub fn pjrt() -> anyhow::Result<Self> {
        let rt = Runtime::new()?;
        Ok(GeoBackend::Pjrt(Box::new(GeoScorer::load(&rt)?)))
    }

    pub fn name(&self) -> &'static str {
        match self {
            GeoBackend::Rust(_) => "rust",
            GeoBackend::Pjrt(_) => "pjrt",
        }
    }
}

impl GeoScoreBackend for GeoBackend {
    fn score(
        &mut self,
        clients: &[(f64, f64)],
        caches: &[CacheSite],
        loads: &[f64],
    ) -> Vec<Vec<f64>> {
        match self {
            GeoBackend::Rust(b) => b.score(clients, caches, loads),
            GeoBackend::Pjrt(b) => {
                <GeoScorer as GeoScoreBackend>::score(b.as_mut(), clients, caches, loads)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;
    use crate::federation::FedSim;

    #[test]
    fn pjrt_backend_drives_federation() {
        let cfg = paper_federation();
        let mut rust_fed = FedSim::build(cfg.clone());
        let pjrt = match GeoBackend::pjrt() {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping pjrt_backend_drives_federation: {e:#}");
                return;
            }
        };
        let mut pjrt_fed = FedSim::build_with_backend(cfg, pjrt);
        for name in crate::config::defaults::COMPUTE_SITES {
            let idx = rust_fed.topo.site_index(name).unwrap();
            assert_eq!(
                rust_fed.nearest_cache_site(idx),
                pjrt_fed.nearest_cache_site(idx),
                "backends disagree at {name}"
            );
        }
    }
}
