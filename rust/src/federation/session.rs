//! Download sessions: one small state machine per in-flight transfer.
//!
//! A [`Session`] is the event-driven replacement for the old blocking
//! `FedSim::download` call stack. Every latency the blocking code
//! modelled with `self.now += …` is now a timer event, and every
//! `run_until_flow_done` is a completion routed back by the
//! [`super::driver::SessionEngine`]. The phases correspond 1:1 to the
//! paper's download anatomy:
//!
//! ```text
//!  stashcp:  Pending ─▶ GeoResolve ─▶ CacheCheck ─┬▶ Transfer(Serve) ──────▶ Done
//!            (arrival)  (startup +    (plan_read)  ├▶ FetchBegin ─▶ Transfer(Fetch) ─▶ Done
//!                        GeoIP, RTT)               └▶ JoinWait ──▶ CacheCheck …
//!  curl:     Pending ─▶ ProxyLookup ─▶ ProxyConnect ─▶ Transfer(Relay) ─▶ Done
//! ```
//!
//! `JoinWait` is the state the blocking engine could never reach: a
//! session whose missing chunks are *already being fetched* by another
//! concurrent session parks until that fetch commits, then re-plans —
//! the cache's chunk-level miss coalescing working across clients.
//!
//! Under fault injection ([`crate::fault`]) any phase can fail: a dead
//! cache or cut link aborts the transfer, the session re-enters
//! `GeoResolve` with that cache excluded, and after
//! [`crate::fault::MAX_FAILOVER_RETRIES`] attempts (or when no cache is
//! reachable at all) it drops to the `DirectConnect → DirectFetch →
//! Transfer(DirectOrigin)` last-resort path straight to the origin.

use crate::cache::ReadPlan;
use crate::client::{Method, TransferRecord};
use crate::namespace::OriginId;
use crate::netsim::{FlowId, LinkId};
use crate::sim::workload::FileRef;
use crate::util::SimTime;

use super::DownloadMethod;

/// Handle to a session within one [`super::driver::SessionEngine`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SessionId(pub u64);

/// Which transfer a session's in-flight flow is performing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Xfer {
    /// Whole-file cache hit: cache → worker.
    StashServe,
    /// Miss: origin → cache → worker stream.
    StashFetch,
    /// Proxy relay: (origin →) proxy → worker.
    ProxyRelay,
    /// Last-resort fallback: origin → worker directly, bypassing every
    /// cache and proxy (after repeated failovers, or when no cache is
    /// reachable at all).
    DirectOrigin,
}

/// Session state: what the *next* event for this session means.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Scheduled but not started (waiting for its arrival event).
    Pending,
    /// (stash) Waiting for stashcp's startup latency (tool spin-up +
    /// GeoIP query); on fire, the redirection policy
    /// ([`crate::redirector::policy`]) picks a cache and the session
    /// pays the cache-connection RTT.
    GeoResolve,
    /// (stash) At the cache — plan the read against resident chunks.
    CacheCheck,
    /// (stash) Chunks reserved and redirector answered — start the
    /// origin stream once the discovery round trips have elapsed.
    FetchBegin,
    /// (stash) Missing chunks are in flight for another session; wait
    /// for its commit, then re-plan.
    JoinWait,
    /// (proxy) Waiting for curl startup, then squid lookup.
    ProxyLookup,
    /// (proxy) Waiting for connection establishment to the proxy.
    ProxyConnect,
    /// (fallback) No cache or proxy can serve this session: connect to
    /// the origin directly. Re-entered (after a backoff) while the
    /// direct path itself is cut.
    DirectConnect,
    /// (fallback) Connected to the origin; start the direct stream once
    /// the request round trips have elapsed.
    DirectFetch,
    /// Bytes moving: waiting for the flow completion.
    Transfer(Xfer),
    /// Finished; `record` is populated.
    Done,
}

/// One download in flight (or finished).
#[derive(Debug)]
pub struct Session {
    pub id: SessionId,
    /// Compute site of the requesting worker.
    pub site_idx: usize,
    pub file: FileRef,
    pub method: DownloadMethod,
    /// Job-arrival instant (the blocking API's call time).
    pub arrival: SimTime,
    pub phase: Phase,
    /// Authoritative origin (resolved at spawn).
    pub(crate) origin: OriginId,

    // --- stash path state -------------------------------------------------
    /// Nearest cache chosen by GeoIP (stash only).
    pub cache_site: Option<usize>,
    /// Transport stashcp's fallback chain settled on.
    pub(crate) transport: Method,
    /// First `plan_read` instant (monitoring `FileOpen` timestamp).
    pub(crate) opened_at: Option<SimTime>,
    /// Was the *first* plan a whole-file hit? (`TransferRecord::cache_hit`.)
    pub(crate) initial_hit: bool,
    /// Plan of the fetch this session owns (miss path).
    pub(crate) plan: Option<ReadPlan>,
    /// Cache per-connection ceiling, bytes/sec.
    pub(crate) per_conn: f64,
    /// Times this session parked in `JoinWait` (coalescing observability).
    pub joins: u32,
    /// While parked in `JoinWait`: the waiter-list key this session sits
    /// under. Symmetric bookkeeping with the engine's waiter lists —
    /// set when parking, cleared on every exit path (wake, failover,
    /// finish) so a session can never linger in a list it has left.
    pub(crate) waiting_on: Option<(usize, String)>,

    // --- failover state ---------------------------------------------------
    /// Caches this session failed against (excluded from re-resolution).
    pub excluded_caches: Vec<usize>,
    /// Mid-transfer aborts survived (cache death, link cut).
    pub failovers: u32,
    /// Re-resolution attempts after any failure (failovers, dead caches
    /// discovered at connect time, redirector outages).
    pub retries: u32,
    /// Has this session given up on caches (direct-to-origin path)?
    pub(crate) direct: bool,
    /// Generation of the session's armed transfer deadline. Bumped on
    /// every arm; a `Deadline` event whose generation does not match is
    /// stale (the phase it guarded was left) and fires as a no-op.
    pub(crate) deadline_gen: u64,

    // --- proxy path state -------------------------------------------------
    pub(crate) url: String,
    pub(crate) proxy_hit: bool,
    pub(crate) cacheable: bool,
    pub(crate) relay_links: Vec<LinkId>,
    pub(crate) relay_cap: f64,

    // --- telemetry (observation only — never read by the protocol) --------
    /// When the current phase was entered; each transition folds
    /// `now − phase_entered_at` into that phase's latency histogram.
    pub(crate) phase_entered_at: SimTime,
    /// Set by a failure re-route: the *next* wait this session sits
    /// through (back in GeoResolve/ProxyLookup/DirectConnect) is
    /// recovery cost and is attributed to the synthetic Failover
    /// phase. Consumed by the first transition after the failure.
    pub(crate) tele_failover: bool,
    /// Full span list, kept only while `--trace` is active.
    pub(crate) spans: Vec<crate::telemetry::PhaseSpan>,

    // --- result -----------------------------------------------------------
    pub(crate) flow: Option<FlowId>,
    pub record: Option<TransferRecord>,
}

impl Session {
    pub(crate) fn new(
        id: SessionId,
        site_idx: usize,
        file: FileRef,
        method: DownloadMethod,
        origin: OriginId,
        arrival: SimTime,
    ) -> Self {
        Session {
            id,
            site_idx,
            file,
            method,
            arrival,
            phase: Phase::Pending,
            origin,
            cache_site: None,
            transport: Method::Xrootd,
            opened_at: None,
            initial_hit: false,
            plan: None,
            per_conn: 0.0,
            joins: 0,
            waiting_on: None,
            excluded_caches: Vec::new(),
            failovers: 0,
            retries: 0,
            direct: false,
            deadline_gen: 0,
            url: String::new(),
            proxy_hit: false,
            cacheable: false,
            relay_links: Vec::new(),
            relay_cap: 0.0,
            phase_entered_at: arrival,
            tele_failover: false,
            spans: Vec::new(),
            flow: None,
            record: None,
        }
    }

    /// Is the session past its arrival and not yet finished?
    pub fn is_active(&self) -> bool {
        !matches!(self.phase, Phase::Pending | Phase::Done)
    }
}
