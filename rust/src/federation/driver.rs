//! The concurrent session engine: advances any number of in-flight
//! download [`Session`]s by popping timer events from a deterministic
//! [`EventQueue`] and routing [`crate::netsim::Network`] completions
//! back to their owning sessions.
//!
//! ## Event loop
//!
//! The engine interleaves two event sources in virtual-time order:
//!
//! 1. its own timer queue (client startup latencies, connection RTTs,
//!    redirector round trips, job arrivals), and
//! 2. the flow-level network's projected completions.
//!
//! Ties go to the network — completions at or before the next timer
//! are drained first — which reproduces the blocking engine's
//! `advance_to` semantics exactly: a campaign of one serial job walks
//! the same instants, draws the same RNG stream, and produces the same
//! `TransferRecord`s as the pre-refactor code.
//!
//! Background origin load lives here too: a completed background flow
//! respawns at its completion instant, so origin contention has no
//! gaps regardless of how many sessions are in flight.
//!
//! ## Cross-session coalescing
//!
//! When a session's `plan_read` finds every missing chunk already in
//! flight (another session is fetching the same file from the origin)
//! it parks in [`Phase::JoinWait`]; the fetching session's
//! `commit_chunks` wakes all waiters at the commit instant and they
//! re-plan — typically into a pure cache hit that never touches the
//! origin. This is the paper's §3 cache behaviour ("capture data
//! requests from clients") finally firing *across* concurrent clients.
//!
//! ## Fault layer
//!
//! The federation's fault schedule ([`crate::fault`]) is a third event
//! source: cache deaths abort the flows that cache was serving or
//! filling (releasing reserved chunks via `abort_fetch` and waking any
//! `JoinWait` joiners so they re-plan), link cuts kill every crossing
//! flow and re-trigger max-min allocation for the survivors, origin
//! brownouts rescale DTN capacity, and redirector outages degrade the
//! HA pair. Interrupted sessions re-enter `GeoResolve` with the failed
//! cache excluded, pay a fresh resolution latency per attempt, and
//! after `[resilience] max_failover_retries` attempts stream directly
//! from the origin — a chaos campaign completes every download or
//! panics; it never silently drops one.
//!
//! ## Resilience layer (gray failures)
//!
//! Binary outages are the easy case. A *gray* failure — a cache whose
//! serving links degraded 20× ([`FaultKind::CacheSlow`]) or whose
//! resident copy is silently corrupted ([`FaultKind::DataCorrupt`]) —
//! leaves the cache nominally up, so nothing above ejects it. Three
//! mechanisms close the gap:
//!
//! * **Transfer deadlines** — when `[resilience] deadline_factor` > 0,
//!   entering `Transfer(StashServe | StashFetch)` or `JoinWait` arms a
//!   deterministic [`EngineEvent::Deadline`] at `expected transfer
//!   time × deadline_factor`. On expiry the session cancels its flow
//!   (or leaves the waiter list) and re-enters the standard failover
//!   ladder with the slow cache excluded — the exact path a cache
//!   death takes, so every fault invariant applies unchanged. Stale
//!   deadlines (the phase was left, or re-armed) are no-ops by
//!   generation check. At the default factor of 0 the timer is never
//!   scheduled, keeping event counts byte-identical to pre-deadline
//!   runs.
//! * **End-to-end digests** — every whole-file cache serve is checked
//!   against the origin keystream ([`crate::origin::content`], the
//!   vendored sha2 pipeline) at transfer end; a poisoned copy fails
//!   the digest, is invalidated at the cache, and the session
//!   exclude-and-refetches.
//! * **The circuit breaker** ([`crate::redirector::breaker`]) — every
//!   timeout / corruption / abort / success outcome feeds a per-cache
//!   health score; a tripped breaker ejects the cache from candidate
//!   sets until a half-open probe succeeds.
//!
//! An armed resilience layer keeps [`SessionEngine::run_threaded`] on
//! the serial path (see the epoch gate), preserving thread-count
//! digest equality.

use crate::cache::{CacheServer, ReadPlan};
use crate::client::stashcp;
use crate::client::{curl, Method, TransferRecord};
use crate::fault::{FaultEvent, FaultKind};
use crate::origin::content;
use crate::redirector::breaker::BreakerOutcome;
use crate::monitoring::packets::Protocol;
use crate::netsim::{Completion, Endpoint, EventQueue, FlowId, FlowSpec, LinkId, Network};
use crate::sim::workload::FileRef;
use crate::telemetry::{PhaseLabel, PhaseSpan, SpanTrace, Telemetry};
use crate::util::stats::Welford;
use crate::util::{Duration, SimTime};
use std::collections::{HashMap, HashSet};
use std::sync::Mutex;
use std::sync::atomic::{AtomicUsize, Ordering};
use super::session::{Phase, Session, SessionId, Xfer};
use super::{DownloadMethod, FedSim};

/// Are all links of a route currently up? (Flows must not start over a
/// severed link; the session retries or fails over instead.)
fn route_is_up(fed: &FedSim, links: &[LinkId]) -> bool {
    links.iter().all(|&l| fed.net.link_is_up(l))
}

/// Bytes of leading extent the client digests at transfer end. Capped:
/// the keystream check is O(extent), and a corrupted copy already
/// differs within its first block (see [`CacheServer::poison`]).
const DIGEST_CHECK_EXTENT: u64 = 4096;

/// The client's end-to-end integrity check at transfer end — the
/// consistency guarantee CVMFS chunk checksums give the production
/// system, run through the vendored sha2 keystream
/// ([`crate::origin::content`]). A healthy cache serves exactly the
/// origin bytes, so the digest comparison passes; a poisoned resident
/// copy differs (modelled as its first block flipped) and fails it.
fn served_bytes_verify(cache: &CacheServer, path: &str, version: u64, size: u64) -> bool {
    if size == 0 {
        return true;
    }
    let len = size.min(DIGEST_CHECK_EXTENT) as usize;
    let mut got = vec![0u8; len];
    content::fill(path, version, 0, &mut got);
    if cache.is_poisoned(path) {
        got[0] ^= 0xff;
    }
    content::verify(path, version, 0, &got)
}

/// Telemetry label of a phase being exited. Pending (zero-length by
/// construction: the arrival event fires at the instant the session
/// entered it) and Done fold nothing; every Transfer variant folds
/// into one Transfer histogram (the variant is visible in the record's
/// method field already).
fn phase_label(phase: Phase) -> Option<PhaseLabel> {
    match phase {
        Phase::Pending | Phase::Done => None,
        Phase::GeoResolve => Some(PhaseLabel::GeoResolve),
        Phase::CacheCheck => Some(PhaseLabel::CacheCheck),
        Phase::JoinWait => Some(PhaseLabel::JoinWait),
        Phase::FetchBegin => Some(PhaseLabel::FetchBegin),
        Phase::Transfer(_) => Some(PhaseLabel::Transfer),
        Phase::DirectConnect => Some(PhaseLabel::DirectConnect),
        Phase::DirectFetch => Some(PhaseLabel::DirectFetch),
        Phase::ProxyLookup => Some(PhaseLabel::ProxyLookup),
        Phase::ProxyConnect => Some(PhaseLabel::ProxyConnect),
    }
}

/// Events the engine schedules for itself.
#[derive(Debug, Clone, Copy)]
enum EngineEvent {
    /// A session's arrival instant (job submission).
    Start(SessionId),
    /// A session's pending latency elapsed; advance its phase.
    Timer(SessionId),
    /// A session's transfer deadline expired. The `u64` is the arming
    /// generation: a firing whose generation no longer matches the
    /// session's is stale (the guarded phase was already left) and
    /// does nothing.
    Deadline(SessionId, u64),
}

/// One enabled event the model checker may fire next, in place of the
/// deterministic virtual-time minimum the run loop would pick. The
/// variants mirror the engine's three event sources (timer queue,
/// network completions, fault schedule); see
/// [`SessionEngine::mc_choices`] / [`SessionEngine::mc_fire`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum McChoice {
    /// A pending timer-queue entry, addressed by its exact
    /// `(time, seq)` scheduling key (stable across replays).
    Timer {
        at: SimTime,
        seq: u64,
        session: SessionId,
    },
    /// An in-flight foreground transfer completing.
    Flow { flow: FlowId, owner: SessionId },
    /// The earliest scheduled fault applying.
    Fault,
}

/// Engine counters (perf + concurrency + fault observability).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EngineStats {
    /// Timer events plus network completions processed.
    pub events_processed: u64,
    pub sessions_completed: u64,
    /// Maximum number of simultaneously active sessions.
    pub peak_concurrent: usize,
    pub background_respawns: u64,
    /// Sessions that parked in `JoinWait` at least once.
    pub coalesced_joins: u64,
    /// Fault events applied (cache/link/origin/redirector transitions).
    pub faults_applied: u64,
    /// Mid-transfer aborts survived (flow cancelled, session re-planned).
    pub failovers: u64,
    /// Session re-resolution attempts after any failure.
    pub retries: u64,
    /// Bytes already transferred by flows that were then aborted
    /// (wasted work the fault layer caused).
    pub aborted_bytes: u64,
    /// Sessions that gave up on caches and streamed from the origin.
    pub direct_fallbacks: u64,
    /// Transfer deadlines that expired and triggered a failover
    /// (armed deadlines superseded by normal progress do not count).
    pub deadline_expiries: u64,
    /// Whole-file serves whose end-to-end digest check failed
    /// (poisoned cache copy detected, invalidated, and refetched).
    pub corruptions_detected: u64,
    /// Allocator passes the network ran while this engine drove it
    /// (see [`crate::netsim::AllocStats`]; deltas over the run).
    pub allocator_passes: u64,
    /// Component water-fills across those passes — the O(affected)
    /// unit of allocator work.
    pub components_touched: u64,
    /// Flow rate assignments across those water-fills. Divided by
    /// `events_processed` this is the allocator's flows-touched-per-
    /// event figure the perf benches report.
    pub flows_refixed: u64,
    /// Largest single component water-filled (flows) during this
    /// engine's runs — per-run like the other allocator counters, even
    /// when several engines share one federation.
    pub peak_component: usize,
}

/// Epoch-loop observability: how often the epoch planner ran, why it
/// bailed, and how much of the run it actually parallelised. Kept
/// *outside* [`EngineStats`] on purpose — these counters describe the
/// execution strategy, not the simulation, so they legitimately differ
/// between thread counts (a serial run plans zero epochs) while every
/// [`EngineStats`] field stays digest-identical.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EpochStats {
    /// Planning attempts that actually ran (gate passed, no cached
    /// bail).
    pub epochs_planned: u64,
    /// Epochs that shipped shards and merged at the barrier.
    pub epochs_engaged: u64,
    /// Sessions retired inside shard workers.
    pub sessions_sharded: u64,
    /// Sessions retired on the serial path.
    pub sessions_serial: u64,
    /// Planning attempts skipped because nothing plan-relevant changed
    /// since the last bail (the O(1) fast path between state changes).
    pub plans_skipped: u64,
    /// Bail: no session prefix provably completes strictly before the
    /// next scheduled fault instant.
    pub bail_pending_fault: u64,
    /// Bail: epoch flows would share links with background (WAN /
    /// origin-LAN) traffic, or a needed route is severed.
    pub bail_wan_coupled: u64,
    /// Bail: the policy reads live telemetry, or its cache pick could
    /// flip as cold fetches shift cache load during the epoch.
    pub bail_policy_unstable: u64,
    /// Bail: too little pending work, work still in flight, or
    /// everything lands in a single shard.
    pub bail_below_threshold: u64,
    /// Bail: resilience machinery (deadlines / circuit breaker) is
    /// armed — gray-failure paths are serial-only.
    pub bail_resilience: u64,
    /// Bail: anything else — failover history, poisoned replicas,
    /// redirector outage, eviction risk, non-stash transports.
    pub bail_other: u64,
}

/// Why one epoch-planning attempt refused to shard. Cached together
/// with the state version that produced it, so repeated probes against
/// unchanged state cost one comparison instead of a re-plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PlanBail {
    PendingFault,
    WanCoupled,
    PolicyUnstable,
    BelowThreshold,
    Resilience,
    Other,
}

/// The event-driven download engine. Create one per batch of work; it
/// borrows the [`FedSim`] only while spawning and running, so drivers
/// can inspect the federation between runs.
pub struct SessionEngine {
    queue: EventQueue<EngineEvent>,
    sessions: Vec<Session>,
    /// Flow → owning session (foreground transfers only).
    flow_owner: HashMap<FlowId, SessionId>,
    /// (cache site, path) → sessions parked until the in-flight fetch
    /// commits.
    waiters: HashMap<(usize, String), Vec<SessionId>>,
    /// Sessions currently assigned per cache site (incremented when a
    /// session binds a cache in `geo_resolve`, released on finish or
    /// failover) — the live-load signal the `least-loaded` redirection
    /// policy reads. Pure bookkeeping under every other policy.
    cache_in_flight: HashMap<usize, u64>,
    /// Spawned sessions not yet `Done`.
    outstanding: usize,
    /// Started sessions not yet `Done`.
    in_flight: usize,
    /// Session ids in completion order.
    completed: Vec<SessionId>,
    /// Summary of start→completion wall durations (seconds) for
    /// sessions retired through sharded terminal epochs: per-shard
    /// [`Welford`] accumulators merged in stable shard order at the
    /// barrier, so the summary is independent of thread scheduling.
    /// Empty after a purely serial run (diagnostics only — not part
    /// of the serial-vs-threaded bit-identity surface).
    pub epoch_durations: Welford,
    /// Epoch-loop counters (planned/engaged/bails). Thread-count
    /// dependent by design; never part of the bit-identity surface.
    pub epochs: EpochStats,
    /// Monotone stamp of plan-relevant state. Bumped whenever a fault
    /// fires, a session finishes or fails over, or new work is
    /// spawned — the events that can change a planning verdict.
    /// Background-flow respawns deliberately do *not* bump it: they
    /// are invisible to the planner's obligations, and re-probing on
    /// every respawn is exactly the thrash the bail cache exists to
    /// kill.
    state_version: u64,
    /// The last failed plan: `(state_version at the attempt, reason)`.
    /// While the version still matches, probing is a no-op.
    last_bail: Option<(u64, PlanBail)>,
    pub stats: EngineStats,
    /// Always-on phase/rollup telemetry. Observation only: it never
    /// touches the queue, the network, or the RNG, so records are
    /// identical with it enabled or disabled — and unlike
    /// `epoch_durations` its sketches *are* bit-identical across
    /// thread counts (integer bucket counts, folded in the same
    /// deterministic completion order the record stream uses).
    pub tele: Telemetry,
}

impl SessionEngine {
    /// An engine whose clock starts at `now` (the federation's current
    /// virtual time).
    pub fn new(now: SimTime) -> Self {
        let mut queue = EventQueue::new();
        queue.advance_to(now);
        SessionEngine {
            queue,
            sessions: Vec::new(),
            flow_owner: HashMap::new(),
            waiters: HashMap::new(),
            cache_in_flight: HashMap::new(),
            outstanding: 0,
            in_flight: 0,
            completed: Vec::new(),
            epoch_durations: Welford::new(),
            epochs: EpochStats::default(),
            state_version: 0,
            last_bail: None,
            stats: EngineStats::default(),
            tele: Telemetry::new(),
        }
    }

    /// Advance `s` to `next`, folding the time spent in the phase
    /// being left into the telemetry histograms (and, under `--trace`,
    /// the session's span list). An associated fn over disjoint
    /// borrows so call sites holding `&mut self.sessions[i]` can pass
    /// `&mut self.tele` alongside. Pending and Done fold nothing;
    /// a pending failover re-route attributes the wait to Failover.
    fn set_phase(tele: &mut Telemetry, s: &mut Session, now: SimTime, next: Phase) {
        let label = if std::mem::take(&mut s.tele_failover) {
            Some(PhaseLabel::Failover)
        } else {
            phase_label(s.phase)
        };
        if let Some(label) = label {
            let dur = now - s.phase_entered_at;
            tele.phase_span(label, dur);
            if tele.trace_enabled() {
                s.spans.push(PhaseSpan {
                    label,
                    start: s.phase_entered_at,
                    dur,
                });
            }
        }
        s.phase = next;
        s.phase_entered_at = now;
    }

    /// Current engine-queue clock (time of the last processed timer).
    /// The federation's `fed.now` can be ahead of this after a run
    /// whose final event was a flow completion — spawn follow-up
    /// sessions at `fed.now`, not at this clock.
    pub fn now(&self) -> SimTime {
        self.queue.now()
    }

    pub fn session(&self, id: SessionId) -> &Session {
        &self.sessions[id.0 as usize]
    }

    pub fn sessions(&self) -> &[Session] {
        &self.sessions
    }

    /// Session ids in the order they finished.
    pub fn completed(&self) -> &[SessionId] {
        &self.completed
    }

    /// Per-cache-site live session counts — the load signal the
    /// `least-loaded` policy reads. After a run drains, every count
    /// must be back to zero (each exit path — finish, failover,
    /// direct-origin fallback, fault abort — releases its slot); tests
    /// assert this to catch leaks that would silently skew redirection.
    pub fn cache_in_flight(&self) -> &HashMap<usize, u64> {
        &self.cache_in_flight
    }

    /// Spawned-but-unfinished session count. Drains to zero when a run
    /// completes — the model checker's termination criterion.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Waiter lists: `(cache site, path)` → sessions parked in
    /// `JoinWait` on that fetch. Exposed for the model checker's
    /// waiter-symmetry invariant and the stale-waiter regression tests;
    /// must be empty after every drained run.
    pub fn waiters(&self) -> &HashMap<(usize, String), Vec<SessionId>> {
        &self.waiters
    }

    /// Foreground flow → owning session. Exposed for the model
    /// checker's choice enumeration and terminal drain check.
    pub fn flow_owners(&self) -> &HashMap<FlowId, SessionId> {
        &self.flow_owner
    }

    /// The finished record of a session (panics if not done).
    pub fn record(&self, id: SessionId) -> TransferRecord {
        self.sessions[id.0 as usize]
            .record
            .clone()
            .expect("session not finished")
    }

    /// Schedule a download to begin at `at` (a job arrival). The file
    /// is materialised at its origin immediately, mirroring the
    /// blocking API.
    pub fn spawn_at(
        &mut self,
        fed: &mut FedSim,
        at: SimTime,
        site_idx: usize,
        file: FileRef,
        method: DownloadMethod,
    ) -> SessionId {
        assert!(
            at >= self.queue.now(),
            "spawning a session in the past: {at} < {}",
            self.queue.now()
        );
        // The network may be ahead of the timer queue (a run whose
        // last event was a flow completion): spawning before `fed.now`
        // would rewind the network clock mid-run.
        assert!(
            at >= fed.now,
            "spawning a session before the federation clock: {at} < {}",
            fed.now
        );
        let origin = fed.ensure_file(&file);
        let id = SessionId(self.sessions.len() as u64);
        self.sessions
            .push(Session::new(id, site_idx, file, method, origin, at));
        self.outstanding += 1;
        self.state_version += 1;
        self.queue.schedule_at(at, EngineEvent::Start(id));
        id
    }

    /// Drive the federation until every spawned session has finished.
    /// Background flows are respawned along the way and left running;
    /// `fed.now` ends at the last processed instant.
    ///
    /// Three event sources interleave in virtual-time order: the
    /// engine's timer queue, the network's projected completions, and
    /// the federation's fault schedule. Completions at or before the
    /// next timer-or-fault drain first (a transfer that finished at the
    /// fault instant finished); a fault ties ahead of a timer at the
    /// same instant, so same-instant timers observe the post-fault
    /// world. Faults due after the last session completes stay pending
    /// for the next engine run.
    pub fn run(&mut self, fed: &mut FedSim) {
        self.run_threaded(fed, 1);
    }

    /// [`SessionEngine::run`] on up to `threads` OS threads,
    /// bit-identical to the serial run. Whenever nothing is in flight,
    /// the loop tries to plan a *bounded epoch*: a prefix of the
    /// pending sessions that provably completes strictly before the
    /// next fault instant (or runs to the end when none is scheduled),
    /// partitioned by union-find over serve/fetch links ∪ cache
    /// anchors ∪ origin-DTN anchors — so cold fetches shard by origin
    /// component instead of forcing serial — and advanced on worker
    /// threads against shard networks (exact by PR 4's component
    /// decomposition). The barrier merges shard results back in the
    /// serial interleaving order, the engine applies the fault at the
    /// horizon serially, and the loop plans the next epoch. Records,
    /// stats, monitoring, and the RNG stream are byte-for-byte what
    /// `threads == 1` produces. Work that fails a proof obligation
    /// (live-telemetry policies, WAN-coupled routes, armed resilience)
    /// stays on the serial path, and the bail reason is cached against
    /// [`Self::state_version`] so re-probing unchanged state is O(1) —
    /// see [`EpochStats`] for the observable outcome counters.
    pub fn run_threaded(&mut self, fed: &mut FedSim, threads: usize) {
        let alloc_before = fed.net.stats;
        // Track this run's own component high-water mark; the
        // network's lifetime peak is restored below.
        fed.net.stats.peak_component = 0;
        let mut guard = 0u64;
        while self.outstanding > 0 {
            if threads > 1 && self.in_flight == 0 {
                match self.last_bail {
                    Some((v, _)) if v == self.state_version => {
                        // Nothing plan-relevant changed since the last
                        // refusal: skip the probe outright.
                        self.epochs.plans_skipped += 1;
                    }
                    _ => match self.try_epoch(fed, threads) {
                        Ok(()) => continue,
                        Err(bail) => {
                            self.note_bail(bail);
                            self.last_bail = Some((self.state_version, bail));
                        }
                    },
                }
            }
            guard += 1;
            assert!(
                guard <= 500_000_000,
                "session engine stuck: {} outstanding at {}",
                self.outstanding,
                self.queue.now()
            );
            let next_timer = self.queue.peek_time();
            let next_fault = fed.next_fault_at();
            let next_net = fed.net.next_completion();
            // Faults and timers compete for the scheduled slot; faults
            // win ties. (A fault left over from an earlier engine run
            // may be past-dated; it still sorts first and is applied at
            // the current clock.)
            let (next_sched, fault_first) = match (next_fault, next_timer) {
                (Some(tf), Some(tt)) if tf <= tt => (Some(tf), true),
                (Some(tf), None) => (Some(tf), true),
                (_, tt) => (tt, false),
            };
            match (next_sched, next_net) {
                // Network completions up to (and at) the next scheduled
                // event go first — the blocking engine's advance_to
                // order.
                (Some(te), Some(tn)) if tn <= te => self.step_network(fed, tn),
                (None, Some(tn)) => self.step_network(fed, tn),
                (Some(_), _) if fault_first => self.step_fault(fed),
                (Some(_), _) => self.step_timer(fed),
                (None, None) => panic!(
                    "session engine stalled: {} sessions outstanding with no pending events",
                    self.outstanding
                ),
            }
        }
        // Fold the network's allocator counters (deltas over this run)
        // into the engine's stats for campaign/sweep observability.
        let alloc = fed.net.stats;
        self.stats.allocator_passes += alloc.allocations - alloc_before.allocations;
        self.stats.components_touched +=
            alloc.components_touched - alloc_before.components_touched;
        self.stats.flows_refixed += alloc.flows_refixed - alloc_before.flows_refixed;
        self.stats.peak_component = self.stats.peak_component.max(alloc.peak_component);
        fed.net.stats.peak_component = alloc.peak_component.max(alloc_before.peak_component);
    }

    /// Advance the network to `t` and dispatch its completions.
    fn step_network(&mut self, fed: &mut FedSim, t: SimTime) {
        fed.now = t;
        let completions = fed.net.advance(t);
        self.dispatch_completions(fed, completions, t);
    }

    /// Pop and dispatch the next timer event.
    fn step_timer(&mut self, fed: &mut FedSim) {
        let Some((t, ev)) = self.queue.pop() else {
            return;
        };
        self.stats.events_processed += 1;
        // Bring the network to the event instant. Completions whose
        // projected (µs-rounded) instant lies past `t` but whose
        // remaining bytes already hit zero are retired here rather
        // than silently dropped.
        fed.now = t;
        let stragglers = fed.net.advance(t);
        self.dispatch_completions(fed, stragglers, t);
        match ev {
            EngineEvent::Start(id) => self.on_start(fed, id, t),
            EngineEvent::Timer(id) => self.on_timer(fed, id, t),
            EngineEvent::Deadline(id, gen) => self.on_deadline(fed, id, gen, t),
        }
    }

    /// Pop and apply the next scheduled fault. Past-dated faults (left
    /// over from an earlier engine run on this federation) apply at the
    /// current clock.
    fn step_fault(&mut self, fed: &mut FedSim) {
        let Some(ev) = fed.pop_fault() else {
            return;
        };
        let t = ev.at.max(fed.now);
        self.stats.events_processed += 1;
        // Transfers that finished at or before the fault instant
        // finished: drain them before the world changes.
        fed.now = t;
        let stragglers = fed.net.advance(t);
        self.dispatch_completions(fed, stragglers, t);
        self.on_fault(fed, ev.kind, t);
    }

    /// Apply one fault to the federation and unwind every session it
    /// interrupts. All iteration orders are deterministic (session-id
    /// order, sorted waiter keys, flow start order from the network).
    fn on_fault(&mut self, fed: &mut FedSim, kind: FaultKind, t: SimTime) {
        self.stats.faults_applied += 1;
        self.state_version += 1;
        fed.fault_log.push(FaultEvent {
            at: t,
            kind: kind.clone(),
        });
        match kind {
            FaultKind::CacheDown { site } => {
                fed.faults.cache_down(site, t);
                // Abort every transfer this cache is serving or
                // filling: the flow dies mid-stream, reserved chunks
                // are released, and the session fails over.
                let victims: Vec<SessionId> = self
                    .sessions
                    .iter()
                    .filter(|s| {
                        s.cache_site == Some(site)
                            && matches!(
                                s.phase,
                                Phase::Transfer(Xfer::StashServe | Xfer::StashFetch)
                            )
                    })
                    .map(|s| s.id)
                    .collect();
                for id in victims {
                    if let Some(b) = fed.breaker.as_mut() {
                        b.record(site, BreakerOutcome::Abort, t);
                    }
                    self.cancel_session_flow(fed, id, t);
                    self.on_flow_aborted(fed, id, t, Some(site));
                }
                // Wake sessions still parked on fetches at this cache
                // (owners not yet transferring): they re-plan, find the
                // cache dead, and fail over.
                let mut parked: Vec<(usize, String)> = self
                    .waiters
                    .keys()
                    .filter(|k| k.0 == site)
                    .cloned()
                    .collect();
                parked.sort();
                for (cache_site, path) in parked {
                    self.wake_waiters(cache_site, &path, t);
                }
            }
            FaultKind::CacheUp { site } => fed.faults.cache_up(site, t),
            FaultKind::LinkCut { link } => {
                for (flow, left) in fed.net.cut_link(link, t) {
                    if let Some(origin_idx) = fed.background.remove(&flow) {
                        // Re-attached when the link heals.
                        fed.deferred_background.push(origin_idx);
                    } else if let Some(id) = self.flow_owner.remove(&flow) {
                        let (size, exclude) = {
                            let s = &mut self.sessions[id.0 as usize];
                            s.flow = None;
                            (s.file.size.as_u64().max(1), s.cache_site)
                        };
                        self.stats.aborted_bytes += size.saturating_sub(left.min(size));
                        if let (Some(cache), Some(b)) = (exclude, fed.breaker.as_mut()) {
                            b.record(cache, BreakerOutcome::Abort, t);
                        }
                        self.on_flow_aborted(fed, id, t, exclude);
                    }
                }
            }
            FaultKind::LinkRestored { link } => {
                fed.net.restore_link(link);
                fed.respawn_deferred_background();
            }
            FaultKind::OriginDegraded { origin, factor } => {
                let link = fed.topo.origin_lan_link(origin);
                fed.net.scale_link_capacity(link, factor, t);
            }
            FaultKind::OriginRestored { origin } => {
                let link = fed.topo.origin_lan_link(origin);
                fed.net.scale_link_capacity(link, 1.0, t);
            }
            FaultKind::RedirectorDown { instance } => {
                fed.redirectors.set_healthy(instance, false);
            }
            FaultKind::RedirectorUp { instance } => {
                fed.redirectors.set_healthy(instance, true);
            }
            FaultKind::CacheSlow { site, factor } => {
                // Gray failure: both serving legs (worker LAN + WAN)
                // degrade, but the cache still answers — in-flight
                // transfers crawl instead of dying. Only a transfer
                // deadline or the breaker gets sessions off it.
                fed.net
                    .scale_link_capacity(fed.topo.cache_lan_link(site), factor, t);
                fed.net
                    .scale_link_capacity(fed.topo.cache_wan_link(site), factor, t);
            }
            FaultKind::CacheRestored { site } => {
                fed.net
                    .scale_link_capacity(fed.topo.cache_lan_link(site), 1.0, t);
                fed.net
                    .scale_link_capacity(fed.topo.cache_wan_link(site), 1.0, t);
            }
            FaultKind::DataCorrupt { site, path } => {
                // Silent: nothing aborts here. Clients discover the
                // damage at transfer end via the digest check in
                // `on_flow_done` and exclude-and-refetch.
                fed.caches
                    .get_mut(&site)
                    .expect("cache site")
                    .poison(&path);
            }
        }
    }

    /// Cancel a session's in-flight flow (if any) and account the
    /// wasted bytes it had already moved.
    fn cancel_session_flow(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        if let Some(flow) = self.sessions[id.0 as usize].flow.take() {
            self.flow_owner.remove(&flow);
            if let Some(left) = fed.net.cancel_flow(flow, t) {
                let size = self.sessions[id.0 as usize].file.size.as_u64().max(1);
                self.stats.aborted_bytes += size.saturating_sub(left.min(size));
            }
        }
    }

    /// A session's transfer was aborted mid-flight (its flow is already
    /// gone): release reserved chunks, wake joiners so they re-plan,
    /// and fail the session over.
    fn on_flow_aborted(
        &mut self,
        fed: &mut FedSim,
        id: SessionId,
        t: SimTime,
        exclude: Option<usize>,
    ) {
        self.sessions[id.0 as usize].failovers += 1;
        self.stats.failovers += 1;
        if let Phase::Transfer(Xfer::StashFetch) = self.sessions[id.0 as usize].phase {
            let (cache_site, path, version, plan) = {
                let s = &mut self.sessions[id.0 as usize];
                (
                    s.cache_site.expect("stash fetch has a cache"),
                    s.file.path.clone(),
                    s.file.version,
                    s.plan.take().expect("fetch had a plan"),
                )
            };
            fed.caches
                .get_mut(&cache_site)
                .expect("cache site")
                .abort_fetch(&path, version, &plan.fetch);
            self.wake_waiters(cache_site, &path, t);
        }
        self.fail_session(fed, id, t, exclude);
    }

    /// Re-plan a failed session: exclude the cache it failed against,
    /// pay a fresh resolution latency, and re-enter `GeoResolve` (or
    /// `ProxyLookup`). After `[resilience] max_failover_retries`
    /// attempts the session gives up on caches and streams from the
    /// origin.
    fn fail_session(
        &mut self,
        fed: &mut FedSim,
        id: SessionId,
        t: SimTime,
        exclude: Option<usize>,
    ) {
        self.stats.retries += 1;
        self.state_version += 1;
        self.release_cache_slot(id);
        // A session failing over out of JoinWait (e.g. its cache died
        // before the fetch owner's commit) must leave the waiter list
        // it was parked in, or a later commit would wake it in the
        // wrong phase.
        self.remove_waiter(id);
        let (method, transport, retries) = {
            let s = &mut self.sessions[id.0 as usize];
            if let Some(site) = exclude {
                if !s.excluded_caches.contains(&site) {
                    s.excluded_caches.push(site);
                }
            }
            s.retries += 1;
            s.plan = None;
            s.flow = None;
            s.cache_site = None;
            (s.method, s.transport, s.retries)
        };
        let attempt = retries.min(8) as usize;
        let give_up = retries > fed.cfg.resilience.max_failover_retries;
        let (phase, delay) = if give_up {
            (
                Phase::DirectConnect,
                stashcp::startup_latency(&fed.startup_costs, Method::HttpOrigin, attempt),
            )
        } else {
            match method {
                DownloadMethod::Stash => (
                    Phase::GeoResolve,
                    stashcp::startup_latency(&fed.startup_costs, transport, attempt),
                ),
                DownloadMethod::HttpProxy => (
                    Phase::ProxyLookup,
                    stashcp::startup_latency(&fed.startup_costs, Method::HttpProxy, attempt),
                ),
            }
        };
        // The aborted phase folds under its own label first; the flag
        // set afterwards attributes the upcoming retry wait (however
        // the session leaves `phase`) to Failover.
        Self::set_phase(&mut self.tele, &mut self.sessions[id.0 as usize], t, phase);
        self.sessions[id.0 as usize].tele_failover = true;
        if give_up {
            self.mark_direct(id);
        }
        self.queue.schedule_at(t + delay, EngineEvent::Timer(id));
    }

    /// Drop a session onto the direct-to-origin path (no cache is
    /// reachable at all). Priced like the give-up path in
    /// [`SessionEngine::fail_session`]: curl startup plus a fresh
    /// connection per attempt.
    fn enter_direct_fallback(&mut self, fed: &FedSim, id: SessionId, t: SimTime) {
        let attempt = {
            let s = &mut self.sessions[id.0 as usize];
            Self::set_phase(&mut self.tele, s, t, Phase::DirectConnect);
            s.retries.min(8) as usize
        };
        self.mark_direct(id);
        let delay = stashcp::startup_latency(&fed.startup_costs, Method::HttpOrigin, attempt);
        self.queue.schedule_at(t + delay, EngineEvent::Timer(id));
    }

    /// Record that a session gave up on caches (counted once per
    /// session no matter how it reached the direct path).
    fn mark_direct(&mut self, id: SessionId) {
        let s = &mut self.sessions[id.0 as usize];
        if !s.direct {
            s.direct = true;
            self.stats.direct_fallbacks += 1;
        }
    }

    /// Poll interval for a direct-to-origin session whose own path is
    /// cut (`[resilience] direct_retry_backoff_secs`).
    fn direct_backoff(fed: &FedSim) -> Duration {
        Duration::from_secs_f64(fed.cfg.resilience.direct_retry_backoff_secs)
    }

    // --- transfer deadlines -------------------------------------------------

    /// Arm the session's progress deadline on entering a guarded phase
    /// (`Transfer(StashServe | StashFetch)` or `JoinWait`): expected
    /// transfer time (`bytes / per-connection rate`) times
    /// `[resilience] deadline_factor`. At the default factor of 0 no
    /// event is ever scheduled — event counts, and therefore campaign
    /// digests, stay byte-identical to pre-deadline runs.
    fn arm_deadline(&mut self, fed: &FedSim, id: SessionId, t: SimTime, bytes: u64, rate_bps: f64) {
        let factor = fed.cfg.resilience.deadline_factor;
        if factor <= 0.0 {
            return;
        }
        let expected_s = bytes.max(1) as f64 / rate_bps.max(1.0);
        let s = &mut self.sessions[id.0 as usize];
        s.deadline_gen += 1;
        let gen = s.deadline_gen;
        self.queue.schedule_at(
            t + Duration::from_secs_f64(expected_s * factor),
            EngineEvent::Deadline(id, gen),
        );
    }

    /// A transfer deadline fired. Stale firings — the generation was
    /// superseded by a re-arm, or the session already left the guarded
    /// phase (completions at the same instant dispatch first) — are
    /// no-ops. A live expiry is a timeout strike against the cache:
    /// the session cancels its flow (or leaves the waiter list) and
    /// re-enters the failover ladder with that cache excluded, exactly
    /// like a cache death.
    fn on_deadline(&mut self, fed: &mut FedSim, id: SessionId, gen: u64, t: SimTime) {
        let phase = {
            let s = &self.sessions[id.0 as usize];
            if s.deadline_gen != gen {
                return;
            }
            match s.phase {
                p @ (Phase::Transfer(Xfer::StashServe | Xfer::StashFetch) | Phase::JoinWait) => p,
                _ => return,
            }
        };
        self.stats.deadline_expiries += 1;
        let cache_site = self.sessions[id.0 as usize].cache_site;
        if let (Some(site), Some(b)) = (cache_site, fed.breaker.as_mut()) {
            b.record(site, BreakerOutcome::Timeout, t);
        }
        match phase {
            Phase::Transfer(_) => {
                // Same unwind as a fault-driven abort: wasted bytes
                // accounted, reserved chunks released (fetch path),
                // joiners woken, session failed over.
                self.cancel_session_flow(fed, id, t);
                self.on_flow_aborted(fed, id, t, cache_site);
            }
            Phase::JoinWait => {
                // Waited too long on another session's fetch at a slow
                // cache: stop waiting and fail over (`fail_session`
                // scrubs the waiter-list entry).
                self.fail_session(fed, id, t, cache_site);
            }
            _ => unreachable!(),
        }
    }

    /// Route a batch of network completions: background flows respawn
    /// at `t`, session flows advance their owners, anything else
    /// (e.g. externally cancelled flows) is dropped.
    fn dispatch_completions(&mut self, fed: &mut FedSim, completions: Vec<Completion>, t: SimTime) {
        for c in completions {
            self.stats.events_processed += 1;
            if let Some(origin_idx) = fed.background.remove(&c.flow) {
                fed.spawn_background(origin_idx);
                self.stats.background_respawns += 1;
            } else if let Some(sid) = self.flow_owner.remove(&c.flow) {
                self.on_flow_done(fed, sid, t);
            }
        }
    }

    /// Job arrival: charge the client tool's startup latency.
    fn on_start(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        self.in_flight += 1;
        if self.in_flight > self.stats.peak_concurrent {
            self.stats.peak_concurrent = self.in_flight;
        }
        let method = self.sessions[id.0 as usize].method;
        match method {
            DownloadMethod::HttpProxy => {
                let delay = fed.startup_costs.curl_startup;
                let s = &mut self.sessions[id.0 as usize];
                s.url = curl::url_for(&s.file.path);
                Self::set_phase(&mut self.tele, s, t, Phase::ProxyLookup);
                self.queue.schedule_at(t + delay, EngineEvent::Timer(id));
            }
            DownloadMethod::Stash => {
                // stashcp walks its fallback chain; the first usable
                // method here is XRootD (attempt index from the chain).
                let chain = stashcp::method_chain(fed.host_env);
                let attempt = chain
                    .iter()
                    .position(|m| *m == Method::Xrootd || *m == Method::HttpCache)
                    .unwrap_or(0);
                let transport = chain[attempt];
                let delay = stashcp::startup_latency(&fed.startup_costs, transport, attempt);
                let s = &mut self.sessions[id.0 as usize];
                s.transport = transport;
                Self::set_phase(&mut self.tele, s, t, Phase::GeoResolve);
                self.queue.schedule_at(t + delay, EngineEvent::Timer(id));
            }
        }
    }

    fn on_timer(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        match self.sessions[id.0 as usize].phase {
            Phase::GeoResolve => self.geo_resolve(fed, id, t),
            Phase::CacheCheck => self.cache_check(fed, id, t),
            Phase::FetchBegin => self.fetch_begin(fed, id, t),
            Phase::ProxyLookup => self.proxy_lookup(fed, id, t),
            Phase::ProxyConnect => self.proxy_connect(fed, id, t),
            Phase::DirectConnect => self.direct_connect(fed, id, t),
            Phase::DirectFetch => self.direct_fetch(fed, id, t),
            phase => unreachable!("timer fired for session {id:?} in phase {phase:?}"),
        }
    }

    /// (stash) Startup paid: the redirection policy picks a cache
    /// (skipping down caches and caches this session already failed
    /// against — ring holes under consistent hashing), then the
    /// connection round trip to that cache.
    fn geo_resolve(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, excluded, path) = {
            let s = &self.sessions[id.0 as usize];
            (s.site_idx, s.excluded_caches.clone(), s.file.path.clone())
        };
        let selected = fed.select_cache(site_idx, &path, &excluded, &self.cache_in_flight);
        let Some(cache_site) = selected else {
            // No cache should serve this session (all excluded/down,
            // or the tiered ladder ran out of rungs): stream from the
            // origin.
            self.enter_direct_fallback(fed, id, t);
            return;
        };
        *self.cache_in_flight.entry(cache_site).or_insert(0) += 1;
        let route = fed
            .topo
            .route(Endpoint::Cache(cache_site), Endpoint::Worker(site_idx));
        let s = &mut self.sessions[id.0 as usize];
        s.cache_site = Some(cache_site);
        Self::set_phase(&mut self.tele, s, t, Phase::CacheCheck);
        self.queue.schedule_at(
            t + Duration::from_secs_f64(route.rtt_ms / 1e3),
            EngineEvent::Timer(id),
        );
    }

    /// (stash) At the cache: plan the read. Whole hit serves directly;
    /// a plan with fresh chunks fetches from the origin; a plan whose
    /// missing chunks are all in flight parks in `JoinWait`.
    fn cache_check(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, cache_site, path, size, version, origin) = {
            let s = &self.sessions[id.0 as usize];
            (
                s.site_idx,
                s.cache_site.expect("geo_resolve ran"),
                s.file.path.clone(),
                s.file.size.as_u64(),
                s.file.version,
                s.origin,
            )
        };
        // The cache may have died while we were connecting (or while
        // parked in JoinWait): a refused connection fails the session
        // over to the next-nearest cache.
        if fed.faults.is_cache_down(cache_site) {
            self.fail_session(fed, id, t, Some(cache_site));
            return;
        }
        let cache = fed.caches.get_mut(&cache_site).expect("cache site");
        let plan = cache.plan_read(&path, 0, size, size, version, t);
        let per_conn = cache.cfg.per_conn_gbps * 1e9 / 8.0;
        let whole_hit = plan.miss_bytes == 0;
        {
            let s = &mut self.sessions[id.0 as usize];
            s.per_conn = per_conn;
            if s.opened_at.is_none() {
                s.opened_at = Some(t);
                s.initial_hit = whole_hit;
            }
        }

        if whole_hit {
            // Pure cache hit: cache → worker.
            let route = fed
                .topo
                .route(Endpoint::Cache(cache_site), Endpoint::Worker(site_idx));
            if !route_is_up(fed, &route.links) {
                // The serve path is severed: treat like a dead cache.
                self.fail_session(fed, id, t, Some(cache_site));
                return;
            }
            let flow = fed.net.start_flow(
                FlowSpec {
                    path: route.links,
                    bytes: size.max(1),
                    rate_cap: Some(per_conn),
                },
                t,
            );
            self.flow_owner.insert(flow, id);
            let s = &mut self.sessions[id.0 as usize];
            s.flow = Some(flow);
            Self::set_phase(&mut self.tele, s, t, Phase::Transfer(Xfer::StashServe));
            self.arm_deadline(fed, id, t, size, per_conn);
        } else if plan.fetch.is_empty() {
            // Every missing chunk is already on its way for another
            // session: join that fetch instead of duplicating it.
            let s = &mut self.sessions[id.0 as usize];
            if s.joins == 0 {
                self.stats.coalesced_joins += 1;
            }
            s.joins += 1;
            Self::set_phase(&mut self.tele, s, t, Phase::JoinWait);
            s.waiting_on = Some((cache_site, path.clone()));
            self.waiters
                .entry((cache_site, path))
                .or_default()
                .push(id);
            // The owner's fetch is capped at the same per-connection
            // rate, so its expected time bounds this wait too.
            self.arm_deadline(fed, id, t, size, per_conn);
        } else {
            // Miss. The cache consults the redirector, which broadcasts
            // to origins (one WAN round trip to the redirector + one to
            // the origins). If every redirector instance is down the
            // fetch cannot be located — back off and retry (chunks are
            // not yet reserved, so nothing needs unwinding).
            let located = match fed.redirectors.locate(&path, &mut fed.origins, t) {
                Ok(outcome) => outcome.expect("file registered at an origin"),
                Err(_) => {
                    self.fail_session(fed, id, t, None);
                    return;
                }
            };
            debug_assert_eq!(located.origin, origin);
            // Reserve the chunks *now* (before the discovery round
            // trips elapse) so any session planning inside that window
            // joins this fetch instead of duplicating origin traffic.
            // Timing-neutral for serial runs: nothing observes the
            // in-flight bits between plan and fetch start there.
            fed.caches
                .get_mut(&cache_site)
                .expect("cache site")
                .begin_fetch(&path, version, &plan.fetch);
            let origin_route = fed
                .topo
                .route(Endpoint::Origin(origin.0), Endpoint::Cache(cache_site));
            let s = &mut self.sessions[id.0 as usize];
            s.plan = Some(plan);
            Self::set_phase(&mut self.tele, s, t, Phase::FetchBegin);
            self.queue.schedule_at(
                t + Duration::from_secs_f64(2.0 * origin_route.rtt_ms / 1e3),
                EngineEvent::Timer(id),
            );
        }
    }

    /// (stash) Discovery round trips paid (chunks were reserved at
    /// plan time): stream origin → cache → worker.
    fn fetch_begin(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, cache_site, size, origin, per_conn) = {
            let s = &self.sessions[id.0 as usize];
            (
                s.site_idx,
                s.cache_site.expect("geo_resolve ran"),
                s.file.size.as_u64(),
                s.origin,
                s.per_conn,
            )
        };
        // The cache may have died during the discovery round trips.
        if fed.faults.is_cache_down(cache_site) {
            self.abort_reserved_fetch(fed, id, t, cache_site);
            return;
        }
        let origin_route = fed
            .topo
            .route(Endpoint::Origin(origin.0), Endpoint::Cache(cache_site));
        let cache_route = fed
            .topo
            .route(Endpoint::Cache(cache_site), Endpoint::Worker(site_idx));
        let mut links = origin_route.links;
        links.extend(&cache_route.links);
        if !route_is_up(fed, &links) {
            self.abort_reserved_fetch(fed, id, t, cache_site);
            return;
        }
        let flow = fed.net.start_flow(
            FlowSpec {
                path: links,
                bytes: size.max(1),
                rate_cap: Some(per_conn),
            },
            t,
        );
        self.flow_owner.insert(flow, id);
        let s = &mut self.sessions[id.0 as usize];
        s.flow = Some(flow);
        Self::set_phase(&mut self.tele, s, t, Phase::Transfer(Xfer::StashFetch));
        self.arm_deadline(fed, id, t, size, per_conn);
    }

    /// A reserved (pinned) fetch cannot start: release the
    /// reservation, wake joiners so they re-plan, and fail over.
    fn abort_reserved_fetch(
        &mut self,
        fed: &mut FedSim,
        id: SessionId,
        t: SimTime,
        cache_site: usize,
    ) {
        let (path, version, plan) = {
            let s = &mut self.sessions[id.0 as usize];
            (
                s.file.path.clone(),
                s.file.version,
                s.plan.take().expect("fetch had a plan"),
            )
        };
        fed.caches
            .get_mut(&cache_site)
            .expect("cache site")
            .abort_fetch(&path, version, &plan.fetch);
        self.wake_waiters(cache_site, &path, t);
        self.fail_session(fed, id, t, Some(cache_site));
    }

    /// (proxy) curl startup paid: squid lookup, then connection
    /// establishment at the path RTT.
    fn proxy_lookup(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        use crate::proxy::ProxyLookup;
        let (site_idx, url, size, origin) = {
            let s = &self.sessions[id.0 as usize];
            (s.site_idx, s.url.clone(), s.file.size.as_u64(), s.origin)
        };
        let proxy = fed
            .proxies
            .get_mut(&site_idx)
            .expect("compute site has proxy");
        let lookup = proxy.lookup(&url, size, t);
        let relay_cap = FedSim::proxy_relay_cap_bps(proxy, size);
        let worker_route = fed
            .topo
            .route(Endpoint::Proxy(site_idx), Endpoint::Worker(site_idx));

        let (links, rtt_ms, hit, cacheable) = match lookup {
            ProxyLookup::Hit => (worker_route.links.clone(), worker_route.rtt_ms, true, false),
            ProxyLookup::Miss { cacheable, .. } => {
                // Proxy streams origin → proxy → worker.
                let up = fed
                    .topo
                    .route(Endpoint::Origin(origin.0), Endpoint::Proxy(site_idx));
                let mut links = up.links;
                links.extend(&worker_route.links);
                (links, up.rtt_ms + worker_route.rtt_ms, false, cacheable)
            }
        };
        let s = &mut self.sessions[id.0 as usize];
        s.proxy_hit = hit;
        s.cacheable = cacheable;
        s.relay_links = links;
        s.relay_cap = relay_cap;
        Self::set_phase(&mut self.tele, s, t, Phase::ProxyConnect);
        self.queue.schedule_at(
            t + Duration::from_secs_f64(rtt_ms / 1e3 * crate::sim::estimate::HANDSHAKE_ROUNDS),
            EngineEvent::Timer(id),
        );
    }

    /// (proxy) Connected: start the relay flow.
    fn proxy_connect(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (links, size, relay_cap) = {
            let s = &self.sessions[id.0 as usize];
            (s.relay_links.clone(), s.file.size.as_u64(), s.relay_cap)
        };
        if !route_is_up(fed, &links) {
            // A cut link broke the relay path: retry the lookup after
            // a backoff (curl reconnects; bounded by the direct-origin
            // fallback like every other retry path).
            self.fail_session(fed, id, t, None);
            return;
        }
        let flow = fed.net.start_flow(
            FlowSpec {
                path: links,
                bytes: size.max(1),
                rate_cap: Some(relay_cap),
            },
            t,
        );
        self.flow_owner.insert(flow, id);
        let s = &mut self.sessions[id.0 as usize];
        s.flow = Some(flow);
        Self::set_phase(&mut self.tele, s, t, Phase::Transfer(Xfer::ProxyRelay));
    }

    /// (fallback) Connect straight to the origin. If the direct path
    /// itself is cut there is nothing left to fail over to: poll for
    /// the link to heal.
    fn direct_connect(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, origin) = {
            let s = &self.sessions[id.0 as usize];
            (s.site_idx, s.origin)
        };
        let route = fed
            .topo
            .route(Endpoint::Origin(origin.0), Endpoint::Worker(site_idx));
        if !route_is_up(fed, &route.links) {
            self.stats.retries += 1;
            self.sessions[id.0 as usize].retries += 1;
            self.queue
                .schedule_at(t + Self::direct_backoff(fed), EngineEvent::Timer(id));
            return;
        }
        Self::set_phase(
            &mut self.tele,
            &mut self.sessions[id.0 as usize],
            t,
            Phase::DirectFetch,
        );
        self.queue.schedule_at(
            t + Duration::from_secs_f64(2.0 * route.rtt_ms / 1e3),
            EngineEvent::Timer(id),
        );
    }

    /// (fallback) Request round trips paid: stream origin → worker.
    fn direct_fetch(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, origin, size) = {
            let s = &self.sessions[id.0 as usize];
            (s.site_idx, s.origin, s.file.size.as_u64())
        };
        let route = fed
            .topo
            .route(Endpoint::Origin(origin.0), Endpoint::Worker(site_idx));
        if !route_is_up(fed, &route.links) {
            // Cut during the round trips: back to polling.
            self.stats.retries += 1;
            let s = &mut self.sessions[id.0 as usize];
            s.retries += 1;
            Self::set_phase(&mut self.tele, s, t, Phase::DirectConnect);
            self.queue
                .schedule_at(t + Self::direct_backoff(fed), EngineEvent::Timer(id));
            return;
        }
        let flow = fed.net.start_flow(
            FlowSpec {
                path: route.links,
                bytes: size.max(1),
                rate_cap: None,
            },
            t,
        );
        self.flow_owner.insert(flow, id);
        let s = &mut self.sessions[id.0 as usize];
        s.flow = Some(flow);
        Self::set_phase(&mut self.tele, s, t, Phase::Transfer(Xfer::DirectOrigin));
    }

    /// A session's flow finished at `t`: post-transfer bookkeeping,
    /// monitoring, waiter wake-ups, and the final record.
    fn on_flow_done(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let xfer = match self.sessions[id.0 as usize].phase {
            Phase::Transfer(x) => x,
            phase => unreachable!("flow completion for session {id:?} in phase {phase:?}"),
        };
        match xfer {
            Xfer::StashServe => {
                let (cache_site, path, version, size) = {
                    let s = &self.sessions[id.0 as usize];
                    (
                        s.cache_site.expect("stash session"),
                        s.file.path.clone(),
                        s.file.version,
                        s.file.size.as_u64(),
                    )
                };
                // Transfer end: the client digests what it received
                // against the origin keystream. A poisoned copy fails,
                // is dropped at the cache (the refetch pulls fresh
                // bytes), and the session exclude-and-refetches.
                if !served_bytes_verify(&fed.caches[&cache_site], &path, version, size) {
                    self.stats.corruptions_detected += 1;
                    self.stats.aborted_bytes += size;
                    if let Some(b) = fed.breaker.as_mut() {
                        b.record(cache_site, BreakerOutcome::Corruption, t);
                    }
                    fed.caches
                        .get_mut(&cache_site)
                        .expect("cache site")
                        .invalidate(&path);
                    self.sessions[id.0 as usize].failovers += 1;
                    self.stats.failovers += 1;
                    self.fail_session(fed, id, t, Some(cache_site));
                    return;
                }
                fed.caches
                    .get_mut(&cache_site)
                    .expect("cache site")
                    .record_served(size, 0);
                if let Some(b) = fed.breaker.as_mut() {
                    b.record(cache_site, BreakerOutcome::Success, t);
                }
                self.emit_monitoring(fed, id, t);
                self.finish(id, t, Method::Xrootd);
            }
            Xfer::StashFetch => {
                let (cache_site, path, version, origin, plan) = {
                    let s = &mut self.sessions[id.0 as usize];
                    (
                        s.cache_site.expect("stash session"),
                        s.file.path.clone(),
                        s.file.version,
                        s.origin,
                        s.plan.take().expect("fetch had a plan"),
                    )
                };
                let cache = fed.caches.get_mut(&cache_site).expect("cache site");
                cache.commit_chunks(&path, version, &plan.fetch, t);
                cache.record_served(plan.hit_bytes, plan.miss_bytes);
                if let Some(b) = fed.breaker.as_mut() {
                    b.record(cache_site, BreakerOutcome::Success, t);
                }
                fed.origins[origin.0].bytes_served += plan.miss_bytes;
                // Chunks just became resident: wake sessions that
                // joined this fetch so they can re-plan (usually into
                // a pure hit).
                self.wake_waiters(cache_site, &path, t);
                self.emit_monitoring(fed, id, t);
                self.finish(id, t, Method::Xrootd);
            }
            Xfer::ProxyRelay => {
                let (site_idx, url, size, origin, hit, cacheable) = {
                    let s = &self.sessions[id.0 as usize];
                    (
                        s.site_idx,
                        s.url.clone(),
                        s.file.size.as_u64(),
                        s.origin,
                        s.proxy_hit,
                        s.cacheable,
                    )
                };
                if !hit {
                    fed.origins[origin.0].bytes_served += size;
                    if cacheable {
                        fed.proxies
                            .get_mut(&site_idx)
                            .expect("proxy")
                            .commit(&url, size, t);
                    }
                }
                self.finish(id, t, Method::HttpProxy);
            }
            Xfer::DirectOrigin => {
                let (origin, size) = {
                    let s = &self.sessions[id.0 as usize];
                    (s.origin, s.file.size.as_u64())
                };
                fed.origins[origin.0].bytes_served += size;
                self.finish(id, t, Method::HttpOrigin);
            }
        }
    }

    /// Emit the monitoring packet trio for a finished stash transfer.
    fn emit_monitoring(&mut self, fed: &mut FedSim, id: SessionId, closed_at: SimTime) {
        let (cache_site, site_idx, path, size, opened_at, protocol) = {
            let s = &self.sessions[id.0 as usize];
            (
                s.cache_site.expect("stash session"),
                s.site_idx,
                s.file.path.clone(),
                s.file.size.as_u64(),
                s.opened_at.expect("cache_check ran"),
                if s.transport == Method::HttpCache {
                    Protocol::Http
                } else {
                    Protocol::Xrootd
                },
            )
        };
        fed.emit_transfer_monitoring(
            cache_site, site_idx, &path, size, size, opened_at, closed_at, protocol,
        );
    }

    /// Wake every session parked on `(cache_site, path)`.
    fn wake_waiters(&mut self, cache_site: usize, path: &str, t: SimTime) {
        let Some(ids) = self.waiters.remove(&(cache_site, path.to_string())) else {
            return;
        };
        for wid in ids {
            let s = &mut self.sessions[wid.0 as usize];
            // Hard invariant (upgraded from a debug_assert): every id
            // in a waiter list is parked in JoinWait. Symmetric removal
            // on every JoinWait exit path ([`Self::remove_waiter`])
            // keeps this true; tripping it means a stale waiter — the
            // lost-wakeup class of protocol bug the model checker
            // hunts.
            assert_eq!(
                s.phase,
                Phase::JoinWait,
                "stale waiter: session {wid:?} still listed under ({cache_site}, {path})"
            );
            s.waiting_on = None;
            Self::set_phase(&mut self.tele, s, t, Phase::CacheCheck);
            self.queue.schedule_at(t, EngineEvent::Timer(wid));
        }
    }

    /// Symmetric counterpart of the `JoinWait` park in
    /// [`Self::cache_check`]: if the session still sits in a waiter
    /// list, scrub it. Every JoinWait exit path funnels through here or
    /// [`Self::wake_waiters`], so a session can never linger in a list
    /// it has left — the stale-waiter audit.
    fn remove_waiter(&mut self, id: SessionId) {
        let Some(key) = self.sessions[id.0 as usize].waiting_on.take() else {
            return;
        };
        if let Some(ids) = self.waiters.get_mut(&key) {
            ids.retain(|&wid| wid != id);
            if ids.is_empty() {
                self.waiters.remove(&key);
            }
        }
    }

    /// Drop a session's claim on its assigned cache (in-flight load
    /// accounting; no-op for sessions without one).
    fn release_cache_slot(&mut self, id: SessionId) {
        if let Some(site) = self.sessions[id.0 as usize].cache_site {
            if let Some(n) = self.cache_in_flight.get_mut(&site) {
                *n = n.saturating_sub(1);
            }
        }
    }

    fn finish(&mut self, id: SessionId, t: SimTime, method: Method) {
        self.release_cache_slot(id);
        self.remove_waiter(id);
        let s = &mut self.sessions[id.0 as usize];
        let cache_hit = match method {
            Method::HttpProxy => s.proxy_hit,
            // Direct-to-origin never touched a cache's copy.
            Method::HttpOrigin => false,
            _ => s.initial_hit,
        };
        s.record = Some(TransferRecord {
            path: s.file.path.clone(),
            bytes: s.file.size.as_u64(),
            method,
            cache_hit,
            duration: t - s.arrival,
        });
        Self::set_phase(&mut self.tele, s, t, Phase::Done);
        s.flow = None;
        let s = &mut self.sessions[id.0 as usize];
        self.tele
            .on_complete(t, s.cache_site, s.file.size.as_u64(), cache_hit);
        if self.tele.trace_enabled() {
            let spans = std::mem::take(&mut s.spans);
            let trace = SpanTrace {
                session: id.0,
                site: s.site_idx,
                path: s.file.path.clone(),
                arrival: s.arrival,
                completed: t,
                bytes: s.file.size.as_u64(),
                cache_site: s.cache_site,
                hit: cache_hit,
                spans,
            };
            self.tele.push_trace(trace);
        }
        self.outstanding -= 1;
        self.in_flight -= 1;
        self.completed.push(id);
        self.stats.sessions_completed += 1;
        self.epochs.sessions_serial += 1;
        self.state_version += 1;
    }

    // --- model-checker seam -----------------------------------------------

    /// Every event enabled right now, in a deterministic order: pending
    /// timer entries in `(time, seq)` order, then in-flight foreground
    /// flows in `FlowId` order, then the fault source if any fault is
    /// scheduled. The deterministic run loop always fires the
    /// virtual-time minimum of these; the model checker
    /// ([`crate::mc`]) instead explores *every* element of this list
    /// from every reached state.
    pub fn mc_choices(&self, fed: &FedSim) -> Vec<McChoice> {
        let mut out = Vec::new();
        for (at, seq, ev) in self.queue.pending_entries() {
            let session = match ev {
                EngineEvent::Start(id)
                | EngineEvent::Timer(id)
                | EngineEvent::Deadline(id, _) => id,
            };
            out.push(McChoice::Timer { at, seq, session });
        }
        let mut flows: Vec<(FlowId, SessionId)> =
            self.flow_owner.iter().map(|(&f, &s)| (f, s)).collect();
        flows.sort_unstable();
        for (flow, owner) in flows {
            out.push(McChoice::Flow { flow, owner });
        }
        if fed.next_fault_at().is_some() {
            out.push(McChoice::Fault);
        }
        out
    }

    /// Fire one enabled event out of arbitration order. The instant is
    /// clamped to `max(scheduled time, engine clock, federation clock)`
    /// — the checker's time abstraction: event *orderings* are
    /// explored, durations are not, so an event chosen "early" simply
    /// fires at the clock the run has already reached. Clocks stay
    /// monotone, so every handler's scheduling and network assertion
    /// holds unchanged. Panics if the choice is no longer enabled (the
    /// checker only fires freshly enumerated choices).
    pub fn mc_fire(&mut self, fed: &mut FedSim, choice: McChoice) {
        match choice {
            McChoice::Timer { at, seq, .. } => {
                let ev = self.queue.take(at, seq).expect("chosen timer is pending");
                let t = at.max(self.queue.now()).max(fed.now);
                self.queue.force_advance(t);
                fed.now = t;
                self.stats.events_processed += 1;
                match ev {
                    EngineEvent::Start(id) => self.on_start(fed, id, t),
                    EngineEvent::Timer(id) => self.on_timer(fed, id, t),
                    EngineEvent::Deadline(id, gen) => self.on_deadline(fed, id, gen, t),
                }
            }
            McChoice::Flow { flow, owner } => {
                let t = self.queue.now().max(fed.now);
                self.queue.force_advance(t);
                fed.now = t;
                // Completing a flow "now" regardless of remaining
                // bytes: the ordering choice is what matters.
                let c = fed
                    .net
                    .force_complete(flow, t)
                    .expect("chosen flow is live");
                debug_assert_eq!(c.flow, flow);
                self.stats.events_processed += 1;
                let removed = self.flow_owner.remove(&flow);
                debug_assert_eq!(removed, Some(owner));
                self.on_flow_done(fed, owner, t);
            }
            McChoice::Fault => {
                let ev = fed.pop_fault().expect("chosen fault is scheduled");
                let t = ev.at.max(self.queue.now()).max(fed.now);
                self.queue.force_advance(t);
                fed.now = t;
                self.stats.events_processed += 1;
                self.on_fault(fed, ev.kind, t);
            }
        }
    }

    // --- sharded epochs ---------------------------------------------------

    /// Count one refused plan under its reason.
    fn note_bail(&mut self, bail: PlanBail) {
        let slot = match bail {
            PlanBail::PendingFault => &mut self.epochs.bail_pending_fault,
            PlanBail::WanCoupled => &mut self.epochs.bail_wan_coupled,
            PlanBail::PolicyUnstable => &mut self.epochs.bail_policy_unstable,
            PlanBail::BelowThreshold => &mut self.epochs.bail_below_threshold,
            PlanBail::Resilience => &mut self.epochs.bail_resilience,
            PlanBail::Other => &mut self.epochs.bail_other,
        };
        *slot += 1;
    }

    /// Attempt one parallel epoch: plan a bounded prefix of the
    /// pending work, fan the shards out over up to `threads` worker
    /// threads, and merge at the barrier. On `Err` the engine and
    /// federation are untouched and the caller caches the reason
    /// against the current state version.
    fn try_epoch(&mut self, fed: &mut FedSim, threads: usize) -> Result<(), PlanBail> {
        // Gray-failure machinery (deadlines, circuit breaker) and
        // load-coupled policies observe mid-epoch state the shards
        // cannot reproduce: serial-only, checked before any planning
        // work is spent.
        if fed.resilience_armed() {
            return Err(PlanBail::Resilience);
        }
        if !fed.policy.epoch_stable() {
            return Err(PlanBail::PolicyUnstable);
        }
        self.epochs.epochs_planned += 1;
        let (tasks, transport) = self.plan_epoch(fed)?;
        let workers = threads.min(tasks.len());
        let slots: Vec<Mutex<Option<ShardTask>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let results: Vec<Mutex<Option<ShardOutcome>>> =
            (0..slots.len()).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let sessions: &[Session] = &self.sessions;
        // Work-stealing over indexed slots: claim order is racy but
        // every result lands in its shard's slot, so the merge below
        // sees a schedule-independent ordering.
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let k = next.fetch_add(1, Ordering::Relaxed);
                    if k >= slots.len() {
                        break;
                    }
                    let task = slots[k].lock().unwrap().take().expect("each shard runs once");
                    let outcome = run_shard(task, sessions);
                    *results[k].lock().unwrap() = Some(outcome);
                });
            }
        });
        let outcomes: Vec<ShardOutcome> = results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker stored a result"))
            .collect();
        self.merge_epoch(fed, outcomes, transport);
        self.epochs.epochs_engaged += 1;
        Ok(())
    }

    /// Prove a prefix of the pending work is exactly parallelisable
    /// within the current epoch window and split it into shard tasks.
    /// The window's horizon is the next scheduled fault instant (or
    /// unbounded when none is pending); proof obligations, checked per
    /// pending session against the epoch-frozen federation:
    ///
    /// - stash method, nothing excluded (no failover history pending);
    /// - the (epoch-stable) policy picks a cache, and — when cold
    ///   fetches will shift cache load mid-epoch — the same pick
    ///   survives an adversarial view charging the pick's cache its
    ///   worst-case load ceiling (competitor scores only rise with
    ///   load, so surviving the ceiling means no ordering flips);
    /// - the serve route (and, for files not wholly resident, the
    ///   combined origin→cache fetch route) is up, and disjoint from
    ///   every origin DTN link whenever background flows exist —
    ///   shard flows must never share a component with parent flows;
    /// - cold fetches fit under each cache's eviction high watermark,
    ///   so mid-epoch LRU evictions cannot invalidate the plan-time
    ///   hit/miss snapshot the completion bounds price;
    /// - the shipped prefix provably completes *strictly* before the
    ///   horizon (a fault beats a same-instant timer in the serial
    ///   arbitration, so a wake timer landing exactly on the horizon
    ///   would fire post-fault) and no later than the first
    ///   left-behind arrival (completions dispatch before same-
    ///   instant Starts, so a tie is safe). The bound is pessimistic:
    ///   per network component, `max arrival + Σ (latency legs +
    ///   size / worst-case max-min floor)` — some session is always
    ///   progressing at no less than the floor rate.
    ///
    /// Sessions sharing any flow link, a cache server (LRU /
    /// reservation state advances in request order), or an origin DTN
    /// are grouped into one shard by union-find. Cold fetches to
    /// distinct origins therefore shard by origin component instead of
    /// forcing the whole run serial. Returns `Err` (federation
    /// untouched) if any obligation fails or fewer than two shards
    /// would result.
    fn plan_epoch(&mut self, fed: &mut FedSim) -> Result<(Vec<ShardTask>, Method), PlanBail> {
        // A foreground flow from an earlier engine still in the
        // network would be invisible to the shards.
        if fed.net.active_flows() != fed.background.len() {
            return Err(PlanBail::BelowThreshold);
        }
        let mut pending: Vec<usize> = self
            .sessions
            .iter()
            .enumerate()
            .filter(|(_, s)| s.phase == Phase::Pending)
            .map(|(i, _)| i)
            .collect();
        if pending.len() != self.outstanding || pending.len() < 2 {
            return Err(PlanBail::BelowThreshold);
        }
        // Arrival order with id tie-break is exactly the queue's
        // `(time, seq)` order: `spawn_at` issues sequence numbers in
        // session-id order, so the prefix cut below can reason about
        // dispatch order without touching the queue.
        pending.sort_unstable_by_key(|&i| (self.sessions[i].arrival, i));
        let horizon = fed.next_fault_at();
        let bg_links: HashSet<LinkId> = (0..fed.origins.len())
            .map(|o| fed.topo.origin_lan_link(o))
            .collect();
        // With no background flows in the parent network, routes may
        // cross origin LANs freely — which is what lets cold fetches
        // shard at all.
        let have_bg = !fed.background.is_empty();
        let mut picks: Vec<PlannedPick> = Vec::with_capacity(pending.len());
        // Why the eligible prefix stopped growing; surfaces as the
        // bail reason only when too little shippable work sits in
        // front of the blocker.
        let mut cap_reason: Option<PlanBail> = None;
        // Version pinned per (cache, path): two sessions reading
        // different versions of one path would invalidate each other's
        // residency mid-epoch, which the plan-time hit/miss snapshot
        // cannot price.
        let mut pinned_version: HashMap<(usize, String), u64> = HashMap::new();
        for &i in &pending {
            let s = &self.sessions[i];
            if let Some(h) = horizon {
                if s.arrival >= h {
                    cap_reason = Some(PlanBail::PendingFault);
                    break;
                }
            }
            let verdict = (|| -> Result<PlannedPick, PlanBail> {
                if s.method != DownloadMethod::Stash || !s.excluded_caches.is_empty() {
                    return Err(PlanBail::Other);
                }
                // One ranked lookup per session, exactly as
                // geo_resolve pays mid-run.
                let cache_site = fed
                    .select_cache(
                        s.site_idx,
                        &s.file.path,
                        &s.excluded_caches,
                        &self.cache_in_flight,
                    )
                    .ok_or(PlanBail::Other)?;
                let cache = &fed.caches[&cache_site];
                if cache.is_poisoned(&s.file.path) {
                    // A poisoned copy fails the digest check at serve
                    // time and detours into invalidate + failover:
                    // serial-only.
                    return Err(PlanBail::Other);
                }
                match pinned_version.entry((cache_site, s.file.path.clone())) {
                    std::collections::hash_map::Entry::Occupied(e) => {
                        if *e.get() != s.file.version {
                            return Err(PlanBail::Other);
                        }
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(s.file.version);
                    }
                }
                let whole = s.file.size.as_u64() == 0
                    || cache.contains_whole(&s.file.path, s.file.version);
                let route = fed
                    .topo
                    .route(Endpoint::Cache(cache_site), Endpoint::Worker(s.site_idx));
                if !route_is_up(fed, &route.links) {
                    return Err(PlanBail::WanCoupled);
                }
                if have_bg && route.links.iter().any(|l| bg_links.contains(l)) {
                    return Err(PlanBail::WanCoupled);
                }
                let fetch = if whole {
                    None
                } else {
                    if have_bg {
                        // The fetch route crosses this origin's LAN
                        // link, where background flows live.
                        return Err(PlanBail::WanCoupled);
                    }
                    if fed.redirectors.healthy_count() == 0 {
                        return Err(PlanBail::Other);
                    }
                    let origin_route = fed
                        .topo
                        .route(Endpoint::Origin(s.origin.0), Endpoint::Cache(cache_site));
                    let origin_rtt_ms = origin_route.rtt_ms;
                    // Combined fetch path, built exactly as
                    // fetch_begin builds it (origin legs first).
                    let mut links = origin_route.links;
                    links.extend_from_slice(&route.links);
                    if !route_is_up(fed, &links) {
                        return Err(PlanBail::WanCoupled);
                    }
                    Some(EpochFetch {
                        origin_idx: s.origin.0,
                        fetch_links: links,
                        origin_rtt_ms,
                    })
                };
                Ok(PlannedPick {
                    session: i,
                    cache_site,
                    serve_links: route.links,
                    rtt_ms: route.rtt_ms,
                    fetch,
                })
            })();
            match verdict {
                Ok(p) => picks.push(p),
                Err(r) => {
                    cap_reason = Some(r);
                    break;
                }
            }
        }
        let kmax = picks.len();
        if kmax < 2 {
            return Err(cap_reason.unwrap_or(PlanBail::BelowThreshold));
        }
        // Upper-bound bytes each cache ingests this epoch: one whole-
        // file fetch per distinct (cache, path) not wholly resident.
        // Feeds the eviction-freedom check and the pick-stability load
        // ceiling below. Computed at kmax; both checks only relax as
        // the prefix shrinks, so they stay valid for any cut.
        let mut inbound: HashMap<usize, u64> = HashMap::new();
        {
            let mut seen: HashSet<(usize, &str)> = HashSet::new();
            for p in &picks {
                if p.fetch.is_some() {
                    let s = &self.sessions[p.session];
                    if seen.insert((p.cache_site, s.file.path.as_str())) {
                        *inbound.entry(p.cache_site).or_insert(0) += s.file.size.as_u64();
                    }
                }
            }
        }
        for (&site, &add) in &inbound {
            let cache = &fed.caches[&site];
            let cap = cache.cfg.capacity.as_u64();
            let high = (cache.cfg.high_watermark * cap as f64) as u64;
            if cache.usage().as_u64() + add > high {
                // Filling past the watermark would trigger mid-epoch
                // LRU evictions; the plan-time residency snapshot (and
                // with it every bound above) would be fiction.
                return Err(PlanBail::Other);
            }
        }
        if !inbound.is_empty() {
            // Adversarial pick-stability: cold fetches raise cache
            // usage mid-epoch, and the geo score charges load via
            // LOAD_PENALTY_KM. For every pick whose cache ingests
            // bytes, re-run the selection against a view where that
            // cache's score carries its worst-case load growth (plus
            // an epsilon absorbing f64 association noise — erring
            // toward a bail). Competitor scores can only *rise* with
            // load, so a pick that beats their floors from its own
            // ceiling cannot flip at any instant inside the epoch.
            for p in &picks {
                let Some(&add) = inbound.get(&p.cache_site) else {
                    continue;
                };
                let bump = {
                    let cache = &fed.caches[&p.cache_site];
                    let cap = cache.cfg.capacity.as_u64() as f64;
                    let lf_max = (cache.usage().as_u64() + add) as f64 / cap;
                    (lf_max - cache.load_factor()) * crate::geoip::LOAD_PENALTY_KM + 1e-6
                };
                let s = &self.sessions[p.session];
                let mut view = fed.federation_view(s.site_idx, &self.cache_in_flight);
                let Some(pos) = view.pos_of_site(p.cache_site) else {
                    return Err(PlanBail::PolicyUnstable);
                };
                for r in view.ranked.iter_mut() {
                    if r.0 == pos {
                        r.1 += bump;
                    }
                }
                // Re-sort with the ranker's exact comparator.
                view.ranked.sort_by(|a, b| {
                    a.1.partial_cmp(&b.1)
                        .expect("rank scores are finite")
                        .then(a.0.cmp(&b.0))
                });
                if fed.policy.select(&s.file.path, &view, &s.excluded_caches)
                    != Some(p.cache_site)
                {
                    return Err(PlanBail::PolicyUnstable);
                }
            }
        }
        // Startup pricing is per-transport, identical for every stash
        // session (mirrors on_start).
        let chain = stashcp::method_chain(fed.host_env);
        let attempt = chain
            .iter()
            .position(|m| *m == Method::Xrootd || *m == Method::HttpCache)
            .unwrap_or(0);
        let transport = chain[attempt];
        let startup_delay = stashcp::startup_latency(&fed.startup_costs, transport, attempt);
        let startup_secs = startup_delay.as_secs_f64();
        let link_count = fed.net.link_count();
        let site_count = fed.topo.site_count();
        let origin_count = fed.origins.len();
        // Pessimistic completion bound for a candidate prefix, checked
        // against the horizon (strict) and the first left-behind
        // arrival (non-strict). Per component: every flow's max-min
        // rate is at least min(per_conn, weakest link capacity /
        // component flow count), some session is always progressing
        // (joiners wait only while their owner streams), so the epoch
        // drains within the sum of individual worst-case itineraries
        // after the last arrival. Cold sessions are priced with their
        // discovery round trips even if they end up joining — an
        // overestimate, never an underestimate.
        let fits = |picks_k: &[PlannedPick], groups: &[Vec<usize>]| -> bool {
            let next_arrival = pending
                .get(picks_k.len())
                .map(|&i| self.sessions[i].arrival);
            if horizon.is_none() && next_arrival.is_none() {
                return true;
            }
            let mut worst = SimTime::ZERO;
            for g in groups {
                let mut min_cap = f64::INFINITY;
                let mut max_arrival = SimTime::ZERO;
                for &pi in g {
                    let p = &picks_k[pi];
                    max_arrival = max_arrival.max(self.sessions[p.session].arrival);
                    for l in p
                        .serve_links
                        .iter()
                        .chain(p.fetch.iter().flat_map(|f| f.fetch_links.iter()))
                    {
                        min_cap = min_cap.min(fed.net.link_effective_capacity(*l));
                    }
                }
                let n_c = g.len() as f64;
                let mut active = 0.0f64;
                for &pi in g {
                    let p = &picks_k[pi];
                    let s = &self.sessions[p.session];
                    let mut lat = startup_secs + p.rtt_ms / 1e3;
                    if let Some(f) = &p.fetch {
                        lat += 2.0 * f.origin_rtt_ms / 1e3;
                    }
                    let per_conn = fed.caches[&p.cache_site].cfg.per_conn_gbps * 1e9 / 8.0;
                    let size = s.file.size.as_u64().max(1) as f64;
                    active += lat + size / per_conn.min(min_cap / n_c);
                }
                // +1 µs absorbs the Duration conversion's rounding.
                let bound = max_arrival + Duration::from_secs_f64(active) + Duration(1);
                worst = worst.max(bound);
            }
            if let Some(h) = horizon {
                if worst >= h {
                    return false;
                }
            }
            if let Some(a) = next_arrival {
                if worst > a {
                    return false;
                }
            }
            true
        };
        // Prefix cut: largest k whose picks partition into ≥ 2 shards
        // and provably drain inside the window. The fast path — no
        // horizon and everything eligible — ships the whole run
        // without computing any bound (PR 6's terminal epoch).
        let full = horizon.is_none() && kmax == pending.len();
        let mut k = kmax;
        let (k, groups) = loop {
            if k < 2 {
                return Err(match (horizon, cap_reason) {
                    (Some(_), _) => PlanBail::PendingFault,
                    (None, Some(r)) => r,
                    (None, None) => PlanBail::BelowThreshold,
                });
            }
            let groups = group_picks(&picks[..k], link_count, site_count, origin_count);
            // Shrinking the prefix removes union edges, so a 1-group
            // cut can still split at smaller k — keep descending.
            let viable = groups.len() >= 2
                && ((full && k == kmax) || fits(&picks[..k], &groups));
            if viable {
                break (k, groups);
            }
            k = if k > 64 { k - k / 8 } else { k - 1 };
        };
        picks.truncate(k);
        // Point of no return: pull the shipped Start events (with
        // their original `(time, seq)` keys — the serial tie-breaks)
        // off the queue, restore the left-behind tail with its keys
        // intact, and move per-group state out of the federation.
        let shipped: HashSet<u64> = picks.iter().map(|p| p.session as u64).collect();
        let drained = self.queue.drain_sorted();
        let mut start_key: HashMap<u64, (SimTime, u64)> = HashMap::with_capacity(picks.len());
        let mut rest: Vec<(SimTime, u64, EngineEvent)> = Vec::new();
        for (t, seq, ev) in drained {
            match ev {
                EngineEvent::Start(id) if shipped.contains(&id.0) => {
                    start_key.insert(id.0, (t, seq));
                }
                EngineEvent::Start(_) => rest.push((t, seq, ev)),
                EngineEvent::Timer(id) => {
                    unreachable!("pending timer for {id:?} with no session in flight")
                }
                EngineEvent::Deadline(id, _) => {
                    unreachable!(
                        "pending deadline for {id:?} in an epoch (resilience is disarmed)"
                    )
                }
            }
        }
        assert_eq!(
            start_key.len(),
            picks.len(),
            "every shipped session had a pending Start"
        );
        self.queue.restore(rest);
        let epoch_start = fed.now;
        let mut tasks = Vec::with_capacity(groups.len());
        for group in groups {
            let mut sessions: Vec<EpochSession> = group
                .into_iter()
                .map(|pi| {
                    let p = &mut picks[pi];
                    let idx = p.session;
                    let (t0, seq) = start_key[&(idx as u64)];
                    EpochSession {
                        id: SessionId(idx as u64),
                        t0,
                        seq,
                        cache_site: p.cache_site,
                        serve_links: std::mem::take(&mut p.serve_links),
                        rtt_ms: p.rtt_ms,
                        fetch: p.fetch.take(),
                    }
                })
                .collect();
            sessions.sort_unstable_by_key(|s| (s.t0, s.seq));
            let mut caches = HashMap::new();
            for s in &sessions {
                if !caches.contains_key(&s.cache_site) {
                    let c = fed
                        .caches
                        .remove(&s.cache_site)
                        .expect("cache site moves into exactly one shard");
                    caches.insert(s.cache_site, c);
                }
            }
            tasks.push(ShardTask {
                sessions,
                caches,
                net: fed.net.shard_clone_empty(epoch_start),
                startup_delay,
                epoch_start,
            });
        }
        Ok((tasks, transport))
    }

    /// The epoch barrier: fold shard results back into the engine and
    /// federation in the exact order the serial engine would have
    /// produced them. Per-shard event relative order already matches
    /// serial; across shards the serial completion order is recovered
    /// by sorting on each done session's dispatch chain key (see
    /// `run_shard`): completion instant, then flow-creation instant,
    /// then the timer-scheduling chain rooted at the original Start
    /// keys. Counters merge as order-independent sums and maxes;
    /// origin byte counters fold commutatively; redirector locates
    /// replay in CacheCheck order; and the RNG-bearing side effects
    /// (monitoring emissions, background respawns) are replayed
    /// serially in the recovered order so `fed.rng` advances
    /// byte-for-byte like a serial run.
    fn merge_epoch(&mut self, fed: &mut FedSim, outcomes: Vec<ShardOutcome>, transport: Method) {
        let link_count = fed.net.link_count();
        let mut all: Vec<ShardDone> = Vec::new();
        // Ordering-independent duration summary: merge the per-shard
        // Welford parts in stable shard order (`outcomes` is indexed
        // by shard slot, not worker finish order).
        let mut durations = Welford::new();
        for o in outcomes {
            durations.merge(&o.durations);
            for (site, cache) in o.caches {
                let prev = fed.caches.insert(site, cache);
                debug_assert!(prev.is_none(), "cache {site} returned twice");
            }
            fed.net.stats.allocations += o.net.stats.allocations;
            fed.net.stats.components_touched += o.net.stats.components_touched;
            fed.net.stats.flows_refixed += o.net.stats.flows_refixed;
            fed.net.stats.peak_component =
                fed.net.stats.peak_component.max(o.net.stats.peak_component);
            for l in 0..link_count {
                let b = o.net.link_bytes_carried(LinkId(l as u32));
                if b != 0.0 {
                    fed.net.add_link_bytes(LinkId(l as u32), b);
                }
            }
            self.stats.events_processed += o.events_processed;
            self.stats.coalesced_joins += o.coalesced_joins;
            all.extend(o.done);
        }
        debug_assert_eq!(
            durations.count() as usize,
            all.len(),
            "shard duration summaries must cover every epoch session exactly once"
        );
        self.epoch_durations.merge(&durations);
        all.sort_unstable_by(|a, b| a.key.cmp(&b.key));

        // Replay the redirector locates the serial engine would have
        // issued at each miss's CacheCheck instant — pool round-robin
        // state and origin locate counters advance identically (the
        // outcome itself is latency-free, and the planner pinned its
        // origin) — and fold each origin's fresh bytes, a commutative
        // sum the serial run accumulates at fetch completion. Locates
        // never draw `fed.rng`, so their order relative to the
        // monitoring replay below is immaterial; among themselves they
        // follow the CacheCheck timer chain.
        let mut locates: Vec<(SimTime, SimTime, SimTime, u64, usize)> = Vec::new();
        for (ai, d) in all.iter().enumerate() {
            if let DoneKind::Miss {
                origin_idx,
                miss_bytes,
            } = d.kind
            {
                fed.origins[origin_idx].bytes_served += miss_bytes;
                locates.push((d.t2, d.t1, d.t0, d.seq, ai));
            }
        }
        locates.sort_unstable();
        for &(t2, .., ai) in &locates {
            let s = &self.sessions[all[ai].id.0 as usize];
            let located = fed
                .redirectors
                .locate(&s.file.path, &mut fed.origins, t2)
                .expect("planner verified a live redirector")
                .expect("file registered at an origin");
            debug_assert_eq!(located.origin, s.origin);
        }

        // Sessions finish in serial order (mirrors `finish`; in_flight
        // never rose, so it does not fall here either).
        let mut max_timer = SimTime::ZERO;
        for d in &all {
            let s = &mut self.sessions[d.id.0 as usize];
            let hit = matches!(d.kind, DoneKind::Hit);
            s.transport = transport;
            s.cache_site = Some(d.cache_site);
            s.per_conn = d.per_conn;
            s.opened_at = Some(d.t2);
            s.initial_hit = hit;
            if matches!(d.kind, DoneKind::Join) {
                s.joins += 1;
            }
            s.flow = None;
            // Serial cache serves record `Method::Xrootd` regardless of
            // the startup transport (see the `Xfer::CacheServe` arm of
            // `on_flow_done`) — mirror that exactly.
            s.record = Some(TransferRecord {
                path: s.file.path.clone(),
                bytes: s.file.size.as_u64(),
                method: Method::Xrootd,
                cache_hit: hit,
                duration: d.tc - s.arrival,
            });
            s.phase = Phase::Done;
            s.phase_entered_at = d.tc;
            // Reconstruct the serial phase spans per itinerary (the
            // histograms are commutative integer buckets, so folding
            // them at the barrier instead of at each serial transition
            // is digest-neutral):
            //   hit:  Geo → Check → Transfer
            //   miss: Geo → Check → FetchBegin → Transfer
            //   join: Geo → Check → JoinWait → Check(0) → Transfer
            let mut spans: Vec<(PhaseLabel, SimTime, Duration)> = Vec::with_capacity(5);
            spans.push((PhaseLabel::GeoResolve, d.t0, d.t1 - d.t0));
            spans.push((PhaseLabel::CacheCheck, d.t1, d.t2 - d.t1));
            match d.kind {
                DoneKind::Hit => {}
                DoneKind::Miss { .. } => {
                    spans.push((PhaseLabel::FetchBegin, d.t2, d.tf - d.t2));
                }
                DoneKind::Join => {
                    spans.push((PhaseLabel::JoinWait, d.t2, d.tf - d.t2));
                    spans.push((PhaseLabel::CacheCheck, d.tf, Duration(0)));
                }
            }
            spans.push((PhaseLabel::Transfer, d.tf, d.tc - d.tf));
            for &(label, _, dur) in &spans {
                self.tele.phase_span(label, dur);
            }
            self.tele
                .on_complete(d.tc, Some(d.cache_site), s.file.size.as_u64(), hit);
            if self.tele.trace_enabled() {
                self.tele.push_trace(SpanTrace {
                    session: d.id.0,
                    site: s.site_idx,
                    path: s.file.path.clone(),
                    arrival: s.arrival,
                    completed: d.tc,
                    bytes: s.file.size.as_u64(),
                    cache_site: Some(d.cache_site),
                    hit,
                    spans: spans
                        .iter()
                        .map(|&(label, start, dur)| PhaseSpan { label, start, dur })
                        .collect(),
                });
            }
            self.outstanding -= 1;
            self.completed.push(d.id);
            self.stats.sessions_completed += 1;
            // geo_resolve + finish leave the slot key present at its
            // pre-epoch count.
            self.cache_in_flight.entry(d.cache_site).or_insert(0);
            // The last timer instant each session popped: its Check
            // (hit), Fetch (miss), or wake (join) — where the serial
            // timer clock would sit after this session's last event.
            max_timer = max_timer.max(match d.kind {
                DoneKind::Hit => d.t2,
                DoneKind::Miss { .. } | DoneKind::Join => d.tf,
            });
        }
        // Peak concurrency by interval sweep. A finish at the same
        // instant as a start drains first — completions dispatch
        // before same-instant timers in the serial loop — which the
        // `(time, −1) < (time, +1)` sort encodes.
        let mut marks: Vec<(SimTime, i8)> = Vec::with_capacity(all.len() * 2);
        for d in &all {
            marks.push((d.t0, 1));
            marks.push((d.tc, -1));
        }
        marks.sort_unstable();
        let mut live = 0isize;
        for &(_, delta) in &marks {
            live += delta as isize;
            if live as usize > self.stats.peak_concurrent {
                self.stats.peak_concurrent = live as usize;
            }
        }
        // Replay the RNG-bearing interleaving against the parent
        // network (background flows only): at each background
        // completion batch, monitoring for serve flows that completed
        // earlier — or were created earlier at the same batch instant
        // — is emitted first.
        let bound = all.last().map(|d| d.tc).expect("epoch had sessions");
        let mut ei = 0usize;
        while let Some(tn) = fed.net.next_completion() {
            if tn > bound {
                break; // stays pending, as after a serial run
            }
            while ei < all.len() && all[ei].tc < tn {
                self.epoch_emit(fed, &all[ei], transport);
                ei += 1;
            }
            fed.now = tn;
            for c in fed.net.advance(tn) {
                // A serve/fetch flow created at the instant this
                // background flow respawned sorts after it: completion
                // dispatch precedes same-instant timers, so the
                // respawn drew the lower flow sequence. `tf` is each
                // session's terminal-flow creation instant (== t2 for
                // hits).
                while ei < all.len() && all[ei].tc == tn && all[ei].tf < c.started {
                    self.epoch_emit(fed, &all[ei], transport);
                    ei += 1;
                }
                self.stats.events_processed += 1;
                let origin_idx = fed
                    .background
                    .remove(&c.flow)
                    .expect("only background flows live in the parent during an epoch");
                fed.spawn_background(origin_idx);
                self.stats.background_respawns += 1;
            }
        }
        while ei < all.len() {
            self.epoch_emit(fed, &all[ei], transport);
            ei += 1;
        }
        // Land exactly where the serial run would: federation clock at
        // the last completion, timer queue at the last popped timer.
        fed.now = bound;
        let tail = fed.net.advance(bound);
        debug_assert!(tail.is_empty(), "completions past the replay bound");
        self.queue.advance_to(max_timer);
        self.epochs.sessions_sharded += all.len() as u64;
        // An epoch retires sessions; the planner's cached bail (if
        // any) no longer describes the engine state.
        self.state_version += 1;
    }

    /// Emit one epoch session's monitoring trio against the parent
    /// federation — the barrier-ordered twin of `emit_monitoring`,
    /// drawing the same RNG/user-id/file-id stream.
    fn epoch_emit(&mut self, fed: &mut FedSim, d: &ShardDone, transport: Method) {
        let s = &self.sessions[d.id.0 as usize];
        let protocol = if transport == Method::HttpCache {
            Protocol::Http
        } else {
            Protocol::Xrootd
        };
        fed.emit_transfer_monitoring(
            d.cache_site,
            s.site_idx,
            &s.file.path,
            s.file.size.as_u64(),
            s.file.size.as_u64(),
            d.t2,
            d.tc,
            protocol,
        );
    }
}

/// Minimal union-find over dense indices (links ∪ cache anchors ∪
/// origin-DTN anchors), path-halving, smaller root wins for
/// determinism.
struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind {
            parent: (0..n as u32).collect(),
        }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            let grandparent = self.parent[self.parent[x] as usize];
            self.parent[x] = grandparent;
            x = grandparent as usize;
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra.max(rb)] = ra.min(rb) as u32;
        }
    }
}

/// A planned cold leg: the origin the redirector pins at plan time,
/// the combined fetch route (origin legs first, then the serve
/// route, exactly as `fetch_begin` builds it), and the origin RTT
/// that prices the redirect round trips.
#[derive(Clone)]
struct EpochFetch {
    origin_idx: usize,
    fetch_links: Vec<LinkId>,
    origin_rtt_ms: f64,
}

/// The planner's per-session dry-run result, before sessions are
/// grouped into shards: which cache serves, over which links, and
/// whether a cold fetch couples the session to an origin DTN.
struct PlannedPick {
    session: usize,
    cache_site: usize,
    serve_links: Vec<LinkId>,
    rtt_ms: f64,
    fetch: Option<EpochFetch>,
}

/// Partition picks into link-connected components. Each pick unions
/// its cache anchor with every serve link, every fetch link, and —
/// for cold picks — the origin-DTN anchor, so two sessions land in
/// one shard iff their flows could share a link, a cache, or an
/// origin. Groups are keyed by the component root of the cache
/// anchor and returned in first-appearance (plan prefix) order, so
/// shard numbering is deterministic.
fn group_picks(
    picks: &[PlannedPick],
    link_count: usize,
    site_count: usize,
    origin_count: usize,
) -> Vec<Vec<usize>> {
    let mut uf = UnionFind::new(link_count + site_count + origin_count);
    for p in picks {
        let anchor = link_count + p.cache_site;
        for l in &p.serve_links {
            uf.union(anchor, l.0 as usize);
        }
        if let Some(f) = &p.fetch {
            for l in &f.fetch_links {
                uf.union(anchor, l.0 as usize);
            }
            uf.union(anchor, link_count + site_count + f.origin_idx);
        }
    }
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut root_to_group: HashMap<usize, usize> = HashMap::new();
    for (pi, p) in picks.iter().enumerate() {
        let root = uf.find(link_count + p.cache_site);
        let g = *root_to_group.entry(root).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[g].push(pi);
    }
    groups
}

/// One pending session's precomputed epoch itinerary: its original
/// Start key (the serial tie-break), the cache the epoch-stable
/// policy picked, the serve route, and — for planned misses — the
/// cold leg. Immutable session data (path, size, version) is read
/// from the shared `&[Session]` slice.
struct EpochSession {
    id: SessionId,
    t0: SimTime,
    seq: u64,
    cache_site: usize,
    serve_links: Vec<LinkId>,
    rtt_ms: f64,
    fetch: Option<EpochFetch>,
}

/// One link-connected partition of the pending sessions, with the
/// caches they hit (moved out of the federation for the epoch) and a
/// flow-less copy of the network to advance against.
struct ShardTask {
    /// In `(t0, seq)` order, so shard-local event sequences preserve
    /// the serial relative order.
    sessions: Vec<EpochSession>,
    caches: HashMap<usize, CacheServer>,
    net: Network,
    startup_delay: Duration,
    epoch_start: SimTime,
}

/// How an epoch session resolved inside its shard — drives the
/// barrier's per-kind write-back (record hit flag, phase spans,
/// origin byte fold, locate replay, join counter).
#[derive(Clone, Copy)]
enum DoneKind {
    /// Whole hit: served straight from the cache at `t2`.
    Hit,
    /// Cold miss: fetched from `origin_idx`, committing `miss_bytes`
    /// fresh bytes at completion.
    Miss { origin_idx: usize, miss_bytes: u64 },
    /// Coalesced join: parked on another session's in-flight fetch
    /// and woken whole at `tf`.
    Join,
}

/// A finished epoch session: the serial dispatch chain key plus what
/// the barrier writes back.
///
/// `key` recovers the serial completion-dispatch order across
/// shards. Element 0 is the completion instant `tc`; each further
/// element is the instant the next-outer timer/flow in the session's
/// dispatch chain was scheduled, ending with the original Start key
/// `[t0, 0, seq]` (0 < any timer instant, standing in for "arrival
/// seq beats every later-issued timer seq at the same instant"):
///   hit:  `[tc, t2, t1, t0, 0, seq]`
///   miss: `[tc, tf, t2, t1, t0, 0, seq]`
///   join: `[tc, w, w, <owner key[1..]>, widx]` — the wake timer was
///         scheduled *at* the wake instant `w` during the owner
///         fetch's completion dispatch, so `w` appears twice (flow
///         creation, then timer scheduling), then ties break by the
///         owner's own chain and park order `widx`.
/// Ambiguity survives only when two distinct serial timers share ≥3
/// consecutive chain instants (zero-RTT topologies); campaign
/// topologies have nonzero RTTs.
struct ShardDone {
    id: SessionId,
    t0: SimTime,
    seq: u64,
    /// GeoResolve instant (startup paid).
    t1: SimTime,
    /// CacheCheck instant == `opened_at`.
    t2: SimTime,
    /// Terminal-flow creation instant: == `t2` for hits, the fetch
    /// flow's start for misses, the wake instant for joins.
    tf: SimTime,
    /// Completion instant.
    tc: SimTime,
    cache_site: usize,
    per_conn: f64,
    kind: DoneKind,
    key: Vec<u64>,
}

struct ShardOutcome {
    net: Network,
    caches: HashMap<usize, CacheServer>,
    events_processed: u64,
    done: Vec<ShardDone>,
    /// Joins that latched onto a fetch already carrying a waiter
    /// (mirrors the serial `coalesced_joins` counter).
    coalesced_joins: u64,
    /// Start→completion durations (seconds) of this shard's sessions,
    /// accumulated in shard-local completion order; the barrier merges
    /// these in stable shard order (parallel Welford reduction).
    durations: Welford,
}

#[derive(Clone, Copy)]
enum ShardPhase {
    Start,
    Geo,
    Check,
    /// Redirect round trips paid; create the fetch flow.
    Fetch,
}

/// Per-shard mutable state, split off so the event loop's borrow of
/// the network stays disjoint from everything the handlers mutate.
struct ShardCtx<'a> {
    sessions: &'a [EpochSession],
    all_sessions: &'a [Session],
    caches: HashMap<usize, CacheServer>,
    queue: EventQueue<(u32, ShardPhase)>,
    flow_owner: HashMap<FlowId, u32>,
    /// Sessions parked on an in-flight fetch, keyed like the serial
    /// engine's waiter map, in park order.
    waiters: HashMap<(usize, String), Vec<u32>>,
    t1: Vec<SimTime>,
    t2: Vec<SimTime>,
    tf: Vec<SimTime>,
    per_conn: Vec<f64>,
    /// First CacheCheck seen (distinguishes a wake re-check).
    opened: Vec<bool>,
    /// The owner's reserved plan, committed at fetch completion.
    plans: Vec<Option<ReadPlan>>,
    /// Set when a parked session is woken: the waking owner's chain
    /// key (sans completion instant) and this waiter's park index.
    wake: Vec<Option<(Vec<u64>, u64)>>,
    done: Vec<ShardDone>,
    coalesced_joins: u64,
    events: u64,
    startup: Duration,
}

impl ShardCtx<'_> {
    /// Flow completions at `t`, dispatched in flow order exactly as
    /// the serial completion handler would: a fetch commits its
    /// chunks, credits the cache, and wakes its joiners in park
    /// order; a serve (first-check hit or woken join) verifies and
    /// credits. Each retirement also fixes the session's serial
    /// dispatch chain key (see [`ShardDone`]).
    fn retire(&mut self, completions: Vec<Completion>, t: SimTime) {
        for c in completions {
            self.events += 1;
            let i = self
                .flow_owner
                .remove(&c.flow)
                .expect("shard flow has an owner") as usize;
            let es = &self.sessions[i];
            let s = &self.all_sessions[es.id.0 as usize];
            let size = s.file.size.as_u64();
            let cache = self
                .caches
                .get_mut(&es.cache_site)
                .expect("shard cache");
            let (kind, key) = if let Some(plan) = self.plans[i].take() {
                // Fetch completion: mirror the serial `StashFetch` arm
                // (origin byte credit and monitoring replay at the
                // barrier).
                cache.commit_chunks(&s.file.path, s.file.version, &plan.fetch, t);
                cache.record_served(plan.hit_bytes, plan.miss_bytes);
                let fetch = es.fetch.as_ref().expect("owner had a planned cold leg");
                let key = vec![t.0, self.tf[i].0, self.t2[i].0, self.t1[i].0, es.t0.0, 0, es.seq];
                if let Some(ids) = self
                    .waiters
                    .remove(&(es.cache_site, s.file.path.clone()))
                {
                    for (widx, &ju) in ids.iter().enumerate() {
                        // Serial `wake_waiters`: re-Check timers at the
                        // commit instant, scheduled in park order.
                        self.wake[ju as usize] = Some((key[1..].to_vec(), widx as u64));
                        self.queue.schedule_at(t, (ju, ShardPhase::Check));
                    }
                }
                (
                    DoneKind::Miss {
                        origin_idx: fetch.origin_idx,
                        miss_bytes: plan.miss_bytes,
                    },
                    key,
                )
            } else {
                // Serve completion. The planner proved the copy is
                // unpoisoned, so the client digest must pass.
                debug_assert!(
                    served_bytes_verify(cache, &s.file.path, s.file.version, size),
                    "epoch serve failed the digest; the planner vetted the copy"
                );
                cache.record_served(size, 0);
                match self.wake[i].take() {
                    Some((chain, widx)) => {
                        // Woken join: wake instant twice (flow creation
                        // and wake-timer scheduling both happened at
                        // `w`), then the owner's chain, then park order.
                        let w = self.tf[i].0;
                        let mut key = Vec::with_capacity(chain.len() + 4);
                        key.extend_from_slice(&[t.0, w, w]);
                        key.extend_from_slice(&chain);
                        key.push(widx);
                        (DoneKind::Join, key)
                    }
                    None => (
                        DoneKind::Hit,
                        vec![t.0, self.t2[i].0, self.t1[i].0, es.t0.0, 0, es.seq],
                    ),
                }
            };
            self.done.push(ShardDone {
                id: es.id,
                t0: es.t0,
                seq: es.seq,
                t1: self.t1[i],
                t2: self.t2[i],
                tf: self.tf[i],
                tc: t,
                cache_site: es.cache_site,
                per_conn: self.per_conn[i],
                kind,
                key,
            });
        }
    }

    /// One popped timer, routed like the serial `on_timer` for the
    /// Stash itinerary.
    fn handle(&mut self, net: &mut Network, iu: u32, phase: ShardPhase, t: SimTime) {
        let i = iu as usize;
        match phase {
            ShardPhase::Start => {
                self.queue
                    .schedule_at(t + self.startup, (iu, ShardPhase::Geo));
            }
            ShardPhase::Geo => {
                self.t1[i] = t;
                self.queue.schedule_at(
                    t + Duration::from_secs_f64(self.sessions[i].rtt_ms / 1e3),
                    (iu, ShardPhase::Check),
                );
            }
            ShardPhase::Check => {
                let es = &self.sessions[i];
                let s = &self.all_sessions[es.id.0 as usize];
                let size = s.file.size.as_u64();
                let cache = self
                    .caches
                    .get_mut(&es.cache_site)
                    .expect("shard cache");
                let plan = cache.plan_read(&s.file.path, 0, size, size, s.file.version, t);
                let whole = plan.miss_bytes == 0;
                let cap = cache.cfg.per_conn_gbps * 1e9 / 8.0;
                self.per_conn[i] = cap;
                if !self.opened[i] {
                    self.opened[i] = true;
                    self.t2[i] = t;
                } else {
                    // A wake re-check: the owner's commit made the copy
                    // whole, exactly as the serial re-plan does.
                    assert!(whole, "woken epoch session must re-plan into a whole hit");
                }
                if whole {
                    self.tf[i] = t;
                    let flow = net.start_flow(
                        FlowSpec {
                            path: es.serve_links.clone(),
                            bytes: size.max(1),
                            rate_cap: Some(cap),
                        },
                        t,
                    );
                    self.flow_owner.insert(flow, iu);
                } else if plan.fetch.is_empty() {
                    // Every missing chunk is in flight for another
                    // epoch session: park. Planned-epoch sessions are
                    // first attempts (the planner bails on retried
                    // sessions), so the serial `joins == 0` guard on
                    // `coalesced_joins` always passes.
                    assert!(self.wake[i].is_none(), "parked session parked twice");
                    self.coalesced_joins += 1;
                    self.waiters
                        .entry((es.cache_site, s.file.path.clone()))
                        .or_default()
                        .push(iu);
                } else {
                    // Miss: reserve now, pay the redirect round trips,
                    // then start the fetch — serial `cache_check` miss
                    // arm with the redirector locate replayed at the
                    // barrier.
                    cache.begin_fetch(&s.file.path, s.file.version, &plan.fetch);
                    self.plans[i] = Some(plan);
                    let f = es
                        .fetch
                        .as_ref()
                        .expect("planner vetted a cold leg for every possible miss");
                    self.queue.schedule_at(
                        t + Duration::from_secs_f64(2.0 * f.origin_rtt_ms / 1e3),
                        (iu, ShardPhase::Fetch),
                    );
                }
            }
            ShardPhase::Fetch => {
                let es = &self.sessions[i];
                let s = &self.all_sessions[es.id.0 as usize];
                let f = es.fetch.as_ref().expect("Fetch timers only follow misses");
                self.tf[i] = t;
                let flow = net.start_flow(
                    FlowSpec {
                        path: f.fetch_links.clone(),
                        bytes: s.file.size.as_u64().max(1),
                        rate_cap: Some(self.per_conn[i]),
                    },
                    t,
                );
                self.flow_owner.insert(flow, iu);
            }
        }
    }
}

/// The shard event loop: the Stash itinerary of the serial engine
/// (Start → startup timer → GeoResolve → RTT timer → CacheCheck →
/// serve flow | redirect timer → fetch flow | JoinWait park → wake →
/// serve flow) against the shard's own network and queue. The
/// planner proved every session stays on these paths — the cache
/// stays up and unpoisoned, routes stay up, nothing evicts, versions
/// don't conflict — so anything else panics rather than silently
/// diverging. Event arbitration mirrors [`SessionEngine::run`]:
/// completions at or before the next timer drain first, and
/// stragglers drain before a popped timer's handler runs.
fn run_shard(task: ShardTask, all_sessions: &[Session]) -> ShardOutcome {
    let ShardTask {
        sessions,
        caches,
        mut net,
        startup_delay,
        epoch_start,
    } = task;
    let n = sessions.len();
    let mut queue: EventQueue<(u32, ShardPhase)> = EventQueue::new();
    queue.advance_to(epoch_start);
    for (i, s) in sessions.iter().enumerate() {
        queue.schedule_at(s.t0, (i as u32, ShardPhase::Start));
    }
    let mut ctx = ShardCtx {
        sessions: &sessions,
        all_sessions,
        caches,
        queue,
        flow_owner: HashMap::with_capacity(n),
        waiters: HashMap::new(),
        t1: vec![SimTime::ZERO; n],
        t2: vec![SimTime::ZERO; n],
        tf: vec![SimTime::ZERO; n],
        per_conn: vec![0.0f64; n],
        opened: vec![false; n],
        plans: (0..n).map(|_| None).collect(),
        wake: (0..n).map(|_| None).collect(),
        done: Vec::with_capacity(n),
        coalesced_joins: 0,
        events: 0,
        startup: startup_delay,
    };
    while ctx.done.len() < n {
        let next_timer = ctx.queue.peek_time();
        let next_net = net.next_completion();
        let net_first = match (next_timer, next_net) {
            (Some(te), Some(tn)) => tn <= te,
            (None, Some(_)) => true,
            (Some(_), None) => false,
            (None, None) => panic!("shard stalled with {} sessions left", n - ctx.done.len()),
        };
        if net_first {
            let tn = next_net.expect("checked");
            let completions = net.advance(tn);
            ctx.retire(completions, tn);
        } else {
            let (t, (iu, phase)) = ctx.queue.pop().expect("peeked a timer");
            ctx.events += 1;
            let stragglers = net.advance(t);
            ctx.retire(stragglers, t);
            ctx.handle(&mut net, iu, phase, t);
        }
    }
    let mut durations = Welford::new();
    for d in &ctx.done {
        durations.push((d.tc - d.t0).as_secs_f64());
    }
    ShardOutcome {
        net,
        caches: ctx.caches,
        events_processed: ctx.events,
        done: ctx.done,
        coalesced_joins: ctx.coalesced_joins,
        durations,
    }
}
