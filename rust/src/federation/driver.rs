//! The concurrent session engine: advances any number of in-flight
//! download [`Session`]s by popping timer events from a deterministic
//! [`EventQueue`] and routing [`crate::netsim::Network`] completions
//! back to their owning sessions.
//!
//! ## Event loop
//!
//! The engine interleaves two event sources in virtual-time order:
//!
//! 1. its own timer queue (client startup latencies, connection RTTs,
//!    redirector round trips, job arrivals), and
//! 2. the flow-level network's projected completions.
//!
//! Ties go to the network — completions at or before the next timer
//! are drained first — which reproduces the blocking engine's
//! `advance_to` semantics exactly: a campaign of one serial job walks
//! the same instants, draws the same RNG stream, and produces the same
//! `TransferRecord`s as the pre-refactor code.
//!
//! Background origin load lives here too: a completed background flow
//! respawns at its completion instant, so origin contention has no
//! gaps regardless of how many sessions are in flight.
//!
//! ## Cross-session coalescing
//!
//! When a session's `plan_read` finds every missing chunk already in
//! flight (another session is fetching the same file from the origin)
//! it parks in [`Phase::JoinWait`]; the fetching session's
//! `commit_chunks` wakes all waiters at the commit instant and they
//! re-plan — typically into a pure cache hit that never touches the
//! origin. This is the paper's §3 cache behaviour ("capture data
//! requests from clients") finally firing *across* concurrent clients.
//!
//! ## Fault layer
//!
//! The federation's fault schedule ([`crate::fault`]) is a third event
//! source: cache deaths abort the flows that cache was serving or
//! filling (releasing reserved chunks via `abort_fetch` and waking any
//! `JoinWait` joiners so they re-plan), link cuts kill every crossing
//! flow and re-trigger max-min allocation for the survivors, origin
//! brownouts rescale DTN capacity, and redirector outages degrade the
//! HA pair. Interrupted sessions re-enter `GeoResolve` with the failed
//! cache excluded, pay a fresh resolution latency per attempt, and
//! after [`MAX_FAILOVER_RETRIES`] attempts stream directly from the
//! origin — a chaos campaign completes every download or panics; it
//! never silently drops one.

use crate::client::stashcp;
use crate::client::{curl, Method, TransferRecord};
use crate::fault::{DIRECT_RETRY_BACKOFF, FaultEvent, FaultKind, MAX_FAILOVER_RETRIES};
use crate::monitoring::packets::Protocol;
use crate::netsim::{Completion, Endpoint, EventQueue, FlowId, FlowSpec, LinkId};
use crate::sim::workload::FileRef;
use crate::util::{Duration, SimTime};
use std::collections::HashMap;
use super::session::{Phase, Session, SessionId, Xfer};
use super::{DownloadMethod, FedSim};

/// Are all links of a route currently up? (Flows must not start over a
/// severed link; the session retries or fails over instead.)
fn route_is_up(fed: &FedSim, links: &[LinkId]) -> bool {
    links.iter().all(|&l| fed.net.link_is_up(l))
}

/// Events the engine schedules for itself.
#[derive(Debug, Clone, Copy)]
enum EngineEvent {
    /// A session's arrival instant (job submission).
    Start(SessionId),
    /// A session's pending latency elapsed; advance its phase.
    Timer(SessionId),
}

/// Engine counters (perf + concurrency + fault observability).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EngineStats {
    /// Timer events plus network completions processed.
    pub events_processed: u64,
    pub sessions_completed: u64,
    /// Maximum number of simultaneously active sessions.
    pub peak_concurrent: usize,
    pub background_respawns: u64,
    /// Sessions that parked in `JoinWait` at least once.
    pub coalesced_joins: u64,
    /// Fault events applied (cache/link/origin/redirector transitions).
    pub faults_applied: u64,
    /// Mid-transfer aborts survived (flow cancelled, session re-planned).
    pub failovers: u64,
    /// Session re-resolution attempts after any failure.
    pub retries: u64,
    /// Bytes already transferred by flows that were then aborted
    /// (wasted work the fault layer caused).
    pub aborted_bytes: u64,
    /// Sessions that gave up on caches and streamed from the origin.
    pub direct_fallbacks: u64,
    /// Allocator passes the network ran while this engine drove it
    /// (see [`crate::netsim::AllocStats`]; deltas over the run).
    pub allocator_passes: u64,
    /// Component water-fills across those passes — the O(affected)
    /// unit of allocator work.
    pub components_touched: u64,
    /// Flow rate assignments across those water-fills. Divided by
    /// `events_processed` this is the allocator's flows-touched-per-
    /// event figure the perf benches report.
    pub flows_refixed: u64,
    /// Largest single component water-filled (flows) during this
    /// engine's runs — per-run like the other allocator counters, even
    /// when several engines share one federation.
    pub peak_component: usize,
}

/// The event-driven download engine. Create one per batch of work; it
/// borrows the [`FedSim`] only while spawning and running, so drivers
/// can inspect the federation between runs.
pub struct SessionEngine {
    queue: EventQueue<EngineEvent>,
    sessions: Vec<Session>,
    /// Flow → owning session (foreground transfers only).
    flow_owner: HashMap<FlowId, SessionId>,
    /// (cache site, path) → sessions parked until the in-flight fetch
    /// commits.
    waiters: HashMap<(usize, String), Vec<SessionId>>,
    /// Sessions currently assigned per cache site (incremented when a
    /// session binds a cache in `geo_resolve`, released on finish or
    /// failover) — the live-load signal the `least-loaded` redirection
    /// policy reads. Pure bookkeeping under every other policy.
    cache_in_flight: HashMap<usize, u64>,
    /// Spawned sessions not yet `Done`.
    outstanding: usize,
    /// Started sessions not yet `Done`.
    in_flight: usize,
    /// Session ids in completion order.
    completed: Vec<SessionId>,
    pub stats: EngineStats,
}

impl SessionEngine {
    /// An engine whose clock starts at `now` (the federation's current
    /// virtual time).
    pub fn new(now: SimTime) -> Self {
        let mut queue = EventQueue::new();
        queue.advance_to(now);
        SessionEngine {
            queue,
            sessions: Vec::new(),
            flow_owner: HashMap::new(),
            waiters: HashMap::new(),
            cache_in_flight: HashMap::new(),
            outstanding: 0,
            in_flight: 0,
            completed: Vec::new(),
            stats: EngineStats::default(),
        }
    }

    /// Current engine-queue clock (time of the last processed timer).
    /// The federation's `fed.now` can be ahead of this after a run
    /// whose final event was a flow completion — spawn follow-up
    /// sessions at `fed.now`, not at this clock.
    pub fn now(&self) -> SimTime {
        self.queue.now()
    }

    pub fn session(&self, id: SessionId) -> &Session {
        &self.sessions[id.0 as usize]
    }

    pub fn sessions(&self) -> &[Session] {
        &self.sessions
    }

    /// Session ids in the order they finished.
    pub fn completed(&self) -> &[SessionId] {
        &self.completed
    }

    /// The finished record of a session (panics if not done).
    pub fn record(&self, id: SessionId) -> TransferRecord {
        self.sessions[id.0 as usize]
            .record
            .clone()
            .expect("session not finished")
    }

    /// Schedule a download to begin at `at` (a job arrival). The file
    /// is materialised at its origin immediately, mirroring the
    /// blocking API.
    pub fn spawn_at(
        &mut self,
        fed: &mut FedSim,
        at: SimTime,
        site_idx: usize,
        file: FileRef,
        method: DownloadMethod,
    ) -> SessionId {
        assert!(
            at >= self.queue.now(),
            "spawning a session in the past: {at} < {}",
            self.queue.now()
        );
        // The network may be ahead of the timer queue (a run whose
        // last event was a flow completion): spawning before `fed.now`
        // would rewind the network clock mid-run.
        assert!(
            at >= fed.now,
            "spawning a session before the federation clock: {at} < {}",
            fed.now
        );
        let origin = fed.ensure_file(&file);
        let id = SessionId(self.sessions.len() as u64);
        self.sessions
            .push(Session::new(id, site_idx, file, method, origin, at));
        self.outstanding += 1;
        self.queue.schedule_at(at, EngineEvent::Start(id));
        id
    }

    /// Drive the federation until every spawned session has finished.
    /// Background flows are respawned along the way and left running;
    /// `fed.now` ends at the last processed instant.
    ///
    /// Three event sources interleave in virtual-time order: the
    /// engine's timer queue, the network's projected completions, and
    /// the federation's fault schedule. Completions at or before the
    /// next timer-or-fault drain first (a transfer that finished at the
    /// fault instant finished); a fault ties ahead of a timer at the
    /// same instant, so same-instant timers observe the post-fault
    /// world. Faults due after the last session completes stay pending
    /// for the next engine run.
    pub fn run(&mut self, fed: &mut FedSim) {
        let alloc_before = fed.net.stats;
        // Track this run's own component high-water mark; the
        // network's lifetime peak is restored below.
        fed.net.stats.peak_component = 0;
        let mut guard = 0u64;
        while self.outstanding > 0 {
            guard += 1;
            assert!(
                guard <= 500_000_000,
                "session engine stuck: {} outstanding at {}",
                self.outstanding,
                self.queue.now()
            );
            let next_timer = self.queue.peek_time();
            let next_fault = fed.next_fault_at();
            let next_net = fed.net.next_completion();
            // Faults and timers compete for the scheduled slot; faults
            // win ties. (A fault left over from an earlier engine run
            // may be past-dated; it still sorts first and is applied at
            // the current clock.)
            let (next_sched, fault_first) = match (next_fault, next_timer) {
                (Some(tf), Some(tt)) if tf <= tt => (Some(tf), true),
                (Some(tf), None) => (Some(tf), true),
                (_, tt) => (tt, false),
            };
            match (next_sched, next_net) {
                // Network completions up to (and at) the next scheduled
                // event go first — the blocking engine's advance_to
                // order.
                (Some(te), Some(tn)) if tn <= te => self.step_network(fed, tn),
                (None, Some(tn)) => self.step_network(fed, tn),
                (Some(_), _) if fault_first => self.step_fault(fed),
                (Some(_), _) => self.step_timer(fed),
                (None, None) => panic!(
                    "session engine stalled: {} sessions outstanding with no pending events",
                    self.outstanding
                ),
            }
        }
        // Fold the network's allocator counters (deltas over this run)
        // into the engine's stats for campaign/sweep observability.
        let alloc = fed.net.stats;
        self.stats.allocator_passes += alloc.allocations - alloc_before.allocations;
        self.stats.components_touched +=
            alloc.components_touched - alloc_before.components_touched;
        self.stats.flows_refixed += alloc.flows_refixed - alloc_before.flows_refixed;
        self.stats.peak_component = self.stats.peak_component.max(alloc.peak_component);
        fed.net.stats.peak_component = alloc.peak_component.max(alloc_before.peak_component);
    }

    /// Advance the network to `t` and dispatch its completions.
    fn step_network(&mut self, fed: &mut FedSim, t: SimTime) {
        fed.now = t;
        let completions = fed.net.advance(t);
        self.dispatch_completions(fed, completions, t);
    }

    /// Pop and dispatch the next timer event.
    fn step_timer(&mut self, fed: &mut FedSim) {
        let Some((t, ev)) = self.queue.pop() else {
            return;
        };
        self.stats.events_processed += 1;
        // Bring the network to the event instant. Completions whose
        // projected (µs-rounded) instant lies past `t` but whose
        // remaining bytes already hit zero are retired here rather
        // than silently dropped.
        fed.now = t;
        let stragglers = fed.net.advance(t);
        self.dispatch_completions(fed, stragglers, t);
        match ev {
            EngineEvent::Start(id) => self.on_start(fed, id, t),
            EngineEvent::Timer(id) => self.on_timer(fed, id, t),
        }
    }

    /// Pop and apply the next scheduled fault. Past-dated faults (left
    /// over from an earlier engine run on this federation) apply at the
    /// current clock.
    fn step_fault(&mut self, fed: &mut FedSim) {
        let Some(ev) = fed.pop_fault() else {
            return;
        };
        let t = ev.at.max(fed.now);
        self.stats.events_processed += 1;
        // Transfers that finished at or before the fault instant
        // finished: drain them before the world changes.
        fed.now = t;
        let stragglers = fed.net.advance(t);
        self.dispatch_completions(fed, stragglers, t);
        self.on_fault(fed, ev.kind, t);
    }

    /// Apply one fault to the federation and unwind every session it
    /// interrupts. All iteration orders are deterministic (session-id
    /// order, sorted waiter keys, flow start order from the network).
    fn on_fault(&mut self, fed: &mut FedSim, kind: FaultKind, t: SimTime) {
        self.stats.faults_applied += 1;
        fed.fault_log.push(FaultEvent { at: t, kind });
        match kind {
            FaultKind::CacheDown { site } => {
                fed.faults.cache_down(site, t);
                // Abort every transfer this cache is serving or
                // filling: the flow dies mid-stream, reserved chunks
                // are released, and the session fails over.
                let victims: Vec<SessionId> = self
                    .sessions
                    .iter()
                    .filter(|s| {
                        s.cache_site == Some(site)
                            && matches!(
                                s.phase,
                                Phase::Transfer(Xfer::StashServe | Xfer::StashFetch)
                            )
                    })
                    .map(|s| s.id)
                    .collect();
                for id in victims {
                    self.cancel_session_flow(fed, id, t);
                    self.on_flow_aborted(fed, id, t, Some(site));
                }
                // Wake sessions still parked on fetches at this cache
                // (owners not yet transferring): they re-plan, find the
                // cache dead, and fail over.
                let mut parked: Vec<(usize, String)> = self
                    .waiters
                    .keys()
                    .filter(|k| k.0 == site)
                    .cloned()
                    .collect();
                parked.sort();
                for (cache_site, path) in parked {
                    self.wake_waiters(cache_site, &path, t);
                }
            }
            FaultKind::CacheUp { site } => fed.faults.cache_up(site, t),
            FaultKind::LinkCut { link } => {
                for (flow, left) in fed.net.cut_link(link, t) {
                    if let Some(origin_idx) = fed.background.remove(&flow) {
                        // Re-attached when the link heals.
                        fed.deferred_background.push(origin_idx);
                    } else if let Some(id) = self.flow_owner.remove(&flow) {
                        let (size, exclude) = {
                            let s = &mut self.sessions[id.0 as usize];
                            s.flow = None;
                            (s.file.size.as_u64().max(1), s.cache_site)
                        };
                        self.stats.aborted_bytes += size.saturating_sub(left.min(size));
                        self.on_flow_aborted(fed, id, t, exclude);
                    }
                }
            }
            FaultKind::LinkRestored { link } => {
                fed.net.restore_link(link);
                fed.respawn_deferred_background();
            }
            FaultKind::OriginDegraded { origin, factor } => {
                let link = fed.topo.origin_lan_link(origin);
                fed.net.scale_link_capacity(link, factor, t);
            }
            FaultKind::OriginRestored { origin } => {
                let link = fed.topo.origin_lan_link(origin);
                fed.net.scale_link_capacity(link, 1.0, t);
            }
            FaultKind::RedirectorDown { instance } => {
                fed.redirectors.set_healthy(instance, false);
            }
            FaultKind::RedirectorUp { instance } => {
                fed.redirectors.set_healthy(instance, true);
            }
        }
    }

    /// Cancel a session's in-flight flow (if any) and account the
    /// wasted bytes it had already moved.
    fn cancel_session_flow(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        if let Some(flow) = self.sessions[id.0 as usize].flow.take() {
            self.flow_owner.remove(&flow);
            if let Some(left) = fed.net.cancel_flow(flow, t) {
                let size = self.sessions[id.0 as usize].file.size.as_u64().max(1);
                self.stats.aborted_bytes += size.saturating_sub(left.min(size));
            }
        }
    }

    /// A session's transfer was aborted mid-flight (its flow is already
    /// gone): release reserved chunks, wake joiners so they re-plan,
    /// and fail the session over.
    fn on_flow_aborted(
        &mut self,
        fed: &mut FedSim,
        id: SessionId,
        t: SimTime,
        exclude: Option<usize>,
    ) {
        self.sessions[id.0 as usize].failovers += 1;
        self.stats.failovers += 1;
        if let Phase::Transfer(Xfer::StashFetch) = self.sessions[id.0 as usize].phase {
            let (cache_site, path, version, plan) = {
                let s = &mut self.sessions[id.0 as usize];
                (
                    s.cache_site.expect("stash fetch has a cache"),
                    s.file.path.clone(),
                    s.file.version,
                    s.plan.take().expect("fetch had a plan"),
                )
            };
            fed.caches
                .get_mut(&cache_site)
                .expect("cache site")
                .abort_fetch(&path, version, &plan.fetch);
            self.wake_waiters(cache_site, &path, t);
        }
        self.fail_session(fed, id, t, exclude);
    }

    /// Re-plan a failed session: exclude the cache it failed against,
    /// pay a fresh resolution latency, and re-enter `GeoResolve` (or
    /// `ProxyLookup`). After [`MAX_FAILOVER_RETRIES`] attempts the
    /// session gives up on caches and streams from the origin.
    fn fail_session(
        &mut self,
        fed: &mut FedSim,
        id: SessionId,
        t: SimTime,
        exclude: Option<usize>,
    ) {
        self.stats.retries += 1;
        self.release_cache_slot(id);
        let (method, transport, retries) = {
            let s = &mut self.sessions[id.0 as usize];
            if let Some(site) = exclude {
                if !s.excluded_caches.contains(&site) {
                    s.excluded_caches.push(site);
                }
            }
            s.retries += 1;
            s.plan = None;
            s.flow = None;
            s.cache_site = None;
            (s.method, s.transport, s.retries)
        };
        let attempt = retries.min(8) as usize;
        let give_up = retries > MAX_FAILOVER_RETRIES;
        let (phase, delay) = if give_up {
            (
                Phase::DirectConnect,
                stashcp::startup_latency(&fed.startup_costs, Method::HttpOrigin, attempt),
            )
        } else {
            match method {
                DownloadMethod::Stash => (
                    Phase::GeoResolve,
                    stashcp::startup_latency(&fed.startup_costs, transport, attempt),
                ),
                DownloadMethod::HttpProxy => (
                    Phase::ProxyLookup,
                    stashcp::startup_latency(&fed.startup_costs, Method::HttpProxy, attempt),
                ),
            }
        };
        self.sessions[id.0 as usize].phase = phase;
        if give_up {
            self.mark_direct(id);
        }
        self.queue.schedule_at(t + delay, EngineEvent::Timer(id));
    }

    /// Drop a session onto the direct-to-origin path (no cache is
    /// reachable at all). Priced like the give-up path in
    /// [`SessionEngine::fail_session`]: curl startup plus a fresh
    /// connection per attempt.
    fn enter_direct_fallback(&mut self, fed: &FedSim, id: SessionId, t: SimTime) {
        let attempt = {
            let s = &mut self.sessions[id.0 as usize];
            s.phase = Phase::DirectConnect;
            s.retries.min(8) as usize
        };
        self.mark_direct(id);
        let delay = stashcp::startup_latency(&fed.startup_costs, Method::HttpOrigin, attempt);
        self.queue.schedule_at(t + delay, EngineEvent::Timer(id));
    }

    /// Record that a session gave up on caches (counted once per
    /// session no matter how it reached the direct path).
    fn mark_direct(&mut self, id: SessionId) {
        let s = &mut self.sessions[id.0 as usize];
        if !s.direct {
            s.direct = true;
            self.stats.direct_fallbacks += 1;
        }
    }

    /// Route a batch of network completions: background flows respawn
    /// at `t`, session flows advance their owners, anything else
    /// (e.g. externally cancelled flows) is dropped.
    fn dispatch_completions(&mut self, fed: &mut FedSim, completions: Vec<Completion>, t: SimTime) {
        for c in completions {
            self.stats.events_processed += 1;
            if let Some(origin_idx) = fed.background.remove(&c.flow) {
                fed.spawn_background(origin_idx);
                self.stats.background_respawns += 1;
            } else if let Some(sid) = self.flow_owner.remove(&c.flow) {
                self.on_flow_done(fed, sid, t);
            }
        }
    }

    /// Job arrival: charge the client tool's startup latency.
    fn on_start(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        self.in_flight += 1;
        if self.in_flight > self.stats.peak_concurrent {
            self.stats.peak_concurrent = self.in_flight;
        }
        let method = self.sessions[id.0 as usize].method;
        match method {
            DownloadMethod::HttpProxy => {
                let delay = fed.startup_costs.curl_startup;
                let s = &mut self.sessions[id.0 as usize];
                s.url = curl::url_for(&s.file.path);
                s.phase = Phase::ProxyLookup;
                self.queue.schedule_at(t + delay, EngineEvent::Timer(id));
            }
            DownloadMethod::Stash => {
                // stashcp walks its fallback chain; the first usable
                // method here is XRootD (attempt index from the chain).
                let chain = stashcp::method_chain(fed.host_env);
                let attempt = chain
                    .iter()
                    .position(|m| *m == Method::Xrootd || *m == Method::HttpCache)
                    .unwrap_or(0);
                let transport = chain[attempt];
                let delay = stashcp::startup_latency(&fed.startup_costs, transport, attempt);
                let s = &mut self.sessions[id.0 as usize];
                s.transport = transport;
                s.phase = Phase::GeoResolve;
                self.queue.schedule_at(t + delay, EngineEvent::Timer(id));
            }
        }
    }

    fn on_timer(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        match self.sessions[id.0 as usize].phase {
            Phase::GeoResolve => self.geo_resolve(fed, id, t),
            Phase::CacheCheck => self.cache_check(fed, id, t),
            Phase::FetchBegin => self.fetch_begin(fed, id, t),
            Phase::ProxyLookup => self.proxy_lookup(fed, id, t),
            Phase::ProxyConnect => self.proxy_connect(fed, id, t),
            Phase::DirectConnect => self.direct_connect(fed, id, t),
            Phase::DirectFetch => self.direct_fetch(fed, id, t),
            phase => unreachable!("timer fired for session {id:?} in phase {phase:?}"),
        }
    }

    /// (stash) Startup paid: the redirection policy picks a cache
    /// (skipping down caches and caches this session already failed
    /// against — ring holes under consistent hashing), then the
    /// connection round trip to that cache.
    fn geo_resolve(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, excluded, path) = {
            let s = &self.sessions[id.0 as usize];
            (s.site_idx, s.excluded_caches.clone(), s.file.path.clone())
        };
        let selected = fed.select_cache(site_idx, &path, &excluded, &self.cache_in_flight);
        let Some(cache_site) = selected else {
            // No cache should serve this session (all excluded/down,
            // or the tiered ladder ran out of rungs): stream from the
            // origin.
            self.enter_direct_fallback(fed, id, t);
            return;
        };
        *self.cache_in_flight.entry(cache_site).or_insert(0) += 1;
        let route = fed
            .topo
            .route(Endpoint::Cache(cache_site), Endpoint::Worker(site_idx));
        let s = &mut self.sessions[id.0 as usize];
        s.cache_site = Some(cache_site);
        s.phase = Phase::CacheCheck;
        self.queue.schedule_at(
            t + Duration::from_secs_f64(route.rtt_ms / 1e3),
            EngineEvent::Timer(id),
        );
    }

    /// (stash) At the cache: plan the read. Whole hit serves directly;
    /// a plan with fresh chunks fetches from the origin; a plan whose
    /// missing chunks are all in flight parks in `JoinWait`.
    fn cache_check(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, cache_site, path, size, version, origin) = {
            let s = &self.sessions[id.0 as usize];
            (
                s.site_idx,
                s.cache_site.expect("geo_resolve ran"),
                s.file.path.clone(),
                s.file.size.as_u64(),
                s.file.version,
                s.origin,
            )
        };
        // The cache may have died while we were connecting (or while
        // parked in JoinWait): a refused connection fails the session
        // over to the next-nearest cache.
        if fed.faults.is_cache_down(cache_site) {
            self.fail_session(fed, id, t, Some(cache_site));
            return;
        }
        let cache = fed.caches.get_mut(&cache_site).expect("cache site");
        let plan = cache.plan_read(&path, 0, size, size, version, t);
        let per_conn = cache.cfg.per_conn_gbps * 1e9 / 8.0;
        let whole_hit = plan.miss_bytes == 0;
        {
            let s = &mut self.sessions[id.0 as usize];
            s.per_conn = per_conn;
            if s.opened_at.is_none() {
                s.opened_at = Some(t);
                s.initial_hit = whole_hit;
            }
        }

        if whole_hit {
            // Pure cache hit: cache → worker.
            let route = fed
                .topo
                .route(Endpoint::Cache(cache_site), Endpoint::Worker(site_idx));
            if !route_is_up(fed, &route.links) {
                // The serve path is severed: treat like a dead cache.
                self.fail_session(fed, id, t, Some(cache_site));
                return;
            }
            let flow = fed.net.start_flow(
                FlowSpec {
                    path: route.links,
                    bytes: size.max(1),
                    rate_cap: Some(per_conn),
                },
                t,
            );
            self.flow_owner.insert(flow, id);
            let s = &mut self.sessions[id.0 as usize];
            s.flow = Some(flow);
            s.phase = Phase::Transfer(Xfer::StashServe);
        } else if plan.fetch.is_empty() {
            // Every missing chunk is already on its way for another
            // session: join that fetch instead of duplicating it.
            let s = &mut self.sessions[id.0 as usize];
            if s.joins == 0 {
                self.stats.coalesced_joins += 1;
            }
            s.joins += 1;
            s.phase = Phase::JoinWait;
            self.waiters
                .entry((cache_site, path))
                .or_default()
                .push(id);
        } else {
            // Miss. The cache consults the redirector, which broadcasts
            // to origins (one WAN round trip to the redirector + one to
            // the origins). If every redirector instance is down the
            // fetch cannot be located — back off and retry (chunks are
            // not yet reserved, so nothing needs unwinding).
            let located = match fed.redirectors.locate(&path, &mut fed.origins, t) {
                Ok(outcome) => outcome.expect("file registered at an origin"),
                Err(_) => {
                    self.fail_session(fed, id, t, None);
                    return;
                }
            };
            debug_assert_eq!(located.origin, origin);
            // Reserve the chunks *now* (before the discovery round
            // trips elapse) so any session planning inside that window
            // joins this fetch instead of duplicating origin traffic.
            // Timing-neutral for serial runs: nothing observes the
            // in-flight bits between plan and fetch start there.
            fed.caches
                .get_mut(&cache_site)
                .expect("cache site")
                .begin_fetch(&path, version, &plan.fetch);
            let origin_route = fed
                .topo
                .route(Endpoint::Origin(origin.0), Endpoint::Cache(cache_site));
            let s = &mut self.sessions[id.0 as usize];
            s.plan = Some(plan);
            s.phase = Phase::FetchBegin;
            self.queue.schedule_at(
                t + Duration::from_secs_f64(2.0 * origin_route.rtt_ms / 1e3),
                EngineEvent::Timer(id),
            );
        }
    }

    /// (stash) Discovery round trips paid (chunks were reserved at
    /// plan time): stream origin → cache → worker.
    fn fetch_begin(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, cache_site, size, origin, per_conn) = {
            let s = &self.sessions[id.0 as usize];
            (
                s.site_idx,
                s.cache_site.expect("geo_resolve ran"),
                s.file.size.as_u64(),
                s.origin,
                s.per_conn,
            )
        };
        // The cache may have died during the discovery round trips.
        if fed.faults.is_cache_down(cache_site) {
            self.abort_reserved_fetch(fed, id, t, cache_site);
            return;
        }
        let origin_route = fed
            .topo
            .route(Endpoint::Origin(origin.0), Endpoint::Cache(cache_site));
        let cache_route = fed
            .topo
            .route(Endpoint::Cache(cache_site), Endpoint::Worker(site_idx));
        let mut links = origin_route.links;
        links.extend(&cache_route.links);
        if !route_is_up(fed, &links) {
            self.abort_reserved_fetch(fed, id, t, cache_site);
            return;
        }
        let flow = fed.net.start_flow(
            FlowSpec {
                path: links,
                bytes: size.max(1),
                rate_cap: Some(per_conn),
            },
            t,
        );
        self.flow_owner.insert(flow, id);
        let s = &mut self.sessions[id.0 as usize];
        s.flow = Some(flow);
        s.phase = Phase::Transfer(Xfer::StashFetch);
    }

    /// A reserved (pinned) fetch cannot start: release the
    /// reservation, wake joiners so they re-plan, and fail over.
    fn abort_reserved_fetch(
        &mut self,
        fed: &mut FedSim,
        id: SessionId,
        t: SimTime,
        cache_site: usize,
    ) {
        let (path, version, plan) = {
            let s = &mut self.sessions[id.0 as usize];
            (
                s.file.path.clone(),
                s.file.version,
                s.plan.take().expect("fetch had a plan"),
            )
        };
        fed.caches
            .get_mut(&cache_site)
            .expect("cache site")
            .abort_fetch(&path, version, &plan.fetch);
        self.wake_waiters(cache_site, &path, t);
        self.fail_session(fed, id, t, Some(cache_site));
    }

    /// (proxy) curl startup paid: squid lookup, then connection
    /// establishment at the path RTT.
    fn proxy_lookup(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        use crate::proxy::ProxyLookup;
        let (site_idx, url, size, origin) = {
            let s = &self.sessions[id.0 as usize];
            (s.site_idx, s.url.clone(), s.file.size.as_u64(), s.origin)
        };
        let proxy = fed
            .proxies
            .get_mut(&site_idx)
            .expect("compute site has proxy");
        let lookup = proxy.lookup(&url, size, t);
        let relay_cap = FedSim::proxy_relay_cap_bps(proxy, size);
        let worker_route = fed
            .topo
            .route(Endpoint::Proxy(site_idx), Endpoint::Worker(site_idx));

        let (links, rtt_ms, hit, cacheable) = match lookup {
            ProxyLookup::Hit => (worker_route.links.clone(), worker_route.rtt_ms, true, false),
            ProxyLookup::Miss { cacheable, .. } => {
                // Proxy streams origin → proxy → worker.
                let up = fed
                    .topo
                    .route(Endpoint::Origin(origin.0), Endpoint::Proxy(site_idx));
                let mut links = up.links;
                links.extend(&worker_route.links);
                (links, up.rtt_ms + worker_route.rtt_ms, false, cacheable)
            }
        };
        let s = &mut self.sessions[id.0 as usize];
        s.proxy_hit = hit;
        s.cacheable = cacheable;
        s.relay_links = links;
        s.relay_cap = relay_cap;
        s.phase = Phase::ProxyConnect;
        self.queue.schedule_at(
            t + Duration::from_secs_f64(rtt_ms / 1e3 * crate::sim::estimate::HANDSHAKE_ROUNDS),
            EngineEvent::Timer(id),
        );
    }

    /// (proxy) Connected: start the relay flow.
    fn proxy_connect(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (links, size, relay_cap) = {
            let s = &self.sessions[id.0 as usize];
            (s.relay_links.clone(), s.file.size.as_u64(), s.relay_cap)
        };
        if !route_is_up(fed, &links) {
            // A cut link broke the relay path: retry the lookup after
            // a backoff (curl reconnects; bounded by the direct-origin
            // fallback like every other retry path).
            self.fail_session(fed, id, t, None);
            return;
        }
        let flow = fed.net.start_flow(
            FlowSpec {
                path: links,
                bytes: size.max(1),
                rate_cap: Some(relay_cap),
            },
            t,
        );
        self.flow_owner.insert(flow, id);
        let s = &mut self.sessions[id.0 as usize];
        s.flow = Some(flow);
        s.phase = Phase::Transfer(Xfer::ProxyRelay);
    }

    /// (fallback) Connect straight to the origin. If the direct path
    /// itself is cut there is nothing left to fail over to: poll for
    /// the link to heal.
    fn direct_connect(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, origin) = {
            let s = &self.sessions[id.0 as usize];
            (s.site_idx, s.origin)
        };
        let route = fed
            .topo
            .route(Endpoint::Origin(origin.0), Endpoint::Worker(site_idx));
        if !route_is_up(fed, &route.links) {
            self.stats.retries += 1;
            self.sessions[id.0 as usize].retries += 1;
            self.queue
                .schedule_at(t + DIRECT_RETRY_BACKOFF, EngineEvent::Timer(id));
            return;
        }
        self.sessions[id.0 as usize].phase = Phase::DirectFetch;
        self.queue.schedule_at(
            t + Duration::from_secs_f64(2.0 * route.rtt_ms / 1e3),
            EngineEvent::Timer(id),
        );
    }

    /// (fallback) Request round trips paid: stream origin → worker.
    fn direct_fetch(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let (site_idx, origin, size) = {
            let s = &self.sessions[id.0 as usize];
            (s.site_idx, s.origin, s.file.size.as_u64())
        };
        let route = fed
            .topo
            .route(Endpoint::Origin(origin.0), Endpoint::Worker(site_idx));
        if !route_is_up(fed, &route.links) {
            // Cut during the round trips: back to polling.
            self.stats.retries += 1;
            let s = &mut self.sessions[id.0 as usize];
            s.retries += 1;
            s.phase = Phase::DirectConnect;
            self.queue
                .schedule_at(t + DIRECT_RETRY_BACKOFF, EngineEvent::Timer(id));
            return;
        }
        let flow = fed.net.start_flow(
            FlowSpec {
                path: route.links,
                bytes: size.max(1),
                rate_cap: None,
            },
            t,
        );
        self.flow_owner.insert(flow, id);
        let s = &mut self.sessions[id.0 as usize];
        s.flow = Some(flow);
        s.phase = Phase::Transfer(Xfer::DirectOrigin);
    }

    /// A session's flow finished at `t`: post-transfer bookkeeping,
    /// monitoring, waiter wake-ups, and the final record.
    fn on_flow_done(&mut self, fed: &mut FedSim, id: SessionId, t: SimTime) {
        let xfer = match self.sessions[id.0 as usize].phase {
            Phase::Transfer(x) => x,
            phase => unreachable!("flow completion for session {id:?} in phase {phase:?}"),
        };
        match xfer {
            Xfer::StashServe => {
                let (cache_site, size) = {
                    let s = &self.sessions[id.0 as usize];
                    (s.cache_site.expect("stash session"), s.file.size.as_u64())
                };
                fed.caches
                    .get_mut(&cache_site)
                    .expect("cache site")
                    .record_served(size, 0);
                self.emit_monitoring(fed, id, t);
                self.finish(id, t, Method::Xrootd);
            }
            Xfer::StashFetch => {
                let (cache_site, path, version, origin, plan) = {
                    let s = &mut self.sessions[id.0 as usize];
                    (
                        s.cache_site.expect("stash session"),
                        s.file.path.clone(),
                        s.file.version,
                        s.origin,
                        s.plan.take().expect("fetch had a plan"),
                    )
                };
                let cache = fed.caches.get_mut(&cache_site).expect("cache site");
                cache.commit_chunks(&path, version, &plan.fetch, t);
                cache.record_served(plan.hit_bytes, plan.miss_bytes);
                fed.origins[origin.0].bytes_served += plan.miss_bytes;
                // Chunks just became resident: wake sessions that
                // joined this fetch so they can re-plan (usually into
                // a pure hit).
                self.wake_waiters(cache_site, &path, t);
                self.emit_monitoring(fed, id, t);
                self.finish(id, t, Method::Xrootd);
            }
            Xfer::ProxyRelay => {
                let (site_idx, url, size, origin, hit, cacheable) = {
                    let s = &self.sessions[id.0 as usize];
                    (
                        s.site_idx,
                        s.url.clone(),
                        s.file.size.as_u64(),
                        s.origin,
                        s.proxy_hit,
                        s.cacheable,
                    )
                };
                if !hit {
                    fed.origins[origin.0].bytes_served += size;
                    if cacheable {
                        fed.proxies
                            .get_mut(&site_idx)
                            .expect("proxy")
                            .commit(&url, size, t);
                    }
                }
                self.finish(id, t, Method::HttpProxy);
            }
            Xfer::DirectOrigin => {
                let (origin, size) = {
                    let s = &self.sessions[id.0 as usize];
                    (s.origin, s.file.size.as_u64())
                };
                fed.origins[origin.0].bytes_served += size;
                self.finish(id, t, Method::HttpOrigin);
            }
        }
    }

    /// Emit the monitoring packet trio for a finished stash transfer.
    fn emit_monitoring(&mut self, fed: &mut FedSim, id: SessionId, closed_at: SimTime) {
        let (cache_site, site_idx, path, size, opened_at, protocol) = {
            let s = &self.sessions[id.0 as usize];
            (
                s.cache_site.expect("stash session"),
                s.site_idx,
                s.file.path.clone(),
                s.file.size.as_u64(),
                s.opened_at.expect("cache_check ran"),
                if s.transport == Method::HttpCache {
                    Protocol::Http
                } else {
                    Protocol::Xrootd
                },
            )
        };
        fed.emit_transfer_monitoring(
            cache_site, site_idx, &path, size, size, opened_at, closed_at, protocol,
        );
    }

    /// Wake every session parked on `(cache_site, path)`.
    fn wake_waiters(&mut self, cache_site: usize, path: &str, t: SimTime) {
        let Some(ids) = self.waiters.remove(&(cache_site, path.to_string())) else {
            return;
        };
        for wid in ids {
            let s = &mut self.sessions[wid.0 as usize];
            debug_assert_eq!(s.phase, Phase::JoinWait);
            s.phase = Phase::CacheCheck;
            self.queue.schedule_at(t, EngineEvent::Timer(wid));
        }
    }

    /// Drop a session's claim on its assigned cache (in-flight load
    /// accounting; no-op for sessions without one).
    fn release_cache_slot(&mut self, id: SessionId) {
        if let Some(site) = self.sessions[id.0 as usize].cache_site {
            if let Some(n) = self.cache_in_flight.get_mut(&site) {
                *n = n.saturating_sub(1);
            }
        }
    }

    fn finish(&mut self, id: SessionId, t: SimTime, method: Method) {
        self.release_cache_slot(id);
        let s = &mut self.sessions[id.0 as usize];
        let cache_hit = match method {
            Method::HttpProxy => s.proxy_hit,
            // Direct-to-origin never touched a cache's copy.
            Method::HttpOrigin => false,
            _ => s.initial_hit,
        };
        s.record = Some(TransferRecord {
            path: s.file.path.clone(),
            bytes: s.file.size.as_u64(),
            method,
            cache_hit,
            duration: t - s.arrival,
        });
        s.phase = Phase::Done;
        s.flow = None;
        self.outstanding -= 1;
        self.in_flight -= 1;
        self.completed.push(id);
        self.stats.sessions_completed += 1;
    }
}
