//! Federation assembly and the download state machine.
//!
//! [`FedSim`] wires every substrate together exactly as Figure 1:
//! origins registered in the global namespace, the redirector HA pair,
//! chunk caches at the Figure 2 sites, squid proxies at compute sites,
//! the GeoIP nearest-cache service, the monitoring pipeline, and the
//! flow-level WAN. It exposes the client operations the drivers run:
//!
//! * [`FedSim::download`] — one blocking download at a site via a
//!   chosen [`DownloadMethod`], advancing virtual time: startup
//!   latencies, GeoIP lookup, redirector discovery, origin fetch
//!   through the cache (or proxy), monitoring packets on completion.
//! * background origin load ("many users of the filesystem, network,
//!   and data transfer nodes during our tests", §4.1) as persistent
//!   flows on the origin's DTN link.

pub mod backend;

use crate::cache::CacheServer;
use crate::client::stashcp::{self, HostEnvironment, StartupCosts};
use crate::client::{curl, Method, TransferRecord};
use crate::config::FederationConfig;
use crate::geoip::{CacheSite, NearestCache};
use crate::monitoring::aggregator::Aggregator;
use crate::monitoring::bus::{Bus, Subscription};
use crate::monitoring::collector::{Collector, TRANSFER_TOPIC};
use crate::monitoring::packets::{Envelope, Packet, Protocol};
use crate::namespace::{Namespace, OriginId};
use crate::netsim::{Endpoint, FlowId, FlowSpec, Network, Topology};
use crate::origin::{FileMeta, Origin};
use crate::proxy::{ProxyLookup, ProxyServer};
use crate::redirector::RedirectorPool;
use crate::sim::workload::FileRef;
use crate::util::{Duration, Pcg64, SimTime};
use backend::GeoBackend;
use std::collections::HashMap;

/// How a download is performed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownloadMethod {
    /// curl through the site HTTP forward proxy (baseline).
    HttpProxy,
    /// stashcp → nearest cache via XRootD (the federation path).
    Stash,
}

/// Squid relays objects above `max_object` without caching, and its
/// single-stream relay degrades on multi-GB bodies (disk buffering;
/// "proxies are optimized for small files", §1). Exponent calibrated
/// against Table 3 — see EXPERIMENTS.md.
pub const PROXY_RELAY_DEGRADE_EXP: f64 = 0.25;

/// Background flows hammering each origin's DTN link (§4.1 realism).
/// Four concurrent pulls leave ~2 Gbps of the 10 Gbps DTN for a test
/// transfer — calibrated against Table 3 (see EXPERIMENTS.md).
pub const DEFAULT_BACKGROUND_FLOWS: usize = 4;

/// The assembled federation.
pub struct FedSim {
    pub cfg: FederationConfig,
    pub net: Network,
    pub topo: Topology,
    /// site_idx → cache / proxy (present per config).
    pub caches: HashMap<usize, CacheServer>,
    pub proxies: HashMap<usize, ProxyServer>,
    pub origins: Vec<Origin>,
    pub namespace: Namespace,
    pub redirectors: RedirectorPool,
    pub geoip: NearestCache<GeoBackend>,
    /// Cache-site indices aligned with `geoip.caches()` order.
    geo_cache_sites: Vec<usize>,
    // Monitoring pipeline.
    pub collector: Collector,
    pub bus: Bus,
    agg_sub: Subscription,
    pub aggregator: Aggregator,
    pub now: SimTime,
    rng: Pcg64,
    /// Active background flows: flow → (origin_idx, link rebuilt on completion).
    background: HashMap<FlowId, usize>,
    next_user_id: u32,
    next_file_id: u32,
    /// Client tool costs (overridable for ablations).
    pub startup_costs: StartupCosts,
    pub host_env: HostEnvironment,
}

impl FedSim {
    /// Build the federation from a config with the pure-rust geo
    /// backend (use [`FedSim::build_with_backend`] for PJRT).
    pub fn build(cfg: FederationConfig) -> Self {
        Self::build_with_backend(cfg, GeoBackend::rust())
    }

    pub fn build_with_backend(cfg: FederationConfig, geo: GeoBackend) -> Self {
        cfg.validate().expect("invalid federation config");
        let mut net = Network::new();
        let topo = Topology::build(&cfg, &mut net);

        let mut caches = HashMap::new();
        let mut proxies = HashMap::new();
        let mut geo_sites = Vec::new();
        let mut geo_cache_sites = Vec::new();
        for (idx, s) in cfg.sites.iter().enumerate() {
            if let Some(cc) = s.cache {
                caches.insert(idx, CacheServer::new(s.name.clone(), cc));
                geo_sites.push(CacheSite {
                    name: s.name.clone(),
                    lat: s.lat,
                    lon: s.lon,
                });
                geo_cache_sites.push(idx);
            }
            if let Some(pc) = s.proxy {
                proxies.insert(idx, ProxyServer::new(s.name.clone(), pc));
            }
        }

        let mut namespace = Namespace::new();
        let mut origins = Vec::new();
        for (i, o) in cfg.origins.iter().enumerate() {
            let id = OriginId(i);
            namespace.register(&o.prefix, id).expect("validated config");
            origins.push(Origin::new(id, o.name.clone(), o.prefix.clone()));
        }

        let mut collector = Collector::new();
        let mut bus = Bus::new();
        let agg_sub = bus.subscribe(TRANSFER_TOPIC);
        for (idx, s) in cfg.sites.iter().enumerate() {
            if s.cache.is_some() {
                collector.register_server(idx as u32, s.name.clone());
            }
        }

        let geoip = NearestCache::with_backend(geo_sites, geo);
        let redirectors = RedirectorPool::new(cfg.redirector_instances);
        let rng = Pcg64::new(cfg.seed, 0xfed);

        FedSim {
            net,
            topo,
            caches,
            proxies,
            origins,
            namespace,
            redirectors,
            geoip,
            geo_cache_sites,
            collector,
            bus,
            agg_sub,
            aggregator: Aggregator::default(),
            now: SimTime::ZERO,
            rng,
            background: HashMap::new(),
            next_user_id: 1,
            next_file_id: 1,
            startup_costs: StartupCosts::default(),
            host_env: HostEnvironment::default(),
            cfg,
        }
    }

    // --- origin dataset management ----------------------------------------

    /// Ensure a file exists at its authoritative origin (the drivers
    /// materialise workload files on first reference).
    pub fn ensure_file(&mut self, file: &FileRef) -> OriginId {
        let oid = self
            .namespace
            .resolve(&file.path)
            .unwrap_or_else(|| panic!("no origin serves {}", file.path));
        let origin = &mut self.origins[oid.0];
        let need_put = match origin.stat(&file.path) {
            Ok(meta) => meta.mtime != file.version || meta.size != file.size.as_u64(),
            Err(_) => true,
        };
        if need_put {
            origin
                .put_file(
                    &file.path,
                    FileMeta {
                        size: file.size.as_u64(),
                        mtime: file.version,
                        perm: 0o644,
                    },
                )
                .expect("path under origin prefix");
        }
        oid
    }

    // --- background origin load --------------------------------------------

    /// Start `n` persistent flows on every origin's DTN link.
    pub fn start_background_load(&mut self, n: usize) {
        for o in 0..self.origins.len() {
            for _ in 0..n {
                self.spawn_background(o);
            }
        }
    }

    fn spawn_background(&mut self, origin_idx: usize) {
        // Other users of the Stash filesystem pulling large datasets.
        // They contend on the origin's DTN link only — their own
        // last-mile legs are elsewhere and uncongested. Sizes are
        // large so months-long simulations don't churn through
        // millions of respawns; contention depends on the *count* of
        // concurrent flows, not their length.
        let bytes = self.rng.gen_range(20_000_000_000, 200_000_000_000);
        let flow = self.net.start_flow(
            FlowSpec {
                path: vec![self.topo.origin_lan_link(origin_idx)],
                bytes,
                rate_cap: None,
            },
            self.now,
        );
        self.background.insert(flow, origin_idx);
    }

    /// Advance virtual time to `t`, restarting background flows as
    /// they finish (each respawn starts at its predecessor's
    /// completion instant, so origin load has no gaps). Returns
    /// completions that were NOT background.
    pub fn advance_to(&mut self, t: SimTime) -> Vec<crate::netsim::Completion> {
        let mut foreground = Vec::new();
        loop {
            match self.net.next_completion() {
                Some(tc) if tc <= t => {
                    let completions = self.net.advance(tc);
                    self.now = tc;
                    for c in completions {
                        if let Some(origin_idx) = self.background.remove(&c.flow) {
                            self.spawn_background(origin_idx);
                        } else {
                            foreground.push(c);
                        }
                    }
                }
                _ => break,
            }
        }
        self.net.advance(t);
        self.now = self.now.max(t);
        foreground
    }

    /// Run the network until `flow` completes; background flows are
    /// restarted along the way. Returns the completion time.
    fn run_until_flow_done(&mut self, flow: FlowId) -> SimTime {
        let mut guard = 0u64;
        loop {
            guard += 1;
            if guard > 1_000_000 {
                panic!(
                    "run_until_flow_done stuck waiting for {flow:?} at {}: {:?}",
                    self.now,
                    self.net.flows_snapshot()
                );
            }
            let t = self
                .net
                .next_completion()
                .expect("active flow must complete");
            let completions = self.net.advance(t);
            self.now = t;
            let mut done = false;
            for c in completions {
                if c.flow == flow {
                    done = true;
                } else if let Some(origin_idx) = self.background.remove(&c.flow) {
                    self.spawn_background(origin_idx);
                }
            }
            if done {
                return self.now;
            }
        }
    }

    // --- GeoIP -------------------------------------------------------------

    /// Pick the nearest cache for a worker at `site_idx`, given live
    /// cache load factors (the CVMFS GeoIP API call stashcp makes).
    pub fn nearest_cache_site(&mut self, site_idx: usize) -> usize {
        let s = &self.cfg.sites[site_idx];
        let loads: Vec<f64> = self
            .geo_cache_sites
            .iter()
            .map(|idx| self.caches[idx].load_factor())
            .collect();
        let ranked = self.geoip.rank(s.lat, s.lon, &loads);
        self.geo_cache_sites[ranked[0].0]
    }

    // --- monitoring --------------------------------------------------------

    fn emit_transfer_monitoring(
        &mut self,
        cache_site: usize,
        site_idx: usize,
        path: &str,
        file_size: u64,
        bytes_read: u64,
        opened_at: SimTime,
        closed_at: SimTime,
        protocol: Protocol,
    ) {
        let server_id = cache_site as u32;
        let user_id = self.next_user_id;
        self.next_user_id += 1;
        let file_id = self.next_file_id;
        self.next_file_id += 1;
        let client_host = format!("worker.{}.osg", self.cfg.sites[site_idx].name);
        let chunk = self.caches[&cache_site].cfg.chunk_size.as_u64().max(1);
        let packets = [
            (
                opened_at,
                Packet::UserLogin {
                    user_id,
                    protocol,
                    ipv6: self.rng.gen_bool(0.35),
                    client_host,
                },
            ),
            (
                opened_at,
                Packet::FileOpen {
                    file_id,
                    user_id,
                    file_size,
                    path: path.to_string(),
                },
            ),
            (
                closed_at,
                Packet::FileClose {
                    file_id,
                    bytes_read,
                    bytes_written: 0,
                    read_ops: bytes_read.div_ceil(chunk) as u32,
                    write_ops: 0,
                },
            ),
        ];
        for (timestamp, packet) in packets {
            let env = Envelope {
                server_id,
                timestamp,
                packet,
            };
            // Sim mode feeds the decoded packet straight in; the same
            // bytes go over real UDP in live mode.
            self.collector.ingest(env, &mut self.bus);
        }
        self.aggregator.consume(&mut self.bus, &mut self.agg_sub);
        // Bound bus memory in months-long simulations.
        self.bus.compact(TRANSFER_TOPIC);
    }

    // --- downloads -----------------------------------------------------------

    /// Effective squid relay ceiling for an object of `size` bytes.
    fn proxy_relay_cap_bps(proxy: &ProxyServer, size: u64) -> f64 {
        let base = proxy.cfg.per_conn_gbps * 1e9 / 8.0;
        let max_obj = proxy.cfg.max_object.as_u64() as f64;
        if size as f64 <= max_obj {
            base
        } else {
            base * (max_obj / size as f64).powf(PROXY_RELAY_DEGRADE_EXP)
        }
    }

    /// Perform one blocking download of `file` by a worker at
    /// `site_idx`. Advances `self.now` through every phase.
    pub fn download(
        &mut self,
        site_idx: usize,
        file: &FileRef,
        method: DownloadMethod,
    ) -> TransferRecord {
        let origin_id = self.ensure_file(file);
        match method {
            DownloadMethod::HttpProxy => self.download_via_proxy(site_idx, file, origin_id),
            DownloadMethod::Stash => self.download_via_stash(site_idx, file, origin_id),
        }
    }

    fn download_via_proxy(
        &mut self,
        site_idx: usize,
        file: &FileRef,
        origin_id: OriginId,
    ) -> TransferRecord {
        let start = self.now;
        let size = file.size.as_u64();
        let url = curl::url_for(&file.path);
        // curl startup; proxy address comes from the environment (§5).
        self.now += self.startup_costs.curl_startup;

        // Process any completions the latency jump passed over (keeps
        // background load respawning on schedule).
        self.advance_to(self.now);

        let proxy = self.proxies.get_mut(&site_idx).expect("compute site has proxy");
        let lookup = proxy.lookup(&url, size, self.now);
        let relay_cap = Self::proxy_relay_cap_bps(proxy, size);
        let worker_route = self.topo.route(Endpoint::Proxy(site_idx), Endpoint::Worker(site_idx));

        let (links, rtt_ms, hit) = match lookup {
            ProxyLookup::Hit => (worker_route.links.clone(), worker_route.rtt_ms, true),
            ProxyLookup::Miss { .. } => {
                // Proxy streams origin → proxy → worker.
                let up = self
                    .topo
                    .route(Endpoint::Origin(origin_id.0), Endpoint::Proxy(site_idx));
                let mut links = up.links;
                links.extend(&worker_route.links);
                (links, up.rtt_ms + worker_route.rtt_ms, false)
            }
        };
        // Connection establishment at the path RTT.
        self.now += Duration::from_secs_f64(rtt_ms / 1e3 * crate::sim::estimate::HANDSHAKE_ROUNDS);
        self.advance_to(self.now);

        let flow = self.net.start_flow(
            FlowSpec {
                path: links,
                bytes: size.max(1),
                rate_cap: Some(relay_cap),
            },
            self.now,
        );
        let done = self.run_until_flow_done(flow);

        // Post-transfer bookkeeping.
        if !hit {
            self.origins[origin_id.0].bytes_served += size;
            let proxy = self.proxies.get_mut(&site_idx).expect("proxy");
            if let ProxyLookup::Miss { cacheable: true, .. } = lookup {
                proxy.commit(&url, size, done);
            }
        }

        TransferRecord {
            path: file.path.clone(),
            bytes: size,
            method: Method::HttpProxy,
            cache_hit: hit,
            duration: done - start,
        }
    }

    fn download_via_stash(
        &mut self,
        site_idx: usize,
        file: &FileRef,
        origin_id: OriginId,
    ) -> TransferRecord {
        let start = self.now;
        let size = file.size.as_u64();
        // stashcp walks its fallback chain; the first usable method
        // here is XRootD (attempt index from the chain).
        let chain = stashcp::method_chain(self.host_env);
        let attempt = chain
            .iter()
            .position(|m| *m == Method::Xrootd || *m == Method::HttpCache)
            .unwrap_or(0);
        let method = chain[attempt];
        self.now += stashcp::startup_latency(&self.startup_costs, method, attempt);

        // Process any completions the latency jump passed over.
        self.advance_to(self.now);

        // GeoIP nearest-cache decision (a remote query — §5's startup
        // cost is charged in startup_latency above).
        let cache_site = self.nearest_cache_site(site_idx);

        // Ask the cache for the file.
        let cache_route = self
            .topo
            .route(Endpoint::Cache(cache_site), Endpoint::Worker(site_idx));
        self.now += Duration::from_secs_f64(cache_route.rtt_ms / 1e3);

        let cache = self.caches.get_mut(&cache_site).expect("cache site");
        let plan = cache.plan_read(&file.path, 0, size, size, file.version, self.now);
        let per_conn = cache.cfg.per_conn_gbps * 1e9 / 8.0;
        let whole_hit = plan.miss_bytes == 0;

        let opened_at = self.now;
        let done = if whole_hit {
            // Pure cache hit: cache → worker.
            self.advance_to(self.now);
            let flow = self.net.start_flow(
                FlowSpec {
                    path: cache_route.links.clone(),
                    bytes: size.max(1),
                    rate_cap: Some(per_conn),
                },
                self.now,
            );
            let done = self.run_until_flow_done(flow);
            self.caches.get_mut(&cache_site).unwrap().record_served(size, 0);
            done
        } else {
            // Miss: cache consults the redirector, which broadcasts to
            // origins (one WAN round trip to the redirector + one to
            // the origins).
            let located = self
                .redirectors
                .locate(&file.path, &mut self.origins, self.now)
                .expect("redirector pool up")
                .expect("file registered at an origin");
            debug_assert_eq!(located.origin, origin_id);
            let origin_route = self
                .topo
                .route(Endpoint::Origin(origin_id.0), Endpoint::Cache(cache_site));
            self.now += Duration::from_secs_f64(2.0 * origin_route.rtt_ms / 1e3);

            let cache = self.caches.get_mut(&cache_site).unwrap();
            cache.begin_fetch(&file.path, &plan.fetch);

            // Stream origin → cache → worker.
            self.advance_to(self.now);
            let mut links = origin_route.links.clone();
            links.extend(&cache_route.links);
            let flow = self.net.start_flow(
                FlowSpec {
                    path: links,
                    bytes: size.max(1),
                    rate_cap: Some(per_conn),
                },
                self.now,
            );
            let done = self.run_until_flow_done(flow);

            let cache = self.caches.get_mut(&cache_site).unwrap();
            cache.commit_chunks(&file.path, &plan.fetch, done);
            cache.record_served(plan.hit_bytes, plan.miss_bytes);
            self.origins[origin_id.0].bytes_served += plan.miss_bytes;
            done
        };

        self.emit_transfer_monitoring(
            cache_site,
            site_idx,
            &file.path,
            size,
            size,
            opened_at,
            done,
            if method == Method::HttpCache {
                Protocol::Http
            } else {
                Protocol::Xrootd
            },
        );

        TransferRecord {
            path: file.path.clone(),
            bytes: size,
            method: Method::Xrootd,
            cache_hit: whole_hit,
            duration: done - start,
        }
    }

    /// WAN link byte counter of a site (Fig 5's graph source).
    pub fn wan_bytes(&self, site_idx: usize) -> f64 {
        self.net.link_bytes_carried(self.topo.wan_link(site_idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;
    use crate::util::ByteSize;

    fn fed() -> FedSim {
        FedSim::build(paper_federation())
    }

    fn file(size: u64) -> FileRef {
        FileRef {
            path: "/ospool/ligo/data/f000000.dat".into(),
            size: ByteSize(size),
            version: 1,
        }
    }

    #[test]
    fn builds_paper_topology() {
        let f = fed();
        assert_eq!(f.caches.len(), 10);
        assert_eq!(f.proxies.len(), 5);
        assert_eq!(f.origins.len(), 10);
        assert_eq!(f.redirectors.instances.len(), 2);
        assert_eq!(f.geoip.caches().len(), 10);
    }

    #[test]
    fn stash_cold_then_hot_is_faster() {
        let mut f = fed();
        let site = f.topo.site_index("syracuse").unwrap();
        let fr = file(2_335_000_000);
        let cold = f.download(site, &fr, DownloadMethod::Stash);
        assert!(!cold.cache_hit);
        let hot = f.download(site, &fr, DownloadMethod::Stash);
        assert!(hot.cache_hit, "second stash download must hit");
        assert!(
            hot.duration < cold.duration,
            "hot {} < cold {}",
            hot.duration,
            cold.duration
        );
    }

    #[test]
    fn proxy_caches_small_not_large() {
        let mut f = fed();
        let site = f.topo.site_index("nebraska").unwrap();
        let small = file(100_000_000);
        let c1 = f.download(site, &small, DownloadMethod::HttpProxy);
        assert!(!c1.cache_hit);
        let c2 = f.download(site, &small, DownloadMethod::HttpProxy);
        assert!(c2.cache_hit, "100 MB object must be cached");
        // 2.335 GB exceeds max_object (1 GB): never cached (§5).
        let big = FileRef {
            path: "/ospool/ligo/data/f000001.dat".into(),
            size: ByteSize(2_335_000_000),
            version: 1,
        };
        let b1 = f.download(site, &big, DownloadMethod::HttpProxy);
        let b2 = f.download(site, &big, DownloadMethod::HttpProxy);
        assert!(!b1.cache_hit && !b2.cache_hit);
    }

    #[test]
    fn small_file_faster_via_proxy() {
        // Fig 8's shape: 5.797 KB via proxy beats stashcp's startup.
        let mut f = fed();
        let site = f.topo.site_index("syracuse").unwrap();
        let tiny = file(5_797);
        let http = f.download(site, &tiny, DownloadMethod::HttpProxy);
        let stash = f.download(site, &tiny, DownloadMethod::Stash);
        assert!(
            http.duration.as_secs_f64() * 3.0 < stash.duration.as_secs_f64(),
            "http {} vs stash {}",
            http.duration,
            stash.duration
        );
    }

    #[test]
    fn colorado_uses_remote_cache_and_crosses_wan() {
        let mut f = fed();
        let col = f.topo.site_index("colorado").unwrap();
        let nearest = f.nearest_cache_site(col);
        assert_ne!(nearest, col, "colorado has no local cache");
        let before = f.wan_bytes(col);
        f.download(col, &file(100_000_000), DownloadMethod::Stash);
        assert!(f.wan_bytes(col) > before, "stash at colorado crosses its WAN");
    }

    #[test]
    fn syracuse_hot_hits_stay_on_lan() {
        let mut f = fed();
        let syr = f.topo.site_index("syracuse").unwrap();
        let fr = file(500_000_000);
        f.download(syr, &fr, DownloadMethod::Stash);
        let wan_after_cold = f.wan_bytes(syr);
        f.download(syr, &fr, DownloadMethod::Stash);
        let wan_after_hot = f.wan_bytes(syr);
        assert!(
            wan_after_hot - wan_after_cold < 1_000_000.0,
            "hot hit must not cross the WAN (Δ={})",
            wan_after_hot - wan_after_cold
        );
    }

    #[test]
    fn monitoring_pipeline_records_stash_downloads() {
        let mut f = fed();
        let site = f.topo.site_index("nebraska").unwrap();
        f.download(site, &file(1_000_000), DownloadMethod::Stash);
        f.download(site, &file(1_000_000), DownloadMethod::Stash);
        assert_eq!(f.aggregator.reports, 2);
        let usage = f.aggregator.experiment_usage("ligo").unwrap();
        assert_eq!(usage.bytes_read, 2_000_000);
        assert_eq!(f.collector.stats.reports_published, 2);
    }

    #[test]
    fn background_load_slows_cold_fetches() {
        let mut fast = fed();
        let mut loaded = fed();
        // Heavy load: 12 pulls shrink the origin DTN share below every
        // other bottleneck on the test path.
        loaded.start_background_load(12);
        let site = fast.topo.site_index("bellarmine").unwrap();
        let fr = file(2_335_000_000);
        let t_fast = fast.download(site, &fr, DownloadMethod::Stash).duration;
        let t_loaded = loaded.download(site, &fr, DownloadMethod::Stash).duration;
        assert!(
            t_loaded.as_secs_f64() > t_fast.as_secs_f64() * 1.5,
            "origin contention must bite: {t_fast} vs {t_loaded}"
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut f = fed();
            f.start_background_load(4);
            let site = f.topo.site_index("chicago").unwrap();
            let mut out = Vec::new();
            for i in 0..5 {
                let fr = FileRef {
                    path: format!("/ospool/des/data/f{i:06}.dat"),
                    size: ByteSize(50_000_000 * (i + 1)),
                    version: 1,
                };
                out.push(f.download(site, &fr, DownloadMethod::Stash).duration);
            }
            out
        };
        assert_eq!(run(), run(), "same seed ⇒ identical timings");
    }
}
