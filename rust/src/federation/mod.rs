//! Federation assembly and the concurrent download engine.
//!
//! [`FedSim`] wires every substrate together exactly as Figure 1:
//! origins registered in the global namespace, the redirector HA pair,
//! chunk caches at the Figure 2 sites, squid proxies at compute sites,
//! the GeoIP nearest-cache service, the monitoring pipeline, and the
//! flow-level WAN.
//!
//! Downloads are **sessions** ([`session::Session`]): small state
//! machines (Startup → GeoIP → CacheCheck → OriginFetch/ProxyRelay →
//! Serve → Monitor) advanced by the event-driven
//! [`driver::SessionEngine`], which interleaves its timer queue with
//! the network's flow completions. Any number of sessions may be in
//! flight at once — hundreds of clients at many sites overlap on
//! shared links, and the cache's chunk-level miss coalescing fires
//! *across* concurrent clients (a session whose missing chunks are
//! already being fetched joins that fetch instead of hitting the
//! origin again). See `ARCHITECTURE.md` for the full state diagram.
//!
//! Two driver styles sit on top:
//!
//! * [`FedSim::download`] — the serial convenience API: one session,
//!   run to completion. A serial campaign walks exactly the instants
//!   the pre-engine blocking implementation walked, so the §4.1
//!   artifacts (Table 3, Figures 6–8) are reproduced bit-for-bit.
//! * [`driver::SessionEngine`] used directly (see
//!   [`crate::sim::campaign`]) — spawn many sessions at their job
//!   arrival instants and run them concurrently.
//!
//! Background origin load ("many users of the filesystem, network,
//! and data transfer nodes during our tests", §4.1) runs as persistent
//! flows on each origin's DTN link, respawned by whichever engine is
//! advancing time.
//!
//! The network's component-local allocator ([`crate::netsim::network`])
//! exposes its work counters through [`crate::netsim::AllocStats`];
//! [`driver::SessionEngine::run`] folds the per-run deltas into
//! [`driver::EngineStats`] (allocator passes, components touched,
//! flows re-fixed, peak component), which campaigns and sweeps carry
//! into their results and `--profile` output.

pub mod backend;
pub mod driver;
pub mod session;

use crate::cache::CacheServer;
use crate::client::stashcp::{HostEnvironment, StartupCosts};
use crate::client::TransferRecord;
use crate::config::FederationConfig;
use crate::fault::{FaultDims, FaultEvent, FaultState, FaultTimeline, TimelineError};
use crate::geoip::{CacheSite, NearestCache};
use crate::monitoring::aggregator::Aggregator;
use crate::monitoring::bus::{Bus, Subscription};
use crate::monitoring::collector::{Collector, TRANSFER_TOPIC};
use crate::monitoring::packets::{Envelope, Packet, Protocol};
use crate::namespace::{Namespace, OriginId};
use crate::netsim::{FlowId, FlowSpec, Network, Topology};
use crate::origin::{FileMeta, Origin};
use crate::proxy::ProxyServer;
use crate::redirector::breaker::CacheBreaker;
use crate::redirector::policy::{self, FederationView, RedirectionPolicy};
use crate::redirector::RedirectorPool;
use crate::sim::workload::FileRef;
use crate::util::{Pcg64, SimTime};
use backend::GeoBackend;
use std::collections::{HashMap, VecDeque};

/// How a download is performed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownloadMethod {
    /// curl through the site HTTP forward proxy (baseline).
    HttpProxy,
    /// stashcp → nearest cache via XRootD (the federation path).
    Stash,
}

/// Squid relays objects above `max_object` without caching, and its
/// single-stream relay degrades on multi-GB bodies (disk buffering;
/// "proxies are optimized for small files", §1). Exponent calibrated
/// against Table 3 — see EXPERIMENTS.md.
pub const PROXY_RELAY_DEGRADE_EXP: f64 = 0.25;

/// Background flows hammering each origin's DTN link (§4.1 realism).
/// Four concurrent pulls leave ~2 Gbps of the 10 Gbps DTN for a test
/// transfer — calibrated against Table 3 (see EXPERIMENTS.md).
pub const DEFAULT_BACKGROUND_FLOWS: usize = 4;

/// The assembled federation.
pub struct FedSim {
    pub cfg: FederationConfig,
    pub net: Network,
    pub topo: Topology,
    /// site_idx → cache / proxy (present per config).
    pub caches: HashMap<usize, CacheServer>,
    pub proxies: HashMap<usize, ProxyServer>,
    pub origins: Vec<Origin>,
    pub namespace: Namespace,
    pub redirectors: RedirectorPool,
    pub geoip: NearestCache<GeoBackend>,
    /// Cache-site indices aligned with `geoip.caches()` order.
    geo_cache_sites: Vec<usize>,
    /// Cache-selection policy (see [`crate::redirector::policy`]).
    /// Built from `cfg.redirection`; `Nearest` is bit-identical to the
    /// legacy hardcoded GeoIP ladder.
    pub policy: Box<dyn RedirectionPolicy>,
    // Monitoring pipeline.
    pub collector: Collector,
    pub bus: Bus,
    agg_sub: Subscription,
    pub aggregator: Aggregator,
    pub now: SimTime,
    rng: Pcg64,
    /// Active background flows: flow → (origin_idx, link rebuilt on completion).
    background: HashMap<FlowId, usize>,
    /// Background flows waiting for their origin's link to be restored.
    deferred_background: Vec<usize>,
    /// Live component-health view (down caches, downtime ledger).
    pub faults: FaultState,
    /// Circuit breaker over cache health ([`crate::redirector::breaker`]).
    /// `None` when `[resilience] breaker = false` (the default) —
    /// candidate sets are then exactly the pre-breaker ones, bit for
    /// bit. When armed, caches whose breaker is open are folded out of
    /// [`FederationView::up`], composing with every redirection policy.
    pub breaker: Option<CacheBreaker>,
    /// Scheduled faults not yet applied, sorted by time. Engines
    /// driving this federation pop and apply them as they come due.
    fault_schedule: VecDeque<FaultEvent>,
    /// Faults applied so far, at their effective instants.
    pub fault_log: Vec<FaultEvent>,
    next_user_id: u32,
    next_file_id: u32,
    /// Client tool costs (overridable for ablations).
    pub startup_costs: StartupCosts,
    pub host_env: HostEnvironment,
}

impl FedSim {
    /// Build the federation from a config with the pure-rust geo
    /// backend (use [`FedSim::build_with_backend`] for PJRT).
    pub fn build(cfg: FederationConfig) -> Self {
        Self::build_with_backend(cfg, GeoBackend::rust())
    }

    pub fn build_with_backend(cfg: FederationConfig, geo: GeoBackend) -> Self {
        cfg.validate().expect("invalid federation config");
        let mut net = Network::new();
        let topo = Topology::build(&cfg, &mut net);

        let mut caches = HashMap::new();
        let mut proxies = HashMap::new();
        let mut geo_sites = Vec::new();
        let mut geo_cache_sites = Vec::new();
        for (idx, s) in cfg.sites.iter().enumerate() {
            if let Some(cc) = s.cache {
                caches.insert(idx, CacheServer::new(s.name.clone(), cc));
                geo_sites.push(CacheSite {
                    name: s.name.clone(),
                    lat: s.lat,
                    lon: s.lon,
                });
                geo_cache_sites.push(idx);
            }
            if let Some(pc) = s.proxy {
                proxies.insert(idx, ProxyServer::new(s.name.clone(), pc));
            }
        }

        let mut namespace = Namespace::new();
        let mut origins = Vec::new();
        for (i, o) in cfg.origins.iter().enumerate() {
            let id = OriginId(i);
            namespace.register(&o.prefix, id).expect("validated config");
            origins.push(Origin::new(id, o.name.clone(), o.prefix.clone()));
        }

        let mut collector = Collector::new();
        let mut bus = Bus::new();
        let agg_sub = bus.subscribe(TRANSFER_TOPIC);
        for (idx, s) in cfg.sites.iter().enumerate() {
            if s.cache.is_some() {
                collector.register_server(idx as u32, s.name.clone());
            }
        }

        // The ring and every other policy hash on cache-site *names*
        // (stable identity), in federation order.
        let cache_names: Vec<&str> = geo_sites.iter().map(|c| c.name.as_str()).collect();
        let policy = policy::build_policy(&cfg.redirection, &cache_names);
        let geoip = NearestCache::with_backend(geo_sites, geo);
        let redirectors =
            RedirectorPool::with_cap(cfg.redirector_instances, cfg.redirection.location_cache_cap);
        let rng = Pcg64::new(cfg.seed, 0xfed);
        let breaker = cfg
            .resilience
            .breaker
            .then(|| CacheBreaker::new(&cfg.resilience));

        FedSim {
            net,
            topo,
            caches,
            proxies,
            origins,
            namespace,
            redirectors,
            geoip,
            geo_cache_sites,
            policy,
            collector,
            bus,
            agg_sub,
            aggregator: Aggregator::default(),
            now: SimTime::ZERO,
            rng,
            background: HashMap::new(),
            deferred_background: Vec::new(),
            faults: FaultState::default(),
            breaker,
            fault_schedule: VecDeque::new(),
            fault_log: Vec::new(),
            next_user_id: 1,
            next_file_id: 1,
            startup_costs: StartupCosts::default(),
            host_env: HostEnvironment::default(),
            cfg,
        }
    }

    // --- origin dataset management ----------------------------------------

    /// Ensure a file exists at its authoritative origin (the drivers
    /// materialise workload files on first reference).
    pub fn ensure_file(&mut self, file: &FileRef) -> OriginId {
        let oid = self
            .namespace
            .resolve(&file.path)
            .unwrap_or_else(|| panic!("no origin serves {}", file.path));
        let origin = &mut self.origins[oid.0];
        let need_put = match origin.stat(&file.path) {
            Ok(meta) => meta.mtime != file.version || meta.size != file.size.as_u64(),
            Err(_) => true,
        };
        if need_put {
            origin
                .put_file(
                    &file.path,
                    FileMeta {
                        size: file.size.as_u64(),
                        mtime: file.version,
                        perm: 0o644,
                    },
                )
                .expect("path under origin prefix");
        }
        oid
    }

    // --- fault injection ----------------------------------------------------

    /// The federation's component bounds, for validating a
    /// [`FaultTimeline`] against what actually exists.
    pub fn fault_dims(&self) -> FaultDims {
        FaultDims {
            cache_sites: self.caches.keys().copied().collect(),
            origins: self.origins.len(),
            links: self.net.link_count(),
            redirector_instances: self.redirectors.instances.len(),
        }
    }

    /// Schedule a fault timeline against this federation. Events apply
    /// at their instants while *any* engine is driving virtual time
    /// (serial [`FedSim::download`] calls, campaigns, scenarios); an
    /// event whose time has already passed when the next engine starts
    /// is applied immediately at that engine's clock. May be called
    /// repeatedly — the schedule stays sorted by time (ties keep
    /// injection order).
    ///
    /// The timeline is validated against this federation's dimensions
    /// first ([`FaultTimeline::validate`]): recoveries without a
    /// matching open failure, out-of-range component indices, and
    /// non-monotone pairs are rejected here, as a typed error, instead
    /// of panicking mid-run.
    pub fn inject_faults(&mut self, timeline: &FaultTimeline) -> Result<(), TimelineError> {
        timeline.validate(&self.fault_dims())?;
        self.fault_schedule.extend(timeline.events().iter().cloned());
        let mut v: Vec<FaultEvent> = self.fault_schedule.drain(..).collect();
        v.sort_by_key(|e| e.at); // stable: equal instants keep order
        self.fault_schedule = v.into();
        Ok(())
    }

    /// Scheduled faults not yet applied.
    pub fn pending_faults(&self) -> usize {
        self.fault_schedule.len()
    }

    pub(crate) fn next_fault_at(&self) -> Option<SimTime> {
        self.fault_schedule.front().map(|e| e.at)
    }

    /// The next scheduled fault, if any (the model checker's trace
    /// printer names it when describing a `Fault` choice).
    pub fn peek_fault(&self) -> Option<&FaultEvent> {
        self.fault_schedule.front()
    }

    pub(crate) fn pop_fault(&mut self) -> Option<FaultEvent> {
        self.fault_schedule.pop_front()
    }

    /// Is any resilience machinery live on this federation? True when
    /// transfer deadlines are armed or the circuit breaker is on.
    /// While armed, the sharded engine's terminal-epoch gate stays
    /// closed (breaker scores and deadline expiries are order-
    /// sensitive), keeping runs serial — the same rule `least-loaded`
    /// already obeys.
    pub fn resilience_armed(&self) -> bool {
        self.cfg.resilience.deadline_factor > 0.0 || self.breaker.is_some()
    }

    // --- background origin load --------------------------------------------

    /// Start `n` persistent flows on every origin's DTN link.
    pub fn start_background_load(&mut self, n: usize) {
        for o in 0..self.origins.len() {
            for _ in 0..n {
                self.spawn_background(o);
            }
        }
    }

    /// Idempotent variant: top up so each origin carries at least `n`
    /// background flows. Repeated drivers (e.g. back-to-back campaigns
    /// on one federation) call this so load does not accumulate.
    pub fn ensure_background_load(&mut self, n: usize) {
        let mut have = vec![0usize; self.origins.len()];
        for &origin_idx in self.background.values() {
            have[origin_idx] += 1;
        }
        for &origin_idx in &self.deferred_background {
            have[origin_idx] += 1;
        }
        for o in 0..self.origins.len() {
            for _ in have[o]..n {
                self.spawn_background(o);
            }
        }
    }

    fn spawn_background(&mut self, origin_idx: usize) {
        // A cut DTN link cannot carry background load: park the flow
        // until the link is restored (no RNG draw, so the deferral
        // leaves other origins' streams untouched).
        if !self.net.link_is_up(self.topo.origin_lan_link(origin_idx)) {
            self.deferred_background.push(origin_idx);
            return;
        }
        // Other users of the Stash filesystem pulling large datasets.
        // They contend on the origin's DTN link only — their own
        // last-mile legs are elsewhere and uncongested. Sizes are
        // large so months-long simulations don't churn through
        // millions of respawns; contention depends on the *count* of
        // concurrent flows, not their length.
        let bytes = self.rng.gen_range(20_000_000_000, 200_000_000_000);
        let flow = self.net.start_flow(
            FlowSpec {
                path: vec![self.topo.origin_lan_link(origin_idx)],
                bytes,
                rate_cap: None,
            },
            self.now,
        );
        self.background.insert(flow, origin_idx);
    }

    /// Retry background flows parked on cut links (called when a link
    /// is restored; flows whose links are still down re-park).
    pub(crate) fn respawn_deferred_background(&mut self) {
        if self.deferred_background.is_empty() {
            return;
        }
        let pending = std::mem::take(&mut self.deferred_background);
        for origin_idx in pending {
            self.spawn_background(origin_idx);
        }
    }

    /// Advance virtual time to `t`, restarting background flows as
    /// they finish (each respawn starts at its predecessor's
    /// completion instant, so origin load has no gaps). Returns
    /// completions that were NOT background.
    pub fn advance_to(&mut self, t: SimTime) -> Vec<crate::netsim::Completion> {
        let mut foreground = Vec::new();
        loop {
            match self.net.next_completion() {
                Some(tc) if tc <= t => {
                    let completions = self.net.advance(tc);
                    self.now = tc;
                    for c in completions {
                        if let Some(origin_idx) = self.background.remove(&c.flow) {
                            self.spawn_background(origin_idx);
                        } else {
                            foreground.push(c);
                        }
                    }
                }
                _ => break,
            }
        }
        self.net.advance(t);
        self.now = self.now.max(t);
        foreground
    }

    // --- GeoIP + redirection ------------------------------------------------

    /// Pick the nearest cache for a worker at `site_idx`, given live
    /// cache load factors (the CVMFS GeoIP API call stashcp makes).
    /// Panics if every cache in the federation is down.
    ///
    /// This is the *geo* ladder, independent of the configured
    /// [`FedSim::policy`] — chaos drills and sweeps use it to find
    /// "the cache nearest to site X" (e.g. as an outage victim);
    /// downloads go through [`FedSim::select_cache`].
    pub fn nearest_cache_site(&mut self, site_idx: usize) -> usize {
        self.nearest_cache_site_filtered(site_idx, &[])
            .expect("no cache in the federation is up")
    }

    /// Like [`FedSim::nearest_cache_site`], but skipping `excluded`
    /// sites (caches a retrying client already failed against) and any
    /// cache that is currently down ([`FaultState`]). `None` when no
    /// cache remains — the caller must fall back to the origin.
    ///
    /// Tie-breaking is pinned: caches ranked by (score, geo index),
    /// and the geo index order is the config's site order — so two
    /// equal-distance, equally-loaded caches always resolve to the
    /// one configured first.
    pub fn nearest_cache_site_filtered(
        &mut self,
        site_idx: usize,
        excluded: &[usize],
    ) -> Option<usize> {
        let s = &self.cfg.sites[site_idx];
        let loads: Vec<f64> = self
            .geo_cache_sites
            .iter()
            .map(|idx| self.caches[idx].load_factor())
            .collect();
        let ranked = self.geoip.rank(s.lat, s.lon, &loads);
        ranked
            .iter()
            .map(|&(i, _)| self.geo_cache_sites[i])
            .find(|site| !excluded.contains(site) && !self.faults.is_cache_down(*site))
    }

    /// Snapshot what the redirection layer may observe when placing a
    /// request from `site_idx`: the GeoIP ranking (identical inputs to
    /// [`FedSim::nearest_cache_site_filtered`], so `Nearest` stays
    /// bit-compatible), storage load, live WAN aggregate rates from
    /// the netsim, the driving engine's per-cache in-flight counts,
    /// distances, and up/down state.
    pub fn federation_view(
        &mut self,
        site_idx: usize,
        in_flight: &HashMap<usize, u64>,
    ) -> FederationView {
        let (lat, lon) = {
            let s = &self.cfg.sites[site_idx];
            (s.lat, s.lon)
        };
        let loads: Vec<f64> = self
            .geo_cache_sites
            .iter()
            .map(|idx| self.caches[idx].load_factor())
            .collect();
        let ranked = self.geoip.rank(lat, lon, &loads);
        let wan_rate_bps = self
            .geo_cache_sites
            .iter()
            .map(|&idx| self.net.link_aggregate_rate(self.topo.cache_wan_link(idx)))
            .collect();
        let distance_km = self
            .geo_cache_sites
            .iter()
            .map(|&idx| self.topo.distance_km(site_idx, idx))
            .collect();
        let up = self
            .geo_cache_sites
            .iter()
            .map(|&idx| {
                !self.faults.is_cache_down(idx)
                    && self
                        .breaker
                        .as_ref()
                        .is_none_or(|b| b.admits(idx, self.now))
            })
            .collect();
        let in_flight = self
            .geo_cache_sites
            .iter()
            .map(|idx| in_flight.get(idx).copied().unwrap_or(0))
            .collect();
        FederationView {
            client_site: site_idx,
            cache_sites: self.geo_cache_sites.clone(),
            ranked,
            wan_rate_bps,
            in_flight,
            distance_km,
            up,
        }
    }

    /// Choose the cache that serves `path` for a worker at `site_idx`
    /// under the configured redirection policy, skipping `excluded`
    /// caches and any cache that is down. `in_flight` is the driving
    /// engine's sessions-per-cache map (pass an empty map from serial
    /// drivers). `None` ⇒ stream from the origin.
    pub fn select_cache(
        &mut self,
        site_idx: usize,
        path: &str,
        excluded: &[usize],
        in_flight: &HashMap<usize, u64>,
    ) -> Option<usize> {
        let view = self.federation_view(site_idx, in_flight);
        self.policy.select(path, &view, excluded)
    }

    // --- monitoring --------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn emit_transfer_monitoring(
        &mut self,
        cache_site: usize,
        site_idx: usize,
        path: &str,
        file_size: u64,
        bytes_read: u64,
        opened_at: SimTime,
        closed_at: SimTime,
        protocol: Protocol,
    ) {
        let server_id = cache_site as u32;
        let user_id = self.next_user_id;
        self.next_user_id += 1;
        let file_id = self.next_file_id;
        self.next_file_id += 1;
        let client_host = format!("worker.{}.osg", self.cfg.sites[site_idx].name);
        let chunk = self.caches[&cache_site].cfg.chunk_size.as_u64().max(1);
        let packets = [
            (
                opened_at,
                Packet::UserLogin {
                    user_id,
                    protocol,
                    ipv6: self.rng.gen_bool(0.35),
                    client_host,
                },
            ),
            (
                opened_at,
                Packet::FileOpen {
                    file_id,
                    user_id,
                    file_size,
                    path: path.to_string(),
                },
            ),
            (
                closed_at,
                Packet::FileClose {
                    file_id,
                    bytes_read,
                    bytes_written: 0,
                    read_ops: bytes_read.div_ceil(chunk) as u32,
                    write_ops: 0,
                },
            ),
        ];
        for (timestamp, packet) in packets {
            let env = Envelope {
                server_id,
                timestamp,
                packet,
            };
            // Sim mode feeds the decoded packet straight in; the same
            // bytes go over real UDP in live mode.
            self.collector.ingest(env, &mut self.bus);
        }
        self.aggregator.consume(&mut self.bus, &mut self.agg_sub);
        // Bound bus memory in months-long simulations.
        self.bus.compact(TRANSFER_TOPIC);
    }

    // --- downloads -----------------------------------------------------------

    /// Effective squid relay ceiling for an object of `size` bytes.
    fn proxy_relay_cap_bps(proxy: &ProxyServer, size: u64) -> f64 {
        let base = proxy.cfg.per_conn_gbps * 1e9 / 8.0;
        let max_obj = proxy.cfg.max_object.as_u64() as f64;
        if size as f64 <= max_obj {
            base
        } else {
            base * (max_obj / size as f64).powf(PROXY_RELAY_DEGRADE_EXP)
        }
    }

    /// Perform one download of `file` by a worker at `site_idx`,
    /// running a single-session engine to completion (the serial
    /// convenience API — the §4.1 drivers and tests use this).
    ///
    /// Timing-equivalent to the pre-engine blocking implementation:
    /// the session walks the same instants, draws the same RNG
    /// stream, and returns the same `TransferRecord`.
    pub fn download(
        &mut self,
        site_idx: usize,
        file: &FileRef,
        method: DownloadMethod,
    ) -> TransferRecord {
        let mut engine = driver::SessionEngine::new(self.now);
        let id = engine.spawn_at(self, self.now, site_idx, file.clone(), method);
        engine.run(self);
        engine.record(id)
    }

    /// WAN link byte counter of a site (Fig 5's graph source).
    pub fn wan_bytes(&self, site_idx: usize) -> f64 {
        self.net.link_bytes_carried(self.topo.wan_link(site_idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::defaults::paper_federation;
    use crate::util::ByteSize;

    fn fed() -> FedSim {
        FedSim::build(paper_federation())
    }

    fn file(size: u64) -> FileRef {
        FileRef {
            path: "/ospool/ligo/data/f000000.dat".into(),
            size: ByteSize(size),
            version: 1,
        }
    }

    #[test]
    fn builds_paper_topology() {
        let f = fed();
        assert_eq!(f.caches.len(), 10);
        assert_eq!(f.proxies.len(), 5);
        assert_eq!(f.origins.len(), 10);
        assert_eq!(f.redirectors.instances.len(), 2);
        assert_eq!(f.geoip.caches().len(), 10);
    }

    #[test]
    fn stash_cold_then_hot_is_faster() {
        let mut f = fed();
        let site = f.topo.site_index("syracuse").unwrap();
        let fr = file(2_335_000_000);
        let cold = f.download(site, &fr, DownloadMethod::Stash);
        assert!(!cold.cache_hit);
        let hot = f.download(site, &fr, DownloadMethod::Stash);
        assert!(hot.cache_hit, "second stash download must hit");
        assert!(
            hot.duration < cold.duration,
            "hot {} < cold {}",
            hot.duration,
            cold.duration
        );
    }

    #[test]
    fn proxy_caches_small_not_large() {
        let mut f = fed();
        let site = f.topo.site_index("nebraska").unwrap();
        let small = file(100_000_000);
        let c1 = f.download(site, &small, DownloadMethod::HttpProxy);
        assert!(!c1.cache_hit);
        let c2 = f.download(site, &small, DownloadMethod::HttpProxy);
        assert!(c2.cache_hit, "100 MB object must be cached");
        // 2.335 GB exceeds max_object (1 GB): never cached (§5).
        let big = FileRef {
            path: "/ospool/ligo/data/f000001.dat".into(),
            size: ByteSize(2_335_000_000),
            version: 1,
        };
        let b1 = f.download(site, &big, DownloadMethod::HttpProxy);
        let b2 = f.download(site, &big, DownloadMethod::HttpProxy);
        assert!(!b1.cache_hit && !b2.cache_hit);
    }

    #[test]
    fn small_file_faster_via_proxy() {
        // Fig 8's shape: 5.797 KB via proxy beats stashcp's startup.
        let mut f = fed();
        let site = f.topo.site_index("syracuse").unwrap();
        let tiny = file(5_797);
        let http = f.download(site, &tiny, DownloadMethod::HttpProxy);
        let stash = f.download(site, &tiny, DownloadMethod::Stash);
        assert!(
            http.duration.as_secs_f64() * 3.0 < stash.duration.as_secs_f64(),
            "http {} vs stash {}",
            http.duration,
            stash.duration
        );
    }

    #[test]
    fn colorado_uses_remote_cache_and_crosses_wan() {
        let mut f = fed();
        let col = f.topo.site_index("colorado").unwrap();
        let nearest = f.nearest_cache_site(col);
        assert_ne!(nearest, col, "colorado has no local cache");
        let before = f.wan_bytes(col);
        f.download(col, &file(100_000_000), DownloadMethod::Stash);
        assert!(f.wan_bytes(col) > before, "stash at colorado crosses its WAN");
    }

    #[test]
    fn syracuse_hot_hits_stay_on_lan() {
        let mut f = fed();
        let syr = f.topo.site_index("syracuse").unwrap();
        let fr = file(500_000_000);
        f.download(syr, &fr, DownloadMethod::Stash);
        let wan_after_cold = f.wan_bytes(syr);
        f.download(syr, &fr, DownloadMethod::Stash);
        let wan_after_hot = f.wan_bytes(syr);
        assert!(
            wan_after_hot - wan_after_cold < 1_000_000.0,
            "hot hit must not cross the WAN (Δ={})",
            wan_after_hot - wan_after_cold
        );
    }

    #[test]
    fn monitoring_pipeline_records_stash_downloads() {
        let mut f = fed();
        let site = f.topo.site_index("nebraska").unwrap();
        f.download(site, &file(1_000_000), DownloadMethod::Stash);
        f.download(site, &file(1_000_000), DownloadMethod::Stash);
        assert_eq!(f.aggregator.reports, 2);
        let usage = f.aggregator.experiment_usage("ligo").unwrap();
        assert_eq!(usage.bytes_read, 2_000_000);
        assert_eq!(f.collector.stats.reports_published, 2);
    }

    #[test]
    fn background_load_slows_cold_fetches() {
        let mut fast = fed();
        let mut loaded = fed();
        // Heavy load: 12 pulls shrink the origin DTN share below every
        // other bottleneck on the test path.
        loaded.start_background_load(12);
        let site = fast.topo.site_index("bellarmine").unwrap();
        let fr = file(2_335_000_000);
        let t_fast = fast.download(site, &fr, DownloadMethod::Stash).duration;
        let t_loaded = loaded.download(site, &fr, DownloadMethod::Stash).duration;
        assert!(
            t_loaded.as_secs_f64() > t_fast.as_secs_f64() * 1.5,
            "origin contention must bite: {t_fast} vs {t_loaded}"
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut f = fed();
            f.start_background_load(4);
            let site = f.topo.site_index("chicago").unwrap();
            let mut out = Vec::new();
            for i in 0..5 {
                let fr = FileRef {
                    path: format!("/ospool/des/data/f{i:06}.dat"),
                    size: ByteSize(50_000_000 * (i + 1)),
                    version: 1,
                };
                out.push(f.download(site, &fr, DownloadMethod::Stash).duration);
            }
            out
        };
        assert_eq!(run(), run(), "same seed ⇒ identical timings");
    }
}
